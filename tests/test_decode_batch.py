"""Property suite: fused multi-page ``decode_batch`` is byte-identical to
per-page ``decode`` + concatenate — across encodings × dtypes × ragged page
sizes × backends, including the 2^31/2^32 device-gate boundaries and the
degenerate empty/single-page morsels.

Runs under hypothesis when it is installed; otherwise a seeded generator
drives the *same* property over a deterministic corpus of >= 40 cases per
backend, so the suite needs no dependency the container lacks.
"""
import numpy as np
import pytest

from repro.core import backend as be
from repro.core import encodings as enc

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

ENCODINGS = [enc.PLAIN, enc.BITPACK, enc.DICT, enc.DELTA, enc.RLE,
             enc.BSS, enc.AUTO]
DTYPES = [np.int64, np.int32, np.uint16, np.int8, np.float32, np.float64,
          np.bool_]
# ragged page-size mixes, incl. empty morsel, single page, empty pages
SIZE_MIXES = [[], [0], [1], [2], [7, 7, 7], [5, 1, 0, 300, 1024],
              [1024, 1024], [0, 0, 3], [513, 1, 511]]
# value regimes: small, page-boundary-straddling, 32-bit boundaries (the
# jax backend's routing gate), beyond-SEG_MAX_BITS wide values
BASES = [0, 1000, 2**31 - 4, 2**31, 2**32 - 4, 2**32, 2**62, -2**31]

BACKENDS = ["numpy"] + (["jax"] if be.jax_available() else [])


def _encodable(encoding, dt) -> bool:
    if encoding in (enc.BITPACK, enc.DICT, enc.DELTA, enc.RLE) \
            and dt.kind == "f":
        return False
    if encoding == enc.DELTA and dt.kind not in "iu":
        return False
    if encoding == enc.BSS and dt == np.bool_:
        return False
    return True


def _page_values(rng, dt, n, base):
    if dt == np.bool_:
        return rng.integers(0, 2, n).astype(bool)
    if dt.kind == "f":
        v = rng.normal(size=n) * (abs(base) + 1)
        if n:
            v[0] = np.nan  # NaN must round-trip bitwise too
        return v.astype(dt)
    info = np.iinfo(dt)
    lo = max(info.min, base - 50)
    hi = min(info.max, base + 50)
    if lo > info.max or hi < info.min or lo >= hi:
        lo, hi = info.min, info.max
    return rng.integers(lo, hi, n, dtype=np.int64).astype(dt)


def _check_property(backend_name, dt, sizes, encodings, seed):
    """THE property: decode_batch == per-page decode, bytewise."""
    dt = np.dtype(dt)
    rng = np.random.default_rng(seed)
    backend = be.get_backend(backend_name)
    specs, refs = [], []
    for i, n in enumerate(sizes):
        encoding = encodings[i % len(encodings)]
        if not _encodable(encoding, dt):
            encoding = enc.PLAIN
        arr = _page_values(rng, dt, n, BASES[(seed + i) % len(BASES)])
        e, m, p = enc.encode(arr, encoding)
        specs.append((e, m, p, n))
        refs.append(enc.decode(e, m, p, n, dt))
    want = (np.concatenate([np.asarray(r, dt) for r in refs])
            if refs else np.empty(0, dt))
    got = backend.decode_batch(specs, dt)
    assert got.dtype == dt
    assert got.tobytes() == want.tobytes(), \
        (backend_name, dt, sizes, [s[0] for s in specs])
    # and the out= path writes the same bytes into a caller buffer
    out = np.empty(len(want), dt)
    backend.decode_batch(specs, dt, out=out)
    assert out.tobytes() == want.tobytes()


def _corpus():
    """Deterministic fallback corpus: >= 40 cases per backend."""
    cases = []
    seed = 0
    for dt in DTYPES:
        for sizes in SIZE_MIXES:
            seed += 1
            cases.append((dt, sizes, ENCODINGS, seed))
    return cases  # 7 dtypes x 9 mixes = 63 cases


@pytest.mark.parametrize("backend_name", BACKENDS)
@pytest.mark.parametrize("dt,sizes,encodings,seed", _corpus())
def test_batch_equals_per_page(backend_name, dt, sizes, encodings, seed):
    _check_property(backend_name, dt, sizes, encodings, seed)


@pytest.mark.parametrize("backend_name", BACKENDS)
@pytest.mark.parametrize("base", [2**31 - 3, 2**32 - 3, 2**62])
@pytest.mark.parametrize("encoding",
                         [enc.BITPACK, enc.DELTA, enc.DICT, enc.PLAIN])
def test_boundary_values_route_or_fall_back_identically(
        backend_name, base, encoding):
    """Around the int32 gates the jax backend must *fall back*, never
    truncate: results stay byte-identical to numpy either way."""
    arr = np.arange(base - 5, base + 5, dtype=np.int64)
    e, m, p = enc.encode(arr, encoding)
    specs = [(e, m, p, len(arr))] * 3
    want = np.concatenate([enc.decode(e, m, p, len(arr), np.int64)] * 3)
    got = be.get_backend(backend_name).decode_batch(specs, np.int64)
    assert got.tobytes() == want.tobytes()


@pytest.mark.parametrize("backend_name", BACKENDS)
def test_mixed_encoding_morsel(backend_name):
    """AUTO-encoded chunks mix encodings page to page; groups must land in
    the right output slices regardless of interleaving."""
    rng = np.random.default_rng(7)
    specs, refs = [], []
    for i, encoding in enumerate([enc.DELTA, enc.DICT, enc.BITPACK, enc.RLE,
                                  enc.PLAIN, enc.DELTA, enc.DICT,
                                  enc.BITPACK] * 3):
        n = [0, 1, 97, 256][i % 4]
        arr = rng.integers(-1000, 1000, n).astype(np.int64)
        if encoding == enc.DELTA:
            arr.sort()
        e, m, p = enc.encode(arr, encoding)
        specs.append((e, m, p, n))
        refs.append(enc.decode(e, m, p, n, np.int64))
    want = np.concatenate(refs)
    got = be.get_backend(backend_name).decode_batch(specs, np.int64)
    assert got.tobytes() == want.tobytes()


def test_empty_and_single_page_morsels():
    for backend_name in BACKENDS:
        b = be.get_backend(backend_name)
        assert len(b.decode_batch([], np.int64)) == 0
        e, m, p = enc.encode(np.arange(5, dtype=np.int64), enc.BITPACK)
        got = b.decode_batch([(e, m, p, 5)], np.int64)
        assert got.tolist() == [0, 1, 2, 3, 4]


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(dt=st.sampled_from(DTYPES),
           sizes=st.lists(st.integers(0, 600), max_size=6),
           seed=st.integers(0, 2**16),
           backend_name=st.sampled_from(BACKENDS))
    def test_batch_equals_per_page_hypothesis(dt, sizes, seed, backend_name):
        _check_property(backend_name, dt, sizes, ENCODINGS, seed)
