"""Numerical correctness of the model substrate:
blockwise attention vs naive softmax; SSD chunked vs recurrence;
prefill+decode vs full forward; sliding-window semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Model, ModelConfig, AttnCfg, SSMCfg, MoECfg
from repro.models.attention import blockwise_attention
from repro.models.ssm import ssd_chunked
from repro.models.frontends import synthetic_embeds

RNG = np.random.default_rng(0)


def naive_attention(q, k, v, causal=True, window=None):
    B, S, H, dh = q.shape
    KH = k.shape[2]
    G = H // KH
    qf = q.reshape(B, S, KH, G, dh) * dh ** -0.5
    s = np.einsum("bqkgd,bpkd->bkgqp", qf, k).astype(np.float64)
    i = np.arange(S)
    mask = np.ones((S, S), bool)
    if causal:
        mask &= i[None, :] <= i[:, None]
    if window:
        mask &= i[None, :] > i[:, None] - window
    s = np.where(mask[None, None, None], s, -1e30)
    w = np.exp(s - s.max(-1, keepdims=True))
    w = w / w.sum(-1, keepdims=True)
    out = np.einsum("bkgqp,bpkd->bqkgd", w, v)
    return out.reshape(B, S, H, dh)


class TestBlockwiseAttention:
    @pytest.mark.parametrize("S,block", [(16, 4), (33, 8), (64, 64), (40, 7)])
    @pytest.mark.parametrize("H,KH", [(4, 4), (4, 2), (8, 1)])
    def test_vs_naive(self, S, block, H, KH):
        B, dh = 2, 8
        q = RNG.standard_normal((B, S, H, dh)).astype(np.float32)
        k = RNG.standard_normal((B, S, KH, dh)).astype(np.float32)
        v = RNG.standard_normal((B, S, KH, dh)).astype(np.float32)
        pos = jnp.arange(S, dtype=jnp.int32)
        out = blockwise_attention(jnp.asarray(q), jnp.asarray(k),
                                  jnp.asarray(v), pos, pos, block_kv=block)
        ref = naive_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)

    def test_window_vs_naive(self):
        B, S, H, dh = 1, 48, 4, 8
        q = RNG.standard_normal((B, S, H, dh)).astype(np.float32)
        k = RNG.standard_normal((B, S, H, dh)).astype(np.float32)
        v = RNG.standard_normal((B, S, H, dh)).astype(np.float32)
        pos = jnp.arange(S, dtype=jnp.int32)
        out = blockwise_attention(jnp.asarray(q), jnp.asarray(k),
                                  jnp.asarray(v), pos, pos, window=8,
                                  block_kv=16)
        ref = naive_attention(q, k, v, window=8)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)

    def test_non_causal(self):
        B, S, H, dh = 1, 24, 2, 8
        q = RNG.standard_normal((B, S, H, dh)).astype(np.float32)
        k = RNG.standard_normal((B, S, H, dh)).astype(np.float32)
        v = RNG.standard_normal((B, S, H, dh)).astype(np.float32)
        pos = jnp.arange(S, dtype=jnp.int32)
        out = blockwise_attention(jnp.asarray(q), jnp.asarray(k),
                                  jnp.asarray(v), pos, pos, causal=False,
                                  block_kv=8)
        ref = naive_attention(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


def ssd_recurrence(Xdt, A_, Bm, Cm):
    """O(T·N) reference recurrence for the SSD dual form."""
    B, T, H, P = Xdt.shape
    G, N = Bm.shape[2], Bm.shape[3]
    HG = H // G
    S = np.zeros((B, H, P, N), np.float64)
    Y = np.zeros((B, T, H, P), np.float64)
    for t in range(T):
        for h in range(H):
            g = h // HG
            S[:, h] = (S[:, h] * np.exp(A_[:, t, h])[:, None, None]
                       + Xdt[:, t, h][:, :, None] * Bm[:, t, g][:, None, :])
            Y[:, t, h] = np.einsum("bpn,bn->bp", S[:, h], Cm[:, t, g])
    return Y, S


class TestSSD:
    @pytest.mark.parametrize("T,chunk", [(16, 4), (32, 8), (8, 8)])
    @pytest.mark.parametrize("G", [1, 2])
    def test_chunked_vs_recurrence(self, T, chunk, G):
        B, H, P, N = 2, 4, 4, 8
        Xdt = RNG.standard_normal((B, T, H, P)).astype(np.float32)
        A_ = -np.abs(RNG.standard_normal((B, T, H))).astype(np.float32) * 0.5
        Bm = RNG.standard_normal((B, T, G, N)).astype(np.float32)
        Cm = RNG.standard_normal((B, T, G, N)).astype(np.float32)
        Y, S_final = ssd_chunked(jnp.asarray(Xdt), jnp.asarray(A_),
                                 jnp.asarray(Bm), jnp.asarray(Cm), chunk)
        Yr, Sr = ssd_recurrence(Xdt, A_, Bm, Cm)
        np.testing.assert_allclose(np.asarray(Y), Yr, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(S_final), Sr, rtol=2e-3,
                                   atol=2e-3)


def _decode_parity_cfg_list():
    attn = AttnCfg(4, 2, 16)
    return [
        ModelConfig("dense", "dense", 2, 64, 128, 128, attn=attn, remat=False),
        ModelConfig("swa", "dense", 2, 64, 128, 128,
                    attn=AttnCfg(4, 2, 16, window=8), remat=False),
        ModelConfig("qkn", "dense", 2, 64, 128, 128,
                    attn=AttnCfg(4, 2, 16, qk_norm=True, qkv_bias=True),
                    remat=False),
        ModelConfig("ssm", "ssm", 2, 64, 0, 128,
                    ssm=SSMCfg(d_state=16, headdim=16, chunk=8), remat=False),
        ModelConfig("hybrid", "hybrid", 4, 64, 128, 128, attn=AttnCfg(4, 4, 16),
                    ssm=SSMCfg(d_state=16, headdim=16, chunk=8),
                    hybrid_share_period=2, remat=False),
        ModelConfig("moe", "moe", 2, 64, 128, 128, attn=attn,
                    moe=MoECfg(4, 2, 96, shared_ff=64, capacity_factor=4.0),
                    remat=False),
        ModelConfig("encdec", "encdec", 2, 64, 128, 128, attn=AttnCfg(4, 4, 16),
                    enc_layers=2, src_seq=8, frontend="audio", remat=False),
    ]


@pytest.mark.parametrize("cfg", _decode_parity_cfg_list(),
                         ids=lambda c: c.name)
def test_prefill_decode_matches_forward(cfg):
    """logits from forward(S+1 tokens) at the last position must equal
    prefill(S) -> decode(token S).  This pins cache semantics across ALL
    families (capacity_factor is raised for MoE so no token drops)."""
    if cfg.family == "ssm" or cfg.family == "hybrid":
        S = 16  # multiple of ssd chunk
    else:
        S = 17
    B = 2
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)
    batch_full = {"tokens": toks}
    batch_pre = {"tokens": toks[:, :S]}
    emb = synthetic_embeds(cfg, B, 3)
    if emb is not None:
        batch_full["embeds"] = emb
        batch_pre["embeds"] = emb

    # full forward logits at final position
    if cfg.family == "encdec":
        from repro.models import encdec
        full_logits, _ = encdec.forward(params, cfg, toks, emb)
    else:
        from repro.models import transformer
        full_logits, _ = transformer.forward(
            params, cfg, toks, extra_embeds=emb)
    want = np.asarray(full_logits[:, -1], np.float32)

    _, cache = model.prefill(params, batch_pre, cache_len=S + 4)
    sf = 0 if (emb is None or cfg.family == "encdec") else emb.shape[1]
    got, _ = model.decode_step(params, cache, toks[:, S:S + 1],
                               jnp.int32(S + sf))
    got = np.asarray(got[:, 0], np.float32)
    # bf16 compute: compare top-1 agreement + loose numeric closeness
    np.testing.assert_allclose(got, want, rtol=0.1, atol=0.15)
    assert (got.argmax(-1) == want.argmax(-1)).all(), cfg.name


def test_decode_sequence_matches_forward_dense():
    """Multi-step: decode 4 tokens one by one == forward at each position."""
    cfg = _decode_parity_cfg_list()[0]
    model = Model(cfg)
    params = model.init(jax.random.key(2))
    B, S = 1, 12
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)
    from repro.models import transformer
    full_logits, _ = transformer.forward(params, cfg, toks)
    _, cache = model.prefill(params, {"tokens": toks[:, :8]}, cache_len=S)
    for t in range(8, S):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t))
        want = np.asarray(full_logits[:, t], np.float32)
        got = np.asarray(lg[:, 0], np.float32)
        assert (got.argmax(-1) == want.argmax(-1)).all(), f"pos {t}"


def test_vector_pos_decode_matches_scalar():
    cfg = _decode_parity_cfg_list()[0]
    model = Model(cfg)
    params = model.init(jax.random.key(3))
    B, S = 2, 8
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)
    _, cache_a = model.prefill(params, {"tokens": toks}, cache_len=S + 2)
    _, cache_b = model.prefill(params, {"tokens": toks}, cache_len=S + 2)
    nxt = toks[:, :1]
    lg_a, _ = model.decode_step(params, cache_a, nxt, jnp.int32(S))
    lg_b, _ = model.decode_step(params, cache_b, nxt,
                                jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_a, np.float32),
                               np.asarray(lg_b, np.float32), rtol=1e-3,
                               atol=1e-3)


def test_loss_decreases_quick_overfit():
    cfg = ModelConfig("tiny", "dense", 2, 64, 128, 64,
                      attn=AttnCfg(4, 2, 16), remat=False)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    local_rng = np.random.default_rng(1234)  # not the shared module RNG
    batch = {"tokens": jnp.asarray(local_rng.integers(0, 64, (4, 32)),
                                   jnp.int32)}

    @jax.jit
    def step(p):
        (l, _), g = jax.value_and_grad(lambda q: model.loss(q, batch),
                                       has_aux=True)(p)
        return l, jax.tree.map(lambda w, gw: w - 0.05 * gw, p, g)

    l0, params = step(params)
    for _ in range(30):
        l, params = step(params)
    assert float(l) < float(l0) * 0.9
