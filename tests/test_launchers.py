"""Launcher-level smoke: train/serve mains, roofline aggregation, registry."""
import json
import os

import numpy as np
import pytest

from repro.configs import registry
from repro.launch import roofline


class TestRegistry:
    def test_all_archs_resolve(self):
        for a in registry.ARCH_NAMES:
            assert registry.get(a).name == a
        assert len(registry.ARCH_NAMES) == 10

    def test_unknown_arch(self):
        with pytest.raises(KeyError):
            registry.get("gpt-17")

    def test_cell_grid(self):
        cells = registry.all_cells()
        assert len(cells) == 33
        assert ("mamba2-780m", "long_500k") in cells


class TestRooflineTool:
    def _rec(self, **kw):
        base = {"arch": "x", "shape": "train_4k", "mesh": "16x16",
                "n_devices": 256, "kind": "train", "seq_len": 4096,
                "global_batch": 256, "flops_per_device": 1e14,
                "bytes_per_device": 1e13,
                "collectives": {"total_bytes": 5e10},
                "memory": {}, "model": {"total_params": 3e9,
                                        "active_params": 3e9}}
        base.update(kw)
        return base

    def test_terms(self):
        r = roofline.analyze(self._rec())
        assert abs(r["t_compute"] - 1e14 / 197e12) < 1e-9
        assert abs(r["t_memory"] - 1e13 / 819e9) < 1e-9
        assert abs(r["t_collective"] - 5e10 / 50e9) < 1e-9
        assert r["dominant"] == "memory"

    def test_useful_ratio(self):
        r = roofline.analyze(self._rec())
        model_flops = 6 * 3e9 * 256 * 4096
        assert abs(r["useful_ratio"] - model_flops / (1e14 * 256)) < 1e-6

    def test_decode_kind_forward_only(self):
        r = roofline.analyze(self._rec(kind="decode", global_batch=128,
                                       seq_len=32768))
        assert r["model_flops"] == pytest.approx(2 * 3e9 * 128)

    def test_load_and_table(self, tmp_path):
        p = tmp_path / "16x16_x_train_4k.json"
        p.write_text(json.dumps(self._rec()))
        recs = roofline.load(str(tmp_path))
        out = roofline.table(recs)
        assert "dominant" in out and "memory" in out


class TestTrainLauncher:
    def test_reduced_train_runs(self, tmp_path):
        from repro.launch.train import main
        rc = main(["--arch", "repro-100m", "--reduced", "--steps", "3",
                   "--batch", "2", "--seq", "32",
                   "--workdir", str(tmp_path)])
        assert rc == 0
        # metrics + checkpoints landed in columnar stores
        assert os.path.exists(tmp_path / "ckpt")

    def test_serve_launcher(self):
        from repro.launch.serve import main
        assert main(["--arch", "repro-100m", "--reduced", "--requests", "2",
                     "--slots", "2", "--max-seq", "48", "--max-new", "3"]) == 0


class TestHloCostParsing:
    def test_empty_module(self):
        from repro.launch.hlo_cost import analyze_hlo
        r = analyze_hlo("")
        assert r["flops"] == 0

    def test_simple_entry(self):
        from repro.launch.hlo_cost import analyze_hlo
        hlo = (
            "ENTRY %main.1 (a: f32[8,16], b: f32[16,4]) -> f32[8,4] {\n"
            "  %a = f32[8,16]{1,0} parameter(0)\n"
            "  %b = f32[16,4]{1,0} parameter(1)\n"
            "  ROOT %dot.1 = f32[8,4]{1,0} dot(%a, %b), "
            "lhs_contracting_dims={1}, rhs_contracting_dims={0}\n"
            "}\n")
        r = analyze_hlo(hlo)
        assert r["flops"] == 2 * 8 * 4 * 16
