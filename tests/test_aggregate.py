"""Aggregate pushdown: footer-answered aggregates vs. decode-path oracles.

Every aggregate here is checked against a numpy reduction over the fully
materialized (filtered) table — the two paths must agree exactly — and the
stats-coverage claims are asserted through the report counters
(``groups_answered_by_stats`` > 0, ``bytes_decoded`` == 0 for fully
covered queries).
"""
import json
import os
import struct
import zlib

import numpy as np
import pytest

from repro.core import LoadConfig, ParquetDB, field
from repro.core.backend import active_backend, jax_available, set_backend
from repro.core.expressions import IsNull
from repro.core.statistics import ColumnStats, merge_stats


@pytest.fixture()
def db(tmp_path):
    """2 files x 4 row groups of 250 rows; x sorted, y cyclic float with
    NaN, s strings, opt nullable."""
    db = ParquetDB(os.path.join(str(tmp_path), "agg"),
                   row_group_rows=250, page_rows=125)
    for f in range(2):
        lo = f * 1000
        db.create([{"x": lo + i,
                    "y": float("nan") if (lo + i) % 10 == 0
                    else float((lo + i) % 7),
                    "s": f"k{(lo + i) % 13:02d}",
                    "opt": None if (lo + i) % 4 == 0 else (lo + i) % 50}
                   for i in range(1000)])
    return db


def _oracle(db, filters=None):
    t = db.read(filters=filters)
    x = np.array(t["x"].to_pylist(), dtype=np.float64)
    y = np.array(t["y"].to_pylist(), dtype=np.float64)
    opt = t["opt"].to_pylist()
    opt_v = np.array([v for v in opt if v is not None], dtype=np.float64)
    s = t["s"].to_pylist()
    return {
        "rows": t.num_rows,
        "x_min": int(x.min()) if len(x) else None,
        "x_max": int(x.max()) if len(x) else None,
        "x_sum": int(x.sum()) if len(x) else None,
        "y_count": len(y),
        "y_sum": float(np.nansum(y)) if np.isfinite(np.nansum(y)) else None,
        "y_vcount": int((~np.isnan(y)).sum()),
        "opt_count": len(opt_v),
        "opt_sum": int(opt_v.sum()) if len(opt_v) else None,
        "s_min": min(s) if s else None,
        "s_max": max(s) if s else None,
    }


class TestUnfiltered:
    def test_full_cover_answers_from_footers(self, db):
        want = _oracle(db)
        got, rep = db.aggregate(
            {"*": "count", "x": ["min", "max", "sum", "mean"],
             "opt": ["count", "sum"], "s": ["min", "max"]}, explain=True)
        assert got["*"]["count"] == want["rows"]
        assert got["x"]["min"] == want["x_min"]
        assert got["x"]["max"] == want["x_max"]
        assert got["x"]["sum"] == want["x_sum"]
        assert got["x"]["mean"] == want["x_sum"] / want["rows"]
        assert got["opt"]["count"] == want["opt_count"]
        assert got["opt"]["sum"] == want["opt_sum"]
        assert got["s"]["min"] == want["s_min"]
        assert got["s"]["max"] == want["s_max"]
        # every group answered from stats, nothing decoded
        assert rep.counters.groups_answered_by_stats == 8
        assert rep.counters.bytes_decoded == 0
        assert rep.counters.pages_scanned == 0
        assert rep.counters.bytes_skipped_agg > 0

    def test_nan_semantics_match_decode_path(self, db):
        want = _oracle(db)
        got = db.aggregate({"y": ["count", "sum", "mean"]})
        # count includes NaN rows (they are values), sum/mean exclude them
        assert got["y"]["count"] == want["y_count"]
        assert got["y"]["sum"] == pytest.approx(want["y_sum"])
        assert got["y"]["mean"] == pytest.approx(
            want["y_sum"] / want["y_vcount"])


class TestFiltered:
    @pytest.mark.parametrize("filters", [
        [field("x") >= 500],                       # aligned on group bounds
        [field("x") > 333],                        # mid-group boundary
        [(field("x") >= 700) & (field("x") < 1_430)],
        [field("s") == "k05"],                     # never stats-decidable
        [field("x") != 777],
        [IsNull("opt")],
        [field("x") < -5],                         # empty result
    ])
    def test_matches_materialized_oracle(self, db, filters):
        want = _oracle(db, filters=filters)
        got = db.aggregate({"*": "count",
                            "x": ["min", "max", "sum"],
                            "opt": ["count", "sum"]}, filters=filters)
        assert got["*"]["count"] == want["rows"]
        assert got["x"]["min"] == want["x_min"]
        assert got["x"]["max"] == want["x_max"]
        assert got["x"]["sum"] == want["x_sum"]
        assert got["opt"]["count"] == want["opt_count"]
        assert got["opt"]["sum"] == want["opt_sum"]

    def test_classification_three_ways(self, db):
        # x >= 500: groups [0,250) [250,500) pruned, [500,750)... covered
        got, rep = db.aggregate({"*": "count", "x": "sum"},
                                filters=[field("x") >= 500], explain=True)
        c = rep.counters
        assert got["*"]["count"] == 1500
        assert c.groups_answered_by_stats == 6   # fully covered
        assert c.pages_scanned == 0              # pruned ones decode nothing
        # mid-group boundary: exactly one partial group decodes
        got, rep = db.aggregate({"*": "count", "x": "sum"},
                                filters=[field("x") >= 510], explain=True)
        c = rep.counters
        assert got["*"]["count"] == 1490
        assert c.groups_answered_by_stats == 5
        assert c.rows_scanned > 0                # the boundary group decoded
        assert got["x"]["sum"] == sum(range(510, 2000))

    def test_parallel_partial_path_matches_serial(self, db):
        filt = [field("x") > 111]
        a = db.aggregate({"*": "count", "x": ["sum", "min", "max"]},
                         filters=filt,
                         load_config=LoadConfig(num_threads=1))
        b = db.aggregate({"*": "count", "x": ["sum", "min", "max"]},
                         filters=filt,
                         load_config=LoadConfig(num_threads=4))
        assert a == b


class TestDeltasFoldExactly:
    def test_update_delete_then_aggregate(self, db):
        db.update([{"id": i, "x": -(i + 1)} for i in range(0, 2000, 9)])
        db.delete(ids=list(range(3, 2000, 17)))
        want = _oracle(db)
        got, rep = db.aggregate(
            {"*": "count", "x": ["min", "max", "sum", "mean"]}, explain=True)
        assert got["*"]["count"] == want["rows"]
        assert got["x"]["min"] == want["x_min"]
        assert got["x"]["max"] == want["x_max"]
        assert got["x"]["sum"] == want["x_sum"]
        # shadowed groups were decoded, not answered from stale stats
        assert rep.counters.rows_scanned > 0

    def test_filtered_aggregate_sees_upserted_values(self, db):
        db.update([{"id": 42, "x": 10**6}])
        got = db.aggregate({"*": "count"}, filters=[field("x") >= 10**6])
        assert got["*"]["count"] == 1
        got = db.aggregate({"x": "max"})
        assert got["x"]["max"] == 10**6

    def test_tombstone_only_fragment_keeps_stats_answer_elsewhere(self, db):
        db.delete(ids=[5])  # shadows one group of file 0 only
        _, rep = db.aggregate({"*": "count"}, explain=True)
        # 7 of 8 groups still answered from footers
        assert rep.counters.groups_answered_by_stats == 7
        assert rep.counters.rows_scanned == 250

    def test_aggregate_after_compaction_restores_full_cover(self, db):
        db.update([{"id": i, "x": -i} for i in range(50)])
        db.delete(ids=[999])
        db.compact(force=True)
        want = _oracle(db)
        got, rep = db.aggregate({"*": "count", "x": "sum"}, explain=True)
        assert got["*"]["count"] == want["rows"]
        assert got["x"]["sum"] == want["x_sum"]
        assert rep.counters.pages_scanned == 0  # fully covered again


def _strip_sum_stats(path):
    """Rewrite a TPQ file's footer without any 'sum' statistic — simulates
    a file written before the sum field existed (backward compat)."""
    with open(path, "rb") as fh:
        buf = fh.read()
    # v2 trailer: <u32 footer crc> <u64 flen> TPQ2 (v1 had no crc)
    v2 = buf[-4:] == b"TPQ2"
    tail = 16 if v2 else 12
    (flen,) = struct.unpack("<Q", buf[-12:-4])
    footer = json.loads(zlib.decompress(buf[-(tail + flen):-tail]))
    for rg in footer["row_groups"]:
        for chunk in rg["columns"].values():
            chunk["stats"].pop("sum", None)
            for page in chunk["pages"]:
                page["stats"].pop("sum", None)
    blob = zlib.compress(json.dumps(footer).encode("utf-8"), 6)
    with open(path, "wb") as fh:
        fh.write(buf[:-(tail + flen)])
        fh.write(blob)
        if v2:
            fh.write(struct.pack("<I", zlib.crc32(blob) & 0xFFFFFFFF))
        fh.write(struct.pack("<Q", len(blob)))
        fh.write(buf[-4:])


class TestBackwardCompat:
    def test_pre_sum_files_fall_back_to_decode(self, db, tmp_path):
        data_dir = os.path.join(str(tmp_path), "agg")
        man = json.load(open(os.path.join(data_dir, "_manifest.json")))
        for fn in man["files"]:
            _strip_sum_stats(os.path.join(data_dir, fn))
        want = _oracle(db)
        got, rep = db.aggregate({"*": "count", "x": ["sum", "min", "max"]},
                                explain=True)
        assert got["x"]["sum"] == want["x_sum"]       # exact, via decode
        assert got["x"]["min"] == want["x_min"]       # min/max still footer
        assert rep.counters.rows_scanned == 2000      # sum forced decode
        # count-only query stays footer-answered even without sums
        _, rep = db.aggregate({"*": "count", "x": ["min", "max"]},
                              explain=True)
        assert rep.counters.groups_answered_by_stats == 8
        assert rep.counters.pages_scanned == 0

    def test_merge_stats_sum_poisoning(self):
        a = ColumnStats(num_values=4, null_count=0, min=0, max=3, sum=6)
        b = ColumnStats(num_values=4, null_count=4)       # all null, no sum
        c = ColumnStats(num_values=4, null_count=0, min=5, max=9)  # pre-sum
        m = merge_stats([a, b])
        assert m.sum == 6          # all-null part contributes zero
        m = merge_stats([a, c])
        assert m.sum is None       # valid values without a sum: poisoned
        assert merge_stats([b]).sum is None


class TestSpecValidationAndSurface:
    def test_bad_specs_raise(self, db):
        with pytest.raises(ValueError):
            db.aggregate({})
        with pytest.raises(ValueError):
            db.aggregate({"x": "median"})
        with pytest.raises(ValueError):
            db.aggregate({"*": "sum"})
        with pytest.raises(KeyError):
            db.aggregate({"nope": "min"})
        with pytest.raises(TypeError):
            db.aggregate({"s": "sum"})

    def test_dataset_aggregate_uses_dataset_filter(self, db):
        ds = db.read(filters=[field("x") >= 1500], load_format="dataset")
        got, rep = ds.aggregate({"*": "count", "x": "min"}, explain=True)
        assert got["*"]["count"] == 500
        assert got["x"]["min"] == 1500
        assert rep.counters.groups_answered_by_stats == 2

    def test_empty_dataset(self, tmp_path):
        empty = ParquetDB(os.path.join(str(tmp_path), "empty"),
                          initial_fields=None)
        empty.create([{"x": 1}])
        empty.delete(ids=[0])
        got = empty.aggregate({"*": "count", "x": ["min", "sum", "mean"]})
        assert got["*"]["count"] == 0
        assert got["x"]["min"] is None
        assert got["x"]["sum"] is None
        assert got["x"]["mean"] is None

    def test_schema_evolution_missing_column_counts_zero(self, tmp_path):
        db = ParquetDB(os.path.join(str(tmp_path), "evo"),
                       eager_schema_align=False)
        db.create([{"x": i} for i in range(100)])
        db.create([{"x": 100 + i, "z": i * 2} for i in range(50)])
        got = db.aggregate({"*": "count", "z": ["count", "sum", "max"]})
        assert got["*"]["count"] == 150
        assert got["z"]["count"] == 50       # old rows are null for z
        assert got["z"]["sum"] == sum(i * 2 for i in range(50))
        assert got["z"]["max"] == 98


class TestStatsBoundSoundness:
    def test_long_string_minmax_decodes_not_footer_bounds(self, tmp_path):
        """Footer string bounds are truncated (min) / sentinel-padded (max)
        for long values — sound for pruning, but an aggregate must never
        report them as column values (regression)."""
        db = ParquetDB(os.path.join(str(tmp_path), "longs"))
        a, z = "a" * 103, "z" * 103
        db.create([{"s": a}, {"s": z}, {"s": "middle"}])
        got, rep = db.aggregate({"s": ["min", "max", "count"]}, explain=True)
        assert got["s"]["min"] == a          # actual value, not a prefix
        assert got["s"]["max"] == z          # no \U0010ffff sentinel
        assert got["s"]["count"] == 3
        assert rep.counters.rows_scanned > 0  # forced to decode
        # short strings still answer from footers
        db2 = ParquetDB(os.path.join(str(tmp_path), "shorts"))
        db2.create([{"s": "aa"}, {"s": "zz"}])
        got, rep = db2.aggregate({"s": ["min", "max"]}, explain=True)
        assert got["s"] == {"min": "aa", "max": "zz"}
        assert rep.counters.pages_scanned == 0

    def test_huge_int_sum_is_exact(self, tmp_path):
        """int64-wrapping sums (footer and decode path) are a silent-wrong
        answer; both must accumulate exactly (regression)."""
        db = ParquetDB(os.path.join(str(tmp_path), "huge"))
        db.create([{"v": 2 ** 62} for _ in range(4)])
        got = db.aggregate({"v": ["sum", "mean"]})
        assert got["v"]["sum"] == 2 ** 64           # stats path, no wrap
        assert got["v"]["mean"] == 2 ** 64 / 4
        # force the decode path with a filter that stats cannot decide
        db.create([{"v": 1}])
        got = db.aggregate({"v": "sum"}, filters=[field("v") > 1])
        assert got["v"]["sum"] == 2 ** 64           # decode path, no wrap


class TestBackendMinmax:
    def test_numpy_reference(self):
        be = active_backend()
        vals = np.array([5, -3, 9, 0], dtype=np.int64)
        assert be.minmax(vals) == (-3, 9)

    @pytest.mark.skipif(not jax_available(), reason="jax not importable")
    @pytest.mark.parametrize("dtype", [np.int8, np.int16, np.int32,
                                       np.uint8, np.uint16, np.uint32,
                                       np.float32, np.int64, np.float64])
    def test_jax_kernel_parity(self, dtype):
        rng = np.random.default_rng(0)
        info_ints = np.issubdtype(dtype, np.integer)
        if info_ints:
            info = np.iinfo(dtype)
            vals = rng.integers(max(info.min, -1000), min(info.max, 1000),
                                size=10_001).astype(dtype)
        else:
            vals = rng.normal(size=10_001).astype(dtype)
        set_backend("jax")
        try:
            lo, hi = active_backend().minmax(vals)
        finally:
            set_backend(None)
        assert lo == vals.min() and hi == vals.max()

    @pytest.mark.skipif(not jax_available(), reason="jax not importable")
    def test_jax_aggregate_matches_numpy(self, tmp_path):
        db = ParquetDB(os.path.join(str(tmp_path), "jx"),
                       row_group_rows=200, page_rows=100)
        db.create([{"v": (i * 37) % 501} for i in range(1000)])
        filt = [field("v") > 13]
        ref = db.aggregate({"v": ["min", "max", "sum"]}, filters=filt)
        set_backend("jax")
        try:
            jx = db.aggregate({"v": ["min", "max", "sum"]}, filters=filt)
        finally:
            set_backend(None)
        assert ref == jx
