"""Expression evaluation + the pushdown-soundness property:
pruning must NEVER discard a chunk that contains a matching row."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import Table, field
from repro.core.statistics import compute_stats
from repro.core.table import Column
from repro import compute as pc


def make_stats(values):
    col, _ = __import__("repro.core.table", fromlist=["infer_column"]).infer_column(values)
    return {"x": compute_stats(col)}


class TestEvaluate:
    def setup_method(self):
        self.t = Table.from_pydict({
            "x": np.array([1, 5, 3, 5, 9]),
            "s": ["a", "b", "c", "b", "e"],
        })

    def test_comparisons(self):
        assert (field("x") == 5).evaluate(self.t).tolist() == [False, True, False, True, False]
        assert (field("x") > 3).evaluate(self.t).sum() == 3
        assert (field("s") == "b").evaluate(self.t).sum() == 2

    def test_logical(self):
        m = ((field("x") > 2) & (field("s") != "b")).evaluate(self.t)
        assert m.tolist() == [False, False, True, False, True]
        m2 = (~(field("x") == 5)).evaluate(self.t)
        assert m2.sum() == 3

    def test_field_vs_field(self):
        t = Table.from_pydict({"a": np.array([1, 2, 3]), "b": np.array([3, 2, 1])})
        assert (field("a") < field("b")).evaluate(t).tolist() == [True, False, False]

    def test_nulls_never_match(self):
        t = Table.from_pylist([{"x": 1}, {"x": None}])
        assert (field("x") == 1).evaluate(t).tolist() == [True, False]
        assert (field("x") != 1).evaluate(t).tolist() == [False, False]
        assert field("x").is_null().evaluate(t).tolist() == [False, True]

    def test_unknown_column_raises(self):
        with pytest.raises(KeyError):
            (field("nope") == 1).evaluate(self.t)

    def test_if_else_band_gap_pattern(self):
        t = Table.from_pydict({"ind": np.array([0.0, 0.5, 2.0]),
                               "dir": np.array([1.0, 1.5, 1.0])})
        expr = pc.if_else(
            (field("ind") != 0) & (field("ind") < field("dir")),
            (field("ind") > 0.1) & (field("ind") < 3),
            (field("dir") > 0.1) & (field("dir") < 3))
        assert expr.evaluate(t).tolist() == [True, True, True]


class TestPrune:
    def test_eq_range(self):
        st_ = make_stats([10, 20, 30])
        assert (field("x") == 20).prune(st_)
        assert not (field("x") == 99).prune(st_)

    def test_bloom_prunes_within_range(self):
        st_ = make_stats([10, 20, 30])
        # 25 is inside [10,30] but bloom says absent (w.h.p.)
        assert not (field("x") == 25).prune(st_)

    def test_inequalities(self):
        st_ = make_stats([10, 20, 30])
        assert not (field("x") < 10).prune(st_)
        assert (field("x") <= 10).prune(st_)
        assert not (field("x") > 30).prune(st_)
        assert (field("x") >= 30).prune(st_)

    def test_unknown_column_is_conservative(self):
        assert (field("y") == 1).prune(make_stats([1]))

    def test_isin(self):
        st_ = make_stats([10, 20, 30])
        assert (field("x").isin([99, 20])).prune(st_)
        assert not (field("x").isin([99, 98])).prune(st_)

    def test_all_null_chunk_pruned_for_eq(self):
        st_ = make_stats([None, None])
        assert not (field("x") == 1).prune(st_)
        assert field("x").is_null().prune(st_)


@given(st.lists(st.one_of(st.integers(-1000, 1000), st.none()),
                min_size=1, max_size=50),
       st.integers(-1000, 1000),
       st.sampled_from(["==", "<", ">", "<=", ">=", "!="]))
@settings(max_examples=200, deadline=None)
def test_property_prune_soundness(values, probe, op):
    """If any row matches, prune() must return True (may-match)."""
    t = Table.from_pylist([{"x": v} for v in values])
    stats = {"x": compute_stats(t.column("x"))}
    expr = {"==": field("x") == probe, "<": field("x") < probe,
            ">": field("x") > probe, "<=": field("x") <= probe,
            ">=": field("x") >= probe, "!=": field("x") != probe}[op]
    mask = expr.evaluate(t)
    if mask.any():
        assert expr.prune(stats), (values, probe, op)


@given(st.lists(st.integers(0, 50), min_size=1, max_size=80),
       st.lists(st.integers(0, 50), min_size=1, max_size=5))
@settings(max_examples=100, deadline=None)
def test_property_isin_soundness(values, probes):
    t = Table.from_pylist([{"x": v} for v in values])
    stats = {"x": compute_stats(t.column("x"))}
    expr = field("x").isin(probes)
    if expr.evaluate(t).any():
        assert expr.prune(stats)


class TestCompute:
    def test_min_max(self):
        t = Table.from_pydict({"e": np.array([3.0, -1.0, 7.0])})
        assert pc.min_max(t["e"]) == {"min": -1.0, "max": 7.0}

    def test_list_flatten_parent_indices(self):
        t = Table.from_pylist([{"el": ["H", "O"]}, {"el": ["Si"]}])
        flat = pc.list_flatten(t["el"])
        idx = pc.list_parent_indices(t["el"])
        assert flat.to_pylist() == ["H", "O", "Si"]
        assert idx.tolist() == [0, 0, 1]

    def test_filter_take(self):
        t = Table.from_pydict({"x": np.arange(5)})
        assert pc.filter(t, np.array([1, 0, 1, 0, 1], bool)).num_rows == 3
        assert pc.take(t, [4, 0])["x"].to_pylist() == [4, 0]
