"""Hive-partitioned datasets: layout, pruning, parity, maintenance, MVCC.

The contract under test: ``partition_by=[col, ...]`` writes ``col=value/``
subdirectories and records each file's partition values in the manifest, a
selective query prunes whole partitions from manifest metadata *before any
footer is opened* (asserted by counting ``reader_of`` calls), and every
read stays byte-identical — order included — to the same dataset stored
unpartitioned, across thread counts and both scan executors.  Maintenance
(compaction, normalize) stays within partitions, and the MVCC fast path
commits partition-disjoint writers without an optimistic restart.
"""
import json
import multiprocessing
import os

import pytest

from repro.core import LoadConfig, ParquetDB, field
from repro.core import transactions as tx
from repro.core.expressions import IsIn
from repro.core.partition import (HIVE_NULL, PartitionSpec, Partitioning,
                                  hash_bucket)
from repro.core.schema import ID_COLUMN
from repro.core.table import concat_tables

N = 1_200
N_PARTS = 4


def _rows(n=N, parts=N_PARTS):
    return [{"p": i % parts, "x": i, "s": f"s{i % 7}"} for i in range(n)]


def _part_db(tmp_path, name="pdb", rows=None, **kw):
    kw.setdefault("row_group_rows", 100)
    kw.setdefault("page_rows", 50)
    db = ParquetDB(os.path.join(str(tmp_path), name), partition_by=["p"],
                   **kw)
    db.create(rows if rows is not None else _rows())
    return db


def _flat_db(tmp_path, name="flat", rows=None, **kw):
    kw.setdefault("row_group_rows", 100)
    kw.setdefault("page_rows", 50)
    db = ParquetDB(os.path.join(str(tmp_path), name), **kw)
    db.create(rows if rows is not None else _rows())
    return db


def _tables_equal(a, b):
    assert a.column_names == b.column_names
    assert a.num_rows == b.num_rows
    for c in a.column_names:
        assert a[c].to_pylist() == b[c].to_pylist(), c


def _count_footers(db):
    """Wrap ``db._reader_of`` to record which files get a footer open."""
    opened = []
    orig = type(db)._reader_of

    def counting(fn):
        opened.append(fn)
        return orig(db, fn)
    db._reader_of = counting
    return opened


class TestLayoutAndSpec:
    def test_create_writes_hive_subdirs(self, tmp_path):
        db = _part_db(tmp_path)
        man = db._dir.load()
        part = Partitioning.from_manifest(man)
        assert part is not None and part.spec.by == ("p",)
        assert set(man.files) == set(part.files)
        for fn, values in part.files.items():
            assert fn.startswith(f"p={values[0]}/"), fn
            assert os.path.exists(os.path.join(db.db_path, fn))
        assert {v[0] for v in part.files.values()} == set(range(N_PARTS))

    def test_spec_persists_and_reopen_adopts(self, tmp_path):
        db = _part_db(tmp_path)
        again = ParquetDB(db.db_path, db.dataset_name)
        assert again.partition_spec == PartitionSpec(("p",), "value", 16)
        same = ParquetDB(db.db_path, db.dataset_name, partition_by=["p"])
        assert same.partition_spec == db.partition_spec

    def test_conflicting_spec_rejected(self, tmp_path):
        db = _part_db(tmp_path)
        with pytest.raises(ValueError, match="partitioned by"):
            ParquetDB(db.db_path, db.dataset_name, partition_by=["s"])
        with pytest.raises(ValueError, match="partitioned by"):
            ParquetDB(db.db_path, db.dataset_name, partition_by=["p"],
                      partition_mode="hash")

    def test_cannot_partition_existing_data(self, tmp_path):
        db = _flat_db(tmp_path)
        with pytest.raises(ValueError, match="before the first create"):
            ParquetDB(db.db_path, db.dataset_name, partition_by=["p"])

    def test_empty_then_first_create_partitions(self, tmp_path):
        path = os.path.join(str(tmp_path), "empty")
        db = ParquetDB(path, partition_by=["p"])
        assert db.read().num_rows == 0
        db.create(_rows(40))
        part = Partitioning.from_manifest(db._dir.load())
        assert len({v[0] for v in part.files.values()}) == N_PARTS

    def test_null_partition_value(self, tmp_path):
        db = ParquetDB(os.path.join(str(tmp_path), "n"), partition_by=["p"])
        db.create([{"p": None, "x": 1}, {"p": 2, "x": 2}])
        part = Partitioning.from_manifest(db._dir.load())
        dirs = {fn.split("/", 1)[0] for fn in part.files}
        assert f"p={HIVE_NULL}" in dirs and "p=2" in dirs
        got = db.read(filters=[field("p").is_null()])
        assert got["x"].to_pylist() == [1]


class TestPruning:
    def test_selective_query_opens_no_pruned_footers(self, tmp_path):
        db = _part_db(tmp_path)
        man = db._dir.load()
        part = Partitioning.from_manifest(man)
        pruned_files = {fn for fn, v in part.files.items() if v[0] != 2}
        opened = _count_footers(db)
        rep = db.explain(filters=[field("p") == 2], execute=True)
        c = rep.counters
        assert c.partitions_total == N_PARTS
        assert c.partitions_pruned == N_PARTS - 1
        assert c.partitions_scanned == 1
        assert c.rows_matched == N // N_PARTS
        # the load-bearing claim: pruning happened from manifest metadata,
        # so no footer in a pruned partition was ever opened
        assert not (set(opened) & pruned_files)
        assert "partitions: 1 scanned" in str(rep)

    def test_pruned_partitions_count_as_skipped_files(self, tmp_path):
        db = _part_db(tmp_path)
        c = db.explain(filters=[field("p") == 0]).counters
        assert c.files_skipped >= c.partitions_pruned
        assert c.files_total == c.files_scanned + c.files_skipped

    def test_isin_and_conjunction_prune(self, tmp_path):
        db = _part_db(tmp_path)
        c = db.explain(filters=[IsIn("p", [0, 3])]).counters
        assert c.partitions_scanned == 2 and c.partitions_pruned == 2
        c = db.explain(
            filters=[(field("p") == 1) & (field("x") >= 0)]).counters
        assert c.partitions_scanned == 1

    def test_hash_mode_prunes_on_equality(self, tmp_path):
        db = ParquetDB(os.path.join(str(tmp_path), "h"), partition_by=["s"],
                       partition_mode="hash", partition_buckets=8)
        db.create(_rows(400))
        c = db.explain(filters=[field("s") == "s3"]).counters
        assert c.partitions_scanned == 1
        assert c.partitions_pruned == c.partitions_total - 1
        got = db.read(filters=[field("s") == "s3"])
        assert got.num_rows == len([r for r in _rows(400)
                                    if r["s"] == "s3"])
        # range predicates cannot prune hash buckets
        c = db.explain(filters=[field("s") > "s3"]).counters
        assert c.partitions_pruned == 0

    def test_hash_bucket_stability(self):
        # the layout on disk depends on this function never changing
        assert hash_bucket(("s3",), 8) == hash_bucket(("s3",), 8)
        assert 0 <= hash_bucket(("anything", 42), 8) < 8

    def test_live_upsert_disables_partition_pruning(self, tmp_path):
        db = _part_db(tmp_path, auto_compact=False)
        db.update([{"id": 0, "x": -1}])
        c = db.explain(filters=[field("p") == 2]).counters
        assert c.partitions_pruned == 0
        # the merged view is still correct
        got = db.read(filters=[field("p") == 2])
        assert got.num_rows == N // N_PARTS
        db.compact(force=True)
        c = db.explain(filters=[field("p") == 2]).counters
        assert c.partitions_pruned == N_PARTS - 1

    def test_aggregate_skips_pruned_partitions(self, tmp_path):
        db = _part_db(tmp_path)
        opened = _count_footers(db)
        assert db.query().where(field("p") == 1).count() == N // N_PARTS
        part = Partitioning.from_manifest(db._dir.load())
        pruned_files = {fn for fn, v in part.files.items() if v[0] != 1}
        assert not (set(opened) & pruned_files)


class TestParity:
    """Partitioned read() is byte-identical to the unpartitioned dataset."""

    @pytest.mark.parametrize("executor", ["thread", "process"])
    def test_read_identical_across_threads_and_executors(
            self, tmp_path, executor):
        part = _part_db(tmp_path)
        flat = _flat_db(tmp_path)
        ref = flat.read(load_config=LoadConfig(num_threads=1))
        for nt in (1, 2, 4):
            cfg = LoadConfig(num_threads=nt,
                             executor=executor if nt > 1 else None)
            _tables_equal(ref, part.read(load_config=cfg))

    def test_filtered_and_projected_parity(self, tmp_path):
        part = _part_db(tmp_path)
        flat = _flat_db(tmp_path)
        for filters in (None, [field("x") >= 600], [field("p") == 3],
                        [(field("p") == 1) & (field("s") == "s1")]):
            for columns in (None, ["x"], ["s", "p"]):
                _tables_equal(flat.read(columns=columns, filters=filters),
                              part.read(columns=columns, filters=filters))

    def test_parity_with_deltas(self, tmp_path):
        part = _part_db(tmp_path, auto_compact=False)
        flat = _flat_db(tmp_path, auto_compact=False)
        for db in (part, flat):
            db.update([{"id": i, "x": -i} for i in range(0, N, 7)])
            db.delete(ids=list(range(0, N, 11)))
        _tables_equal(flat.read(), part.read())

    def test_counters_identical_across_executors(self, tmp_path):
        """Satellite: per-partition counter merge is exact, not sampled."""
        db = _part_db(tmp_path)
        expr = [field("p") == 2]
        serial = db.explain(filters=expr, execute=True,
                            load_config=LoadConfig(num_threads=1)).counters
        for cfg in (LoadConfig(num_threads=4),
                    LoadConfig(num_threads=2, executor="process")):
            par = db.explain(filters=expr, execute=True,
                             load_config=cfg).counters
            assert par == serial


class TestImmutablePartitionColumns:
    def test_update_of_partition_column_rejected(self, tmp_path):
        db = _part_db(tmp_path)
        with pytest.raises(ValueError, match="partition is immutable"):
            db.update([{"id": 0, "p": 3}])
        # updating other columns of the same row is fine
        assert db.update([{"id": 0, "x": 777}]) == 1

    def test_dropping_partition_column_rejected(self, tmp_path):
        db = _part_db(tmp_path)
        with pytest.raises(ValueError, match="layout depends"):
            db.delete(columns=["p"])
        # other columns still droppable; files stay inside their subdirs
        db.delete(columns=["s"])
        part = Partitioning.from_manifest(db._dir.load())
        for fn, values in part.files.items():
            assert fn.startswith(f"p={values[0]}/")


class TestMaintenance:
    def test_compact_stays_within_partitions(self, tmp_path):
        db = _part_db(tmp_path, auto_compact=False)
        db.create(_rows(400))          # second wave: small files per part
        db.update([{"id": i, "x": -1} for i in range(0, 100)])
        res = db.compact(force=True)
        assert res.compacted
        man = db._dir.load()
        part = Partitioning.from_manifest(man)
        assert set(man.files) == set(part.files)
        by_part = {}
        for fn, values in part.files.items():
            assert fn.startswith(f"p={values[0]}/")
            by_part.setdefault(values[0], []).append(fn)
        assert set(by_part) == set(range(N_PARTS))
        got = db.read(filters=[field("p") == 2])
        assert set(got[ID_COLUMN].to_pylist()) == \
            {i for i in range(N + 400) if (i % N_PARTS if i < N else
                                           (i - N) % N_PARTS) == 2}

    def test_normalize_regroups_per_partition(self, tmp_path):
        db = _part_db(tmp_path)
        before = db.read()
        db.normalize()
        part = Partitioning.from_manifest(db._dir.load())
        for fn, values in part.files.items():
            assert fn.startswith(f"p={values[0]}/")
        _tables_equal(before, db.read())


class TestManifestLogPruning:
    def test_keep_window_vs_long_lived_snapshot(self, tmp_path, monkeypatch):
        """Satellite: MANIFEST_KEEP prunes old log generations while a
        reader holding a pre-prune snapshot of the partitioned table keeps
        reading — delta commits never unlink data files, only log files."""
        monkeypatch.setattr(tx, "MANIFEST_KEEP", 4)
        db = _part_db(tmp_path, auto_compact=False, rows=_rows(200))
        snap_man = db._dir.load()        # long-lived reader's snapshot
        snap_gen = snap_man.generation
        expect = db.read()
        for k in range(12):              # push the head past the window
            db.update([{"id": 0, "x": 1000 + k}])
        head = db._dir.load().generation
        gens = db._dir.log_generations()
        assert min(gens) >= head - 4
        assert snap_gen not in gens      # the snapshot's log file is gone
        # the held manifest still reads: every file it references is live
        plan = db._scan_plan(None, None, LoadConfig(), man=snap_man)
        _tables_equal(expect, concat_tables(list(plan.execute())))
        # and a fresh open sees the newest value
        got = db.read(filters=[field("x") >= 1000])
        assert got["x"].to_pylist() == [1011]


def _disjoint_writer(path, part_value, q):
    try:
        db = ParquetDB(path, "pdb", auto_compact=False)
        n = db.update([{"id": i, "x": -part_value}
                       for i in range(part_value, 400, N_PARTS)])
        q.put((part_value, n, None))
    except BaseException as e:  # pragma: no cover - failure reporting
        q.put((part_value, -1, repr(e)))


@pytest.mark.concurrency
def test_disjoint_partition_writers_commit_without_retry(tmp_path):
    """Satellite: two writers touching disjoint partitions both commit,
    and the published ``txn_retries`` metadata stays 0 — the partition
    fast path never forced an optimistic restart."""
    if (os.cpu_count() or 1) < 2 and not os.environ.get(
            "REPRO_FORCE_CONCURRENCY"):
        pytest.skip("SKIPPED (loud): needs >= 2 cpus; this box has "
                    f"{os.cpu_count()} — run the CI concurrency job, or "
                    "set REPRO_FORCE_CONCURRENCY=1")
    path = os.path.join(str(tmp_path), "pdb")
    db = ParquetDB(path, "pdb", partition_by=["p"], auto_compact=False)
    db.create([{"p": i % N_PARTS, "x": i} for i in range(400)])
    ctx = multiprocessing.get_context("spawn")
    q = ctx.Queue()
    procs = [ctx.Process(target=_disjoint_writer, args=(path, pv, q))
             for pv in (1, 3)]
    for p in procs:
        p.start()
    results = [q.get(timeout=120) for _ in procs]
    for p in procs:
        p.join(timeout=60)
        assert p.exitcode == 0
    for pv, n, err in results:
        assert err is None, f"writer p={pv}: {err}"
        assert n == 100
    man = db._dir.load()
    assert man.metadata.get("op") == "delta"
    assert man.metadata.get("txn_retries") == 0
    got = db.read(filters=[IsIn("p", [1, 3])])
    assert set(got["x"].to_pylist()) == {-1, -3}


class TestDeltaEntryPartitions:
    def test_staged_deltas_record_partitions(self, tmp_path):
        db = _part_db(tmp_path, auto_compact=False)
        db.update([{"id": 1, "x": -1}])          # row 1 lives in p=1
        db.delete(ids=[2])                       # row 2 lives in p=2
        man = db._dir.load()
        kinds = {d.kind: d.partitions for d in man.deltas}
        assert kinds[tx.DELTA_UPSERT] == ("p=1",)
        assert kinds[tx.DELTA_TOMBSTONE] == ("p=2",)

    def test_manifest_roundtrip_preserves_partitions(self, tmp_path):
        db = _part_db(tmp_path, auto_compact=False)
        db.update([{"id": 1, "x": -1}])
        man = db._dir.load()
        doc = json.loads(json.dumps(man.to_dict()))
        back = type(man).from_dict(doc)
        assert [d.partitions for d in back.deltas] == \
            [d.partitions for d in man.deltas]
