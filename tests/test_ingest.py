"""Vectorized ingest: uniform fast path, schema-hint reuse, property tests.

``Table.from_pylist`` now takes a 2-D transpose fast path for uniform
scalar records and bulk builders per column otherwise; these tests assert
the fast paths are *semantically invisible* — same schemas, same values,
same null handling as element-wise inference — including under a
hypothesis-generated record soup.
"""
import os

import numpy as np
import pytest

from repro.core import ParquetDB, Schema, Table
from repro.core.dtypes import DType
from repro.core.schema import Field
from repro.core.table import (_from_pylist_uniform, concat_tables,
                              infer_column)


def norm(v):
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, dict):
        return {k: norm(x) for k, x in v.items()}
    if isinstance(v, list):
        return [norm(x) for x in v]
    return v


class TestUniformFastPath:
    def test_all_int_records(self):
        rows = [{"b": i * 2, "a": i} for i in range(100)]
        t = Table.from_pylist(rows)
        assert t.column_names == ["a", "b"]
        assert t.schema["a"].dtype.code == "i8"
        assert t["a"].to_pylist() == list(range(100))
        assert t["b"].to_pylist() == [i * 2 for i in range(100)]

    def test_all_float_records(self):
        rows = [{"x": float(i), "y": i / 3} for i in range(50)]
        t = Table.from_pylist(rows)
        assert t.schema["x"].dtype.code == "f8"
        assert t["y"].to_pylist() == [i / 3 for i in range(50)]

    def test_fast_path_taken_and_fallback_cases(self):
        assert _from_pylist_uniform([{"a": 1}, {"a": 2}], None) is not None
        # mixed int/float first record: falls back
        assert _from_pylist_uniform([{"a": 1, "b": 2.0}], None) is None
        # strings: falls back
        assert _from_pylist_uniform([{"a": "x"}], None) is None
        # bools are not ints (b1 inference must win): falls back
        assert _from_pylist_uniform([{"a": True}], None) is None
        # missing key in a later record: falls back
        assert _from_pylist_uniform([{"a": 1}, {"b": 2}], None) is None
        # extra key: falls back
        assert _from_pylist_uniform([{"a": 1}, {"a": 2, "b": 3}], None) is None
        # None value: falls back (object dtype)
        assert _from_pylist_uniform([{"a": 1}, {"a": None}], None) is None
        # nested dict value: falls back to the flattening path
        assert _from_pylist_uniform([{"a": {"b": 1}}], None) is None

    def test_fast_path_matches_slow_path_exactly(self):
        rows = [{"a": i, "b": i * i, "c": -i} for i in range(200)]
        fast = Table.from_pylist(rows)
        slow_cols = {}
        for name in ("a", "b", "c"):
            slow_cols[name], _ = infer_column([r[name] for r in rows])
        for name in ("a", "b", "c"):
            assert fast[name].dtype == slow_cols[name].dtype
            np.testing.assert_array_equal(fast[name].values,
                                          slow_cols[name].values)

    def test_uint64_values_not_wrapped(self):
        # np.asarray infers uint64 for values >= 2**63; the 2-D fast path
        # must bail out (not astype(int64)-wrap them negative) so that
        # per-column inference keeps exact dtypes: a stays u8, b stays i8
        rows = [{"a": 2**63, "b": 1}, {"a": 2**63 + 1, "b": 2}]
        t = Table.from_pylist(rows)
        assert t.schema["a"].dtype.code == "u8"
        assert t.schema["b"].dtype.code == "i8"
        assert t["a"].to_pylist() == [2**63, 2**63 + 1]
        assert t["b"].to_pylist() == [1, 2]

    def test_non_string_keys_coerced_like_flatten(self):
        # flatten_records coerces keys via str(); skipping flatten for flat
        # records must not regress that (mixed key types used to crash sort)
        t = Table.from_pylist([{1: "x", "a": "y"}, {1: "z", "a": "w"}])
        assert t.column_names == ["1", "a"]
        assert t["1"].to_pylist() == ["x", "z"]
        t2 = Table.from_pylist([{2: 10}, {2: 20}])
        assert t2["2"].to_pylist() == [10, 20]

    def test_key_order_insensitive(self):
        rows = [{"a": 1, "b": 2}, {"b": 20, "a": 10}]
        t = Table.from_pylist(rows)
        assert t["a"].to_pylist() == [1, 10]
        assert t["b"].to_pylist() == [2, 20]


class TestSchemaHint:
    def test_hint_skips_inference_same_result(self):
        hint = Schema([Field("n", DType.numeric("i8")),
                       Field("s", DType.string())])
        rows = [{"n": i, "s": f"v{i}", "extra": 1.5} for i in range(20)]
        hinted = Table.from_pylist(rows, schema_hint=hint)
        plain = Table.from_pylist(rows)
        assert hinted.schema.names == plain.schema.names
        for name in hinted.column_names:
            assert hinted.schema[name].dtype == plain.schema[name].dtype
            assert hinted[name].to_pylist() == plain[name].to_pylist()

    def test_hint_never_truncates(self):
        # floats arriving at an int-hinted column must re-infer (f8), not
        # silently truncate
        hint = Schema([Field("n", DType.numeric("i8"))])
        t = Table.from_pydict({"n": [1.5, 2.5]}, schema_hint=hint)
        assert t.schema["n"].dtype.code == "f8"
        assert t["n"].to_pylist() == [1.5, 2.5]

    def test_hint_with_nulls_falls_back(self):
        hint = Schema([Field("n", DType.numeric("i8"))])
        t = Table.from_pydict({"n": [1, None, 3]}, schema_hint=hint)
        assert t["n"].to_pylist() == [1, None, 3]

    def test_list_hint_survives_all_empty_batch(self, tmp_path):
        # an all-empty list batch used to re-infer as tensor<(0,)> and fail
        # schema unification; the dataset hint now pins it to a ragged list
        db = ParquetDB(os.path.join(str(tmp_path), "lists"))
        db.create([{"a": i, "tags": list(range(i % 3))} for i in range(20)])
        db.create([{"a": i, "tags": []} for i in range(20, 30)])
        out = db.read()
        assert out.num_rows == 30
        tags = dict(zip(out["a"].to_pylist(), out["tags"].to_pylist()))
        assert tags[1] == [0] and tags[25] == []

    def test_steady_state_append_keeps_schema(self, tmp_path):
        db = ParquetDB(os.path.join(str(tmp_path), "app"))
        db.create([{"a": i, "s": f"r{i}"} for i in range(50)])
        before = db.schema.to_dict()
        db.create([{"a": i, "s": f"r{i}"} for i in range(50, 100)])
        assert db.schema.to_dict() == before
        out = db.read()
        assert out.num_rows == 100
        assert sorted(out["a"].to_pylist()) == list(range(100))


class TestBulkBuilders:
    def test_bulk_strings_one_pass(self):
        col, meta = infer_column(["a", "bb", None, "dddd", ""])
        assert meta is None
        assert col.to_pylist() == ["a", "bb", None, "dddd", ""]

    def test_bulk_strings_rejects_mixed(self):
        col, meta = infer_column(["a", 5, "c"])
        assert meta is not None  # fell through to serialization

    def test_unicode_roundtrip(self):
        vals = ["héllo", "жизнь", "日本語", "🎉" * 3, ""]
        col, _ = infer_column(vals)
        assert col.to_pylist() == vals


@pytest.mark.parametrize("n", [0, 1, 7, 1000])
def test_empty_and_small(n):
    rows = [{"x": i} for i in range(n)]
    t = Table.from_pylist(rows)
    assert t.num_rows == n


def test_property_ingest_roundtrip():
    """Property test: arbitrary uniform-ish record batches round-trip
    through from_pylist -> to_pylist unchanged (modulo int/float widening
    rules that elementwise inference also applies)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    scalar = st.one_of(
        st.none(),
        st.integers(min_value=-2**53, max_value=2**53),
        st.floats(allow_nan=False, allow_infinity=False, width=32),
        st.text(max_size=8),
        st.booleans(),
    )
    # records share one value *kind* per column (mixed kinds serialize —
    # exercised elsewhere); keys vary to hit the missing-field backfill
    record = st.fixed_dictionaries(
        {}, optional={"a": st.integers(min_value=-10**6, max_value=10**6),
                      "b": st.text(max_size=5),
                      "c": st.floats(allow_nan=False, allow_infinity=False,
                                     width=32),
                      "d": st.booleans()})

    @given(st.lists(record, max_size=40))
    @settings(max_examples=80, deadline=None)
    def check(records):
        t = Table.from_pylist(records)
        assert t.num_rows == len(records)
        out = t.to_pylist()
        for rec, got in zip(records, out):
            for k in ("a", "b", "c", "d"):
                expect = rec.get(k)
                assert norm(got.get(k)) == pytest.approx(expect) \
                    if isinstance(expect, float) else norm(got.get(k)) == expect

    check()


def test_property_scalar_column_inference():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(st.lists(st.one_of(st.none(),
                              st.integers(min_value=-2**60, max_value=2**60)),
                    max_size=100))
    @settings(max_examples=60, deadline=None)
    def check(vals):
        col, meta = infer_column(vals)
        assert meta is None
        assert col.to_pylist() == vals

    check()
