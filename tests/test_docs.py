"""Docs stay true: links resolve, code blocks run, API.md is fresh.

Mirrors the CI docs job (scripts/check_docs.py + gen_api_docs.py --check)
so a doc-rotting change fails locally too, not just on the runner.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script, *args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", script), *args],
        capture_output=True, text=True, env=env, cwd=REPO)


def test_markdown_links_and_code_blocks():
    res = _run("check_docs.py")
    assert res.returncode == 0, res.stdout + res.stderr


def test_api_reference_is_fresh():
    res = _run("gen_api_docs.py", "--check")
    assert res.returncode == 0, res.stdout + res.stderr


def test_quickstart_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    res = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "quickstart.py")],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert res.returncode == 0, res.stdout + res.stderr
    assert res.stdout.strip().endswith("OK")
