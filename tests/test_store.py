"""ParquetDB store: CRUD, schema evolution, normalize, nested rebuild."""
import numpy as np
import pytest

from repro.core import (LoadConfig, NormalizeConfig, ParquetDB, Schema,
                        Table, field)


@pytest.fixture
def db(tmp_path):
    return ParquetDB(str(tmp_path / "db"), "db")


class TestCreate:
    def test_id_generation_monotonic(self, db):
        ids1 = db.create([{"a": 1}, {"a": 2}])
        ids2 = db.create([{"a": 3}])
        assert ids1.tolist() == [0, 1] and ids2.tolist() == [2]

    def test_ids_continue_after_delete(self, db):
        db.create([{"a": 1}, {"a": 2}])
        db.delete(ids=[1])
        ids = db.create([{"a": 3}])
        assert ids.tolist() == [2]  # never reused

    def test_schema_evolution_backfills_null(self, db):
        db.create([{"a": 1}])
        db.create([{"a": 2, "b": "new"}])
        rows = db.read().to_pylist()
        assert rows[0]["b"] is None and rows[1]["b"] == "new"

    def test_numeric_widening(self, db):
        db.create([{"x": 1}])
        db.create([{"x": 2.5}])
        assert db.schema["x"].dtype.code == "f8"
        assert db.read(columns=["x"]).to_pydict()["x"] == [1.0, 2.5]

    def test_create_from_pydict_and_table(self, db):
        db.create({"v": np.arange(4)})
        db.create(Table.from_pydict({"v": np.arange(2)}))
        assert db.n_rows == 6

    def test_irreconcilable_schema_fails_cleanly(self, db):
        db.create([{"x": 1}])
        with pytest.raises(TypeError):
            db.create([{"x": "string now"}])
        assert db.n_rows == 1  # nothing committed

    def test_table_metadata(self, db):
        db.create([{"a": 1}], metadata={"source": "api"})
        assert db.schema.metadata.get("source") == "api"


class TestRead:
    def test_ids_columns(self, db):
        db.create([{"a": i, "b": -i} for i in range(10)])
        t = db.read(ids=[3, 7], columns=["a"])
        assert sorted(t.to_pydict()["a"]) == [3, 7]

    def test_exclude_columns(self, db):
        db.create([{"a": 1, "b": 2, "c": 3}])
        t = db.read(columns=["b"], include_cols=False)
        assert t.column_names == ["a", "c", "id"]

    def test_filters_combined_with_and(self, db):
        db.create([{"x": i, "y": i % 3} for i in range(30)])
        t = db.read(filters=[field("x") < 10, field("y") == 1])
        assert t.to_pydict()["x"] == [1, 4, 7]

    def test_batches_generator(self, db):
        db.create({"x": np.arange(1000)})
        sizes = [b.num_rows for b in db.read(load_format="batches", batch_size=300)]
        assert sizes == [300, 300, 300, 100]

    def test_dataset_handle(self, db):
        db.create({"x": np.arange(10)})
        ds = db.read(load_format="dataset", columns=["x"])
        assert ds.to_table().num_rows == 10

    def test_dotted_parent_selects_children(self, db):
        db.create([{"s": {"a": 1, "b": 2}}])
        t = db.read(columns=["s"])
        assert set(t.column_names) == {"s.a", "s.b"}

    def test_empty_db_read(self, db):
        assert db.read().num_rows == 0

    def test_no_threads(self, db):
        db.create({"x": np.arange(10)})
        t = db.read(load_config=LoadConfig(use_threads=False))
        assert t.num_rows == 10


class TestUpdate:
    def test_basic_update(self, db):
        db.create([{"a": 1, "b": "x"}, {"a": 2, "b": "y"}])
        n = db.update([{"id": 0, "a": 100}])
        assert n == 1
        rows = db.read().to_pylist()
        assert rows[0]["a"] == 100 and rows[0]["b"] == "x"

    def test_update_adds_new_field(self, db):
        db.create([{"a": 1}, {"a": 2}])
        db.update([{"id": 1, "z": 9.5}])
        rows = db.read(columns=["z"]).to_pydict()["z"]
        assert rows == [None, 9.5]

    def test_update_requires_key(self, db):
        db.create([{"a": 1}])
        with pytest.raises(ValueError):
            db.update([{"a": 5}])

    def test_update_nonexistent_id_noop(self, db):
        db.create([{"a": 1}])
        assert db.update([{"id": 999, "a": 5}]) == 0

    def test_update_by_custom_key(self, db):
        db.create([{"k": "u1", "v": 1}, {"k": "u2", "v": 2}])
        n = db.update([{"k": "u2", "v": 20}], update_keys="k")
        assert n == 1
        assert db.read(filters=[field("k") == "u2"]).to_pydict()["v"] == [20]

    def test_bulk_update(self, db):
        db.create({"x": np.zeros(5000, np.int64)})
        n = db.update({"id": np.arange(0, 5000, 2),
                       "x": np.ones(2500, np.int64)})
        assert n == 2500
        assert db.read(columns=["x"]).column("x").values.sum() == 2500

    def test_last_write_wins(self, db):
        db.create([{"a": 0}])
        db.update([{"id": 0, "a": 1}, {"id": 0, "a": 2}])
        assert db.read(columns=["a"]).to_pydict()["a"] == [2]


class TestDelete:
    def test_delete_rows_by_id(self, db):
        db.create([{"a": i} for i in range(5)])
        assert db.delete(ids=[1, 3]) == 2
        assert db.read(columns=["a"]).to_pydict()["a"] == [0, 2, 4]

    def test_delete_by_filter(self, db):
        db.create([{"a": i} for i in range(10)])
        assert db.delete(filters=[field("a") >= 5]) == 5
        assert db.n_rows == 5

    def test_delete_columns(self, db):
        db.create([{"a": 1, "b": 2}])
        db.delete(columns=["b"])
        assert "b" not in db.schema

    def test_cannot_delete_id(self, db):
        db.create([{"a": 1}])
        with pytest.raises(ValueError):
            db.delete(columns=["id"])

    def test_row_and_column_mutually_exclusive(self, db):
        db.create([{"a": 1}])
        with pytest.raises(ValueError):
            db.delete(ids=[0], columns=["a"])


class TestNormalize:
    def test_normalize_balances_files(self, db):
        for _ in range(8):
            db.create({"x": np.arange(100)})
        assert db.n_files == 8
        db.normalize(NormalizeConfig(max_rows_per_file=400))
        assert db.n_files == 2
        assert db.n_rows == 800

    def test_normalize_during_create(self, db):
        db.create({"x": np.arange(10)})
        db.create({"x": np.arange(10)}, normalize_dataset=True,
                  normalize_config=NormalizeConfig(max_rows_per_file=100))
        assert db.n_files == 1

    def test_data_survives_normalize(self, db):
        db.create([{"s": "abc", "v": [1.0, 2.0]}, {"s": "def", "v": [3.0, 4.0]}])
        db.normalize()
        rows = db.read().to_pylist()
        assert rows[0]["s"] == "abc" and rows[1]["v"].tolist() == [3.0, 4.0]


class TestNestedRebuild:
    def test_rebuild_and_cache(self, db, tmp_path):
        db.create([{"structure": {"sites": [{"xyz": [0.0, 0.0]}],
                                  "lattice": {"a": 1.0}}, "e": -1.0}])
        t = db.read(columns=["structure"], rebuild_nested_struct=True)
        rec = t.to_pylist(rebuild_nested=True)[0]
        assert rec["structure"]["lattice"]["a"] == 1.0
        # cached second read
        t2 = db.read(columns=["structure"], rebuild_nested_struct=True)
        assert t2.num_rows == 1

    def test_rebuild_from_scratch_after_update(self, db):
        db.create([{"d": {"spg": 1}}])
        db.read(rebuild_nested_struct=True)
        db.update([{"id": 0, "d.spg": 204}])
        t = db.read(rebuild_nested_struct=True, rebuild_nested_from_scratch=True)
        assert t.to_pylist(rebuild_nested=True)[0]["d"]["spg"] == 204


class TestMetadata:
    def test_set_metadata(self, db):
        db.create([{"a": 1}])
        db.set_metadata({"owner": "test"})
        assert db.metadata["owner"] == "test"

    def test_field_metadata(self, db):
        db.create([{"a": 1}])
        db.set_field_metadata("a", {"unit": "eV"})
        assert db.schema["a"].metadata["unit"] == "eV"
