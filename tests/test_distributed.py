"""Distribution: sharding-rule unit tests + an 8-host-device integration run
(subprocess, because XLA device count must be set before jax initializes)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from jax.sharding import PartitionSpec as PS


class TestSpecFor:
    def _mesh(self, shape=(2, 4), axes=("data", "model")):
        # host platform has 1 device in this process: build an abstract mesh.
        # jax >= 0.5 takes (axis_sizes, axis_names); 0.4.x wants one
        # ((name, size), ...) shape tuple — probe the new form first.
        from jax.sharding import AbstractMesh
        try:
            return AbstractMesh(shape, axes)
        except TypeError:
            return AbstractMesh(tuple(zip(axes, shape)))

    def test_dense_weight(self):
        from repro.distributed.sharding import spec_for
        mesh = self._mesh()
        assert spec_for((64, 128), ("embed", "ffn"), mesh) == PS("data", "model")

    def test_heads_not_divisible_falls_back_to_embed(self):
        from repro.distributed.sharding import spec_for
        mesh = self._mesh((2, 4))
        # 3 heads unshardable on 4-wide model axis -> model stacks on embed
        spec = spec_for((64, 3, 16), ("embed", "heads", "hdim"), mesh)
        assert spec == PS(("data", "model"), None, None)

    def test_kv_cache_seq_fallback(self):
        from repro.distributed.sharding import spec_for
        mesh = self._mesh((2, 4))
        # kv=2 unshardable on 4-wide model -> model lands on seq
        spec = spec_for((8, 2, 64, 2, 16),
                        ("layers", "batch", "seq", "kv", "hdim"), mesh)
        assert spec == PS(None, "data", "model", None, None)

    def test_batch_one_replicated(self):
        from repro.distributed.sharding import batch_spec
        mesh = self._mesh((2, 4))
        assert batch_spec(mesh, 2, batch_dim=1) == PS(None, None)
        assert batch_spec(mesh, 2, batch_dim=6) == PS("data", None)

    def test_expert_weights(self):
        from repro.distributed.sharding import spec_for
        mesh = self._mesh()
        spec = spec_for((8, 64, 96), ("exp", "embed", "ffn"), mesh)
        assert spec == PS("model", "data", None)

    def test_multi_pod_batch(self):
        from repro.distributed.sharding import batch_spec
        mesh = self._mesh((2, 2, 2), ("pod", "data", "model"))
        assert batch_spec(mesh, 2, batch_dim=8) == PS(("pod", "data"), None)


_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax, jax.numpy as jnp, numpy as np
    sys.path.insert(0, {src!r})
    from repro.models import Model, ModelConfig, AttnCfg, MoECfg, SSMCfg
    from repro.launch.mesh import make_mesh
    from repro.train.train_step import build_train_step
    from repro.train import optimizer as opt

    out = {{}}
    for name, cfg, mesh_shape, axes in [
        ("dense_2x4", ModelConfig("d", "dense", 2, 64, 128, 256,
                                  attn=AttnCfg(4, 2, 16), remat=True),
         (2, 4), ("data", "model")),
        ("moe_2x4", ModelConfig("m", "moe", 2, 64, 128, 256,
                                attn=AttnCfg(4, 2, 16),
                                moe=MoECfg(8, 2, 96, shared_ff=64)),
         (2, 4), ("data", "model")),
        ("ssm_pod", ModelConfig("s", "ssm", 2, 64, 0, 256,
                                ssm=SSMCfg(d_state=16, headdim=16, chunk=8)),
         (2, 2, 2), ("pod", "data", "model")),
    ]:
        mesh = make_mesh(mesh_shape, axes)
        model = Model(cfg)
        params = model.init(jax.random.key(0))
        ostate = opt.init_opt_state(params)
        _, jit_step, shards = build_train_step(
            model, mesh, opt.OptConfig(lr=1e-3, warmup_steps=2,
                                       total_steps=50),
            microbatches=2)
        B, S = 8, 32
        rng = np.random.default_rng(0)
        batch = {{"tokens": jnp.asarray(rng.integers(0, 256, (B, S)),
                                        jnp.int32)}}
        f = jit_step({{"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}})
        params = jax.device_put(params, shards["params"])
        ostate = jax.device_put(ostate, shards["opt"])
        losses = []
        for _ in range(4):
            params, ostate, m = f(params, ostate, batch)
            losses.append(float(m["loss"]))
        out[name] = losses
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_8device_train_all_parallelism_modes(tmp_path):
    """DP×TP (+EP via shard_map, +pod axis) on 8 host devices: losses finite
    and decreasing for dense, MoE and SSM families."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SUBPROCESS_SCRIPT.format(src=os.path.abspath(src))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    res = json.loads(line[len("RESULT "):])
    for name, losses in res.items():
        assert all(np.isfinite(losses)), (name, losses)
        assert losses[-1] < losses[0], (name, losses)
