"""Parity oracle + semantics tests for the lazy composable Query API.

The core contract: the legacy surface (``read``/``aggregate``/``explain``)
is a set of thin shims over ``db.query()``, so for a matrix of
(filters × projections × deltas × num_threads) the Query path must be
byte-identical — row order included — to the legacy calls.  Grouped
aggregation is checked against a pure-python oracle, and ``limit`` must
demonstrably terminate the scan early (fewer rows decoded per
``explain(execute=True)`` counters).
"""
import math
import os

import numpy as np
import pytest

from repro.core import LoadConfig, ParquetDB, Query, field
from repro.core import scan as scan_mod
from repro.core.expressions import Arith, IsIn, IsNull


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------
def _mkdb(path, deltas: bool) -> ParquetDB:
    """4 files x 8 row groups of 100 rows; x unique ints, y cyclic float
    with NaN, s strings, opt nullable.  ``deltas=True`` stages an upsert
    and a tombstone chain on top."""
    db = ParquetDB(path, row_group_rows=100, page_rows=50,
                   auto_compact=False)
    for f in range(4):
        lo = f * 800
        db.create([{"x": lo + i,
                    "y": float("nan") if (lo + i) % 11 == 0
                    else float((lo + i) % 7),
                    "s": f"k{(lo + i) % 5}",
                    "opt": None if (lo + i) % 4 == 0 else (lo + i) % 50}
                   for i in range(800)])
    if deltas:
        db.update([{"id": i, "opt": 99} for i in range(0, 3200, 101)])
        db.delete(ids=list(range(7, 3200, 97)))
    return db


@pytest.fixture(scope="module", params=[False, True],
                ids=["plain", "deltas"])
def db(request, tmp_path_factory):
    path = tmp_path_factory.mktemp("qdb")
    return _mkdb(os.path.join(str(path), "db"), request.param)


FILTERS = {
    "none": None,
    "range": [field("x") >= 400, field("x") < 2500],
    "eq": [field("s") == "k3"],
    "isin": [IsIn("opt", [1, 5, 99])],
    "null": [field("opt").is_null()],
    "neg": [~(field("y") > 3.0)],
}
PROJECTIONS = {
    "all": None,
    "two": ["x", "s"],
    "one": ["opt"],
}
THREADS = [None, 1, 4]


def assert_tables_equal(a, b):
    assert a.column_names == b.column_names
    assert a.num_rows == b.num_rows
    for n in a.column_names:
        ca, cb = a.column(n), b.column(n)
        assert ca.dtype == cb.dtype, n
        la, lb = ca.to_pylist(), cb.to_pylist()
        if ca.dtype.kind == "numeric" and ca.dtype.is_float:
            np.testing.assert_array_equal(np.array(la, float),
                                          np.array(lb, float))
        else:
            assert la == lb, n


# ---------------------------------------------------------------------------
# parity oracle: query vs legacy read
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fname", list(FILTERS))
@pytest.mark.parametrize("pname", list(PROJECTIONS))
@pytest.mark.parametrize("nt", THREADS)
def test_query_matches_read(db, fname, pname, nt):
    filters, columns = FILTERS[fname], PROJECTIONS[pname]
    cfg = LoadConfig(num_threads=nt)
    legacy = db.read(columns=columns, filters=filters, load_config=cfg)
    q = db.query(load_config=cfg)
    for f in (filters or []):
        q = q.where(f)
    if columns is not None:
        q = q.select(*columns)
    assert_tables_equal(legacy, q.to_table())


def test_query_on_empty_dataset(tmp_path):
    db = ParquetDB(os.path.join(str(tmp_path), "empty"))
    t = db.query().select("id").to_table()
    assert t.num_rows == 0 and t.column_names == ["id"]
    assert db.query().count() == 0
    assert db.query().group_by("id").agg({"*": "count"}).to_table() \
             .num_rows == 0


# ---------------------------------------------------------------------------
# ungrouped agg parity (footer-stats fast path)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fname", ["none", "range", "eq"])
def test_agg_matches_aggregate(db, fname):
    filters = FILTERS[fname]
    spec = {"*": "count", "x": ["min", "max", "sum", "mean"],
            "opt": ["count", "sum"], "s": ["min", "max"]}
    v1, r1 = db.aggregate(spec, filters=filters, explain=True)
    q = db.query()
    for f in (filters or []):
        q = q.where(f)
    v2, r2 = q.agg(spec, explain=True)
    assert v1 == v2
    c1, c2 = r1.counters, r2.counters
    assert c1.groups_answered_by_stats == c2.groups_answered_by_stats
    assert c1.bytes_skipped_agg == c2.bytes_skipped_agg
    assert c1.pages_scanned == c2.pages_scanned


def test_dataset_is_a_query_prefix(db):
    ds = db.read(columns=["x", "s"], filters=[field("x") < 1000],
                 load_format="dataset")
    q = ds.query()
    assert isinstance(q, Query)
    assert_tables_equal(ds.to_table(), q.to_table())
    # and it keeps composing
    g = q.group_by("s").agg({"x": "sum"}).order_by("s").to_table()
    rows = ds.to_table().to_pylist()
    want = {}
    for r in rows:
        want[r["s"]] = want.get(r["s"], 0) + r["x"]
    assert {r["s"]: r["x_sum"] for r in g.to_pylist()} == want


# ---------------------------------------------------------------------------
# group_by vs a pure-python oracle
# ---------------------------------------------------------------------------
def _group_oracle(rows, keys, col, ops):
    groups = {}
    order_probe = []
    for r in rows:
        kv = tuple(("NaN" if isinstance(r[k], float) and math.isnan(r[k])
                    else r[k]) for k in keys)
        groups.setdefault(kv, []).append(r)
        order_probe.append(kv)
    out = {}
    for kv, rs in groups.items():
        vals = [r[col] for r in rs if r[col] is not None] if col != "*" else []
        nn = [v for v in vals
              if not (isinstance(v, float) and math.isnan(v))]
        ent = {}
        for op in ops:
            if col == "*":
                ent["count"] = len(rs)
            elif op == "count":
                ent["count"] = len(vals)
            elif not nn:
                ent[op] = None
            elif op == "min":
                ent[op] = min(nn)
            elif op == "max":
                ent[op] = max(nn)
            elif op == "sum":
                ent[op] = sum(nn)
            elif op == "mean":
                ent[op] = sum(nn) / len(nn)
        out[kv] = ent
    return out


def _norm_key(v):
    return "NaN" if isinstance(v, float) and math.isnan(v) else v


@pytest.mark.parametrize("nt", THREADS)
@pytest.mark.parametrize("keys,col,ops", [
    (["s"], "x", ["count", "min", "max", "sum", "mean"]),
    (["s"], "*", ["count"]),
    (["opt"], "x", ["sum"]),              # null key group
    (["y"], "*", ["count"]),              # NaN key group
    (["s", "opt"], "x", ["min", "max"]),  # multi-key
])
def test_group_by_oracle(db, nt, keys, col, ops):
    rows = db.read().to_pylist()
    want = _group_oracle(rows, keys, col, ops)
    spec = {col: list(ops)} if col != "*" else {"*": "count"}
    t = (db.query(load_config=LoadConfig(num_threads=nt))
           .group_by(*keys).agg(spec).to_table())
    assert t.num_rows == len(want)
    got_rows = t.to_pylist()
    for r in got_rows:
        kv = tuple(_norm_key(r[k]) for k in keys)
        assert kv in want, kv
        for op in ops:
            name = "count" if col == "*" else f"{col}_{op}"
            got, exp = r[name], want[kv][op if col != "*" else "count"]
            if isinstance(exp, float):
                assert got == pytest.approx(exp), (kv, op)
            else:
                assert got == exp, (kv, op)


def test_group_by_string_minmax(db):
    rows = db.read().to_pylist()
    want = _group_oracle(rows, ["opt"], "s", ["min", "max", "count"])
    t = db.query().group_by("opt").agg({"s": ["min", "max", "count"]}) \
          .to_table()
    for r in t.to_pylist():
        kv = (_norm_key(r["opt"]),)
        assert r["s_min"] == want[kv]["min"]
        assert r["s_max"] == want[kv]["max"]
        assert r["s_count"] == want[kv]["count"]


def test_group_by_order_limit(db):
    t = (db.query().group_by("s").agg({"*": "count", "x": "min"})
           .order_by("count", desc=True).order_by("s").limit(3).to_table())
    assert t.num_rows == 3
    counts = [r["count"] for r in t.to_pylist()]
    assert counts == sorted(counts, reverse=True)


def test_global_group(db):
    """group_by() with no keys = one global group."""
    t = db.query().group_by().agg({"x": ["sum", "count"]}).to_table()
    assert t.num_rows == 1
    agg = db.aggregate({"x": ["sum", "count"]})
    r = t.to_pylist()[0]
    assert r["x_sum"] == agg["x"]["sum"]
    assert r["x_count"] == agg["x"]["count"]


# ---------------------------------------------------------------------------
# where-fusion, computed columns, distinct, order, limit/offset
# ---------------------------------------------------------------------------
def test_where_fusion_equals_combined(db):
    a = db.query().where(field("x") >= 100).where(field("x") < 900) \
          .select("x").to_table()
    b = db.read(columns=["x"],
                filters=[field("x") >= 100, field("x") < 900])
    assert_tables_equal(a, b)
    rep = db.query().where(field("x") >= 100).where(field("x") < 900) \
            .explain()
    filt = dict(rep.ops)["Filter"]
    assert "2 predicates fused" in filt and "AND" in filt


def test_computed_columns(db):
    t = (db.query().where(field("x") < 10)
           .select("x", "opt", double=field("x") * 2,
                   ratio=field("opt") / 4, shifted=field("x") + 1 - 3)
           .to_table())
    for r in t.to_pylist():
        assert r["double"] == r["x"] * 2
        assert r["shifted"] == r["x"] - 2
        if r["opt"] is None:
            assert r["ratio"] is None  # null propagates
        else:
            assert r["ratio"] == pytest.approx(r["opt"] / 4)


def test_computed_only_projection_keeps_inputs_out(db):
    t = db.query().select("x", total=field("x") + field("opt")) \
          .limit(4).to_table()
    assert set(t.column_names) == {"x", "total"}  # opt not leaked


def test_computed_agg_fallback(db):
    """agg over a computed column aggregates the materialized output."""
    q = db.query().where(field("x") < 100).select(d=field("x") * 2)
    got = q.agg({"d": ["sum", "max"]})
    rows = db.read(columns=["x"], filters=[field("x") < 100]).to_pylist()
    assert got["d"]["sum"] == sum(2 * r["x"] for r in rows)
    assert got["d"]["max"] == max(2 * r["x"] for r in rows)


def test_distinct(db):
    t = db.query().select("s").distinct().to_table()
    legacy = db.read(columns=["s"]).to_pylist()
    seen, want = set(), []
    for r in legacy:
        if r["s"] not in seen:
            seen.add(r["s"])
            want.append(r["s"])
    assert t["s"].to_pylist() == want  # first occurrence, order kept


@pytest.mark.parametrize("desc", [False, True])
def test_order_by_stable_and_nulls_last(db, desc):
    t = db.query().select("opt", "x").order_by("opt", desc=desc).to_table()
    vals = t["opt"].to_pylist()
    non_null = [v for v in vals if v is not None]
    assert non_null == sorted(non_null, reverse=desc)
    assert vals[len(non_null):] == [None] * (len(vals) - len(non_null))
    # stable: ties keep scan (id) order
    xs = t["x"].to_pylist()
    by_val = {}
    for v, x in zip(vals, xs):
        by_val.setdefault(v, []).append(x)
    for v, group in by_val.items():
        assert group == sorted(group), f"ties for {v!r} reordered"


def test_order_with_limit_matches_full_sort(db):
    full = db.query().select("y", "x").order_by("y").to_table()
    topk = db.query().select("y", "x").order_by("y").limit(17).offset(3) \
             .to_table()
    assert_tables_equal(topk, full.slice(3, 20))


@pytest.mark.parametrize("nt", THREADS)
def test_limit_offset_streaming(db, nt):
    cfg = LoadConfig(num_threads=nt)
    full = db.read(load_config=cfg)
    got = db.query(load_config=cfg).limit(50).offset(25).to_table()
    assert_tables_equal(got, full.slice(25, 75))
    assert db.query(load_config=cfg).limit(0).to_table().num_rows == 0


def test_offset_past_end_is_empty(db):
    """Regression: offset beyond the result must not crash var-len slices."""
    n = db.read().num_rows
    for q in (db.query().select("s", "x").offset(n + 50),
              db.query().select("s").order_by("s").offset(n + 50),
              db.query().group_by("s").agg({"*": "count"}).offset(99)):
        t = q.to_table()
        assert t.num_rows == 0
    assert db.query().select("s").offset(n - 2).to_table().num_rows == 2


def test_multikey_group_codes_no_overflow(tmp_path):
    """Regression: many near-unique keys must not overflow the mixed-radix
    combination (int64 wrap silently corrupted key tuples)."""
    db = ParquetDB(os.path.join(str(tmp_path), "wide"))
    n = 5000
    rows = [{"a": i, "b": (i * 7919) % n, "c": (i * 104729) % n,
             "d": (i * 1299709) % n} for i in range(n)]
    db.create(rows)
    t = db.query().group_by("a", "b", "c", "d").agg({"*": "count"}) \
          .to_table()
    assert t.num_rows == n
    want = {(r["a"], r["b"], r["c"], r["d"]) for r in rows}
    got = {(r["a"], r["b"], r["c"], r["d"]) for r in t.to_pylist()}
    assert got == want
    assert all(r["count"] == 1 for r in t.to_pylist())


def test_agg_projection_consistent_between_paths(db):
    """A projection never hides physical columns from agg — with or
    without a limit (fast path vs materialized fallback)."""
    fast = db.query().select("s").agg({"x": ["min", "max"]})
    big = db.read().num_rows + 10
    slow = db.query().select("s").limit(big).agg({"x": ["min", "max"]})
    assert fast == slow
    # distinct() restricts the spec to the distinct output columns
    with pytest.raises(KeyError):
        db.query().select("s").distinct().agg({"x": "min"})


def test_dropped_computed_is_pruned(db):
    q = db.query().select(c=field("x") + 1).select("s")
    cp = q._compile()
    assert cp.computed == [] and "x" not in cp.scan_cols
    assert q.limit(3).to_table().column_names == ["s"]


def test_count(db):
    n_all = db.read().num_rows
    assert db.query().count() == n_all
    expr = field("x") < 500
    assert db.query().where(expr).count() == \
        db.read(filters=[expr]).num_rows
    assert db.query().limit(10).count() == 10
    assert db.query().offset(n_all - 3).count() == 3
    assert db.query().select("s").distinct().count() == 5


def test_to_pylist_and_iter_batches_terminal(db):
    q = db.query().where(field("x") < 130).select("x")
    assert q.to_pylist() == q.to_table().to_pylist()
    chunks = list(q.iter_batches(batch_size=7))
    assert all(c.num_rows <= 7 for c in chunks)
    assert sum(c.num_rows for c in chunks) == q.count()


# ---------------------------------------------------------------------------
# limit pushdown: early-terminating scans (fig7-style needle)
# ---------------------------------------------------------------------------
def test_limit_terminates_scan_early(db):
    cfg = LoadConfig(use_threads=False)  # deterministic decode counters
    full = db.query(load_config=cfg).select("x").explain(execute=True)
    lim = db.query(load_config=cfg).select("x").limit(10) \
            .explain(execute=True)
    assert lim.counters.rows_scanned < full.counters.rows_scanned / 2
    assert lim.counters.pages_scanned < full.counters.pages_scanned / 2
    # the planned read set is identical — only execution stopped early
    assert lim.counters.row_groups_total == full.counters.row_groups_total


def test_needle_limit_decodes_less_than_full_needle_scan(db):
    """fig7 shape: selective filter; limit(1) stops after the first hit."""
    cfg = LoadConfig(use_threads=False)
    expr = field("s") == "k2"  # matches in every row group
    full = db.query(load_config=cfg).where(expr).select("x") \
             .explain(execute=True)
    lim = db.query(load_config=cfg).where(expr).select("x").limit(1) \
            .explain(execute=True)
    assert lim.counters.rows_scanned < full.counters.rows_scanned
    assert lim.executed and str(lim)  # renders


# ---------------------------------------------------------------------------
# plan-build-time validation
# ---------------------------------------------------------------------------
def test_unknown_columns_raise_clear_keyerror(db):
    with pytest.raises(KeyError, match=r"typo.*schema columns"):
        db.read(columns=["typo"])
    with pytest.raises(KeyError, match=r"typo.*schema columns"):
        db.query().select("typo")
    with pytest.raises(KeyError, match=r"typo.*schema columns"):
        db.query().where(field("typo") > 1)
    with pytest.raises(KeyError, match=r"typo.*schema columns"):
        db.query().group_by("typo")
    with pytest.raises(KeyError, match="order_by"):
        db.query().select("x").order_by("y")
    with pytest.raises(KeyError):
        db.query().group_by("s").agg({"typo": "sum"})


def test_bool_columns_do_integer_arithmetic(tmp_path):
    db = ParquetDB(os.path.join(str(tmp_path), "b"))
    db.create([{"p": True, "q": False}, {"p": True, "q": True}])
    t = db.query().select(s=field("p") + field("q"),
                          d=field("p") - field("q"),
                          m=field("p") * 3).to_table()
    rows = t.to_pylist()
    assert [r["s"] for r in rows] == [1, 2]
    assert [r["d"] for r in rows] == [1, 0]
    assert [r["m"] for r in rows] == [3, 3]


def test_where_select_distinct_rejected_after_window(db):
    with pytest.raises(ValueError, match="before order_by"):
        db.query().limit(3).where(field("x") > 5)
    with pytest.raises(ValueError, match="before order_by"):
        db.query().order_by("x").select("x")
    with pytest.raises(ValueError, match="before order_by"):
        db.query().offset(1).distinct()


def test_grouped_count_star_scans_id_column(db):
    cp = (db.query().group_by().agg({"*": "count"}))._compile()
    assert cp.scan_cols == ["id"]


def test_builder_shape_errors(db):
    with pytest.raises(ValueError, match="precede"):
        db.query().group_by("s").agg({"*": "count"}).where(field("x") > 1)
    with pytest.raises(ValueError, match="precede"):
        db.query().group_by("s").agg({"*": "count"}).select("s")
    with pytest.raises(ValueError, match="before"):
        db.query().limit(3).group_by("s")
    with pytest.raises(TypeError, match="value expression"):
        db.query().select(bad=field("x") > 1)  # predicate, not value
    with pytest.raises(TypeError, match="Expr"):
        db.query().where("x > 1")
    with pytest.raises(ValueError):
        db.query().limit(-1)


def test_query_is_immutable(db):
    q = db.query().where(field("x") < 100)
    q2 = q.select("x")
    q3 = q.limit(1)
    assert q._select is None and q._limit is None
    assert q2._select == ["x"] and q3._limit == 1
    assert q.count() == db.read(filters=[field("x") < 100]).num_rows


# ---------------------------------------------------------------------------
# SQL-ish Expr reprs (used by ScanReport / Query.explain)
# ---------------------------------------------------------------------------
def test_expr_reprs_are_sqlish():
    e = (field("a") > 1) & ((field("b") == "x") | ~field("c").is_null())
    assert repr(e) == \
        "((a > 1) AND ((b == 'x') OR (NOT (c IS NULL))))"
    assert repr(IsNull("c", negate=True)) == "(c IS NOT NULL)"
    assert repr(IsIn("k", [1, 2])) == "(k IN (1, 2))"
    assert repr(field("x") * 2 + 1) == "((x * 2) + 1)"
    assert isinstance(field("x") + field("y"), Arith)


def test_explain_tree_structure(db):
    rep = (db.query().where(field("x") > 10).select("x", d=field("x") * 2)
             .order_by("x").limit(5).explain())
    ops = [o for o, _ in rep.ops]
    assert ops == ["Limit", "OrderBy", "Project", "Filter"]
    s = str(rep)
    assert "ScanPlan" in s and "Limit" in s
    d = rep.to_dict()
    assert d["executed"] is False and "scan" in d
    grep = db.query().group_by("s").agg({"x": "mean"}).explain()
    assert "Aggregate" in [o for o, _ in grep.ops]


# ---------------------------------------------------------------------------
# Dataset.iter_batches matrix (satellite): batch_size x deltas x threads
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("nt", THREADS)
@pytest.mark.parametrize("batch_size", [1, 64, 100, 333, 10_000])
def test_dataset_iter_batches_matrix(db, nt, batch_size):
    cfg = LoadConfig(num_threads=nt)
    ds = db.read(columns=["x", "opt"], filters=[field("x") >= 0],
                 load_format="dataset", load_config=cfg)
    want = ds.to_table()
    batches = list(ds.iter_batches(batch_size=batch_size))
    assert all(b.num_rows <= batch_size for b in batches)
    # exact batch boundaries except the tail
    assert all(b.num_rows == batch_size for b in batches[:-1])
    got_x = [v for b in batches for v in b["x"].to_pylist()]
    # no duplicate/lost rows at morsel boundaries, order preserved
    assert got_x == want["x"].to_pylist()
    got_opt = [v for b in batches for v in b["opt"].to_pylist()]
    assert got_opt == want["opt"].to_pylist()


@pytest.mark.parametrize("nt", [None, 2])
def test_dataset_iter_batches_across_morsel_boundaries(tmp_path, nt,
                                                       monkeypatch):
    """Tiny forced morsels: batches must tile the scan exactly."""
    monkeypatch.setattr(scan_mod, "MORSEL_ROWS", 150)
    db = _mkdb(os.path.join(str(tmp_path), "m"), deltas=True)
    cfg = LoadConfig(num_threads=nt)
    ds = db.read(load_format="dataset", load_config=cfg)
    want = db.read(load_config=LoadConfig(num_threads=1))
    for bs in (37, 256):
        ids = [v for b in ds.iter_batches(batch_size=bs)
               for v in b["id"].to_pylist()]
        assert ids == want["id"].to_pylist()
        assert len(ids) == len(set(ids))


def test_query_iter_batches_with_limit_stops_early(db):
    cfg = LoadConfig(use_threads=False)
    q = db.query(load_config=cfg).select("x").limit(30)
    batches = list(q.iter_batches(batch_size=8))
    assert sum(b.num_rows for b in batches) == 30
    full = db.query(load_config=cfg).select("x").to_table()
    got = [v for b in batches for v in b["x"].to_pylist()]
    assert got == full["x"].to_pylist()[:30]
