"""§Perf optimization flags must not change numerics (only shardings/dtypes
of intermediates).  Single-device: constraints are no-ops, dtype flags are
exercised for correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import AttnCfg, Model, ModelConfig

BASE = ModelConfig("tiny", "dense", 2, 64, 128, 128,
                   attn=AttnCfg(4, 2, 16), remat=False)
RNG = np.random.default_rng(7)


def _loss(cfg, params, batch):
    return float(Model(cfg).loss(params, batch)[0])


@pytest.fixture
def setup():
    model = Model(BASE)
    params = model.init(jax.random.key(0))
    batch = {"tokens": jnp.asarray(RNG.integers(0, 128, (2, 32)), jnp.int32)}
    return params, batch


def test_scores_bf16_close_to_baseline(setup):
    params, batch = setup
    base = _loss(BASE, params, batch)
    opt = _loss(dataclasses.replace(BASE, attn_scores_bf16=True), params, batch)
    assert abs(base - opt) < 0.05, (base, opt)


def test_rmsnorm_bf16_close_to_baseline(setup):
    params, batch = setup
    base = _loss(BASE, params, batch)
    opt = _loss(dataclasses.replace(BASE, rmsnorm_bf16=True), params, batch)
    assert abs(base - opt) < 0.05, (base, opt)


def test_shard_flags_noop_without_mesh(setup):
    params, batch = setup
    base = _loss(BASE, params, batch)
    opt = _loss(dataclasses.replace(BASE, shard_activations=True,
                                    attn_batch_shard=True), params, batch)
    assert base == opt  # exact: constraints are identity without a mesh


def test_all_flags_decode_parity(setup):
    params, batch = setup
    cfg2 = dataclasses.replace(BASE, attn_scores_bf16=True, rmsnorm_bf16=True,
                               shard_activations=True)
    m1, m2 = Model(BASE), Model(cfg2)
    _, c1 = m1.prefill(params, batch, cache_len=40)
    _, c2 = m2.prefill(params, batch, cache_len=40)
    l1, _ = m1.decode_step(params, c1, batch["tokens"][:, :1], jnp.int32(32))
    l2, _ = m2.decode_step(params, c2, batch["tokens"][:, :1], jnp.int32(32))
    a = np.asarray(l1, np.float32)
    b = np.asarray(l2, np.float32)
    assert (a.argmax(-1) == b.argmax(-1)).all()
    np.testing.assert_allclose(a, b, rtol=0.1, atol=0.2)
