"""Encoding/codec roundtrips — including hypothesis property tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import encodings as enc

RNG = np.random.default_rng(0)

CASES = [
    ("plain", np.arange(100, dtype=np.int64)),
    ("plain", RNG.standard_normal(333).astype(np.float32)),
    ("dict", np.repeat(np.array([7, -3, 10**12], np.int64), 50)),
    ("dict", RNG.integers(0, 4, 1000).astype(np.int32)),
    ("rle", np.repeat(np.arange(10, dtype=np.int64), 100)),
    ("bitpack", RNG.integers(-50, 1000, 777).astype(np.int64)),
    ("bitpack", RNG.integers(0, 2, 64).astype(bool)),
    ("delta", np.cumsum(RNG.integers(-3, 9, 500)).astype(np.int64)),
    ("delta", np.arange(0, 10**7, 1000, dtype=np.int64)),
    ("bss", RNG.standard_normal(256).astype(np.float64)),
    ("bss", RNG.standard_normal(100).astype(np.float16)),
]


@pytest.mark.parametrize("encoding,arr", CASES,
                         ids=[f"{e}-{a.dtype}-{len(a)}" for e, a in CASES])
def test_roundtrip(encoding, arr):
    chosen, meta, payload = enc.encode(arr, encoding)
    out = enc.decode(chosen, meta, payload, len(arr), arr.dtype)
    np.testing.assert_array_equal(out, arr)


@pytest.mark.parametrize("encoding", ["plain", "dict", "rle", "bitpack", "delta"])
def test_empty(encoding):
    arr = np.empty(0, np.int64)
    chosen, meta, payload = enc.encode(arr, encoding)
    out = enc.decode(chosen, meta, payload, 0, np.int64)
    assert len(out) == 0


@pytest.mark.parametrize("codec", ["none", "zlib", "lzma"])
def test_codecs(codec):
    data = bytes(range(256)) * 40
    assert enc.decompress(enc.compress(data, codec), codec) == data


def test_auto_picks_sane_encodings():
    assert enc.choose_encoding(np.zeros(1000, np.int64)) in ("bitpack", "dict", "rle", "delta")
    assert enc.choose_encoding(RNG.standard_normal(100)) == "bss"
    assert enc.choose_encoding(np.ones(10, bool)) == "bitpack"


def test_bitpack_saves_space():
    arr = RNG.integers(0, 16, 10000).astype(np.int64)
    _, _, payload = enc.encode(arr, "bitpack")
    assert len(payload) <= 10000 * 4 // 8 + 16  # 4 bits/value


@given(st.lists(st.integers(min_value=-2**62, max_value=2**62), max_size=300),
       st.sampled_from(["plain", "dict", "bitpack", "delta", "rle", "auto"]))
@settings(max_examples=60, deadline=None)
def test_property_int_roundtrip(xs, encoding):
    arr = np.array(xs, np.int64)
    if encoding == "delta" and len(arr) == 0:
        encoding = "plain"
    chosen, meta, payload = enc.encode(arr, encoding)
    out = enc.decode(chosen, meta, payload, len(arr), np.int64)
    np.testing.assert_array_equal(out, arr)


@given(st.lists(st.floats(allow_nan=False, allow_infinity=False,
                          width=32), max_size=200),
       st.sampled_from(["plain", "bss", "auto"]))
@settings(max_examples=40, deadline=None)
def test_property_float_roundtrip(xs, encoding):
    arr = np.array(xs, np.float32)
    chosen, meta, payload = enc.encode(arr, encoding)
    out = enc.decode(chosen, meta, payload, len(arr), np.float32)
    np.testing.assert_array_equal(out, arr)


@given(st.integers(min_value=0, max_value=64),
       st.lists(st.integers(min_value=0), min_size=1, max_size=100))
@settings(max_examples=60, deadline=None)
def test_property_pack_bits(k, vals):
    vals = [v % (2**k if k else 1) for v in vals]
    arr = np.array(vals, np.uint64)
    buf = enc.pack_bits(arr, k)
    out = enc.unpack_bits(buf, len(arr), k)
    np.testing.assert_array_equal(out, arr)


def test_zigzag_involution():
    v = np.array([-2**62, -1, 0, 1, 2**62], np.int64)
    np.testing.assert_array_equal(enc.unzigzag(enc.zigzag(v)), v)
