"""Pallas kernel validation: sweep shapes/dtypes, assert_allclose vs ref.py
oracles and vs the numpy codecs (interpret=True executes kernel bodies on CPU).
"""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import encodings as enc
from repro.kernels import ops, ref
from repro.kernels.bitunpack import bitunpack
from repro.kernels.bss_decode import bss_decode
from repro.kernels.delta_decode import delta_decode
from repro.kernels.dict_decode import dict_decode
from repro.kernels.filter_kernel import filter_range
from repro.kernels.stats_kernel import page_minmax

RNG = np.random.default_rng(42)


def _packed_words(vals, k):
    buf = enc.pack_bits(vals.astype(np.uint64), k)
    pad = (-len(buf)) % 4
    return jnp.asarray(np.frombuffer(buf + b"\0" * pad, np.uint32))


class TestBitunpack:
    @pytest.mark.parametrize("k", [1, 2, 3, 5, 8, 11, 13, 16, 17, 24, 31, 32])
    @pytest.mark.parametrize("n", [1, 7, 1024, 1025, 5000])
    def test_sweep_vs_oracle(self, k, n):
        hi = 2**k if k < 32 else 2**31
        vals = RNG.integers(0, hi, n).astype(np.uint64)
        words = _packed_words(vals, k)
        out = bitunpack(words, n, k)
        oracle = ref.bitunpack(words, n, k)
        np.testing.assert_array_equal(
            np.asarray(out).astype(np.uint32), vals.astype(np.uint32))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))

    def test_k0(self):
        assert bitunpack(jnp.zeros(0, jnp.uint32), 5, 0).tolist() == [0] * 5


class TestDictDecode:
    @pytest.mark.parametrize("d", [1, 2, 37, 1000])
    @pytest.mark.parametrize("dtype", [np.int32, np.float32])
    def test_sweep(self, d, dtype):
        dictionary = (RNG.standard_normal(d) * 100).astype(dtype)
        idx = RNG.integers(0, d, 777).astype(np.int32)
        out = dict_decode(jnp.asarray(idx), jnp.asarray(dictionary))
        oracle = ref.dict_decode(jnp.asarray(idx), jnp.asarray(dictionary))
        np.testing.assert_allclose(np.asarray(out), dictionary[idx], rtol=1e-6)
        np.testing.assert_allclose(np.asarray(out), np.asarray(oracle), rtol=1e-6)

    def test_large_dict_falls_back_to_gather(self):
        dictionary = np.arange(10_000, dtype=np.int32)
        idx = RNG.integers(0, 10_000, 100).astype(np.int32)
        out = dict_decode(jnp.asarray(idx), jnp.asarray(dictionary))
        np.testing.assert_array_equal(np.asarray(out), dictionary[idx])


class TestDeltaDecode:
    @pytest.mark.parametrize("n", [1, 2, 100, 2048, 2049, 9999])
    def test_sweep_vs_numpy_codec(self, n):
        arr = np.cumsum(RNG.integers(-100, 101, n)).astype(np.int64)
        arr = np.clip(arr, -2**30, 2**30)  # int32 range on device
        chosen, meta, payload = enc.encode(arr, "delta")
        out = ops.decode_on_device(chosen, meta, payload, n, np.int32)
        np.testing.assert_array_equal(np.asarray(out), arr.astype(np.int32))

    def test_carry_across_blocks(self):
        # block boundary at 2048: the SMEM carry must thread through
        n = 4096 + 7
        arr = np.arange(n, dtype=np.int64) * 3 + 11
        chosen, meta, payload = enc.encode(arr, "delta")
        out = ops.decode_on_device(chosen, meta, payload, n, np.int32)
        np.testing.assert_array_equal(np.asarray(out), arr.astype(np.int32))

    def test_vs_oracle(self):
        zz = jnp.asarray(RNG.integers(0, 50, 3000).astype(np.uint32))
        first = jnp.int32(-17)
        np.testing.assert_array_equal(
            np.asarray(delta_decode(zz, first)),
            np.asarray(ref.delta_decode(zz, first)))


class TestBssDecode:
    @pytest.mark.parametrize("n", [1, 100, 2048, 4097])
    def test_sweep(self, n):
        arr = RNG.standard_normal(n).astype(np.float32)
        _, meta, payload = enc.encode(arr, "bss")
        planes = jnp.asarray(np.frombuffer(payload, np.uint8).reshape(4, n))
        out = bss_decode(planes)
        oracle = ref.bss_decode(planes)
        np.testing.assert_array_equal(np.asarray(out), arr)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))

    def test_specials(self):
        arr = np.array([0.0, -0.0, np.inf, -np.inf, 1e-38, 3.4e38], np.float32)
        _, meta, payload = enc.encode(arr, "bss")
        planes = jnp.asarray(np.frombuffer(payload, np.uint8).reshape(4, len(arr)))
        np.testing.assert_array_equal(np.asarray(bss_decode(planes)), arr)


class TestFilterKernel:
    @pytest.mark.parametrize("dtype", [np.int32, np.float32])
    @pytest.mark.parametrize("n", [5, 2048, 6000])
    def test_sweep(self, dtype, n):
        x = (RNG.standard_normal(n) * 100).astype(dtype)
        mask, counts = filter_range(jnp.asarray(x), -50, 50)
        oracle = np.asarray(ref.filter_range(jnp.asarray(x), dtype(-50), dtype(50)))
        np.testing.assert_array_equal(np.asarray(mask), oracle)
        assert int(counts.sum()) == int(oracle.sum())

    def test_empty_range(self):
        x = jnp.arange(100, dtype=jnp.int32)
        mask, counts = filter_range(x, 1000, 2000)
        assert int(counts.sum()) == 0 and not bool(mask.any())


class TestStatsKernel:
    @pytest.mark.parametrize("page", [128, 1024])
    @pytest.mark.parametrize("dtype", [np.int32, np.float32])
    def test_sweep(self, page, dtype):
        n = page * 7 + 13
        x = (RNG.standard_normal(n) * 1000).astype(dtype)
        mins, maxs = page_minmax(jnp.asarray(x), page)
        # compare on the full pages; ragged tail is padded with x[-1]
        xr = np.concatenate([x, np.full(page * 8 - n, x[-1], dtype)]).reshape(8, page)
        np.testing.assert_array_equal(np.asarray(mins), xr.min(1))
        np.testing.assert_array_equal(np.asarray(maxs), xr.max(1))

    def test_vs_oracle_exact_pages(self):
        x = jnp.asarray(RNG.standard_normal(4096).astype(np.float32))
        mins, maxs = page_minmax(x, 512)
        omin, omax = ref.page_minmax(x, 512)
        np.testing.assert_array_equal(np.asarray(mins), np.asarray(omin))
        np.testing.assert_array_equal(np.asarray(maxs), np.asarray(omax))


@given(st.integers(1, 31), st.integers(1, 400))
@settings(max_examples=30, deadline=None)
def test_property_bitunpack_any_k_n(k, n):
    vals = RNG.integers(0, 2**k, n).astype(np.uint64)
    out = bitunpack(_packed_words(vals, k), n, k)
    np.testing.assert_array_equal(np.asarray(out).astype(np.uint64), vals)


@given(st.lists(st.integers(-2**20, 2**20), min_size=1, max_size=300))
@settings(max_examples=30, deadline=None)
def test_property_delta_device_matches_host(xs):
    arr = np.array(xs, np.int64)
    chosen, meta, payload = enc.encode(arr, "delta")
    host = enc.decode(chosen, meta, payload, len(arr), np.int64)
    dev = ops.decode_on_device(chosen, meta, payload, len(arr), np.int32)
    np.testing.assert_array_equal(np.asarray(dev), host.astype(np.int32))


def test_end_to_end_page_decode_matches_host():
    """Write a TPQ page, decode the same buffers on 'device', compare."""
    for encoding in ("bitpack", "dict", "delta", "bss"):
        if encoding == "bss":
            arr = RNG.standard_normal(3000).astype(np.float32)
        else:
            arr = np.sort(RNG.integers(0, 2**20, 3000)).astype(np.int64)
        chosen, meta, payload = enc.encode(arr, encoding)
        host = enc.decode(chosen, meta, payload, len(arr), arr.dtype)
        dt = np.float32 if encoding == "bss" else (
            np.int64 if encoding == "dict" else np.int32)
        dev = np.asarray(ops.decode_on_device(chosen, meta, payload, len(arr), dt))
        np.testing.assert_array_equal(dev.astype(arr.dtype), host)
