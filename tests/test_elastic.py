"""Elastic scaling: a checkpoint written on one mesh restores onto a
different mesh (different DP/TP split) with bit-identical parameters and an
identical next loss — run in a subprocess with 8 host devices."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys, tempfile
    import jax, jax.numpy as jnp, numpy as np
    sys.path.insert(0, {src!r})
    from repro.models import Model, ModelConfig, AttnCfg
    from repro.launch.mesh import make_mesh
    from repro.train.checkpoint import CheckpointStore
    from repro.train.trainer import restore_elastic
    from repro.distributed import sharding as shd

    cfg = ModelConfig("t", "dense", 2, 64, 128, 256,
                      attn=AttnCfg(4, 2, 16), remat=False)
    model = Model(cfg)
    batch = {{"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, 256, (8, 32)), jnp.int32)}}

    tmp = tempfile.mkdtemp()
    store = CheckpointStore(tmp)

    # train mesh A = (2 data, 4 model): init, one loss, save
    mesh_a = make_mesh((2, 4), ("data", "model"))
    shard_a = shd.tree_shardings(model.init_abstract(), model.params_axes(),
                                 mesh_a)
    params_a = jax.device_put(model.init(jax.random.key(0)), shard_a)
    loss_a = float(model.loss(params_a, batch, mesh=mesh_a)[0])
    store.save(1, {{"params": params_a}})

    # restore onto mesh B = (4 data, 2 model) — different DP/TP split
    mesh_b = make_mesh((4, 2), ("data", "model"))
    params_b, shard_b = restore_elastic(store, model, mesh_b)
    loss_b = float(model.loss(params_b, batch, mesh=mesh_b)[0])

    # and onto a single device
    mesh_c = make_mesh((1, 1), ("data", "model"))
    params_c, _ = restore_elastic(store, model, mesh_c)
    loss_c = float(model.loss(params_c, batch)[0])

    identical = all(jax.tree.leaves(jax.tree.map(
        lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
        params_a, params_b)))
    print("RESULT " + json.dumps(
        {{"loss_a": loss_a, "loss_b": loss_b, "loss_c": loss_c,
          "identical": identical}}))
""")


@pytest.mark.slow
def test_elastic_reshard_across_meshes():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT.format(src=src)],
                          env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    res = json.loads(line[len("RESULT "):])
    assert res["identical"]
    assert abs(res["loss_a"] - res["loss_b"]) < 1e-4, res
    assert abs(res["loss_a"] - res["loss_c"]) < 1e-4, res
