"""Scan planner: pruning counters, explain(), and the pruned==unpruned oracle.

These tests construct datasets with *known* min/max ranges per file and per
row group, so exact skip counts can be asserted — and check end to end that
pruning never changes results (soundness: a planned scan is row-identical
to a full scan).
"""
import os

import numpy as np
import pytest

from repro.core import (LoadConfig, ParquetDB, ScanPlan, Table, field)
from repro.core.scan import file_may_match, rechunk
from repro.core.store import _get_reader


@pytest.fixture()
def ranged_db(tmp_path):
    """4 files, 100 rows each, x in [0,100), [100,200), [200,300), [300,400)."""
    db = ParquetDB(os.path.join(str(tmp_path), "ranged"))
    for lo in (0, 100, 200, 300):
        db.create([{"x": lo + i, "y": f"s{lo + i}"} for i in range(100)])
    return db


@pytest.fixture()
def grouped_db(tmp_path):
    """1 file, 4 row groups of 100 sorted rows (row_group_rows=100)."""
    db = ParquetDB(os.path.join(str(tmp_path), "grouped"),
                   row_group_rows=100, page_rows=50)
    db.create([{"x": i} for i in range(400)])
    return db


class TestExplainCounters:
    def test_impossible_predicate_scans_nothing(self, ranged_db):
        rep = ranged_db.explain(filters=[field("x") > 10**9])
        assert rep.counters.files_scanned == 0
        assert rep.counters.files_skipped == 4
        assert rep.counters.row_groups_scanned == 0
        assert rep.counters.bytes_selected == 0
        # executing it decodes nothing and returns nothing
        rep = ranged_db.explain(filters=[field("x") > 10**9], execute=True)
        assert rep.counters.pages_scanned == 0
        assert rep.counters.bytes_decoded == 0
        assert rep.counters.rows_matched == 0
        assert ranged_db.read(filters=[field("x") > 10**9]).num_rows == 0

    def test_exact_file_skip_counts(self, ranged_db):
        rep = ranged_db.explain(filters=[field("x") == 150])
        assert rep.counters.files_total == 4
        assert rep.counters.files_scanned == 1
        assert rep.counters.files_skipped == 3
        assert [f.pruned for f in rep.fragments] == [True, False, True, True]

    def test_range_predicate_spans_two_files(self, ranged_db):
        rep = ranged_db.explain(filters=[(field("x") >= 150) &
                                         (field("x") < 250)])
        assert rep.counters.files_scanned == 2
        assert rep.counters.files_skipped == 2

    def test_row_group_skip_counts(self, grouped_db):
        rep = grouped_db.explain(filters=[field("x") == 250])
        assert rep.counters.files_total == 1
        assert rep.counters.row_groups_total == 4
        assert rep.counters.row_groups_scanned == 1
        assert rep.counters.row_groups_skipped == 3
        assert rep.fragments[0].row_groups == [2]

    def test_executed_counters_match_result(self, grouped_db):
        expr = field("x") >= 390
        rep = grouped_db.explain(filters=[expr], execute=True)
        assert rep.executed
        assert rep.counters.rows_matched == 10
        assert rep.counters.row_groups_scanned == 1
        # page pruning inside the surviving row group (page_rows=50)
        assert rep.counters.pages_scanned == 1
        assert rep.counters.pages_skipped >= 1
        assert 0 < rep.counters.bytes_decoded <= rep.counters.bytes_selected

    def test_bloom_prunes_value_inside_minmax(self, tmp_path):
        # even values only: an odd probe lies inside [min, max] but the
        # bloom fingerprint proves absence
        db = ParquetDB(os.path.join(str(tmp_path), "bloom"))
        db.create([{"x": 2 * i} for i in range(100)])  # 0..198 even
        rep = db.explain(filters=[field("x").isin([51])])
        assert rep.counters.files_scanned == 0
        assert db.read(filters=[field("x").isin([51])]).num_rows == 0
        # present value is found
        rep = db.explain(filters=[field("x").isin([50])])
        assert rep.counters.files_scanned == 1
        assert db.read(filters=[field("x").isin([50])]).num_rows == 1

    def test_no_filter_scans_everything(self, ranged_db):
        rep = ranged_db.explain()
        assert rep.counters.files_scanned == 4
        assert rep.counters.files_skipped == 0
        assert rep.filter is None

    def test_projection_shrinks_selected_bytes(self, ranged_db):
        full = ranged_db.explain()
        proj = ranged_db.explain(columns=["x"])
        assert proj.counters.bytes_selected < full.counters.bytes_selected
        assert proj.columns == ["x"]

    def test_report_str_and_dict(self, ranged_db):
        rep = ranged_db.explain(filters=[field("x") == 150])
        s = str(rep)
        assert "1 scanned, 3 pruned (of 4)" in s
        d = rep.to_dict()
        assert d["counters"]["files_skipped"] == 3
        assert len(d["fragments"]) == 4

    def test_dataset_explain(self, ranged_db):
        ds = ranged_db.read(load_format="dataset",
                            filters=[field("x") == 150])
        rep = ds.explain()
        assert rep.counters.files_skipped == 3
        assert ds.to_table().num_rows == 1


class TestOracle:
    """Pruned reads must be row-identical to unpruned reads."""

    EXPRS = [
        field("x") == 150,
        field("x") != 150,
        (field("x") >= 37) & (field("x") < 251),
        (field("x") < 10) | (field("x") > 390),
        ~(field("x") == 150),
        ~((field("x") >= 100) & (field("x") < 300)),
        field("x").isin([0, 150, 399, 12345]),
        field("y") == "s150",
    ]

    @pytest.mark.parametrize("expr", EXPRS, ids=[repr(e) for e in EXPRS])
    def test_pruned_equals_unpruned(self, ranged_db, expr):
        pruned = ranged_db.read(filters=[expr])
        # oracle 1: in-memory filter of a full scan
        full = ranged_db.read()
        oracle = full.filter_mask(expr.evaluate(full))
        assert pruned.to_pylist() == oracle.to_pylist()
        # oracle 2: the planner itself with pruning disabled
        names = ranged_db._resolve_columns(None, True)
        plan = ranged_db._scan_plan(names, expr, LoadConfig(), prune=False)
        unpruned = [t for t in plan.execute()]
        rows = [r for t in unpruned for r in t.to_pylist()]
        assert pruned.to_pylist() == rows
        assert plan.last_counters.row_groups_skipped == 0

    def test_oracle_across_row_groups_and_pages(self, grouped_db):
        expr = (field("x") >= 123) & (field("x") <= 301)
        pruned = grouped_db.read(filters=[expr])
        full = grouped_db.read()
        oracle = full.filter_mask(expr.evaluate(full))
        assert pruned.to_pylist() == oracle.to_pylist()


class TestPlanMechanics:
    def test_schema_evolution_file_missing_filter_column(self, tmp_path):
        # first file lacks column z (schema evolved later, no eager
        # rewrite): no pushdown there, residual filter must still produce
        # correct rows (z null => no match)
        db = ParquetDB(os.path.join(str(tmp_path), "evo"),
                       eager_schema_align=False)
        db.create([{"x": 100 + i} for i in range(10)])
        db.create([{"x": i, "z": i} for i in range(10)])
        got = db.read(filters=[field("z") == 3])
        assert [r["x"] for r in got.to_pylist()] == [3]
        rep = db.explain(filters=[field("z") == 3])
        pushdowns = {f.file: f.pushdown for f in rep.fragments}
        assert sorted(pushdowns.values()) == [False, True]

    def test_rechunk_exact_batches(self, ranged_db):
        batches = list(ranged_db.read(load_format="batches", batch_size=64))
        assert [b.num_rows for b in batches] == [64] * 6 + [16]

    def test_file_may_match(self, ranged_db):
        man = ranged_db._dir.load()
        rd = _get_reader(ranged_db._dir.file_path(man.files[0]))  # x in [0,100)
        assert file_may_match(rd, field("x") == 50)
        assert not file_may_match(rd, field("x") == 500)
        # missing column => conservative True
        assert file_may_match(rd, field("nope") == 1)

    def test_update_rewrites_no_base_file(self, ranged_db):
        before = set(ranged_db._dir.load().files)
        n = ranged_db.update([{"id": 150, "y": "updated"}])
        assert n == 1
        man = ranged_db._dir.load()
        # merge-on-read: every base file survives; one upsert delta staged
        assert set(man.files) == before
        assert [d.kind for d in man.deltas] == ["upsert"]
        got = ranged_db.read(ids=[150], columns=["y"])
        assert got.to_pylist() == [{"y": "updated"}]

    def test_delete_rewrites_no_base_file(self, ranged_db):
        before = set(ranged_db._dir.load().files)
        n = ranged_db.delete(filters=[field("x") == 150])
        assert n == 1
        man = ranged_db._dir.load()
        assert set(man.files) == before
        assert [d.kind for d in man.deltas] == ["tombstone"]
        assert ranged_db.n_rows == 399

    def test_normalize_roundtrip_via_planner(self, ranged_db):
        before = ranged_db.read().to_pylist()
        ranged_db.normalize(max_rows_per_file=64, max_rows_per_group=32)
        assert ranged_db.n_files == (400 + 63) // 64
        assert ranged_db.read().to_pylist() == before

    def test_not_over_is_null_prunes_without_crashing(self, tmp_path):
        # regression: IsNull's negate flag must not shadow Expr.negate()
        db = ParquetDB(os.path.join(str(tmp_path), "notnull"))
        db.create([{"x": None if i % 2 else i} for i in range(10)])
        got = db.read(filters=[~field("x").is_null()])
        assert sorted(r["x"] for r in got.to_pylist()) == [0, 2, 4, 6, 8]
        got = db.read(filters=[~((field("x") == 0) & field("x").is_null())])
        assert got.num_rows == 10

    def test_not_equal_prune_keeps_nan_rows(self, tmp_path):
        # regression: float stats exclude NaN, but NaN rows match "!=" —
        # ~(f == v) over a min==max==v chunk must not prune the NaN row
        db = ParquetDB(os.path.join(str(tmp_path), "nan"))
        db.create({"f": np.array([1.0, np.nan])})
        got = db.read(filters=[~(field("f") == 1.0)])
        assert got.num_rows == 1 and np.isnan(got["f"].values[0])
        got = db.read(filters=[field("f") != 1.0])
        assert got.num_rows == 1 and np.isnan(got["f"].values[0])

    def test_not_ordering_prune_keeps_nan_rows(self, tmp_path):
        # regression: ~(x < v) matches NaN rows; the negation pushdown must
        # carry an IsNaN term because min/max stats cannot see NaN
        db = ParquetDB(os.path.join(str(tmp_path), "nanord"))
        db.create({"x": np.array([1.0, np.nan])})
        got = db.read(filters=[~(field("x") < 5.0)])
        assert got.num_rows == 1 and np.isnan(got["x"].values[0])

    def test_inf_rows_survive_range_pruning(self, tmp_path):
        # regression: float min/max must include ±inf or range predicates
        # prune chunks that contain matching inf rows
        db = ParquetDB(os.path.join(str(tmp_path), "inf"))
        db.create({"x": np.array([1.0, np.inf])})
        got = db.read(filters=[field("x") > 100.0])
        assert got.num_rows == 1 and np.isinf(got["x"].values[0])
        db2 = ParquetDB(os.path.join(str(tmp_path), "ninf"))
        db2.create({"x": np.array([-np.inf, 1.0])})
        assert db2.read(filters=[field("x") < -100.0]).num_rows == 1

    def test_long_string_keys_prune_soundly(self, tmp_path):
        # regression: string max stats are truncated to 64 chars — the
        # stored bound must still sort >= longer values sharing the prefix
        db = ParquetDB(os.path.join(str(tmp_path), "longstr"))
        long_key = "z" * 100
        db.create([{"k": "aaa", "v": 1}, {"k": long_key, "v": 2}])
        n = db.update([{"k": long_key, "v": 99}], update_keys="k")
        assert n == 1
        got = db.read(filters=[field("k") == long_key], columns=["v"])
        assert got.to_pylist() == [{"v": 99}]

    def test_update_with_many_float_keys_and_nan(self, tmp_path):
        # regression: a NaN key must not poison the >256-key range fallback
        db = ParquetDB(os.path.join(str(tmp_path), "nankeys"))
        db.create({"k": np.arange(300, dtype=np.float64),
                   "v": np.zeros(300)})
        keys = np.concatenate([np.arange(300, dtype=np.float64), [np.nan]])
        n = db.update({"k": keys, "v": np.ones(301)}, update_keys="k")
        assert n == 300
        assert db.read(columns=["v"])["v"].values.sum() == 300

    def test_not_prune_is_null_safe(self, tmp_path):
        # ~(z == 1) matches rows where z is null — negation pushdown must
        # not prune a file of all-null z
        db = ParquetDB(os.path.join(str(tmp_path), "nulls"))
        db.create([{"x": i, "z": None if i < 5 else 1} for i in range(10)])
        got = db.read(filters=[~(field("z") == 1)])
        assert sorted(r["x"] for r in got.to_pylist()) == [0, 1, 2, 3, 4]
