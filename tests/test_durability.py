"""ACID behaviour: crash injection, recovery, locks — beyond-paper durability."""
import os

import numpy as np
import pytest

from repro.core import ParquetDB, field
from repro.core import transactions as tx


class Crash(Exception):
    pass


@pytest.fixture
def db(tmp_path):
    return ParquetDB(str(tmp_path / "db"), "db")


def crash_next_commit():
    def hook():
        tx.PRE_COMMIT_HOOK = None
        raise Crash()
    tx.PRE_COMMIT_HOOK = hook


@pytest.fixture(autouse=True)
def _clean_hook():
    yield
    tx.PRE_COMMIT_HOOK = None


def test_crash_during_create_rolls_back(db, tmp_path):
    db.create([{"a": 1}])
    crash_next_commit()
    with pytest.raises(Crash):
        db.create([{"a": 2}])
    # reopen: uncommitted file garbage-collected, data intact
    db2 = ParquetDB(str(tmp_path / "db"), "db")
    assert db2.read(columns=["a"]).to_pydict()["a"] == [1]
    tpqs = [f for f in os.listdir(str(tmp_path / "db")) if f.endswith(".tpq")]
    assert len(tpqs) == db2.n_files


def test_crash_during_update_preserves_old_data(db, tmp_path):
    db.create([{"a": i} for i in range(100)])
    crash_next_commit()
    with pytest.raises(Crash):
        db.update([{"id": 5, "a": -1}])
    db2 = ParquetDB(str(tmp_path / "db"), "db")
    assert db2.read(ids=[5], columns=["a"]).to_pydict()["a"] == [5]


def test_crash_during_delete_preserves_rows(db, tmp_path):
    db.create([{"a": i} for i in range(10)])
    crash_next_commit()
    with pytest.raises(Crash):
        db.delete(filters=[field("a") < 5])
    db2 = ParquetDB(str(tmp_path / "db"), "db")
    assert db2.n_rows == 10


def test_crash_during_normalize(db, tmp_path):
    for _ in range(4):
        db.create({"x": np.arange(50)})
    crash_next_commit()
    with pytest.raises(Crash):
        db.normalize()
    db2 = ParquetDB(str(tmp_path / "db"), "db")
    assert db2.n_rows == 200 and db2.n_files == 4


def test_id_counter_survives_crash(db, tmp_path):
    db.create([{"a": 1}])  # id 0
    crash_next_commit()
    with pytest.raises(Crash):
        db.create([{"a": 2}])  # would be id 1, rolled back
    db2 = ParquetDB(str(tmp_path / "db"), "db")
    ids = db2.create([{"a": 3}])
    rows = db2.read().to_pylist()
    assert len({r["id"] for r in rows}) == len(rows)  # ids unique
    assert ids.tolist() == [1]


def test_write_lock_excludes_second_writer(db, tmp_path):
    db.create([{"a": 1}])
    lock = db._dir.acquire_lock()
    with lock:
        db2 = ParquetDB(str(tmp_path / "db"), "db")
        with pytest.raises(TimeoutError):
            with db2._dir.acquire_lock(timeout=0.1):
                pass

    # released: now fine
    db.create([{"a": 2}])
    assert db.n_rows == 2


def test_readers_unaffected_by_writer_lock(db):
    db.create([{"a": 1}])
    with db._dir.acquire_lock():
        assert db.read().num_rows == 1  # reads need no lock


def test_manifest_atomic_replace(tmp_path):
    p = str(tmp_path / "m.json")
    tx.atomic_write_json(p, {"x": 1})
    tx.atomic_write_json(p, {"x": 2})
    import json
    assert json.load(open(p)) == {"x": 2}
    assert not os.path.exists(p + ".tmp")
