"""ACID behaviour: crash injection, recovery, locks — beyond-paper durability."""
import os

import numpy as np
import pytest

from repro.core import ParquetDB, field
from repro.core import transactions as tx


class Crash(Exception):
    pass


@pytest.fixture
def db(tmp_path):
    return ParquetDB(str(tmp_path / "db"), "db")


def crash_next_commit():
    def hook():
        tx.PRE_COMMIT_HOOK = None
        raise Crash()
    tx.PRE_COMMIT_HOOK = hook


@pytest.fixture(autouse=True)
def _clean_hook():
    yield
    tx.PRE_COMMIT_HOOK = None


def test_crash_during_create_rolls_back(db, tmp_path):
    db.create([{"a": 1}])
    crash_next_commit()
    with pytest.raises(Crash):
        db.create([{"a": 2}])
    # reopen: uncommitted file garbage-collected, data intact
    db2 = ParquetDB(str(tmp_path / "db"), "db")
    assert db2.read(columns=["a"]).to_pydict()["a"] == [1]
    tpqs = [f for f in os.listdir(str(tmp_path / "db")) if f.endswith(".tpq")]
    assert len(tpqs) == db2.n_files


def test_crash_during_update_preserves_old_data(db, tmp_path):
    db.create([{"a": i} for i in range(100)])
    crash_next_commit()
    with pytest.raises(Crash):
        db.update([{"id": 5, "a": -1}])
    db2 = ParquetDB(str(tmp_path / "db"), "db")
    assert db2.read(ids=[5], columns=["a"]).to_pydict()["a"] == [5]


def test_crash_during_delete_preserves_rows(db, tmp_path):
    db.create([{"a": i} for i in range(10)])
    crash_next_commit()
    with pytest.raises(Crash):
        db.delete(filters=[field("a") < 5])
    db2 = ParquetDB(str(tmp_path / "db"), "db")
    assert db2.n_rows == 10


def test_crash_during_normalize(db, tmp_path):
    for _ in range(4):
        db.create({"x": np.arange(50)})
    crash_next_commit()
    with pytest.raises(Crash):
        db.normalize()
    db2 = ParquetDB(str(tmp_path / "db"), "db")
    assert db2.n_rows == 200 and db2.n_files == 4


def test_id_counter_survives_crash(db, tmp_path):
    db.create([{"a": 1}])  # id 0
    crash_next_commit()
    with pytest.raises(Crash):
        db.create([{"a": 2}])  # would be id 1, rolled back
    db2 = ParquetDB(str(tmp_path / "db"), "db")
    ids = db2.create([{"a": 3}])
    rows = db2.read().to_pylist()
    assert len({r["id"] for r in rows}) == len(rows)  # ids unique
    assert ids.tolist() == [1]


def test_write_lock_excludes_second_writer(db, tmp_path):
    db.create([{"a": 1}])
    lock = db._dir.acquire_lock()
    with lock:
        db2 = ParquetDB(str(tmp_path / "db"), "db")
        with pytest.raises(TimeoutError):
            with db2._dir.acquire_lock(timeout=0.1):
                pass

    # released: now fine
    db.create([{"a": 2}])
    assert db.n_rows == 2


def test_readers_unaffected_by_writer_lock(db):
    db.create([{"a": 1}])
    with db._dir.acquire_lock():
        assert db.read().num_rows == 1  # reads need no lock


class TestWriteLockDiagnostics:
    """Stale-lock handling: pid+timestamp in the lock file, dead-holder
    break, and loud timeouts naming the live holder."""

    def _lock_path(self, db):
        return os.path.join(db.db_path, tx.LOCKFILE)

    def test_lock_file_records_holder(self, db):
        import json
        import socket
        import time
        with db._dir.acquire_lock():
            with open(self._lock_path(db)) as fh:
                info = json.load(fh)
            assert info["pid"] == os.getpid()
            assert info["host"] == socket.gethostname()
            assert abs(info["ts"] - time.time()) < 30
        assert not os.path.exists(self._lock_path(db))

    def test_dead_holder_broken_immediately(self, db):
        import json
        import multiprocessing
        import socket
        import time
        db.create([{"a": 1}])
        p = multiprocessing.get_context("spawn").Process(target=int)
        p.start()
        p.join()  # p.pid is now certainly dead
        with open(self._lock_path(db), "w") as fh:
            json.dump({"pid": p.pid, "host": socket.gethostname(),
                       "ts": time.time()}, fh)
        t0 = time.time()
        with db._dir.acquire_lock(timeout=0):  # no sleeping out a timeout
            pass
        assert time.time() - t0 < 5.0
        db.create([{"a": 2}])  # and writes work again
        assert db.n_rows == 2

    def test_dead_holder_legacy_bare_pid_format(self, db):
        import multiprocessing
        p = multiprocessing.get_context("spawn").Process(target=int)
        p.start()
        p.join()
        with open(self._lock_path(db), "w") as fh:
            fh.write(str(p.pid))  # pre-log lock format
        with db._dir.acquire_lock(timeout=0):
            pass

    def test_live_holder_timeout0_fast_fails_naming_holder(self, db):
        from repro.core import WriteLockTimeout
        with db._dir.acquire_lock():
            with pytest.raises(WriteLockTimeout) as ei:
                with db._dir.acquire_lock(timeout=0):
                    pass
        msg = str(ei.value)
        assert f"held by pid {os.getpid()}" in msg
        assert "alive" in msg

    def test_timeout_diagnostic_is_a_timeout_error(self, db):
        # backward compat: callers catching TimeoutError still work
        from repro.core import WriteLockTimeout
        assert issubclass(WriteLockTimeout, TimeoutError)


class TestDeltaCrashes:
    """Crash points of the merge-on-read lifecycle (docs/TRANSACTIONS.md)."""

    def test_crash_during_delta_commit_update(self, tmp_path, monkeypatch):
        db = ParquetDB(str(tmp_path / "db"), "db", auto_compact=False)
        db.create([{"a": i} for i in range(20)])
        crash_next_commit()
        with pytest.raises(Crash):
            db.update([{"id": 3, "a": -3}])
        # previous generation intact; the staged upsert file is orphaned
        db2 = ParquetDB(str(tmp_path / "db"), "db", auto_compact=False)
        assert db2.n_delta_files == 0
        assert db2.read(ids=[3], columns=["a"]).to_pydict()["a"] == [3]
        # the orphan survives the first reopen: its writer (this pid) looks
        # alive and it is younger than the staging grace period...
        assert [f for f in os.listdir(str(tmp_path / "db"))
                if f.endswith(".upsert.tpq")]
        # ...but once aged out of the grace window it is GC'd on open
        monkeypatch.setenv("REPRO_STAGE_GC_SECONDS", "0")
        ParquetDB(str(tmp_path / "db"), "db", auto_compact=False)
        assert not [f for f in os.listdir(str(tmp_path / "db"))
                    if f.endswith(".upsert.tpq")]

    def test_crash_during_delta_commit_delete(self, tmp_path, monkeypatch):
        db = ParquetDB(str(tmp_path / "db"), "db", auto_compact=False)
        db.create([{"a": i} for i in range(10)])
        crash_next_commit()
        with pytest.raises(Crash):
            db.delete(ids=[4])
        db2 = ParquetDB(str(tmp_path / "db"), "db", auto_compact=False)
        assert db2.n_rows == 10 and db2.n_delta_files == 0
        monkeypatch.setenv("REPRO_STAGE_GC_SECONDS", "0")
        ParquetDB(str(tmp_path / "db"), "db", auto_compact=False)
        assert not [f for f in os.listdir(str(tmp_path / "db"))
                    if f.endswith(".tombstone.tpq")]

    def test_crash_mid_compaction_old_generation_readable(self, tmp_path):
        db = ParquetDB(str(tmp_path / "db"), "db", auto_compact=False)
        for lo in (0, 100):
            db.create([{"a": lo + i} for i in range(100)])
        db.update([{"id": 5, "a": -5}])
        db.delete(ids=[7])
        merged = db.read(columns=["a"]).to_pydict()["a"]
        crash_next_commit()
        with pytest.raises(Crash):
            db.compact()
        # the pre-compaction generation (base + delta chain) is fully
        # readable — both via the crashed handle and after reopen
        assert db.read(columns=["a"]).to_pydict()["a"] == merged
        db2 = ParquetDB(str(tmp_path / "db"), "db", auto_compact=False)
        assert db2.n_delta_files == 2
        assert db2.read(columns=["a"]).to_pydict()["a"] == merged
        # staged-but-uncommitted compaction output was GC'd on open
        tpqs = set(os.listdir(str(tmp_path / "db")))
        man = db2._dir.load()
        live = set(man.files) | {d.name for d in man.deltas}
        assert {f for f in tpqs if f.endswith(".tpq")} == live

    def test_crash_after_compaction_commit_keeps_new_generation(self, tmp_path):
        db = ParquetDB(str(tmp_path / "db"), "db", auto_compact=False)
        db.create([{"a": i} for i in range(50)])
        db.update([{"id": 2, "a": -2}])
        merged = db.read(columns=["a"]).to_pydict()["a"]
        res = db.compact()
        assert res.compacted
        # old generation lingers (snapshot grace); reopen GCs it and the
        # compacted state is the committed truth
        db2 = ParquetDB(str(tmp_path / "db"), "db", auto_compact=False)
        assert db2.n_delta_files == 0
        assert db2.read(columns=["a"]).to_pydict()["a"] == merged


def test_manifest_atomic_replace(tmp_path):
    p = str(tmp_path / "m.json")
    tx.atomic_write_json(p, {"x": 1})
    tx.atomic_write_json(p, {"x": 2})
    import json
    assert json.load(open(p)) == {"x": 2}
    assert not os.path.exists(p + ".tmp")
