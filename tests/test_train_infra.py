"""Training substrate: checkpoint store, trainer fault tolerance, data
pipeline, serve engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ParquetDB
from repro.launch.mesh import make_mesh
from repro.models import AttnCfg, Model, ModelConfig
from repro.serve.engine import ServeEngine
from repro.train import trainer as trn
from repro.train.checkpoint import CheckpointStore
from repro.train.optimizer import OptConfig, init_opt_state, apply_updates
from repro.data.tokenstore import TokenStore
from repro.data.sharded_loader import ShardedLoader, WorkQueue, device_feed

TINY = ModelConfig("tiny", "dense", 2, 64, 128, 256,
                   attn=AttnCfg(4, 2, 16), remat=False)


@pytest.fixture
def model():
    return Model(TINY)


@pytest.fixture
def params(model):
    return model.init(jax.random.key(0))


class TestCheckpoint:
    def test_roundtrip(self, tmp_path, params):
        st = CheckpointStore(str(tmp_path))
        st.save(5, {"params": params})
        back = st.restore(like={"params": jax.tree.map(jnp.zeros_like, params)})
        same = jax.tree.map(lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
                            {"params": params}, back)
        assert all(jax.tree.leaves(same))

    def test_partial_restore_projection(self, tmp_path, params):
        st = CheckpointStore(str(tmp_path))
        st.save(1, {"params": params})
        arrays = st.restore(1, paths=["params/final_norm"])
        assert list(arrays) == ["params/final_norm"]

    def test_latest_and_gc(self, tmp_path, params):
        st = CheckpointStore(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            st.save(s, {"p": jnp.zeros(3)})
        assert st.latest_step() == 4
        assert st.steps() == [3, 4]

    def test_schema_evolution_new_leaf_keeps_init(self, tmp_path):
        st = CheckpointStore(str(tmp_path))
        st.save(1, {"a": jnp.ones(4)})
        like = {"a": jnp.zeros(4), "b": jnp.full(2, 7.0)}   # 'b' added later
        back = st.restore(1, like=like)
        assert np.asarray(back["a"]).sum() == 4
        assert np.asarray(back["b"]).tolist() == [7.0, 7.0]

    def test_async_save(self, tmp_path, params):
        st = CheckpointStore(str(tmp_path))
        th = st.async_save(9, {"params": params})
        th.join()
        assert st.latest_step() == 9

    def test_elastic_restore_other_mesh(self, tmp_path, model, params):
        st = CheckpointStore(str(tmp_path))
        st.save(3, {"params": params})
        mesh = make_mesh((1, 1), ("data", "model"))
        from repro.train.trainer import restore_elastic
        restored, _ = restore_elastic(st, model, mesh)
        ok = jax.tree.map(lambda a, b: bool(np.allclose(np.asarray(a),
                                                        np.asarray(b))),
                          params, restored)
        assert all(jax.tree.leaves(ok))


class TestOptimizer:
    def test_adamw_moves_params(self, params):
        st = init_opt_state(params)
        g = jax.tree.map(jnp.ones_like, params)
        p2, st2, stats = apply_updates(params, g, st, OptConfig())
        assert int(st2["step"]) == 1
        assert float(stats["grad_norm"]) > 0
        diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                             params, p2)
        assert max(jax.tree.leaves(diffs)) > 0

    def test_clipping(self, params):
        st = init_opt_state(params)
        g = jax.tree.map(lambda p: jnp.full_like(p, 1e6), params)
        _, _, stats = apply_updates(params, g, st, OptConfig(clip_norm=1.0))
        assert float(stats["grad_norm"]) > 1.0  # reported pre-clip


class TestTrainerFaultTolerance:
    def _mk(self, tmp_path, model):
        mesh = make_mesh((1, 1), ("data", "model"))
        return trn.Trainer(model, mesh,
                           OptConfig(lr=1e-3, warmup_steps=2, total_steps=50),
                           ckpt_dir=str(tmp_path / "ckpt"),
                           metrics_dir=str(tmp_path / "metrics"),
                           ckpt_every=3)

    def _batches(self):
        rng = np.random.default_rng(0)
        while True:
            yield {"tokens": jnp.asarray(rng.integers(0, 256, (4, 32)),
                                         jnp.int32)}

    def test_recovers_from_injected_fault(self, tmp_path, model):
        t = self._mk(tmp_path, model)
        calls = {"n": 0}

        def fault(step):
            if step == 5 and calls["n"] == 0:
                calls["n"] += 1
                raise RuntimeError("simulated node failure")
        trn.FAULT_HOOK = fault
        try:
            res = t.run(self._batches(), steps=8)
        finally:
            trn.FAULT_HOOK = None
        assert res["steps"] == 8 and calls["n"] == 1

    def test_gives_up_after_max_retries(self, tmp_path, model):
        t = self._mk(tmp_path, model)
        t.max_retries = 1
        trn.FAULT_HOOK = lambda step: (_ for _ in ()).throw(
            RuntimeError("always fails"))
        try:
            with pytest.raises(RuntimeError):
                t.run(self._batches(), steps=3)
        finally:
            trn.FAULT_HOOK = None

    def test_restart_resumes_from_checkpoint(self, tmp_path, model):
        t = self._mk(tmp_path, model)
        t.run(self._batches(), steps=6)
        assert t.store.latest_step() == 6
        t2 = self._mk(tmp_path, model)
        res = t2.run(self._batches(), steps=9)   # resumes at 6
        assert res["steps"] == 9
        assert len(res["history"]) == 3

    def test_metrics_logged_to_columnar_store(self, tmp_path, model):
        t = self._mk(tmp_path, model)
        t.run(self._batches(), steps=4, log_every=1)
        db = ParquetDB(str(tmp_path / "metrics"), "metrics")
        rows = db.read(columns=["step", "loss"]).to_pydict()
        assert len(rows["step"]) == 4
        assert all(np.isfinite(rows["loss"]))


class TestDataPipeline:
    def test_tokenstore_pack_and_count(self, tmp_path):
        ts = TokenStore(str(tmp_path / "t"), seq_len=16, vocab=100)
        n = ts.append_documents([np.arange(40), np.arange(50)])
        assert n == (40 + 50) // 16
        assert ts.n_sequences == n

    def test_quality_filter_pushdown(self, tmp_path):
        ts = TokenStore(str(tmp_path / "t"), seq_len=8, vocab=100)
        rng = np.random.default_rng(0)
        ts.append_documents([rng.integers(0, 100, 800)],
                            quality=np.linspace(0, 1, 100))
        got = sum(b.shape[0] for b in ts.read_batches(
            4, min_quality=0.5, drop_remainder=False))
        assert 0 < got < 100

    def test_loader_ranks_partition_disjoint_complete(self, tmp_path):
        ts = TokenStore(str(tmp_path / "t"), seq_len=4, vocab=1000)
        rng = np.random.default_rng(1)
        ts.append_documents([rng.integers(0, 1000, 4 * 64)])
        seen = []
        for rank in range(4):
            ld = ShardedLoader(ts.db, batch_size=4, rank=rank, world=4,
                               steal=False, prefetch=1)
            for b in ld.epoch(0):
                seen.extend(map(tuple, b.tolist()))
        assert len(seen) == len(set(seen))  # disjoint

    def test_work_stealing_covers_all(self):
        wq = WorkQueue(list(range(20)), rank=0, world=4)
        got = []
        while True:
            i = wq.next()
            if i is None:
                break
            got.append(i)
        # rank 0 owns 5 items but steals the 15 others from the tail
        assert sorted(got) == list(range(20))

    def test_device_feed_roundtrip(self):
        tok = np.random.default_rng(0).integers(0, 50000, (2, 64)).astype(np.int32)
        out = device_feed(tok, 50000)
        np.testing.assert_array_equal(np.asarray(out), tok)


class TestServeEngine:
    def test_batched_requests_complete(self, model, params):
        eng = ServeEngine(model, params, slots=2, max_seq=64)
        rng = np.random.default_rng(0)
        for _ in range(5):
            eng.submit(rng.integers(0, 256, 4).astype(np.int32),
                       max_new_tokens=6)
        done = eng.run_to_completion()
        assert len(done) == 5
        assert all(len(r.out_tokens) == 6 for r in done)

    def test_batching_matches_single_request(self, model, params):
        prompt = np.array([5, 6, 7], np.int32)
        eng1 = ServeEngine(model, params, slots=1, max_seq=32)
        eng1.submit(prompt, max_new_tokens=5)
        ref = eng1.run_to_completion()[0].out_tokens

        eng2 = ServeEngine(model, params, slots=3, max_seq=32)
        rng = np.random.default_rng(1)
        eng2.submit(rng.integers(0, 256, 5).astype(np.int32), max_new_tokens=5)
        rid = eng2.submit(prompt, max_new_tokens=5)
        eng2.submit(rng.integers(0, 256, 2).astype(np.int32), max_new_tokens=5)
        done = {r.rid: r.out_tokens for r in eng2.run_to_completion()}
        assert done[rid] == ref

    def test_eos_stops_early(self, model, params):
        eng = ServeEngine(model, params, slots=1, max_seq=64)
        # run once to find the greedy first token, then use it as "eos"
        eng.submit(np.array([1, 2], np.int32), max_new_tokens=4)
        first = eng.run_to_completion()[0].out_tokens[0]
        eng2 = ServeEngine(model, params, slots=1, max_seq=64)
        eng2.submit(np.array([1, 2], np.int32), max_new_tokens=8, eos_id=first)
        out = eng2.run_to_completion()[0]
        assert len(out.out_tokens) == 1
