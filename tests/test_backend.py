"""Decode-backend dispatch: numpy reference vs jax (Pallas kernel) backend.

The jax backend must be *byte-identical* to the numpy reference on every
encoding — it routes a page to the device kernels only when the 32-bit
safety gate proves the decode exact, and falls back to numpy otherwise.
The sweep here covers both sides of that gate (values that route and
values that must fall back) plus whole-table reads through the store.
"""
import os

import numpy as np
import pytest

from repro.core import ParquetDB, Table, backend, field
from repro.core import encodings as enc

jax = pytest.importorskip("jax")

RNG = np.random.default_rng(11)


@pytest.fixture()
def jax_backend():
    be = backend.get_backend("jax")
    yield be
    backend.set_backend(None)


# (encoding, array) — mixes device-routable pages with gate-fallback pages
MATRIX = [
    ("plain", np.arange(500, dtype=np.int64)),
    ("plain", RNG.standard_normal(333).astype(np.float32)),
    ("bitpack", RNG.integers(0, 1_000, 2048).astype(np.int64)),
    ("bitpack", RNG.integers(-50, 50, 100).astype(np.int32)),
    ("bitpack", RNG.integers(0, 2, 64).astype(bool)),
    ("bitpack", np.array([2**40, 2**40 + 7], np.int64)),       # > int32: fallback
    ("dict", np.repeat(np.array([7, -3, 1000], np.int64), 50)),
    ("dict", np.repeat(np.array([10**12, -10**12], np.int64), 30)),  # fallback
    ("dict", np.repeat(RNG.standard_normal(4).astype(np.float32), 25)),
    ("dict", np.repeat(RNG.standard_normal(4), 25)),           # f64: fallback
    ("delta", np.cumsum(RNG.integers(-3, 9, 500)).astype(np.int64)),
    ("delta", np.arange(0, 10**7, 1000, dtype=np.int64)),
    ("delta", np.cumsum(RNG.integers(0, 2**40, 10)).astype(np.int64)),  # fallback
    ("rle", np.repeat(np.arange(10, dtype=np.int64), 100)),    # no kernel: fallback
    ("bss", RNG.standard_normal(256).astype(np.float32)),
    ("bss", RNG.standard_normal(256).astype(np.float64)),      # f64: fallback
]


@pytest.mark.parametrize("encoding,arr", MATRIX,
                         ids=[f"{e}-{a.dtype}-{i}"
                              for i, (e, a) in enumerate(MATRIX)])
def test_parity_full_encoding_matrix(jax_backend, encoding, arr):
    chosen, meta, payload = enc.encode(arr, encoding)
    ref = backend.get_backend("numpy").decode(
        chosen, meta, payload, len(arr), arr.dtype)
    dev = jax_backend.decode(chosen, meta, payload, len(arr), arr.dtype)
    assert dev.dtype == ref.dtype == arr.dtype
    np.testing.assert_array_equal(dev, ref)
    np.testing.assert_array_equal(dev, arr)


def test_parity_out_param(jax_backend):
    arr = RNG.integers(0, 100, 300).astype(np.int64)
    chosen, meta, payload = enc.encode(arr, "bitpack")
    out = np.empty(len(arr), np.int64)
    got = jax_backend.decode(chosen, meta, payload, len(arr), np.int64,
                             out=out)
    assert got is out
    np.testing.assert_array_equal(out, arr)


def test_range_mask_parity(jax_backend):
    vals = RNG.integers(-1000, 1000, 4096).astype(np.int64)
    ref = backend.get_backend("numpy").range_mask(vals, -10, 250)
    dev = jax_backend.range_mask(vals, -10, 250)
    np.testing.assert_array_equal(np.asarray(dev), ref)
    # out-of-float32-exact bounds must fall back, still correct
    big = vals.astype(np.int64) * 2**30
    ref = backend.get_backend("numpy").range_mask(big, -2**35, 2**35)
    dev = jax_backend.range_mask(big, -2**35, 2**35)
    np.testing.assert_array_equal(np.asarray(dev), ref)


def test_range_mask_wide_int64_values_not_truncated(jax_backend):
    # 2**32+50 truncates to 50 in 32-bit lanes: the gate must fall back
    # to numpy instead of wrongly matching the range
    vals = np.array([50, 2**32 + 50, 70], np.int64)
    ref = backend.get_backend("numpy").range_mask(vals, 0, 100)
    dev = jax_backend.range_mask(vals, 0, 100)
    np.testing.assert_array_equal(np.asarray(dev), ref)
    assert list(np.asarray(dev)) == [True, False, True]


def test_range_mask_f32_inexact_bounds_fall_back(jax_backend):
    # strict bounds are nextafter-adjusted in float64 and not f32-exact;
    # routing them through the kernel would round back and readmit x == 0.5
    vals = np.array([0.5, 0.6], np.float32)
    lo = np.nextafter(0.5, np.inf)  # float64
    ref = backend.get_backend("numpy").range_mask(vals, lo, np.inf)
    dev = jax_backend.range_mask(vals, lo, np.inf)
    np.testing.assert_array_equal(np.asarray(dev), ref)
    assert list(np.asarray(dev)) == [False, True]


def test_fused_range_scan_parity_wide_values(tmp_path):
    # end-to-end: the reader's fused range path must return identical rows
    # on both backends even when the column holds >32-bit values
    db = ParquetDB(os.path.join(str(tmp_path), "wide"))
    n = 2_000
    a = RNG.integers(0, 100, n).astype(np.int64)
    a[::3] += 2**32
    db.create(Table.from_pydict({"a": a, "s": [f"r{i}" for i in range(n)]}))
    expr = [(field("a") >= 0) & (field("a") <= 100)]
    backend.set_backend("numpy")
    ref = db.read(filters=expr).to_pydict()
    backend.set_backend("jax")
    try:
        dev = db.read(filters=expr).to_pydict()
    finally:
        backend.set_backend(None)
    assert ref == dev
    assert all(v <= 100 for v in dev["a"])


def test_whole_table_read_identical(tmp_path):
    """End-to-end: numpy and jax backends produce identical tables."""
    n = 5_000
    db = ParquetDB(os.path.join(str(tmp_path), "parity"))
    db.create(Table.from_pydict({
        "small": RNG.integers(0, 50, n),           # dict/bitpack territory
        "wide": RNG.integers(-2**52, 2**52, n),    # forces 64-bit fallback
        "seq": np.arange(n),                       # delta
        "f32": RNG.standard_normal(n).astype(np.float32),   # bss
        "f64": RNG.standard_normal(n),
        "s": [f"name_{i % 97}" for i in range(n)],
        "flag": RNG.integers(0, 2, n).astype(bool),
    }))
    backend.set_backend("numpy")
    ref = db.read().to_pydict()
    backend.set_backend("jax")
    try:
        dev = db.read().to_pydict()
        filt = db.read(filters=[field("small") < 10]).to_pydict()
    finally:
        backend.set_backend(None)
    assert ref.keys() == dev.keys()
    for k in ref:
        assert ref[k] == dev[k], f"backend mismatch in column {k}"
    assert all(v < 10 for v in filt["small"])


def test_env_selection(monkeypatch):
    monkeypatch.setenv(backend.ENV_VAR, "jax")
    assert backend.active_backend().name == "jax"
    monkeypatch.setenv(backend.ENV_VAR, "numpy")
    assert backend.active_backend().name == "numpy"
    monkeypatch.delenv(backend.ENV_VAR)
    assert backend.active_backend().name == "numpy"


def test_set_backend_overrides_env(monkeypatch):
    monkeypatch.setenv(backend.ENV_VAR, "numpy")
    backend.set_backend("jax")
    try:
        assert backend.active_backend().name == "jax"
    finally:
        backend.set_backend(None)


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        backend.get_backend("tpu3000")
