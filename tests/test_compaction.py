"""Merge-on-read deltas + compaction: overlay semantics, triggers, lifecycle."""
import os

import numpy as np
import pytest

from repro.core import (CompactionPolicy, ParquetDB, field)
from repro.core.store import _READER_CACHE


@pytest.fixture
def db(tmp_path):
    # auto_compact off: these tests assert exact delta-chain states
    return ParquetDB(str(tmp_path / "db"), "db", auto_compact=False)


def make_ranged(tmp_path, name="ranged", files=4, rows=100):
    db = ParquetDB(os.path.join(str(tmp_path), name), auto_compact=False)
    for lo in range(0, files * rows, rows):
        db.create([{"x": lo + i, "y": f"s{lo + i}"} for i in range(rows)])
    return db


class TestDeltaSemantics:
    def test_update_stages_upsert_not_rewrite(self, db):
        db.create([{"a": i} for i in range(10)])
        files = list(db._dir.load().files)
        assert db.update([{"id": 3, "a": -3}]) == 1
        man = db._dir.load()
        assert man.files == files
        assert len(man.deltas) == 1 and man.deltas[0].kind == "upsert"
        assert man.deltas[0].name.endswith(".upsert.tpq")

    def test_read_order_preserved_after_update(self, db):
        db.create([{"a": i} for i in range(5)])
        db.update([{"id": 2, "a": 200}])
        assert db.read(columns=["a"]).to_pydict()["a"] == [0, 1, 200, 3, 4]

    def test_last_committed_delta_wins(self, db):
        db.create([{"a": 0}])
        db.update([{"id": 0, "a": 1}])
        db.update([{"id": 0, "a": 2}])
        assert db.read(columns=["a"]).to_pydict()["a"] == [2]
        assert db.n_delta_files == 2

    def test_filter_sees_merged_values(self, tmp_path):
        db = make_ranged(tmp_path)
        # x=5 lives in a file whose stats say x in [0,100); update it to 999
        db.update([{"id": 5, "x": 999}])
        got = db.read(filters=[field("x") == 999], columns=["x"])
        assert got.to_pydict()["x"] == [999]
        # the stored value no longer matches
        assert db.read(filters=[field("x") == 5]).num_rows == 0

    def test_delete_then_update_is_noop(self, db):
        db.create([{"a": i} for i in range(4)])
        assert db.delete(ids=[1]) == 1
        assert db.update([{"id": 1, "a": 100}]) == 0
        assert db.read(columns=["a"]).to_pydict()["a"] == [0, 2, 3]

    def test_update_then_delete_row_gone(self, db):
        db.create([{"a": i} for i in range(4)])
        db.update([{"id": 1, "a": 100}])
        assert db.delete(filters=[field("a") == 100]) == 1
        assert db.read(columns=["a"]).to_pydict()["a"] == [0, 2, 3]
        assert db.n_rows == 3

    def test_projection_without_id_still_merges(self, db):
        db.create([{"a": 1, "b": "x"}, {"a": 2, "b": "y"}])
        db.update([{"id": 0, "b": "z"}])
        t = db.read(columns=["b"])
        assert t.column_names == ["b"]
        assert t.to_pydict()["b"] == ["z", "y"]

    def test_update_by_custom_key_over_delta(self, db):
        db.create([{"k": "u1", "v": 1}, {"k": "u2", "v": 2}])
        db.update([{"k": "u2", "v": 20}], update_keys="k")
        # second update must match against the merged view
        assert db.update([{"k": "u2", "v": 30}], update_keys="k") == 1
        assert db.read(filters=[field("k") == "u2"]).to_pydict()["v"] == [30]

    def test_schema_evolution_via_update_delta(self, db):
        db.create([{"a": 1}, {"a": 2}])
        db.update([{"id": 1, "z": 9.5}])
        assert db.read(columns=["z"]).to_pydict()["z"] == [None, 9.5]

    def test_n_rows_subtracts_tombstones(self, db):
        db.create([{"a": i} for i in range(10)])
        db.delete(ids=[0, 9])
        assert db.n_rows == 8

    def test_explain_reports_delta_counters(self, tmp_path):
        db = make_ranged(tmp_path)
        db.update([{"id": 5, "x": 999}])
        db.delete(ids=[7, 8])
        rep = db.explain()
        c = rep.counters
        assert c.delta_files == 2
        assert c.delta_upsert_rows == 1
        assert c.delta_tombstone_rows == 2
        assert "deltas:" in str(rep)
        rep = db.explain(execute=True)
        assert rep.counters.delta_rows_applied == 1
        assert rep.counters.rows_shadowed == 2
        # only the overlapped fragment loses pushdown
        overlapped = [f for f in rep.fragments if f.delta_overlap]
        assert len(overlapped) == 1 and not overlapped[0].pushdown

    def test_pruning_still_sound_with_deltas(self, tmp_path):
        db = make_ranged(tmp_path)
        db.update([{"id": 150, "x": -1}])
        db.delete(ids=[201])
        # pruned scan == unpruned scan over the merged view
        expr = field("x") < 100
        pruned = db.read(filters=[expr]).to_pylist()
        plan = db._scan_plan(None, expr, None, prune=False)
        unpruned = []
        for t in plan.execute():
            unpruned.extend(t.to_pylist())
        assert pruned == unpruned
        assert any(r["x"] == -1 for r in pruned)

    def test_delete_all_rows(self, db):
        db.create([{"a": i} for i in range(5)])
        assert db.delete(filters=[field("a") >= 0]) == 5
        assert db.n_rows == 0
        assert db.read().num_rows == 0

    def test_normalize_folds_deltas(self, db):
        db.create([{"a": i} for i in range(10)])
        db.update([{"id": 2, "a": -2}])
        db.delete(ids=[5])
        db.normalize(max_rows_per_file=4)
        man = db._dir.load()
        assert man.deltas == []
        assert db.read(columns=["a"]).to_pydict()["a"] == \
            [0, 1, -2, 3, 4, 6, 7, 8, 9]

    def test_delete_columns_folds_deltas_first(self, db):
        db.create([{"a": 1, "b": 2}, {"a": 3, "b": 4}])
        db.update([{"id": 0, "a": 10}])
        db.delete(columns=["b"])
        man = db._dir.load()
        assert man.deltas == []
        assert "b" not in db.schema
        assert db.read(columns=["a"]).to_pydict()["a"] == [10, 3]

    def test_delta_file_kind_flag(self, db):
        db.create([{"a": 1}])
        db.update([{"id": 0, "a": 2}])
        db.delete(ids=[0])
        man = db._dir.load()
        kinds = {d.kind: db._reader_of(d.name).file_kind for d in man.deltas}
        assert kinds == {"upsert": "upsert", "tombstone": "tombstone"}
        assert db._reader_of(man.files[0]).file_kind == "base"


class TestCompaction:
    def test_compact_folds_chain(self, tmp_path):
        db = make_ranged(tmp_path)
        before = db.read().to_pylist()
        db.update([{"id": 5, "x": 999}])
        db.delete(ids=[7])
        merged = db.read().to_pylist()
        res = db.compact()
        assert res.compacted and res.deltas_merged == 2
        man = db._dir.load()
        assert man.deltas == []
        assert db.read().to_pylist() == merged
        assert merged != before

    def test_compact_untouched_files_keep_names(self, tmp_path):
        # target small enough that the 100-row base files are "well filled"
        pol = CompactionPolicy(target_rows_per_file=100, min_file_fill=0.5)
        db = make_ranged(tmp_path)
        db.compaction_policy = pol
        files = list(db._dir.load().files)
        db.update([{"id": 5, "x": 999}])  # touches only the first file
        res = db.compact()
        assert res.compacted and res.files_merged == 1
        man = db._dir.load()
        assert set(files[1:]) <= set(man.files)  # untouched keep names
        assert files[0] not in man.files

    def test_compact_noncontiguous_merge_keeps_global_id_order(self, tmp_path):
        # deltas touch the first and last of three files; the kept middle
        # file's id range must not be spanned by any compaction output
        pol = CompactionPolicy(target_rows_per_file=100)
        db = make_ranged(tmp_path, files=3)
        db.compaction_policy = pol
        db.update([{"id": 5, "x": -5}, {"id": 250, "x": -250}])
        res = db.compact()
        assert res.compacted and res.files_merged == 2
        ids = db.read(columns=["id"]).to_pydict()["id"]
        assert ids == list(range(300))  # global order preserved
        # and no base file's id range overlaps another's
        man = db._dir.load()
        ranges = []
        for fn in man.files:
            st = db._reader_of(fn).file_stats()["id"]
            ranges.append((st.min, st.max))
        ranges.sort()
        assert all(a[1] < b[0] for a, b in zip(ranges, ranges[1:]))

    def test_compact_output_sorted_by_id(self, tmp_path):
        db = make_ranged(tmp_path, files=3)
        db.update([{"id": i, "x": -i} for i in range(0, 300, 7)])
        db.compact(force=True)
        ids = db.read(columns=["id"]).to_pydict()["id"]
        assert ids == sorted(ids)

    def test_compact_nothing_to_do(self, tmp_path):
        pol = CompactionPolicy(target_rows_per_file=100)
        db = make_ranged(tmp_path)
        db.compaction_policy = pol
        res = db.compact()
        assert not res.compacted

    def test_compact_defers_gc_until_next_open(self, tmp_path):
        db = make_ranged(tmp_path, files=2)
        db.update([{"id": 1, "x": -1}])
        res = db.compact()
        # old generation still on disk (snapshot grace)...
        for fn in res.dropped_files:
            assert os.path.exists(db._dir.file_path(fn))
        # ...collected on next open
        db2 = ParquetDB(db.db_path, db.dataset_name, auto_compact=False)
        for fn in res.dropped_files:
            assert not os.path.exists(db2._dir.file_path(fn))
        assert db2.read(ids=[1], columns=["x"]).to_pydict()["x"] == [-1]

    def test_compact_evicts_reader_cache(self, tmp_path):
        db = make_ranged(tmp_path, files=2)
        db.update([{"id": 1, "x": -1}])
        db.read()  # populate the cache with delta + base footers
        res = db.compact()
        dropped = {db._dir.file_path(f) for f in res.dropped_files}
        assert not any(k[0] in dropped for k in _READER_CACHE)

    def test_maintenance_stats_and_trigger(self, db):
        db.create([{"a": i} for i in range(100)])
        st = db.maintenance_stats()
        assert st.base_files == 1 and st.delta_files == 0
        assert not st.should_compact
        for i in range(5):
            db.update([{"id": i, "a": -i}])
        st = db.maintenance_stats()
        assert st.delta_files == 5 and st.upsert_rows == 5
        assert st.should_compact  # chain length 5 > max_delta_files=4
        assert any("chain" in r for r in st.reasons)
        db.compact()
        assert not db.maintenance_stats().should_compact

    def test_delta_ratio_trigger(self, db):
        db.create([{"a": i} for i in range(10)])
        db.update([{"id": i, "a": -i} for i in range(5)])  # ratio 0.5
        st = db.maintenance_stats()
        assert st.delta_ratio == pytest.approx(0.5)
        assert st.should_compact

    def test_row_group_fill_metric(self, db):
        db.create([{"a": i} for i in range(100)])
        pol = CompactionPolicy(target_rows_per_group=200,
                               min_row_group_fill=0.9)
        st = db.maintenance_stats(policy=pol)
        assert st.row_group_fill == pytest.approx(0.5)
        assert st.should_compact

    def test_auto_compact_background(self, tmp_path):
        db = ParquetDB(str(tmp_path / "auto"), "auto", auto_compact=True)
        db.create([{"a": i} for i in range(100)])
        for i in range(6):  # exceed max_delta_files=4
            db.update([{"id": i, "a": -i}])
        db.wait_for_maintenance()
        # updates racing the background thread may stage a fresh delta after
        # the fold — but the chain must have been compacted below threshold
        assert db.n_delta_files < 6
        assert not db.maintenance_stats().should_compact
        got = db.read(columns=["a"]).to_pydict()["a"]
        assert got[:6] == [0, -1, -2, -3, -4, -5]

    def test_restored_pruning_after_compact(self, tmp_path):
        db = make_ranged(tmp_path)
        db.update([{"id": 5, "x": 999}])
        rep = db.explain(filters=[field("x") == 250])
        assert rep.counters.files_scanned == 2  # overlapped file can't prune
        db.compact()
        rep = db.explain(filters=[field("x") == 250])
        assert rep.counters.files_scanned == 1  # pruning restored


class TestSnapshotIsolation:
    def test_reader_snapshot_survives_compaction(self, tmp_path):
        db = make_ranged(tmp_path, files=2)
        db.update([{"id": 1, "x": -1}])
        ds = db.read(load_format="dataset")
        plan = ds.scan_plan()  # binds the pre-compaction manifest snapshot
        db.compact()
        rows = []
        for t in plan.execute():  # old files still on disk (deferred GC)
            rows.extend(t.to_pylist())
        assert len(rows) == 200
        assert [r["x"] for r in rows if r["id"] == 1] == [-1]
