"""Multi-writer MVCC verified by a deterministic interleaving harness.

The commit protocol (docs/TRANSACTIONS.md) splits an optimistic writer into
four named steps — snapshot → stage → validate → publish — exposed by
``repro.core.store._DeltaTxn``.  The harness here drives two-plus scripted
writers through **every** interleaving of those steps on one thread, so each
schedule is perfectly reproducible, and checks a serializability oracle: the
committed state must be byte-identical to replaying *some* serial order of
the transactions that committed.  On top of the same schedules it re-runs
the PR 2 crash-injection matrix (``PRE_COMMIT_HOOK`` / ``POST_COMMIT_HOOK``)
to prove a crash loses only in-flight transactions, never a committed
generation.

The conflict-detection property suite mirrors ``test_decode_batch.py``:
hypothesis drives it when installed, and a deterministic corpus covers the
same property (accept/reject equals a brute-force id-intersection oracle)
when it is not.  The multi-process stress test is ``concurrency``-marked and
skips loudly on 1-vCPU boxes (CI runs it in the dedicated concurrency job).
"""
import itertools
import json
import multiprocessing
import os

import numpy as np
import pytest

from repro.core import CommitConflict, ParquetDB
from repro.core import transactions as tx
from repro.core.schema import ID_COLUMN
from repro.core.shm import live_segments
from repro.core.store import _DeltaTxn

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


class Crash(Exception):
    pass


def crash_next_commit():
    """Arm a one-shot crash just before the next generation link."""
    def hook():
        tx.PRE_COMMIT_HOOK = None
        raise Crash()
    tx.PRE_COMMIT_HOOK = hook


def crash_after_next_link():
    """Arm a one-shot crash right after the link, before pointer rewrite."""
    def hook():
        tx.POST_COMMIT_HOOK = None
        raise Crash()
    tx.POST_COMMIT_HOOK = hook


@pytest.fixture(autouse=True)
def _clean_hooks():
    yield
    tx.PRE_COMMIT_HOOK = None
    tx.POST_COMMIT_HOOK = None


# ---------------------------------------------------------------------------
# deterministic interleaving harness
# ---------------------------------------------------------------------------
STEPS = ("snapshot", "stage", "validate", "publish")
BASE_N = 10  # base rows, ids 0..9, x == id


def interleavings(n_writers: int, n_steps: int = len(STEPS)):
    """Every ordering of ``n_writers`` writers' protocol steps.

    A schedule is a tuple of writer indices of length n_writers*n_steps;
    each writer's own steps stay in protocol order.  For two writers this
    is C(8, 4) == 70 schedules — exhaustive.
    """
    slots = n_writers * n_steps
    for positions in itertools.combinations(range(slots), n_steps):
        if n_writers == 2:
            sched = [1] * slots
            for p in positions:
                sched[p] = 0
            yield tuple(sched)
        else:  # recurse: writer 0 takes `positions`, rest fill the gap
            rest = [i for i in range(slots) if i not in positions]
            for sub in interleavings(n_writers - 1, n_steps):
                sched = [0] * slots
                for slot, w in zip(rest, sub):
                    sched[slot] = w + 1
                yield tuple(sched)


class ScriptedWriter:
    """One optimistic transaction driven step-by-step by a schedule.

    ``kind`` is "upsert" (rows: id -> new x) or "delete" (ids).  A publish
    that raises :class:`CommitConflict` aborts the writer (staged files
    dropped) — the real retry loop is exercised elsewhere; the harness keeps
    single-attempt semantics so every schedule's outcome is a pure function
    of the schedule.
    """

    def __init__(self, db: ParquetDB, kind: str, payload):
        self.db = db
        self.kind = kind
        self.payload = payload
        self.txn = None
        self.committed = False
        self.conflicted = False
        self.crashed = False

    def _build(self):
        if self.kind == "upsert":
            rows = [{"id": i, "x": v} for i, v in self.payload]
            return self.db._upsert_build(self.db._to_table(rows, None),
                                         [ID_COLUMN])
        expr = self.db._build_filter(list(self.payload), None)
        return self.db._tombstone_build(expr)

    def apply_serially(self, db: ParquetDB) -> None:
        """The same operation via the public API (the oracle's replay)."""
        if self.kind == "upsert":
            db.update([{"id": i, "x": v} for i, v in self.payload])
        else:
            db.delete(ids=list(self.payload))

    def step(self, name: str) -> None:
        if self.conflicted or self.crashed:
            return  # aborted writers take no further protocol steps
        if name == "snapshot":
            self.txn = _DeltaTxn(self.db, self._build(),
                                 "update" if self.kind == "upsert"
                                 else "delete")
            self.txn.snapshot()
        elif name == "stage":
            self.txn.stage()
        elif name == "validate":
            self.txn.validate()  # advisory: result may be stale, ignore
        elif name == "publish":
            try:
                self.txn.publish()
                self.committed = True
            except CommitConflict:
                self.txn.abort()
                self.conflicted = True


def run_schedule(schedule, writers):
    """Drive the writers' steps in schedule order (single-threaded)."""
    cursor = [0] * len(writers)
    for w in schedule:
        writers[w].step(STEPS[cursor[w]])
        cursor[w] += 1


def canonical(db: ParquetDB) -> bytes:
    """Canonical byte serialization of the committed table state."""
    t = db.read()
    return json.dumps(t.to_pydict(), sort_keys=True).encode()


def fresh_db(tmp_path, tag) -> ParquetDB:
    db = ParquetDB(str(tmp_path / tag), "db", auto_compact=False)
    db.create([{"x": i} for i in range(BASE_N)])
    return db


_ORACLE_CACHE = {}


def serial_states(tmp_path, committed, tag):
    """Byte states of every serial order of the committed transactions.

    Cached on the (order-independent) set of operations — schedules share
    replays, and the oracle only depends on what committed, not when.
    """
    key = frozenset((w.kind, tuple(w.payload)) for w in committed)
    if key in _ORACLE_CACHE:
        return _ORACLE_CACHE[key]
    out = []
    for k, order in enumerate(itertools.permutations(committed)):
        db = fresh_db(tmp_path, f"{tag}-serial{k}")
        for w in order:
            w.apply_serially(db)
        out.append(canonical(db))
    out = out or [canonical(fresh_db(tmp_path, f"{tag}-serial-empty"))]
    _ORACLE_CACHE[key] = out
    return out


@pytest.fixture(autouse=True)
def _fresh_oracle_cache():
    _ORACLE_CACHE.clear()
    yield


def orphan_stage_files(db: ParquetDB):
    """Stage-named files on disk that no committed manifest references."""
    man = db._dir.load()
    live = set(man.files) | {d.name for d in man.deltas}
    return [f for f in os.listdir(db.db_path)
            if tx.STAGE_MARKER in f and f not in live]


def same_snapshot_race(schedule) -> bool:
    """True when every writer snapshots before any writer publishes."""
    last_snapshot = max(i for i, w in enumerate(schedule)
                        if schedule[:i + 1].count(w) == 1)
    first_publish = min(i for i, w in enumerate(schedule)
                        if schedule[:i + 1].count(w) == len(STEPS))
    return last_snapshot < first_publish


class TestInterleavings:
    """Exhaustive two-writer schedules against the serializability oracle."""

    def test_non_overlapping_both_commit_every_interleaving(self, tmp_path):
        expected = None
        for k, sched in enumerate(interleavings(2)):
            db = fresh_db(tmp_path, f"d{k}")
            a = ScriptedWriter(db, "upsert", [(0, 100), (1, 101)])
            b = ScriptedWriter(db, "upsert", [(5, 205), (6, 206)])
            run_schedule(sched, [a, b])
            # disjoint ids: both always succeed, whatever the interleaving
            # (the later one rebases at most once — no lock contention)
            assert a.committed and b.committed, sched
            assert db._dir.load().generation == 3, sched  # create + 2
            if expected is None:
                expected = serial_states(tmp_path, [a, b], "base")[0]
            assert canonical(db) == expected, sched

    def test_overlapping_serializable_every_interleaving(self, tmp_path):
        outcomes = set()
        for k, sched in enumerate(interleavings(2)):
            db = fresh_db(tmp_path, f"d{k}")
            a = ScriptedWriter(db, "upsert", [(2, 100), (3, 100)])
            b = ScriptedWriter(db, "upsert", [(3, 200), (4, 200)])
            run_schedule(sched, [a, b])
            committed = tuple(w for w in (a, b) if w.committed)
            if same_snapshot_race(sched):
                # both bound the same generation and race to the same row:
                # exactly one may win
                assert len(committed) == 1, sched
            else:
                # one snapshotted after the other published: serial, both fine
                assert len(committed) == 2, sched
            assert canonical(db) in serial_states(tmp_path, list(committed),
                                                  f"o{k}"), sched
            outcomes.add(tuple(w.committed for w in (a, b)))
        # the matrix really exercised both race outcomes and serial runs
        assert (True, False) in outcomes and (False, True) in outcomes

    def test_update_delete_interleavings(self, tmp_path):
        """Upsert vs tombstone on overlapping ids is a conflict too."""
        for k, sched in enumerate(interleavings(2)):
            db = fresh_db(tmp_path, f"d{k}")
            a = ScriptedWriter(db, "upsert", [(3, 300)])
            b = ScriptedWriter(db, "delete", [3, 4])
            run_schedule(sched, [a, b])
            committed = [w for w in (a, b) if w.committed]
            if same_snapshot_race(sched):
                assert len(committed) == 1, sched
            assert canonical(db) in serial_states(tmp_path, committed,
                                                  f"o{k}"), sched

    def test_three_writer_schedules(self, tmp_path):
        """A deterministic sample of the 3-writer schedule space.

        A and B are disjoint; C overlaps B — so any schedule commits A, and
        commits at least one of B/C; the result must still replay serially.
        """
        all_scheds = sorted(set(interleavings(3)))
        rng = np.random.default_rng(7)
        picks = [all_scheds[i] for i in
                 rng.choice(len(all_scheds), size=40, replace=False)]
        picks += [tuple([0] * 4 + [1] * 4 + [2] * 4),   # serial A,B,C
                  tuple([2] * 4 + [1] * 4 + [0] * 4),   # serial C,B,A
                  tuple([0, 1, 2] * 4)]                 # round-robin
        for k, sched in enumerate(picks):
            db = fresh_db(tmp_path, f"d{k}")
            a = ScriptedWriter(db, "upsert", [(0, 100)])
            b = ScriptedWriter(db, "upsert", [(4, 200), (5, 200)])
            c = ScriptedWriter(db, "delete", [5, 6])
            run_schedule(sched, [a, b, c])
            committed = [w for w in (a, b, c) if w.committed]
            assert a.committed, sched
            assert len(committed) >= 2, sched
            assert canonical(db) in serial_states(tmp_path, committed,
                                                  f"o{k}"), sched


# ---------------------------------------------------------------------------
# multi-writer crash injection
# ---------------------------------------------------------------------------
class TestMultiWriterCrashes:
    """A crash may lose only in-flight transactions, never a committed
    generation — across every two-writer interleaving and both crash points
    (before and after the generation link)."""

    def _run_crashing(self, tmp_path, sched, k, arm):
        db = fresh_db(tmp_path, f"d{k}")
        a = ScriptedWriter(db, "upsert", [(2, 100), (3, 100)])
        b = ScriptedWriter(db, "upsert", [(3, 200), (4, 200)])
        writers = [a, b]
        cursor = [0, 0]
        first_publish_crashed = False
        for w in sched:
            step = STEPS[cursor[w]]
            cursor[w] += 1
            if step == "publish" and not first_publish_crashed:
                first_publish_crashed = True
                arm()
                with pytest.raises(Crash):
                    writers[w].step(step)
                writers[w].crashed = True
            else:
                writers[w].step(step)
        return db, a, b

    def _reopen(self, db, monkeypatch):
        monkeypatch.setenv("REPRO_STAGE_GC_SECONDS", "0")
        return ParquetDB(db.db_path, db.dataset_name, auto_compact=False)

    def test_crash_before_link_loses_only_inflight(self, tmp_path,
                                                   monkeypatch):
        for k, sched in enumerate(interleavings(2)):
            db, a, b = self._run_crashing(tmp_path, sched, k,
                                          crash_next_commit)
            crashed, other = (a, b) if a.crashed else (b, a)
            # nothing was linked: the crashed txn is lost entirely...
            assert not crashed.committed, sched
            # ...and the survivor — the crash is always the schedule's first
            # publish — found an unchanged head and committed, never blocked
            # by the dead writer's staged leftovers
            assert other.committed, sched
            db2 = self._reopen(db, monkeypatch)
            committed = [w for w in (a, b) if w.committed]
            assert canonical(db2) in serial_states(tmp_path, committed,
                                                   f"o{k}"), sched
            # the crashed txn's staged file was GC'd on reopen — no orphans
            assert not orphan_stage_files(db2), sched

    def test_crash_after_link_keeps_committed_generation(self, tmp_path,
                                                         monkeypatch):
        for k, sched in enumerate(interleavings(2)):
            db, a, b = self._run_crashing(tmp_path, sched, k,
                                          crash_after_next_link)
            crashed = a if a.crashed else b
            other = b if crashed is a else a
            # the generation WAS linked before the crash: durable, even
            # though the writer never saw its publish() return.  On ids only
            # the crashed writer touches, its value must survive reopen (the
            # shared id may be overwritten serially by a later commit).
            db2 = self._reopen(db, monkeypatch)
            state = json.loads(canonical(db2))
            other_ids = {i for i, _ in other.payload}
            for i, v in crashed.payload:
                if i not in other_ids:
                    assert state["x"][state[ID_COLUMN].index(i)] == v, sched
            committed = [w for w in (a, b) if w.committed or w.crashed]
            assert canonical(db2) in serial_states(tmp_path, committed,
                                                   f"o{k}"), sched
            assert not orphan_stage_files(db2), sched

    def test_group_commit_crash_loses_whole_batch(self, tmp_path):
        """A persistent pre-link crash fails every queued writer; the base
        generation survives untouched."""
        import threading
        db = fresh_db(tmp_path, "d")
        tx.PRE_COMMIT_HOOK = lambda: (_ for _ in ()).throw(Crash())
        errs = []

        def work(i):
            try:
                db.update([{"id": i, "x": -1}])
            except Crash:
                errs.append(i)
        threads = [threading.Thread(target=work, args=(i,)) for i in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        tx.PRE_COMMIT_HOOK = None
        assert sorted(errs) == [0, 1, 2]
        db2 = ParquetDB(db.db_path, db.dataset_name, auto_compact=False)
        assert canonical(db2) == canonical(fresh_db(tmp_path, "ref"))


# ---------------------------------------------------------------------------
# conflict-detection property suite (hypothesis + deterministic corpus)
# ---------------------------------------------------------------------------
def _race(tmp_path, tag, ids_a, ids_b):
    """Stage two same-snapshot upserts; commit A then B.  Returns whether B
    was accepted."""
    db = fresh_db(tmp_path, tag)
    a = ScriptedWriter(db, "upsert", [(i, 100) for i in ids_a])
    b = ScriptedWriter(db, "upsert", [(i, 200) for i in ids_b])
    for w in (a, b):
        w.step("snapshot")
        w.step("stage")
    a.step("publish")
    assert a.committed
    b.step("publish")
    # oracle: B may commit iff its exact id set is disjoint from A's —
    # overlapping *ranges* alone (checked first via footer stats) must not
    # reject, and any true intersection must
    expect_accept = not (set(ids_a) & set(ids_b))
    assert b.committed == expect_accept, (ids_a, ids_b)
    if b.committed:
        state = json.loads(canonical(db))
        for i in ids_b:
            assert state["x"][state[ID_COLUMN].index(i)] == 200
    return b.committed


# disjoint / adjacent / overlap-by-one / nested / identical / interleaved
CONFLICT_CORPUS = [
    ([0, 1, 2], [5, 6, 7]),        # disjoint ranges
    ([0, 1, 2], [3, 4]),           # adjacent, still disjoint
    ([0, 1, 2], [2, 3]),           # overlap by exactly one id
    ([0, 9], [3, 4]),              # nested range, exact ids disjoint
    ([0, 9], [0, 9]),              # identical
    ([0, 2, 4, 6, 8], [1, 3, 5, 7, 9]),  # interleaved: ranges overlap,
                                         # exact ids don't -> must accept
    ([5], [5]),                    # single-row collision
    ([0], [9]),                    # extremes
]


@pytest.mark.parametrize("ids_a,ids_b", CONFLICT_CORPUS,
                         ids=[f"case{i}" for i in range(len(CONFLICT_CORPUS))])
def test_conflict_decision_matches_oracle(tmp_path, ids_a, ids_b):
    _race(tmp_path, "db", ids_a, ids_b)


def test_interleaved_ids_prove_exact_check(tmp_path):
    """The evens/odds case must commit BOTH writers: footer id ranges fully
    overlap, so only the exact-intersection pass can accept it."""
    assert _race(tmp_path, "db", [0, 2, 4, 6, 8], [1, 3, 5, 7, 9])


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(ids_a=st.sets(st.integers(0, BASE_N - 1), min_size=1),
           ids_b=st.sets(st.integers(0, BASE_N - 1), min_size=1))
    def test_conflict_decision_hypothesis(tmp_path_factory, ids_a, ids_b):
        tmp = tmp_path_factory.mktemp("mvcc-hyp")
        _race(tmp, "db", sorted(ids_a), sorted(ids_b))
else:
    def test_conflict_decision_seeded_random(tmp_path):
        rng = np.random.default_rng(42)
        for k in range(40):
            ids_a = sorted(rng.choice(BASE_N, rng.integers(1, 6),
                                      replace=False).tolist())
            ids_b = sorted(rng.choice(BASE_N, rng.integers(1, 6),
                                      replace=False).tolist())
            _race(tmp_path, f"r{k}", ids_a, ids_b)


# ---------------------------------------------------------------------------
# multi-process stress
# ---------------------------------------------------------------------------
N_WRITERS = 3
N_BATCHES = 4
SLICE = 8  # ids per writer


def _stress_worker(path, wid, q):
    try:
        db = ParquetDB(path, "db", auto_compact=False)
        lo = wid * SLICE
        done = 0
        for b in range(N_BATCHES):
            n = db.update([{"id": i, "x": wid * 1000 + b}
                           for i in range(lo, lo + SLICE)])
            assert n == SLICE, (wid, b, n)
            done += 1
        q.put((wid, done, None))
    except BaseException as e:  # pragma: no cover - failure reporting
        q.put((wid, -1, repr(e)))


@pytest.mark.concurrency
def test_multiprocess_writers_stress(tmp_path, monkeypatch):
    if (os.cpu_count() or 1) < 2 and not os.environ.get(
            "REPRO_FORCE_CONCURRENCY"):
        pytest.skip("SKIPPED (loud): multi-process stress needs >= 2 cpus; "
                    f"this box has {os.cpu_count()} — run the CI "
                    "concurrency job, or set REPRO_FORCE_CONCURRENCY=1")
    path = str(tmp_path / "db")
    db = ParquetDB(path, "db", auto_compact=False)
    db.create([{"x": -1} for _ in range(N_WRITERS * SLICE)])
    ctx = multiprocessing.get_context("spawn")
    q = ctx.Queue()
    procs = [ctx.Process(target=_stress_worker, args=(path, w, q))
             for w in range(N_WRITERS)]
    for p in procs:
        p.start()
    results = [q.get(timeout=120) for _ in procs]
    for p in procs:
        p.join(timeout=60)
        assert p.exitcode == 0
    for wid, done, err in results:
        assert err is None, f"writer {wid}: {err}"
        assert done == N_BATCHES
    # final table == serial application of every committed batch: the last
    # batch per writer wins on its own slice (disjoint slices never conflict)
    monkeypatch.setenv("REPRO_STAGE_GC_SECONDS", "0")
    db2 = ParquetDB(path, "db", auto_compact=False)
    got = db2.read(columns=[ID_COLUMN, "x"]).to_pydict()
    for wid in range(N_WRITERS):
        for i in range(wid * SLICE, (wid + 1) * SLICE):
            assert got["x"][got[ID_COLUMN].index(i)] == \
                wid * 1000 + (N_BATCHES - 1)
    # no leaked locks, no orphan files, no shm segments
    assert not os.path.exists(os.path.join(path, tx.LOCKFILE))
    man = db2._dir.load()
    live = set(man.files) | {d.name for d in man.deltas}
    on_disk = {f for f in os.listdir(path) if f.endswith(".tpq")}
    assert on_disk == live
    assert live_segments() == []


# ---------------------------------------------------------------------------
# startup-recovery GC safety (satellite regression)
# ---------------------------------------------------------------------------
class TestStagedFileGC:
    def test_open_spares_live_writers_staging(self, tmp_path):
        """Another process's in-flight staging survives a concurrent open."""
        db = fresh_db(tmp_path, "db")
        w = ScriptedWriter(db, "upsert", [(0, 100)])
        w.step("snapshot")
        w.step("stage")  # lock-free: no lock held while staged
        staged = [f for f in os.listdir(db.db_path) if tx.STAGE_MARKER in f]
        assert staged
        ParquetDB(db.db_path, db.dataset_name)  # concurrent open runs GC
        for f in staged:
            assert os.path.exists(os.path.join(db.db_path, f))
        w.step("publish")  # the writer can still finish its commit
        assert w.committed

    def test_open_collects_staging_of_dead_writer(self, tmp_path):
        """A stage file whose embedded pid is dead is collected at once,
        without waiting out the grace period."""
        db = fresh_db(tmp_path, "db")
        w = ScriptedWriter(db, "upsert", [(0, 100)])
        w.step("snapshot")
        w.step("stage")
        staged = [f for f in os.listdir(db.db_path) if tx.STAGE_MARKER in f]
        # forge the name so it claims a pid that is certainly dead
        ctx = multiprocessing.get_context("spawn")
        p = ctx.Process(target=_noop)
        p.start()
        p.join()
        dead = [f.replace(f"{tx.STAGE_MARKER}{os.getpid():x}-",
                          f"{tx.STAGE_MARKER}{p.pid:x}-") for f in staged]
        for old, new in zip(staged, dead):
            os.rename(os.path.join(db.db_path, old),
                      os.path.join(db.db_path, new))
        ParquetDB(db.db_path, db.dataset_name)
        for f in dead:
            assert not os.path.exists(os.path.join(db.db_path, f))

    def test_aged_out_staging_is_collected(self, tmp_path, monkeypatch):
        db = fresh_db(tmp_path, "db")
        w = ScriptedWriter(db, "upsert", [(0, 100)])
        w.step("snapshot")
        w.step("stage")
        monkeypatch.setenv("REPRO_STAGE_GC_SECONDS", "0")
        ParquetDB(db.db_path, db.dataset_name)
        assert not [f for f in os.listdir(db.db_path)
                    if tx.STAGE_MARKER in f]


def _noop():
    pass
