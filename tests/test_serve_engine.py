"""Smoke tests for the LM serving engine (serve/engine.py).

The engine only needs a model exposing ``init_cache`` and a jit-able
``decode_step``; a tiny deterministic counter model (next token =
last token + 1, one-hot logits) makes slot admission, eos termination and
queue drain checkable exactly, with no weights and no tokenizer.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
jnp = jax.numpy

from repro.serve.engine import ServeEngine  # noqa: E402

VOCAB = 32


class CounterModel:
    """Greedy argmax always picks ``(last_token + 1) % VOCAB``."""

    def init_cache(self, slots, max_seq):
        return jnp.zeros((slots,), jnp.int32)

    def decode_step(self, params, cache, tokens, pos, mesh=None):
        nxt = (tokens[:, -1] + 1) % VOCAB
        logits = jax.nn.one_hot(nxt, VOCAB)[:, None, :]
        return logits, nxt


def make_engine(slots=2, max_seq=64):
    return ServeEngine(CounterModel(), params={}, slots=slots,
                       max_seq=max_seq)


def test_slot_admission_bounds_active_set():
    eng = make_engine(slots=2)
    for i in range(5):
        eng.submit(np.array([i], np.int32), max_new_tokens=4)
    assert len(eng.queue) == 5 and not eng.active
    eng.step()
    # two slots, five requests: exactly two admitted, three still queued
    assert len(eng.active) == 2
    assert len(eng.queue) == 3
    assert sorted(r.slot for r in eng.active.values()) == [0, 1]
    # occupied slots have a real position; free slots stay -1
    assert (eng.pos >= 0).sum() == 2


def test_slot_reuse_after_completion():
    eng = make_engine(slots=1)
    eng.submit(np.array([3], np.int32), max_new_tokens=2)
    eng.submit(np.array([9], np.int32), max_new_tokens=2)
    done = []
    while len(done) < 2:
        done.extend(eng.step())
    # both ran through the single slot, in submission order
    assert [r.rid for r in done] == [0, 1]
    assert all(r.slot == 0 for r in done)
    assert eng.pos[0] == -1  # slot freed


def test_eos_terminates_before_max_new_tokens():
    eng = make_engine(slots=2)
    # counter model emits 8 right after prompt [7] -> eos fires on step 1
    rid_eos = eng.submit(np.array([7], np.int32), max_new_tokens=10,
                         eos_id=8)
    rid_full = eng.submit(np.array([7], np.int32), max_new_tokens=3)
    done = eng.run_to_completion()
    by_rid = {r.rid: r for r in done}
    assert by_rid[rid_eos].out_tokens == [8]          # stopped at eos
    assert by_rid[rid_full].out_tokens == [8, 9, 10]  # ran to the cap
    assert all(r.done for r in done)


def test_queue_drains_and_outputs_are_deterministic():
    eng = make_engine(slots=2)
    rids = [eng.submit(np.array([i], np.int32), max_new_tokens=3)
            for i in range(5)]
    done = eng.run_to_completion()
    assert sorted(r.rid for r in done) == rids
    assert not eng.active and not eng.queue
    assert (eng.pos == -1).all()
    for r in done:
        start = int(r.prompt[-1])
        assert r.out_tokens == [(start + k) % VOCAB for k in (1, 2, 3)]


def test_max_seq_caps_generation():
    eng = make_engine(slots=1, max_seq=4)
    eng.submit(np.array([0], np.int32), max_new_tokens=100)
    (r,) = eng.run_to_completion()
    # pos hits max_seq - 1 after 3 generated tokens: capped, marked done
    assert r.done and len(r.out_tokens) == 3
