"""TPQ file format: roundtrip, projection + predicate pushdown, page pruning."""
import numpy as np
import pytest

from repro.core import Table, TPQReader, write_table, field


def norm(v):
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, dict):
        return {k: norm(x) for k, x in v.items()}
    if isinstance(v, list):
        return [norm(x) for x in v]
    return v


@pytest.fixture
def mixed_table():
    n = 1000
    rng = np.random.default_rng(7)
    return Table.from_pydict({
        "i": np.arange(n),
        "f": rng.standard_normal(n),
        "s": [f"name_{i % 37}" for i in range(n)],
        "t": rng.standard_normal((n, 3, 3)),
        "l": [[j for j in range(i % 5)] for i in range(n)],
        "b": rng.integers(0, 2, n).astype(bool),
    })


def test_roundtrip_all_kinds(tmp_path, mixed_table):
    p = str(tmp_path / "m.tpq")
    write_table(p, mixed_table)
    out = TPQReader(p).read()
    assert norm(out.to_pylist()) == norm(mixed_table.to_pylist())


def test_roundtrip_with_nulls(tmp_path):
    t = Table.from_pylist([
        {"a": 1, "s": "x"}, {"a": None, "s": None}, {"a": 3, "s": "z"}])
    p = str(tmp_path / "n.tpq")
    write_table(p, t)
    assert TPQReader(p).read().to_pylist() == t.to_pylist()


def test_projection_reads_fewer_bytes(tmp_path, mixed_table):
    p = str(tmp_path / "m.tpq")
    write_table(p, mixed_table)
    rd = TPQReader(p)
    all_bytes = rd.read_row_group_bytes(0)
    i_bytes = rd.read_row_group_bytes(0, columns=["i"])
    assert i_bytes < all_bytes / 5  # tensor column dominates


def test_predicate_pushdown_skips_row_groups(tmp_path):
    n = 100_000
    t = Table.from_pydict({"x": np.arange(n)})
    p = str(tmp_path / "rg.tpq")
    write_table(p, t, row_group_rows=10_000, page_rows=2_000)
    rd = TPQReader(p)
    assert len(rd.row_groups) == 10
    out = rd.read(filter_expr=field("x") == 54_321)
    assert out["x"].to_pylist() == [54_321]
    # stats prune 9 of 10 row groups
    pruned = sum(
        (field("x") == 54_321).prune(rd.row_group_stats(i))
        for i in range(10))
    assert pruned == 1


def test_page_pruning_matches_full_scan(tmp_path):
    rng = np.random.default_rng(3)
    n = 50_000
    t = Table.from_pydict({"k": rng.integers(0, 10_000, n), "v": rng.standard_normal(n)})
    p = str(tmp_path / "pp.tpq")
    write_table(p, t, row_group_rows=50_000, page_rows=1_000)
    rd = TPQReader(p)
    expr = field("k") == 1234
    pruned = rd.read(filter_expr=expr, prune_pages=True)
    full = rd.read(filter_expr=expr, prune_pages=False)
    assert norm(pruned.to_pylist()) == norm(full.to_pylist())


def test_filter_column_not_projected_still_works(tmp_path, mixed_table):
    p = str(tmp_path / "m.tpq")
    write_table(p, mixed_table)
    out = TPQReader(p).read(columns=["s"], filter_expr=field("i") < 3)
    assert out.column_names == ["s"] and out.num_rows == 3


def test_string_filter(tmp_path, mixed_table):
    p = str(tmp_path / "m.tpq")
    write_table(p, mixed_table)
    out = TPQReader(p).read(columns=["i"], filter_expr=field("s") == "name_5")
    assert all(i % 37 == 5 for i in out["i"].to_pylist())


def test_empty_table_roundtrip(tmp_path):
    t = Table.from_pydict({"a": np.empty(0, np.int64)})
    p = str(tmp_path / "e.tpq")
    write_table(p, t)
    rd = TPQReader(p)
    assert rd.num_rows == 0
    assert rd.read().num_rows == 0


def test_corrupt_file_detected(tmp_path):
    p = str(tmp_path / "c.tpq")
    write_table(p, Table.from_pydict({"a": np.arange(5)}))
    with open(p, "r+b") as fh:
        fh.seek(-2, 2)
        fh.write(b"xx")
    with pytest.raises(IOError):
        TPQReader(p)


def test_field_level_encoding_codec_override(tmp_path):
    n = 10_000
    t = Table.from_pydict({"a": np.arange(n), "b": np.arange(n)})
    p1, p2 = str(tmp_path / "1.tpq"), str(tmp_path / "2.tpq")
    write_table(p1, t, field_encodings={"a": "plain", "b": "plain"},
                field_codecs={"a": "none", "b": "none"})
    write_table(p2, t, field_encodings={"a": "delta", "b": "delta"})
    import os
    assert os.path.getsize(p2) < os.path.getsize(p1) / 4
    np.testing.assert_array_equal(TPQReader(p2).read()["a"].values, t["a"].values)


def test_isin_and_compound_filters(tmp_path):
    t = Table.from_pydict({"x": np.arange(100), "y": np.arange(100) % 7})
    p = str(tmp_path / "f.tpq")
    write_table(p, t)
    rd = TPQReader(p)
    out = rd.read(filter_expr=(field("x") < 50) & (field("y").isin([0, 1])))
    xs = out["x"].to_pylist()
    assert all(x < 50 and x % 7 in (0, 1) for x in xs)
    out2 = rd.read(filter_expr=(field("x") >= 98) | (field("x") < 1))
    assert sorted(out2["x"].to_pylist()) == [0, 98, 99]
