"""Unit tests: in-memory Table, type inference, nested flattening."""
import numpy as np
import pytest

from repro.core import Table, concat_tables
from repro.core.nested import flatten_record, rebuild_record
from repro.core.table import Column, infer_column


def norm(v):
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, dict):
        return {k: norm(x) for k, x in v.items()}
    if isinstance(v, list):
        return [norm(x) for x in v]
    return v


class TestInference:
    def test_ints(self):
        col, meta = infer_column([1, 2, None, 4])
        assert col.dtype.code == "i8" and meta is None
        assert col.to_pylist() == [1, 2, None, 4]

    def test_mixed_int_float_promotes(self):
        col, _ = infer_column([1, 2.5])
        assert col.dtype.code == "f8"

    def test_bool_not_int(self):
        col, _ = infer_column([True, False])
        assert col.dtype.code == "b1"

    def test_strings_with_null(self):
        col, _ = infer_column(["a", None, "ccc"])
        assert col.to_pylist() == ["a", None, "ccc"]

    def test_fixed_shape_lists_become_tensor(self):
        col, _ = infer_column([[1.0, 2.0], [3.0, 4.0]])
        assert col.dtype.kind == "tensor" and col.dtype.shape == (2,)

    def test_ragged_lists(self):
        col, _ = infer_column([[1, 2], [3]], )
        assert col.dtype.kind == "list"
        assert col.to_pylist() == [[1, 2], [3]]

    def test_forced_ragged(self):
        col, _ = infer_column([[1, 2], [3, 4]], ragged=True)
        assert col.dtype.kind == "list"

    def test_list_of_strings(self):
        col, _ = infer_column([["a", "b"], ["c"], None])
        assert col.to_pylist() == [["a", "b"], ["c"], None]

    def test_dict_fallback_serializes(self):
        col, meta = infer_column([{"a": 1}, {"b": [2, 3]}])
        assert meta == {"serialized": "json"}

    def test_nd_tensor(self):
        col, _ = infer_column([np.eye(3), np.ones((3, 3))])
        assert col.dtype.shape == (3, 3)


class TestNested:
    def test_flatten_rebuild_roundtrip(self):
        rec = {"a": 1, "b": {"c": 2, "d": {"e": "x"}}, "f": [1, 2]}
        flat = flatten_record(rec)
        assert flat == {"a": 1, "b.c": 2, "b.d.e": "x", "f": [1, 2]}
        assert rebuild_record(flat) == rec

    def test_empty_struct_dummy(self):
        flat = flatten_record({"a": {}})
        assert flat == {"a.dummy_variable": True}
        assert rebuild_record(flat) == {"a": {}}


class TestTable:
    def test_from_pylist_missing_fields_null(self):
        t = Table.from_pylist([{"a": 1}, {"b": "x"}])
        assert norm(t.to_pylist()) == [{"a": 1, "b": None}, {"a": None, "b": "x"}]

    def test_columns_alphabetical(self):
        t = Table.from_pylist([{"z": 1, "a": 2, "m": 3}])
        assert t.column_names == ["a", "m", "z"]

    def test_take_slice_filter(self):
        t = Table.from_pydict({"x": np.arange(10), "s": [f"r{i}" for i in range(10)]})
        assert t.take(np.array([3, 1]))["x"].to_pylist() == [3, 1]
        assert t.slice(2, 4)["s"].to_pylist() == ["r2", "r3"]
        assert t.filter_mask(np.arange(10) % 2 == 0).num_rows == 5

    def test_concat_unifies_schema(self):
        a = Table.from_pylist([{"x": 1}])
        b = Table.from_pylist([{"x": 2.5, "y": "n"}])
        c = concat_tables([a, b])
        assert c.schema["x"].dtype.code == "f8"
        assert norm(c.to_pylist()) == [{"x": 1.0, "y": None}, {"x": 2.5, "y": "n"}]

    def test_list_take_roundtrip(self):
        t = Table.from_pylist([{"l": [1, 2, 3]}, {"l": []}, {"l": [9]}])
        out = t.take(np.array([2, 0]))["l"].to_pylist()
        assert out == [[9], [1, 2, 3]]

    def test_ragged_table_rejected(self):
        from repro.core.schema import Field, Schema
        from repro.core.dtypes import DType
        with pytest.raises(ValueError):
            Table(Schema([Field("a", DType.numeric("i8")),
                          Field("b", DType.numeric("i8"))]),
                  {"a": Column.numeric(np.arange(3)),
                   "b": Column.numeric(np.arange(4))})

    def test_rebuild_nested_in_pylist(self):
        t = Table.from_pylist([{"a": {"b": 1, "c": {"d": 2}}}])
        assert t.to_pylist(rebuild_nested=True) == [{"a": {"b": 1, "c": {"d": 2}}}]
