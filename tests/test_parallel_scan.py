"""Morsel-driven parallel scan: parity, counters, prefetch, compaction races.

The contract under test: ``read()`` with ``num_threads > 1`` is
byte-identical — order included — to the serial scan, counters lose no
updates to threading, a prefetch worker can neither swallow a traceback
nor leak blocked on a full queue, and parallel readers racing a background
``compact()`` always see a consistent snapshot.
"""
import os

# Force the shared-memory result transport for every process-executor test
# in this module (must precede the first worker spawn: workers freeze their
# environment at spawn time).
os.environ.setdefault("REPRO_SHM_MIN_BYTES", "0")

import threading
import time
import traceback

import numpy as np
import pytest

from repro.core import LoadConfig, ParquetDB, field, shm
from repro.core.scan import (MORSEL_ROWS, prefetch, process_scan_pool,
                             resolve_num_threads, scan_pool)


def _mkdb(tmp_path, name="pdb", n=4_000, files=4, **kw):
    """Several files with interleaved-range columns and some nulls."""
    kw.setdefault("row_group_rows", 500)
    kw.setdefault("page_rows", 125)
    db = ParquetDB(os.path.join(str(tmp_path), name), **kw)
    per = n // files
    for f in range(files):
        lo = f * per
        db.create([{"x": lo + i,
                    "y": float((lo + i) % 17),
                    "s": f"s{(lo + i) % 23:02d}",
                    "opt": None if (lo + i) % 5 == 0 else (lo + i) % 97}
                   for i in range(per)])
    return db


def _tables_equal(a, b):
    assert a.column_names == b.column_names
    assert a.num_rows == b.num_rows
    for c in a.column_names:
        assert a[c].to_pylist() == b[c].to_pylist(), c


FILTERS = [
    None,
    [field("x") >= 1_000],
    [(field("x") >= 700) & (field("x") < 2_900)],
    [field("s") == "s07"],
    [field("opt").is_null()],
    [field("y") != 3.0],
]
PROJECTIONS = [None, ["x"], ["s", "y"], ["opt", "x"]]


class TestParallelParity:
    @pytest.mark.parametrize("filters", FILTERS)
    @pytest.mark.parametrize("columns", PROJECTIONS)
    def test_matrix_threads_vs_serial(self, tmp_path, filters, columns):
        db = _mkdb(tmp_path)
        serial = db.read(columns=columns, filters=filters,
                         load_config=LoadConfig(num_threads=1))
        for nt in (2, 4):
            par = db.read(columns=columns, filters=filters,
                          load_config=LoadConfig(num_threads=nt))
            _tables_equal(serial, par)

    def test_parity_with_deltas(self, tmp_path):
        db = _mkdb(tmp_path, auto_compact=False)
        db.update([{"id": i, "x": -i} for i in range(0, 4_000, 7)])
        db.delete(ids=list(range(0, 4_000, 11)))
        db.update([{"id": 3, "x": 10**6}])
        for filters in (None, [field("x") >= 0],
                        [(field("x") > -50) & (field("x") < 2_000)]):
            serial = db.read(filters=filters,
                             load_config=LoadConfig(num_threads=1))
            par = db.read(filters=filters,
                          load_config=LoadConfig(num_threads=4))
            _tables_equal(serial, par)

    def test_batches_format_parity(self, tmp_path):
        db = _mkdb(tmp_path)
        s = list(db.read(load_format="batches", batch_size=333,
                         load_config=LoadConfig(num_threads=1)))
        p = list(db.read(load_format="batches", batch_size=333,
                         load_config=LoadConfig(num_threads=4)))
        assert [t.num_rows for t in s] == [t.num_rows for t in p]
        for a, b in zip(s, p):
            _tables_equal(a, b)

    def test_use_threads_false_forces_serial(self):
        assert resolve_num_threads(LoadConfig(use_threads=False,
                                              num_threads=8)) == 1
        assert resolve_num_threads(LoadConfig(num_threads=3)) == 3
        assert resolve_num_threads(LoadConfig()) == max(1, os.cpu_count() or 1)

    def test_pool_is_shared_and_grows(self):
        a = scan_pool(2)
        assert scan_pool(2) is a          # same size: same pool
        b = scan_pool(max(4, a._max_workers + 1))
        assert b is not a                 # grew: replaced
        assert scan_pool(2) is b          # never shrinks

    def test_pool_growth_does_not_kill_inflight_scans(self):
        """A scan holding the old pool must keep submitting after another
        caller grows the global slot (regression: grow-by-replace used to
        shut the old executor down, making refill submits raise)."""
        old = scan_pool(2)
        scan_pool(old._max_workers + 2)
        assert old.submit(lambda: 42).result() == 42


class TestCounterMerge:
    def test_no_lost_updates_under_threads(self, tmp_path):
        """Exec counters from an 8-way scan equal the serial scan's exactly;
        a racy shared `+=` would drop increments on this many row groups."""
        db = _mkdb(tmp_path, n=8_000, files=8)
        expr = [field("x") >= 0]
        serial = db.explain(filters=expr, execute=True,
                            load_config=LoadConfig(num_threads=1)).counters
        for _ in range(3):  # repeat: races are probabilistic
            par = db.explain(filters=expr, execute=True,
                             load_config=LoadConfig(num_threads=8)).counters
            assert par.to_dict() == serial.to_dict()

    def test_merge_from_sums_every_field(self):
        from repro.core import ScanCounters
        import dataclasses
        a = ScanCounters(**{f.name: 1 for f in
                            dataclasses.fields(ScanCounters)})
        b = ScanCounters(**{f.name: 2 for f in
                            dataclasses.fields(ScanCounters)})
        a.merge_from(b)
        assert all(getattr(a, f.name) == 3
                   for f in dataclasses.fields(ScanCounters))


class TestPrefetchRegression:
    def test_worker_traceback_propagates(self):
        def _inner_kaboom():
            raise ValueError("kaboom")

        def gen():
            yield 1
            _inner_kaboom()

        with pytest.raises(ValueError, match="kaboom") as ei:
            list(prefetch(gen(), 2))
        tb = "".join(traceback.format_exception(
            ei.type, ei.value, ei.tb))
        # the frame that raised inside the worker must be visible
        assert "_inner_kaboom" in tb

    def test_early_close_does_not_leak_blocked_worker(self):
        produced = threading.Event()

        def gen():  # unbounded producer: would block forever on a full
            i = 0   # queue if close() didn't drain + signal stop
            while True:
                produced.set()
                yield i
                i += 1

        g = prefetch(gen(), 1)
        assert next(g) == 0
        assert produced.wait(timeout=5)
        g.close()  # finally-block: stop, drain, join
        deadline = time.monotonic() + 5
        while time.monotonic() < deadline:
            if not any(t.name == "tpq-prefetch" and t.is_alive()
                       for t in threading.enumerate()):
                return
            time.sleep(0.01)
        pytest.fail("prefetch worker still alive after consumer close()")

    def test_error_mid_stream_also_joins_worker(self):
        def gen():
            yield from range(100)
            raise RuntimeError("late failure")

        with pytest.raises(RuntimeError, match="late failure"):
            list(prefetch(gen(), 1))
        time.sleep(0.05)
        assert not any(t.name == "tpq-prefetch" and t.is_alive()
                       for t in threading.enumerate())


class TestCompactionRace:
    def test_parallel_readers_see_consistent_snapshot(self, tmp_path):
        """Scans racing compact() must never mix generations or see
        partial merges (deferred GC keeps the old snapshot readable)."""
        db = _mkdb(tmp_path, n=2_000, files=4, auto_compact=False)
        db.update([{"id": i, "x": -1000 - i} for i in range(0, 2_000, 13)])
        db.delete(ids=list(range(5, 2_000, 31)))
        expected = db.read(load_config=LoadConfig(num_threads=1))
        exp_by_id = sorted(zip(expected["id"].to_pylist(),
                               expected["x"].to_pylist()))

        errors = []
        stop = threading.Event()

        def reader():
            cfg = LoadConfig(num_threads=2)
            try:
                while not stop.is_set():
                    t = db.read(load_config=cfg)
                    got = sorted(zip(t["id"].to_pylist(),
                                     t["x"].to_pylist()))
                    if got != exp_by_id:
                        errors.append("snapshot mismatch during compaction")
                        return
            except Exception as e:  # pragma: no cover - failure detail
                errors.append(f"{type(e).__name__}: {e}")

        threads = [threading.Thread(target=reader) for _ in range(4)]
        for t in threads:
            t.start()
        time.sleep(0.05)
        result = db.compact(force=True)
        time.sleep(0.1)
        stop.set()
        for t in threads:
            t.join(timeout=30)
        assert not errors, errors
        assert result.compacted
        # post-compaction reads still match, chain folded
        after = db.read(load_config=LoadConfig(num_threads=4))
        assert sorted(zip(after["id"].to_pylist(),
                          after["x"].to_pylist())) == exp_by_id
        assert db.n_delta_files == 0


PROC_CFG = LoadConfig(num_threads=2, executor="process")


class TestProcessExecutorParity:
    """executor="process": byte-identical (order included) to serial, with
    the shared-memory result transport forced on (REPRO_SHM_MIN_BYTES=0)."""

    @pytest.mark.parametrize("filters", FILTERS)
    @pytest.mark.parametrize("columns", PROJECTIONS)
    def test_matrix_process_vs_serial(self, tmp_path, filters, columns):
        db = _mkdb(tmp_path)
        serial = db.read(columns=columns, filters=filters,
                         load_config=LoadConfig(num_threads=1))
        par = db.read(columns=columns, filters=filters, load_config=PROC_CFG)
        _tables_equal(serial, par)
        assert shm.live_segments() == []

    def test_parity_with_deltas(self, tmp_path):
        """Merge-on-read under the process executor: overlay/residual run in
        the parent, so upserts+tombstones must land exactly as serial."""
        db = _mkdb(tmp_path, auto_compact=False)
        db.update([{"id": i, "x": -i} for i in range(0, 4_000, 7)])
        db.delete(ids=list(range(0, 4_000, 11)))
        db.update([{"id": 3, "x": 10**6}])
        for filters in (None, [field("x") >= 0],
                        [(field("x") > -50) & (field("x") < 2_000)]):
            serial = db.read(filters=filters,
                             load_config=LoadConfig(num_threads=1))
            par = db.read(filters=filters, load_config=PROC_CFG)
            _tables_equal(serial, par)
        assert shm.live_segments() == []

    def test_counters_match_serial_exactly(self, tmp_path):
        db = _mkdb(tmp_path, n=4_000, files=4)
        expr = [field("x") >= 0]
        serial = db.explain(filters=expr, execute=True,
                            load_config=LoadConfig(num_threads=1)).counters
        par = db.explain(filters=expr, execute=True,
                         load_config=PROC_CFG).counters
        assert par.to_dict() == serial.to_dict()

    def test_executor_value_validated(self, tmp_path):
        db = _mkdb(tmp_path, n=100, files=1)
        with pytest.raises(ValueError, match="unknown scan executor"):
            db.read(load_config=LoadConfig(num_threads=2, executor="forkpool"))

    def test_compaction_race_process_readers(self, tmp_path):
        """A worker process can lose its base file to a racing compact()
        (GC unlinks it); the parent must fall back to its cached mapping and
        the result must stay snapshot-consistent."""
        db = _mkdb(tmp_path, n=2_000, files=4, auto_compact=False)
        db.update([{"id": i, "x": -1000 - i} for i in range(0, 2_000, 13)])
        db.delete(ids=list(range(5, 2_000, 31)))
        expected = db.read(load_config=LoadConfig(num_threads=1))
        exp_by_id = sorted(zip(expected["id"].to_pylist(),
                               expected["x"].to_pylist()))
        errors = []
        stop = threading.Event()

        def reader():
            try:
                while not stop.is_set():
                    t = db.read(load_config=PROC_CFG)
                    got = sorted(zip(t["id"].to_pylist(),
                                     t["x"].to_pylist()))
                    if got != exp_by_id:
                        errors.append("snapshot mismatch during compaction")
                        return
            except Exception as e:  # pragma: no cover - failure detail
                errors.append(f"{type(e).__name__}: {e}")

        threads = [threading.Thread(target=reader) for _ in range(2)]
        for t in threads:
            t.start()
        time.sleep(0.05)
        result = db.compact(force=True)
        time.sleep(0.1)
        stop.set()
        for t in threads:
            t.join(timeout=60)
        assert not errors, errors
        assert result.compacted
        after = db.read(load_config=PROC_CFG)
        assert sorted(zip(after["id"].to_pylist(),
                          after["x"].to_pylist())) == exp_by_id
        assert shm.live_segments() == []

    def test_pool_is_shared_and_grows(self):
        a = process_scan_pool(2)
        assert process_scan_pool(2) is a
        b = process_scan_pool(a._max_workers + 1)
        assert b is not a
        assert process_scan_pool(2) is b  # never shrinks

    def test_broken_pool_is_replaced(self):
        """A BrokenProcessPool corpse must not stay cached — the next scan
        gets fresh workers."""
        a = process_scan_pool(2)
        a._broken = "workers terminated (simulated)"
        try:
            b = process_scan_pool(2)
            assert b is not a
            assert not b._broken
            assert b.submit(max, 2, 3).result(timeout=60) == 3
        finally:
            a._broken = False  # let the executor atexit hook reap it

    def test_broken_pool_mid_scan_degrades_inline(self, tmp_path,
                                                  monkeypatch):
        """If the pool breaks mid-scan (worker OOM-killed, or a spawn child
        of a __main__-guard-less script dying at bootstrap), the scan must
        finish inline with identical results — not raise."""
        from concurrent.futures import BrokenExecutor

        from repro.core import scan as scan_mod

        db = _mkdb(tmp_path)
        serial = db.read(load_config=LoadConfig(num_threads=1))

        class BrokenPool:
            def submit(self, *a, **kw):
                raise BrokenExecutor("simulated dead pool")

        monkeypatch.setattr(scan_mod, "process_scan_pool",
                            lambda n: BrokenPool())
        with pytest.warns(RuntimeWarning, match="process pool broke"):
            degraded = db.read(load_config=PROC_CFG)
        _tables_equal(serial, degraded)
        assert shm.live_segments() == []


class TestProcessEarlyTermination:
    def test_limit_shutdown_leaks_nothing(self, tmp_path):
        """Closing a process-executor scan mid-stream (limit satisfied) must
        drain in-flight morsels: no orphaned worker, no leaked shared-memory
        segment (atexit-checked registry stays empty), and the pool stays
        usable for the next scan."""
        db = _mkdb(tmp_path, n=8_000, files=8)
        q = (db.read(load_format="dataset",
                     load_config=LoadConfig(num_threads=2,
                                            executor="process",
                                            fragment_readahead=1))
             .query().limit(700))
        got = q.to_table()
        assert got.num_rows == 700
        serial = (db.read(load_format="dataset",
                          load_config=LoadConfig(num_threads=1))
                  .query().limit(700).to_table())
        _tables_equal(serial, got)
        # the finally-block drained every in-flight envelope
        assert shm.live_segments() == []
        # iterator-close path too (not just limit): abandon mid-iteration
        it = (db.read(load_format="dataset", load_config=PROC_CFG)
              .query().iter_batches(500))
        next(it)
        it.close()
        assert shm.live_segments() == []
        # no orphaned workers: the shared pool still answers
        pool = process_scan_pool(2)
        assert pool.submit(max, 2, 3).result(timeout=60) == 3


@pytest.mark.perf_smoke
@pytest.mark.skipif((os.cpu_count() or 1) < 4,
                    reason="GIL-convoy speedup needs >= 4 CPUs")
def test_process_executor_beats_gil_convoy(tmp_path):
    """The tentpole claim: on GIL-bound (entropy-coded, uncompressed) data,
    4 process workers must beat 1 by a real margin where 4 *threads* merely
    convoy.  The CI perf job runs this on a 4-CPU box; the hard >= 3x gate
    lives in scripts/check_perf.py over bench/BENCH_fig11.json."""
    db = ParquetDB(os.path.join(str(tmp_path), "convoy"), codec="none",
                   encoding="delta", row_group_rows=50_000, page_rows=4096,
                   with_bloom=False)
    n = 1_200_000
    db.create({"a": np.arange(n, dtype=np.int64),
               "b": np.arange(n, dtype=np.int64) * 3})

    def timed(cfg):
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            db.read(load_config=cfg)
            best = min(best, time.perf_counter() - t0)
        return best

    t1 = timed(LoadConfig(num_threads=1))
    tp = timed(LoadConfig(num_threads=4, executor="process"))
    assert tp < t1 / 1.5, (t1, tp)


class TestMorselShapes:
    def test_single_morsel_falls_back_to_serial_path(self, tmp_path):
        # one small file, one row group: must not spin up the pool
        db = ParquetDB(os.path.join(str(tmp_path), "tiny"))
        db.create([{"x": i} for i in range(10)])
        t = db.read(load_config=LoadConfig(num_threads=8))
        assert t.num_rows == 10

    def test_morsels_respect_row_cap_and_order(self, tmp_path):
        db = ParquetDB(os.path.join(str(tmp_path), "caps"),
                       row_group_rows=100, page_rows=50)
        db.create([{"x": i} for i in range(1_000)])
        plan = db.read(load_format="dataset").scan_plan()
        plan.fragments()
        morsels = plan._morsels()
        rgs = [i for _, run in morsels for i in run]
        assert rgs == sorted(rgs)  # plan order preserved
        rd_rows = 100
        for _, run in morsels:
            assert (len(run) - 1) * rd_rows < MORSEL_ROWS
