"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED config of the same family and runs one forward/train
step + a prefill/decode step on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import Model
from repro.models.frontends import synthetic_embeds

ARCHS = registry.ARCH_NAMES


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    emb = synthetic_embeds(cfg, B, seed)
    if emb is not None:
        batch["embeds"] = emb
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_family_matches_full(arch):
    full, red = registry.get(arch), registry.get_reduced(arch)
    assert full.family == red.family
    assert (full.attn is None) == (red.attn is None)
    assert (full.ssm is None) == (red.ssm is None)
    assert (full.moe is None) == (red.moe is None)
    if full.moe:
        assert (full.moe.every_k_layers == 2) == (red.moe.every_k_layers == 2)
        assert (full.moe.first_dense > 0) == (red.moe.first_dense > 0)
    if full.attn:
        assert bool(full.attn.window) == bool(red.attn.window)
        assert full.attn.qk_norm == red.attn.qk_norm
        assert full.attn.qkv_bias == red.attn.qkv_bias


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = registry.get_reduced(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss(p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss)), arch
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, f"{arch}: degenerate grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = registry.get_reduced(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    logits, cache = model.prefill(params, batch, cache_len=S + 4)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    lg, cache2 = model.decode_step(
        params, cache, batch["tokens"][:, :1], jnp.int32(S))
    assert lg.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all(), arch
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """Exact assigned numbers (the full configs are only compiled, never run)."""
    spec = {
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "mamba2-780m": (48, 1536, None, None, 0, 50280),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
    }[arch]
    cfg = registry.get(arch)
    L, d, H, KV, ff, V = spec
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.d_ff == ff and cfg.vocab == V
    if H is not None:
        assert cfg.attn.n_heads == H and cfg.attn.n_kv_heads == KV
    else:
        assert cfg.attn is None and cfg.ssm is not None
        assert cfg.ssm.d_state == 128


def test_moe_active_params_much_smaller_than_total():
    cfg = registry.get("moonshot-v1-16b-a3b")
    assert cfg.active_params_estimate() < cfg.total_params_estimate() / 3


def test_cells_skip_rules():
    cells = dict((a, [s.name for s in registry.cells_for(a)])
                 for a in ARCHS)
    assert "long_500k" in cells["mamba2-780m"]
    assert "long_500k" in cells["zamba2-2.7b"]
    assert "long_500k" in cells["h2o-danube-3-4b"]
    assert "long_500k" not in cells["qwen3-32b"]
    assert "long_500k" not in cells["seamless-m4t-medium"]
    total = sum(len(v) for v in cells.values())
    assert total == 33  # 10×3 + 3 long-context cells
