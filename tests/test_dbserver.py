"""Tests for the DB serving tier: plan canonicalization, wire protocol,
admission control, morsel budget, and snapshot-consistent result caching.

The cross-process writer test (``concurrency`` marker) is the headline:
while a second process commits MVCC updates mid-traffic, every server
response must be internally consistent with exactly one manifest
generation — the result cache may serve stale *generations* never, mixed
rows never.
"""
import os
import subprocess
import sys
import threading
import time

import pytest

from repro.core import (LoadConfig, MorselBudget, ParquetDB, field,
                        register_commit_listener)
from repro.core.query import canonical_expr
from repro.serve.dbserver import DBServer
from repro.serve.protocol import (DBClient, ProtocolError, encode_frame,
                                  expr_from_json, expr_to_json)

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


@pytest.fixture
def db(tmp_path):
    d = ParquetDB(str(tmp_path / "db"), "t", auto_compact=False)
    d.create([{"a": i, "b": i % 5, "v": 0, "s": f"s{i % 7}"}
              for i in range(2000)])
    return d


@pytest.fixture
def server(db):
    srv = DBServer(db, max_concurrent=2, max_queue=4)
    srv.start()
    yield srv
    srv.stop()


@pytest.fixture
def client(server):
    c = DBClient(*server.address)
    yield c
    c.close()


# ---------------------------------------------------------------------------
# plan-key canonicalization
# ---------------------------------------------------------------------------
class TestPlanKeyCanonicalization:
    def test_commutative_where_conjuncts(self, db):
        a, b, c = field("a") > 5, field("b") == 1, field("s") != "s0"
        q1 = db.query().where(a).where(b).where(c)
        q2 = db.query().where(c).where(a).where(b)
        q3 = db.query().where((c & b) & a)  # different tree shape too
        assert q1.plan_key() == q2.plan_key() == q3.plan_key()

    def test_reordered_select(self, db):
        q1 = db.query().select("a", "b", "s")
        q2 = db.query().select("s", "a", "b")
        assert q1.plan_key() == q2.plan_key()
        assert q1.plan_key() != db.query().select("a", "b").plan_key()

    def test_isin_value_order(self, db):
        q1 = db.query().where(field("b").isin([3, 1, 2]))
        q2 = db.query().where(field("b").isin([2, 3, 1, 1]))
        assert q1.plan_key() == q2.plan_key()
        q3 = db.query().where(field("b").isin([1, 2]))
        assert q1.plan_key() != q3.plan_key()

    def test_limit_offset_differentiate(self, db):
        base = db.query().where(field("b") == 1)
        assert base.limit(10).plan_key() != base.limit(11).plan_key()
        assert (base.limit(10).plan_key()
                != base.limit(10).offset(5).plan_key())
        assert base.plan_key() != base.limit(10).plan_key()

    def test_order_by_is_order_sensitive(self, db):
        q1 = db.query().order_by("a").order_by("b")
        q2 = db.query().order_by("b").order_by("a")
        assert q1.plan_key() != q2.plan_key()
        assert (db.query().order_by("a").plan_key()
                != db.query().order_by("a", desc=True).plan_key())

    def test_value_types_differentiate(self, db):
        # 1 and 1.0 compare equal in python but filter differently on
        # typed columns — the canonical form must keep them apart
        assert (canonical_expr(field("a") == 1)
                != canonical_expr(field("a") == 1.0))

    def test_and_or_not_conflated(self, db):
        q_and = db.query().where((field("a") > 5) & (field("b") == 1))
        q_or = db.query().where((field("a") > 5) | (field("b") == 1))
        assert q_and.plan_key() != q_or.plan_key()

    def test_server_converges_equivalent_requests(self, client):
        r1 = client.query(where=(field("a") > 100) & (field("b") == 2),
                          select=["a", "b"])
        r2 = client.query(where=(field("b") == 2) & (field("a") > 100),
                          select=["b", "a"])
        assert r1["status"] == r2["status"] == 200
        assert r1["plan_key"] == r2["plan_key"]
        assert r1["cache"] == "miss" and r2["cache"] == "hit"
        assert r2["rows"] == r1["rows"]
        r3 = client.query(where=(field("a") > 100) & (field("b") == 2),
                          select=["a", "b"], limit=3)
        assert r3["plan_key"] != r1["plan_key"]


# ---------------------------------------------------------------------------
# wire protocol
# ---------------------------------------------------------------------------
class TestProtocol:
    def test_expr_roundtrip(self):
        e = ((field("a") >= 3) & field("b").isin([1, 2])
             | ~(field("s") == "x")) & field("v").is_null().negate()
        spec = expr_to_json(e)
        assert canonical_expr(expr_from_json(spec)) == canonical_expr(e)

    def test_bad_expr_specs_raise(self):
        for bad in ([], ["cmp", "a"], ["cmp", "a", "~", 1],
                    ["isin", "a", 3], ["nope", "a"], "a > 3"):
            with pytest.raises(ProtocolError):
                expr_from_json(bad)

    def test_oversized_frame_refused(self):
        with pytest.raises(ProtocolError):
            encode_frame({"x": "y" * (70 << 20)})

    def test_server_rejects_garbage(self, client):
        assert client.request({"op": "no-such-op"})["status"] == 400
        assert client.request({"not-op": 1})["status"] == 400
        r = client.query(where=["cmp", "nope", "==", 1])
        assert r["status"] == 400 and "nope" in r["error"]

    def test_pipelined_requests_answer_in_order(self, server):
        c = DBClient(*server.address)
        try:
            c._sock.sendall(encode_frame({"op": "count"})
                            + encode_frame({"op": "ping"}))
            from repro.serve.protocol import recv_frame
            first, second = recv_frame(c._sock), recv_frame(c._sock)
            assert first["count"] == 2000
            assert second["pong"] is True
        finally:
            c.close()


# ---------------------------------------------------------------------------
# query surface vs the direct-API oracle
# ---------------------------------------------------------------------------
class TestQuerySurface:
    def test_rows_match_direct_query(self, db, client):
        expr = (field("a") > 50) & (field("b") == 3)
        want = (db.query().where(expr).select("a", "s")
                .order_by("a", desc=True).limit(7).to_pylist())
        got = client.query(where=expr, select=["a", "s"],
                           order_by=[["a", True]], limit=7)
        assert got["rows"] == want

    def test_count_and_scalar_agg(self, db, client):
        expr = field("b") == 1
        assert client.count(expr)["count"] == db.query().where(expr).count()
        want = db.query().agg({"a": ["min", "max", "mean"], "*": "count"})
        assert client.agg({"a": ["min", "max", "mean"],
                           "*": "count"})["values"] == want

    def test_group_by_agg(self, db, client):
        want = (db.query().group_by("b").agg({"a": "sum"})
                .order_by("b").to_pylist())
        got = client.query(group_by=["b"], agg={"a": "sum"},
                           order_by=["b"])
        assert got["rows"] == want

    def test_distinct(self, db, client):
        want = db.query().select("b").distinct().order_by("b").to_pylist()
        got = client.query(select=["b"], distinct=True, order_by=["b"])
        assert got["rows"] == want

    def test_explain_reports_plan(self, client):
        r = client.explain(where=field("a") > 100, limit=5)
        assert r["status"] == 200
        assert any(op == "Limit" for op, _ in r["ops"])
        assert any(op == "Filter" for op, _ in r["ops"])
        assert r["executed"] is False

    def test_writes_apply_and_bump_generation(self, db, client):
        g0 = client.ping() and db._load_snapshot()[0].generation
        u = client.update([{"id": 5, "v": 42}])
        assert u["status"] == 200 and u["updated"] == 1
        assert u["generation"] == g0 + 1
        got = client.query(where=field("a") == 5, select=["v"])
        assert got["rows"] == [{"v": 42}]
        d = client.delete(ids=[5])
        assert d["deleted"] == 1
        assert client.count(field("a") == 5)["count"] == 0


# ---------------------------------------------------------------------------
# caches + invalidation
# ---------------------------------------------------------------------------
class TestResultCache:
    def test_hit_after_miss_and_plan_cache(self, client, server):
        kw = dict(where=field("b") == 4, select=["a"])
        assert client.query(**kw)["cache"] == "miss"
        assert client.query(**kw)["cache"] == "hit"
        s = client.stats()
        assert s["stats"]["result_hits"] >= 1
        assert s["stats"]["plan_hits"] >= 1
        assert s["result_cache_entries"] >= 1

    def test_write_invalidates_only_superseded(self, db, client, server):
        kw = dict(where=field("b") == 4, select=["a", "v"])
        r1 = client.query(**kw)
        assert r1["cache"] == "miss"
        client.update([{"id": 4, "v": 7}])  # commits gen+1, fires listener
        r2 = client.query(**kw)
        assert r2["cache"] == "miss"  # superseded entry was dropped
        assert r2["generation"] == r1["generation"] + 1
        assert {"a": 4, "v": 7} in r2["rows"]
        assert client.query(**kw)["cache"] == "hit"  # new gen re-cached

    def test_out_of_band_writer_never_served_stale(self, db, server):
        """A writer with its own handle (no server, same files) — the
        in-process listener does fire (same process, same registry), but
        even without eager eviction the generation pin must redirect
        lookups to fresh entries."""
        c = DBClient(*server.address)
        try:
            kw = dict(where=field("a") < 50, select=["a", "v"])
            r1 = c.query(**kw)
            writer = ParquetDB(db.db_path, "t", auto_compact=False)
            writer.update([{"id": 1, "v": 99}])
            r2 = c.query(**kw)
            assert r2["generation"] > r1["generation"]
            assert {"a": 1, "v": 99} in r2["rows"]
        finally:
            c.close()


# ---------------------------------------------------------------------------
# admission control + morsel budget
# ---------------------------------------------------------------------------
class TestAdmission:
    def test_shed_beyond_queue(self, db):
        srv = DBServer(db, max_concurrent=1, max_queue=2)
        srv.start()
        gate = threading.Event()
        orig = srv._execute

        def gated(req):
            if req.get("limit") == 424242:  # blocker marker
                gate.wait(10)
            return orig(req)

        srv._execute = gated
        try:
            results = []

            def fire():
                c = DBClient(*srv.address)
                try:
                    results.append(c.query(limit=424242))
                finally:
                    c.close()

            threads = [threading.Thread(target=fire) for _ in range(3)]
            for t in threads:
                t.start()
            # wait until all three blockers are admitted (1 running + 2
            # queued), then the next request must shed immediately
            deadline = time.time() + 5
            while srv._pending < 3 and time.time() < deadline:
                time.sleep(0.01)
            assert srv._pending == 3
            prober = DBClient(*srv.address)
            try:
                t0 = time.time()
                shed = prober.query(where=field("a") > 0, limit=1)
                assert shed["status"] == 503
                assert shed["retry"] is True
                assert shed["queue_depth"] == 2
                assert time.time() - t0 < 2  # immediate, not queued
                # control verbs bypass admission even under full load
                assert prober.ping()["status"] == 200
                assert prober.stats()["status"] == 200
            finally:
                prober.close()
            gate.set()
            for t in threads:
                t.join(10)
            assert all(r["status"] == 200 for r in results)
            assert srv.stats.snapshot()["shed"] == 1
        finally:
            gate.set()
            srv.stop()


class TestMorselBudget:
    def test_limits_and_counters(self):
        mb = MorselBudget(2)
        mb.acquire()
        mb.acquire()
        assert mb.saturated
        assert not mb.try_acquire()
        mb.release()
        assert mb.try_acquire()
        mb.release()
        mb.release()
        st = mb.stats()
        assert st == {"limit": 2, "in_flight": 0, "peak_in_flight": 2,
                      "total_acquired": 3, "waits": 1}
        with pytest.raises(ValueError):
            MorselBudget(0)

    def test_blocking_acquire_wakes_on_release(self):
        mb = MorselBudget(1)
        mb.acquire()
        acquired = threading.Event()

        def waiter():
            mb.acquire()
            acquired.set()
            mb.release()

        t = threading.Thread(target=waiter)
        t.start()
        time.sleep(0.05)
        assert not acquired.is_set()
        mb.release()
        t.join(5)
        assert acquired.is_set()
        assert mb.stats()["waits"] == 1

    @pytest.mark.parametrize("executor", ["thread", "process"])
    def test_scan_charges_and_returns_permits(self, tmp_path, executor):
        if executor == "process":
            pytest.importorskip("multiprocessing")
        db = ParquetDB(str(tmp_path / "db"), "t", auto_compact=False)
        db.create([{"x": i, "y": i % 3} for i in range(20_000)])
        mb = MorselBudget(1)  # tightest budget must still complete
        cfg = LoadConfig(num_threads=2, executor=executor,
                         morsel_budget=mb)
        t = db.query(load_config=cfg).where(field("y") == 1).to_table()
        assert t.num_rows == len([i for i in range(20_000) if i % 3 == 1])
        st = mb.stats()
        assert st["in_flight"] == 0          # every permit returned
        assert st["peak_in_flight"] <= 1     # cap respected
        assert st["total_acquired"] >= 1

    def test_early_close_returns_permits(self, tmp_path):
        db = ParquetDB(str(tmp_path / "db"), "t", auto_compact=False)
        db.create([{"x": i} for i in range(50_000)])
        mb = MorselBudget(2)
        cfg = LoadConfig(num_threads=2, executor="thread",
                         morsel_budget=mb)
        # limit(1) closes the scan generator early — the finally path
        # must hand back the permits of cancelled in-flight morsels
        rows = db.query(load_config=cfg).limit(1).to_pylist()
        assert len(rows) == 1
        assert mb.stats()["in_flight"] == 0

    def test_concurrent_scans_share_budget(self, tmp_path):
        db = ParquetDB(str(tmp_path / "db"), "t", auto_compact=False)
        db.create([{"x": i} for i in range(60_000)])
        mb = MorselBudget(2)
        cfg = LoadConfig(num_threads=2, executor="thread",
                         morsel_budget=mb)
        errors = []

        def scan():
            try:
                n = db.query(load_config=cfg).count()
                assert n == 60_000
            except Exception as e:  # pragma: no cover
                errors.append(e)

        threads = [threading.Thread(target=scan) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60)
        assert not errors
        assert not any(t.is_alive() for t in threads), "budget deadlock"
        st = mb.stats()
        assert st["in_flight"] == 0
        assert st["peak_in_flight"] <= 2


# ---------------------------------------------------------------------------
# snapshot consistency under a concurrent writer process
# ---------------------------------------------------------------------------
_WRITER_CODE = """
import sys, time
sys.path.insert(0, {src!r})
from repro.core import ParquetDB
db = ParquetDB({path!r}, "t", auto_compact=False)
for k in range(1, {commits} + 1):
    db.update([{{"id": i, "v": k}} for i in range({rows})])
    time.sleep(0.01)
print("writer done", flush=True)
"""


@pytest.mark.concurrency
def test_server_snapshot_consistent_under_writer_process(tmp_path):
    if (os.cpu_count() or 1) < 2 and not os.environ.get(
            "REPRO_FORCE_CONCURRENCY"):
        pytest.skip("SKIPPED (loud): cross-process writer test needs >= 2 "
                    f"cpus; this box has {os.cpu_count()} — run the CI "
                    "concurrency job, or set REPRO_FORCE_CONCURRENCY=1")
    n_rows, commits = 200, 12
    db = ParquetDB(str(tmp_path / "db"), "t", auto_compact=False)
    # commit k sets every row's v to k, so a snapshot-consistent response
    # must be uniform in v and satisfy v == generation - 1 exactly
    # (generation 1 is the create with v=0)
    db.create([{"a": i, "v": 0} for i in range(n_rows)])
    srv = DBServer(db, max_concurrent=2, max_queue=8)
    host, port = srv.start()
    code = _WRITER_CODE.format(src=SRC, path=db.db_path,
                               commits=commits, rows=n_rows)
    proc = subprocess.Popen([sys.executable, "-c", code],
                            stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE)
    c = DBClient(host, port)
    try:
        last_gen, seen_gens = 0, set()
        deadline = time.time() + 120
        while time.time() < deadline:
            r = c.query(select=["v"])  # cached or not — both must hold
            assert r["status"] == 200
            vs = {row["v"] for row in r["rows"]}
            assert len(r["rows"]) == n_rows
            assert len(vs) == 1, (
                f"torn read: generation {r['generation']} mixed v={vs}")
            (v,) = vs
            assert v == r["generation"] - 1, (
                f"stale cache: generation {r['generation']} served v={v}")
            assert r["generation"] >= last_gen, "generation went backwards"
            last_gen = r["generation"]
            seen_gens.add(r["generation"])
            if proc.poll() is not None and v == commits:
                break
        out, err = proc.communicate(timeout=60)
        assert proc.returncode == 0, err.decode()
        assert last_gen == commits + 1  # observed the writer's final commit
        assert len(seen_gens) > 1      # actually raced through generations
    finally:
        proc.kill()
        c.close()
        srv.stop()
