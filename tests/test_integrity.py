"""End-to-end integrity: checksums, the scrubber, and the IO fault matrix.

Every test here follows the same contract:

- **corruption is never silent** — a damaged byte either raises a typed
  :class:`IntegrityError` subclass (with file / row-group / column / page
  coordinates) or the read returns exactly the pristine oracle rows;
- **write faults never damage the committed snapshot** — an ENOSPC at any
  byte offset during create/update/compact leaves the previously committed
  files byte-identical and readable on reopen.

Fault injection uses the hooks in :mod:`repro.core.integrity`
(``WRITE_FAULT_HOOK`` / ``READ_FAULT_HOOK``), the ``REPRO_TEST_KILL_WORKER``
env switch in :mod:`repro.core.scan`, and plain byte surgery on .tpq files.
"""
import errno
import json
import os
import warnings

import numpy as np
import pytest

from repro.core import (CorruptFooterError, CorruptPageError, IntegrityError,
                        LoadConfig, ParquetDB, Table, TPQReader, TPQWriter,
                        TruncatedFileError, write_table)
from repro.core import integrity, scan
from repro.core import transactions as tx
from repro.core.fileformat import MAGIC, TRAILER_V2

pytestmark = pytest.mark.faults


@pytest.fixture(autouse=True)
def _clean_hooks():
    yield
    integrity.WRITE_FAULT_HOOK = None
    integrity.READ_FAULT_HOOK = None


def _mixed_table(n: int = 3000) -> Table:
    rng = np.arange(n)
    return Table.from_pydict({
        "x": rng,
        "f": rng * 0.25,
        "s": np.array([f"row-{i % 17}" for i in range(n)], dtype=object),
    })


def _flip(path: str, offset: int, mask: int = 0x40) -> None:
    with open(path, "r+b") as fh:
        fh.seek(offset)
        b = fh.read(1)
        fh.seek(offset)
        fh.write(bytes([b[0] ^ mask]))


def _first_page_offset(path: str) -> int:
    rd = TPQReader(path)
    for _rg, _col, _page, _key, buf in rd.iter_page_buffers():
        return buf["off"] + buf["len"] // 2
    raise AssertionError("file has no pages")


def _tpq_bytes(dirpath: str) -> dict:
    out = {}
    for fn in os.listdir(dirpath):
        if fn.endswith(".tpq"):
            with open(os.path.join(dirpath, fn), "rb") as fh:
                out[fn] = fh.read()
    return out


# ---------------------------------------------------------------------------
# Open-time parse errors (wrong magic, torn trailer, truncation, empty file)
# ---------------------------------------------------------------------------
class TestOpenErrors:
    @pytest.fixture
    def tpq(self, tmp_path):
        p = str(tmp_path / "f.tpq")
        write_table(p, _mixed_table(500))
        return p

    def test_wrong_magic(self, tpq):
        _flip(tpq, 0)
        with pytest.raises(CorruptFooterError, match="magic"):
            TPQReader(tpq)

    def test_trailer_garbage(self, tpq):
        size = os.path.getsize(tpq)
        _flip(tpq, size - 2)
        with pytest.raises(TruncatedFileError):
            TPQReader(tpq)

    def test_footer_length_past_eof(self, tpq):
        size = os.path.getsize(tpq)
        with open(tpq, "r+b") as fh:
            fh.seek(size - 12)  # v2 trailer: <crc u32> <flen u64> TPQ2
            fh.write((1 << 40).to_bytes(8, "little"))
        with pytest.raises(TruncatedFileError):
            TPQReader(tpq)

    def test_empty_file(self, tmp_path):
        p = str(tmp_path / "empty.tpq")
        open(p, "wb").close()
        with pytest.raises(TruncatedFileError):
            TPQReader(p)

    def test_tiny_file(self, tmp_path):
        p = str(tmp_path / "tiny.tpq")
        with open(p, "wb") as fh:
            fh.write(MAGIC + b"1234")
        with pytest.raises(TruncatedFileError):
            TPQReader(p)

    def test_torn_footer_blob(self, tpq):
        size = os.path.getsize(tpq)
        with open(tpq, "rb") as fh:
            buf = fh.read()
        flen = int.from_bytes(buf[size - 12:size - 4], "little")
        _flip(tpq, size - 16 - flen + flen // 2)  # mid-footer-blob
        with pytest.raises(CorruptFooterError, match="checksum"):
            TPQReader(tpq)

    def test_errors_pickle_with_coordinates(self):
        import pickle
        e = CorruptPageError("f.tpq", "crc mismatch", row_group=2,
                             column="s", page=7)
        e2 = pickle.loads(pickle.dumps(e))
        assert isinstance(e2, CorruptPageError) and isinstance(e2, IOError)
        assert (e2.row_group, e2.column, e2.page) == (2, "s", 7)
        assert "rg=2" in str(e2) and "col=s" in str(e2)


# ---------------------------------------------------------------------------
# Bit-flip matrix: every page payload in the file, one flip at a time
# ---------------------------------------------------------------------------
def test_bitflip_every_page_detected(tmp_path):
    p = str(tmp_path / "f.tpq")
    t = _mixed_table(20_000)  # several pages per column
    write_table(p, t, page_rows=4096, row_group_rows=8192)
    oracle = t.to_pydict()
    with open(p, "rb") as fh:
        pristine = fh.read()
    targets = [(rg, col, page, buf["off"], buf["len"])
               for rg, col, page, _key, buf in TPQReader(p).iter_page_buffers()]
    assert len(targets) >= 12, "matrix too small to be meaningful"
    for rg, col, page, off, ln in targets:
        damaged = bytearray(pristine)
        damaged[off + ln // 2] ^= 0x40
        with open(p, "wb") as fh:
            fh.write(bytes(damaged))
        try:
            got = TPQReader(p).read().to_pydict()
        except CorruptPageError as e:
            assert (e.row_group, e.column, e.page) == (rg, col, page), \
                f"wrong coordinates for flip in rg={rg} col={col} page={page}"
        else:
            pytest.fail(f"silent corruption: flip at rg={rg} col={col} "
                        f"page={page} off={off} returned rows "
                        f"{'equal to' if got == oracle else 'DIFFERENT from'}"
                        " oracle without raising")
    # restore and prove the oracle still holds
    with open(p, "wb") as fh:
        fh.write(pristine)
    assert TPQReader(p).read().to_pydict() == oracle


def test_verify_pages_sweep_finds_flip_without_decode(tmp_path):
    p = str(tmp_path / "f.tpq")
    write_table(p, _mixed_table(2000))
    assert TPQReader(p).verify_pages() > 0
    _flip(p, _first_page_offset(p))
    with pytest.raises(CorruptPageError):
        TPQReader(p).verify_pages()


def test_truncation_ladder(tmp_path):
    p = str(tmp_path / "f.tpq")
    write_table(p, _mixed_table(4000))
    with open(p, "rb") as fh:
        pristine = fh.read()
    size = len(pristine)
    cuts = sorted({0, 1, 4, 15, 16, size // 4, size // 2, 3 * size // 4,
                   size - 25, size - 16, size - 12, size - 4, size - 1})
    for cut in cuts:
        with open(p, "wb") as fh:
            fh.write(pristine[:cut])
        with pytest.raises(IntegrityError):
            TPQReader(p).read()


# ---------------------------------------------------------------------------
# Legacy v1 files: readable, reported unchecksummed
# ---------------------------------------------------------------------------
def test_legacy_v1_roundtrip_and_report(tmp_path):
    p = str(tmp_path / "v1.tpq")
    t = _mixed_table(1000)
    write_table(p, t, checksums=False)
    with open(p, "rb") as fh:
        tail = fh.read()[-4:]
    assert tail == MAGIC and tail != TRAILER_V2
    rd = TPQReader(p)
    assert rd.checksummed is False
    assert rd.verify_pages() == 0  # nothing to sweep
    assert rd.read().to_pydict() == t.to_pydict()
    check = integrity.verify_file(p, deep=True)
    assert check.status == "ok" and check.checksummed is False
    assert "legacy" in str(check)


def test_v2_default_and_verify_modes(tmp_path):
    p = str(tmp_path / "v2.tpq")
    t = _mixed_table(1000)
    write_table(p, t)
    with open(p, "rb") as fh:
        assert fh.read()[-4:] == TRAILER_V2
    rd = TPQReader(p)
    assert rd.checksummed is True
    for mode in (None, "page", "footer", "off"):
        assert rd.read(verify=mode).to_pydict() == t.to_pydict()


# ---------------------------------------------------------------------------
# The scrubber: db.verify()
# ---------------------------------------------------------------------------
class TestScrubber:
    @pytest.fixture
    def db(self, tmp_path):
        db = ParquetDB(str(tmp_path / "db"), "db")
        db.create([{"x": i, "s": f"s{i}"} for i in range(200)])
        db.update([{"id": 5, "x": -5}])          # upsert delta
        db.delete(ids=[7])                       # tombstone delta
        return db

    def test_clean_dataset(self, db):
        rep = db.verify()
        assert rep.ok and rep.deep
        assert rep.files_corrupt == 0 and rep.files_missing == 0
        assert rep.files_ok == len(rep.files) >= 3  # base + 2 deltas
        assert rep.pages_verified > 0
        assert {c.kind for c in rep.files} == {"base", "upsert", "tombstone"}
        assert "OK" in str(rep)
        shallow = db.verify(deep=False)
        assert shallow.ok and shallow.pages_verified == 0

    def test_corrupt_and_missing_files_reported(self, db, tmp_path):
        man, _ = db._load_snapshot()
        deltas = [d.name for d in man.deltas]
        _flip(db._dir.file_path(deltas[0]),
              _first_page_offset(db._dir.file_path(deltas[0])))
        os.remove(db._dir.file_path(deltas[1]))
        rep = ParquetDB(str(tmp_path / "db"), "db").verify()
        assert not rep.ok
        assert rep.files_corrupt == 1 and rep.files_missing == 1
        assert isinstance(rep.first_error, IntegrityError)
        assert "CORRUPT" in str(rep) and deltas[0] in str(rep)

    def test_shallow_misses_page_damage_deep_catches_it(self, db, tmp_path):
        man, _ = db._load_snapshot()
        base = db._dir.file_path(man.files[0])
        _flip(base, _first_page_offset(base))
        db2 = ParquetDB(str(tmp_path / "db"), "db")
        assert db2.verify(deep=False).ok          # footer is intact
        deep = ParquetDB(str(tmp_path / "db"), "db").verify(deep=True)
        assert not deep.ok and deep.files_corrupt == 1


# ---------------------------------------------------------------------------
# Scan-time corruption policy: raise vs quarantine
# ---------------------------------------------------------------------------
class TestCorruptionPolicy:
    @pytest.fixture
    def dbdir(self, tmp_path):
        db = ParquetDB(str(tmp_path / "db"), "db")
        db.create([{"x": i} for i in range(100)])
        db.update([{"id": 5, "x": -5}])
        return str(tmp_path / "db")

    def _corrupt_delta(self, dbdir):
        db = ParquetDB(dbdir, "db")
        man, _ = db._load_snapshot()
        path = db._dir.file_path(man.deltas[0].name)
        _flip(path, _first_page_offset(path))

    def test_default_raises_on_corrupt_delta(self, dbdir):
        self._corrupt_delta(dbdir)
        with pytest.raises(IntegrityError):
            ParquetDB(dbdir, "db").read()

    def test_quarantine_skips_delta_and_counts_it(self, dbdir):
        self._corrupt_delta(dbdir)
        cfg = LoadConfig(on_corruption="quarantine")
        with pytest.warns(RuntimeWarning, match="quarantin"):
            t = ParquetDB(dbdir, "db").read(load_config=cfg)
        got = t.to_pydict()["x"]
        assert sorted(got) == list(range(100))  # base rows, upsert skipped
        with pytest.warns(RuntimeWarning, match="quarantin"):
            rep = ParquetDB(dbdir, "db").explain(execute=True,
                                                 load_config=cfg)
        assert rep.counters.files_quarantined == 1
        assert "QUARANTINED" in str(rep)

    def test_corrupt_base_always_raises(self, dbdir):
        db = ParquetDB(dbdir, "db")
        man, _ = db._load_snapshot()
        base = db._dir.file_path(man.files[0])
        _flip(base, _first_page_offset(base))
        with pytest.raises(IntegrityError):
            ParquetDB(dbdir, "db").read(
                load_config=LoadConfig(on_corruption="quarantine"))

    def test_bad_knob_values_rejected(self, dbdir):
        with pytest.raises(ValueError):
            ParquetDB(dbdir, "db").read(load_config=LoadConfig(verify="no"))
        with pytest.raises(ValueError):
            ParquetDB(dbdir, "db").read(
                load_config=LoadConfig(on_corruption="ignore"))


# ---------------------------------------------------------------------------
# Write faults: ENOSPC after K bytes must never damage the committed snapshot
# ---------------------------------------------------------------------------
def _budget_hook(k: int):
    state = {"written": 0}
    def hook(path, nbytes):
        if state["written"] + nbytes > k:
            raise OSError(errno.ENOSPC, "No space left on device (injected)")
        state["written"] += nbytes
    return hook


def _write_sizes(op) -> list:
    """Run ``op`` once recording every TPQWriter write size, fault-free."""
    sizes = []
    integrity.WRITE_FAULT_HOOK = lambda path, n: sizes.append(n)
    try:
        op()
    finally:
        integrity.WRITE_FAULT_HOOK = None
    return sizes


class TestWriteFaults:
    def _assert_snapshot_intact(self, dbdir, files_before, rows_before):
        assert _tpq_bytes(dbdir) == files_before, \
            "fault left partial/altered .tpq files behind"
        db = ParquetDB(dbdir, "db")
        assert db.read().to_pydict() == rows_before

    def test_enospc_sweep_during_create(self, tmp_path):
        batch = [{"x": 1000 + i, "s": "new"} for i in range(500)]
        # probe the write-size profile on a scratch dataset
        sdb = ParquetDB(str(tmp_path / "scratch"), "db")
        sdb.create([{"x": i, "s": f"s{i}"} for i in range(300)])
        sizes = _write_sizes(lambda: sdb.create(batch))
        total = sum(sizes)
        # the real dataset whose snapshot must survive every cut
        dbdir = str(tmp_path / "db")
        db = ParquetDB(dbdir, "db")
        db.create([{"x": i, "s": f"s{i}"} for i in range(300)])
        files_before = _tpq_bytes(dbdir)
        rows_before = db.read().to_pydict()
        bounds = np.cumsum(sizes)
        cuts = sorted({0, 1, *(int(b) - 1 for b in bounds if b > 0),
                       *(int(b) for b in bounds[:-1]), total // 2})
        cuts = [k for k in cuts if 0 <= k < total]
        assert len(cuts) >= 5
        for k in cuts:
            integrity.WRITE_FAULT_HOOK = _budget_hook(k)
            with pytest.raises(OSError):
                ParquetDB(dbdir, "db").create(batch)
            integrity.WRITE_FAULT_HOOK = None
            self._assert_snapshot_intact(dbdir, files_before, rows_before)
        # disk "freed": the same create now commits
        ParquetDB(dbdir, "db").create(batch)
        assert ParquetDB(dbdir, "db").n_rows == 800

    def test_enospc_during_update_stage(self, tmp_path):
        dbdir = str(tmp_path / "db")
        db = ParquetDB(dbdir, "db")
        db.create([{"x": i} for i in range(100)])
        files_before = _tpq_bytes(dbdir)
        rows_before = db.read().to_pydict()
        integrity.WRITE_FAULT_HOOK = _budget_hook(0)
        with pytest.raises(OSError):
            ParquetDB(dbdir, "db").update([{"id": 3, "x": -3}])
        integrity.WRITE_FAULT_HOOK = None
        self._assert_snapshot_intact(dbdir, files_before, rows_before)

    def test_enospc_during_compaction(self, tmp_path):
        dbdir = str(tmp_path / "db")
        db = ParquetDB(dbdir, "db")
        for i in range(6):  # several small files worth compacting
            db.create([{"x": 100 * i + j} for j in range(100)])
        db = ParquetDB(dbdir, "db")
        files_before = _tpq_bytes(dbdir)
        rows_before = db.read().to_pydict()
        sizes = _write_sizes(
            lambda: ParquetDB(str(tmp_path / "scratch"), "db").create(
                [{"x": i} for i in range(600)]))
        for k in (0, 1, sum(sizes) // 2):
            integrity.WRITE_FAULT_HOOK = _budget_hook(k)
            with pytest.raises(OSError):
                ParquetDB(dbdir, "db").compact(force=True)
            integrity.WRITE_FAULT_HOOK = None
            self._assert_snapshot_intact(dbdir, files_before, rows_before)
        # and the retry succeeds once space is back
        res = ParquetDB(dbdir, "db").compact(force=True)
        assert res.compacted
        db2 = ParquetDB(dbdir, "db")
        assert sorted(db2.read().to_pydict()["x"]) == sorted(rows_before["x"])
        assert db2.n_files < len(files_before)

    def test_failed_writer_leaves_no_valid_footer(self, tmp_path):
        p = str(tmp_path / "partial.tpq")
        with pytest.raises(RuntimeError):
            with TPQWriter(p) as w:
                w.write_table(_mixed_table(100))
                raise RuntimeError("interrupted mid-write")
        # the partial file must not parse as a sealed TPQ file
        with pytest.raises(IntegrityError):
            TPQReader(p)


# ---------------------------------------------------------------------------
# Transient read faults: bounded-backoff retry
# ---------------------------------------------------------------------------
class TestReadRetries:
    def test_transient_eio_retried(self, tmp_path, monkeypatch):
        monkeypatch.setattr(integrity, "READ_RETRY_BACKOFF", 0.0001)
        p = str(tmp_path / "f.tpq")
        write_table(p, _mixed_table(100))
        calls = {"n": 0}
        def hook(path):
            calls["n"] += 1
            if calls["n"] <= 2:
                raise OSError(errno.EIO, "I/O error (injected)")
        integrity.READ_FAULT_HOOK = hook
        rd = integrity.with_read_retries(lambda: TPQReader(p), p)
        assert calls["n"] == 3 and rd.num_rows == 100

    def test_persistent_eio_gives_up(self, tmp_path, monkeypatch):
        monkeypatch.setattr(integrity, "READ_RETRY_BACKOFF", 0.0001)
        calls = {"n": 0}
        def hook(path):
            calls["n"] += 1
            raise OSError(errno.EIO, "I/O error (injected)")
        integrity.READ_FAULT_HOOK = hook
        with pytest.raises(OSError):
            integrity.with_read_retries(lambda: None, "f.tpq")
        assert calls["n"] == integrity.READ_RETRIES

    def test_corruption_is_not_retried(self, tmp_path):
        p = str(tmp_path / "f.tpq")
        write_table(p, _mixed_table(100))
        _flip(p, 0)  # break the magic
        calls = {"n": 0}
        def hook(path):
            calls["n"] += 1
        integrity.READ_FAULT_HOOK = hook
        with pytest.raises(CorruptFooterError):
            integrity.with_read_retries(lambda: TPQReader(p), p)
        assert calls["n"] == 1

    def test_db_read_survives_one_transient_fault(self, tmp_path, monkeypatch):
        monkeypatch.setattr(integrity, "READ_RETRY_BACKOFF", 0.0001)
        db = ParquetDB(str(tmp_path / "db"), "db")
        db.create([{"x": i} for i in range(50)])
        failed = {"done": False}
        def hook(path):
            if not failed["done"]:
                failed["done"] = True
                raise OSError(errno.EIO, "I/O error (injected)")
        integrity.READ_FAULT_HOOK = hook
        db2 = ParquetDB(str(tmp_path / "db"), "db")
        assert db2.read().num_rows == 50 and failed["done"]


# ---------------------------------------------------------------------------
# Manifest pointer corruption (regression: used to escape as JSONDecodeError
# or TypeError from ParquetDB.__init__)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("blob", [b"", b'{"da', b"null", b"{}", b"[]",
                                  b'{"generation": "not-a-manifest"}'])
def test_damaged_pointer_self_heals(tmp_path, blob):
    dbdir = str(tmp_path / "db")
    db = ParquetDB(dbdir, "db")
    db.create([{"x": i} for i in range(20)])
    ptr = db._dir.file_path(tx.MANIFEST)
    with open(ptr, "wb") as fh:
        fh.write(blob)
    db2 = ParquetDB(dbdir, "db")  # must not raise
    assert db2.read().to_pydict()["x"] == list(range(20))
    with open(ptr, "rb") as fh:  # pointer repaired to valid JSON
        man = json.load(fh)
    assert man["dataset"] == "db" and man["generation"] >= 1


# ---------------------------------------------------------------------------
# Process-pool worker crash: rebuild once, then finish inline — right rows
# ---------------------------------------------------------------------------
def _reset_process_pool():
    with scan._PPOOL_LOCK:
        if scan._PPOOL is not None:
            scan._PPOOL.shutdown(wait=False, cancel_futures=True)
        scan._PPOOL = None
        scan._PPOOL_WORKERS = 0


@pytest.mark.skipif((os.cpu_count() or 1) < 2, reason="needs >= 2 cpus")
def test_worker_crash_rebuilds_then_decodes_inline(tmp_path):
    db = ParquetDB(str(tmp_path / "db"), "db")
    for i in range(6):  # several fragments => several morsels
        db.create([{"x": 1000 * i + j} for j in range(500)])
    db = ParquetDB(str(tmp_path / "db"), "db")
    oracle = sorted(db.read().to_pydict()["x"])
    cfg = LoadConfig(executor="process", num_threads=2)
    os.environ[scan.ENV_TEST_KILL_WORKER] = "1"
    _reset_process_pool()  # fresh pool so spawned workers see the kill switch
    try:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            rep = db.explain(execute=True, load_config=cfg)
            t = db.read(load_config=cfg)
        assert any("pool" in str(x.message) for x in w)
        assert sorted(t.to_pydict()["x"]) == oracle  # never wrong rows
        c = rep.counters
        assert c.pool_rebuilds == 1
        assert c.morsels_decoded_inline >= 1
        assert "degraded" in str(rep)
    finally:
        os.environ.pop(scan.ENV_TEST_KILL_WORKER, None)
        _reset_process_pool()  # don't poison later tests with dying workers
