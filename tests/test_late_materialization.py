"""Selection-vector late materialization: edge cases + counter reporting.

The two-phase reader turns the filter-column mask into a per-page selection
vector and materializes only the selected rows of payload columns.  These
tests pin the edge cases — empty selection, all-rows selection, all-null
pages, var-len/list/tensor payloads — and assert the result is always
row-identical to a full scan, with ``rows_skipped_late``/``bytes_saved_late``
reported by ``explain(execute=True)``.
"""
import os

import numpy as np
import pytest

from repro.core import (LoadConfig, NormalizeConfig, ParquetDB, Table,
                        TPQReader, field, write_table)
from repro.core.scan import ScanCounters


def norm(v):
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, dict):
        return {k: norm(x) for k, x in v.items()}
    if isinstance(v, list):
        return [norm(x) for x in v]
    return v


@pytest.fixture()
def mixed_file(tmp_path):
    """One file, 4 pages of 250 rows, every column kind as payload."""
    n = 1000
    rng = np.random.default_rng(5)
    t = Table.from_pydict({
        "k": np.arange(n),
        "f": rng.standard_normal(n),
        "s": [f"val_{i % 13}_{'x' * (i % 7)}" for i in range(n)],
        "t": rng.standard_normal((n, 2, 2)),
        "l": [[j for j in range(i % 4)] for i in range(n)],
        "ls": [[f"s{j}" for j in range(i % 3)] for i in range(n)],
    })
    p = str(tmp_path / "late.tpq")
    write_table(p, t, page_rows=250, row_group_rows=1000)
    return p, t


def _read(path, expr, **kw):
    c = ScanCounters()
    out = TPQReader(path).read(filter_expr=expr, counters=c, **kw)
    return out, c


class TestSelectionVector:
    def test_sparse_selection_all_kinds(self, mixed_file):
        p, t = mixed_file
        out, c = _read(p, (field("k") >= 100) & (field("k") < 103))
        assert out.num_rows == 3
        full = t.filter_mask(((np.arange(1000) >= 100) & (np.arange(1000) < 103)))
        assert norm(out.to_pylist()) == norm(full.to_pylist())
        assert c.rows_skipped_late > 0
        assert c.bytes_saved_late > 0

    def test_all_rows_selection_skips_nothing(self, mixed_file):
        p, t = mixed_file
        out, c = _read(p, field("k") >= 0)   # every row matches
        assert out.num_rows == 1000
        assert c.rows_skipped_late == 0
        assert c.bytes_saved_late == 0
        assert norm(out.to_pylist()) == norm(t.to_pylist())

    def test_empty_selection_yields_nothing(self, mixed_file):
        p, _ = mixed_file
        # explicit row-group selection is authoritative (no stats pruning)
        # and page pruning is off: every page reaches phase 1, every mask
        # comes back empty, no payload column is ever touched
        out, c = _read(p, field("k") < 0, row_groups=[0], prune_pages=False)
        assert out.num_rows == 0
        assert c.rows_skipped_late == 0   # nothing was kept to late-skip

    def test_single_row_per_page(self, mixed_file):
        p, t = mixed_file
        out, _ = _read(p, field("k").isin([10, 260, 510, 990]))
        assert sorted(out["k"].to_pylist()) == [10, 260, 510, 990]
        oracle = t.filter_mask(np.isin(np.arange(1000), [10, 260, 510, 990]))
        assert norm(out.to_pylist()) == norm(oracle.to_pylist())

    def test_all_null_payload_page(self, tmp_path):
        t = Table.from_pylist(
            [{"k": i, "v": None if i < 500 else float(i)} for i in range(1000)])
        p = str(tmp_path / "nulls.tpq")
        write_table(p, t, page_rows=250, row_group_rows=1000)
        out, c = _read(p, (field("k") >= 100) & (field("k") < 110))
        assert out["v"].to_pylist() == [None] * 10
        out2, _ = _read(p, (field("k") >= 700) & (field("k") < 705))
        assert out2["v"].to_pylist() == [700.0, 701.0, 702.0, 703.0, 704.0]

    def test_validity_respected_under_selection(self, tmp_path):
        t = Table.from_pylist(
            [{"k": i, "s": None if i % 3 == 0 else f"s{i}"} for i in range(500)])
        p = str(tmp_path / "vs.tpq")
        write_table(p, t, page_rows=100, row_group_rows=500)
        out, _ = _read(p, (field("k") >= 150) & (field("k") < 156))
        assert out["s"].to_pylist() == [None, "s151", "s152", None, "s154",
                                        "s155"]

    def test_multi_filter_columns(self, mixed_file):
        p, t = mixed_file
        expr = (field("k") < 300) & (field("s") == "val_5_")
        out, _ = _read(p, expr)
        ks = out["k"].to_pylist()
        assert ks and all(k < 300 and k % 13 == 5 and k % 7 == 0 for k in ks)


class TestFusedRangeMask:
    """The single-column range fast path (backend.range_mask) must be
    mask-identical to Expr.evaluate for every op and dtype mix."""

    @pytest.mark.parametrize("make_expr", [
        lambda f: f == 500, lambda f: f != 500,
        lambda f: f < 123, lambda f: f <= 123,
        lambda f: f > 877, lambda f: f >= 877,
        lambda f: (f >= 100) & (f < 200),
        lambda f: (f > 100) & (f <= 200),
    ], ids=["eq", "ne", "lt", "le", "gt", "ge", "range", "range-open"])
    @pytest.mark.parametrize("col,vals", [
        ("k", None),                       # int64
        ("f", None),                       # float64
    ])
    def test_ops_match_full_scan(self, tmp_path, make_expr, col, vals):
        n = 1000
        rng = np.random.default_rng(17)
        t = Table.from_pydict({
            "k": rng.integers(0, 1000, n),
            "f": rng.integers(0, 1000, n).astype(np.float64),
            "payload": [f"p{i}" for i in range(n)],
        })
        p = str(tmp_path / "rm.tpq")
        write_table(p, t, page_rows=250, row_group_rows=1000)
        expr = make_expr(field(col))
        out = TPQReader(p).read(filter_expr=expr, prune_pages=False)
        oracle = t.filter_mask(expr.evaluate(t))
        assert norm(out.to_pylist()) == norm(oracle.to_pylist())

    def test_float_strict_bounds_on_int_and_float(self, tmp_path):
        t = Table.from_pydict({"x": np.arange(10),
                               "y": np.arange(10) + 0.5,
                               "pay": ["z"] * 10})
        p = str(tmp_path / "fb.tpq")
        write_table(p, t, page_rows=5, row_group_rows=10)
        rd = TPQReader(p)
        out = rd.read(filter_expr=(field("x") > 2.5) & (field("x") < 5))
        assert out["x"].to_pylist() == [3, 4]
        out = rd.read(filter_expr=field("y") > 4.5)
        assert out["y"].to_pylist() == [4.5 + i for i in range(1, 6)]
        out = rd.read(filter_expr=field("x") == 2.5)
        assert out.num_rows == 0

    def test_projection_independent_near_2p53(self, tmp_path):
        # float bounds within one ulp of 2^53 must not take the exact-int
        # fused path while the residual path compares in rounded float64 —
        # results would depend on which columns were projected
        t = Table.from_pydict({"a": np.array([1, 2**53, 2**53 + 1], np.int64),
                               "pay": ["x", "y", "z"]})
        p = str(tmp_path / "p53.tpq")
        write_table(p, t, page_rows=3, row_group_rows=3)
        rd = TPQReader(p)
        expr = field("a") > float(2**53)
        two_phase = rd.read(filter_expr=expr)            # fused-eligible
        residual = rd.read(filter_expr=expr, columns=["a"])  # evaluate path
        assert two_phase["a"].to_pylist() == residual["a"].to_pylist()

    def test_as_range_shapes(self):
        assert (field("a") == 5).as_range() == ("a", 5, False, 5, False)
        assert ((field("a") >= 1) & (field("a") < 9)).as_range() == \
            ("a", 1, False, 9, True)
        assert ((field("a") > 1) & (field("b") < 9)).as_range() is None
        assert (field("a") != 5).as_range() is None
        assert (field("a") == "s").as_range() is None
        assert (field("a") == True).as_range() is None  # noqa: E712


def test_uint64_bloom_probe_full_domain():
    # bloom build hashes values mod 2^64; int and float probes in
    # [2^63, 2^64) must do the same — they used to overflow or byte-hash
    from repro.core.statistics import compute_stats
    from repro.core.table import Column
    col = Column.numeric(np.array([1, 2**63, 2**64 - 1], np.uint64))
    st = compute_stats(col)
    assert st.bloom is not None
    assert st.may_contain(2**63)
    assert st.may_contain(float(2**63))
    assert st.may_contain(2**64 - 1)


def test_float_literal_equality_not_bloom_pruned(tmp_path):
    # field('x') == 1.0 on an int column: the chunk bloom is built with the
    # integer hash, so the float literal must probe the same way — this
    # used to prune the whole file and return 0 rows
    from repro.core.statistics import compute_stats
    from repro.core.table import Column
    col = Column.numeric(np.arange(100, dtype=np.int64))
    st = compute_stats(col)
    assert st.bloom is not None
    assert st.may_contain(1.0)
    assert st.may_contain(np.float64(42.0))
    db = ParquetDB(os.path.join(str(tmp_path), "fb"))
    db.create([{"x": i, "y": i * 2} for i in range(100)])
    assert db.read(filters=[field("x") == 7.0]).num_rows == 1
    assert db.read(filters=[field("x") == 7]).num_rows == 1


class TestExplainReporting:
    def test_selective_scan_reports_late_savings(self, tmp_path):
        n = 20_000
        db = ParquetDB(os.path.join(str(tmp_path), "late"))
        db.create([{"a": i, "b": f"payload_{i}", "c": float(i)}
                   for i in range(n)])
        db.normalize(NormalizeConfig(max_rows_per_file=5_000,
                                     max_rows_per_group=2_048))
        rep = db.explain(filters=[field("a") == n // 2], execute=True)
        assert rep.counters.rows_matched == 1
        assert rep.counters.rows_skipped_late > 0
        assert rep.counters.bytes_saved_late > 0
        assert "late mat." in str(rep)
        # a full scan reports none
        rep = db.explain(execute=True)
        assert rep.counters.rows_skipped_late == 0
        assert rep.counters.bytes_saved_late == 0

    def test_to_dict_carries_new_counters(self, tmp_path):
        db = ParquetDB(os.path.join(str(tmp_path), "d"))
        db.create([{"a": i, "b": i} for i in range(10)])
        d = db.explain(execute=True).to_dict()
        assert "rows_skipped_late" in d["counters"]
        assert "bytes_saved_late" in d["counters"]

    def test_pruned_equals_unpruned_under_late_mat(self, tmp_path):
        """Oracle: late materialization never changes scan results."""
        rng = np.random.default_rng(9)
        n = 10_000
        db = ParquetDB(os.path.join(str(tmp_path), "oracle"))
        db.create(Table.from_pydict({
            "k": rng.integers(0, 500, n),
            "s": [f"r{i}" for i in range(n)],
            "v": rng.standard_normal(n),
        }))
        db.normalize(NormalizeConfig(max_rows_per_file=2_500,
                                     max_rows_per_group=512))
        expr = field("k") == 123
        pruned = db.read(filters=[expr])
        full = db.read()
        oracle = full.filter_mask(expr.evaluate(full))
        assert norm(pruned.to_pylist()) == norm(oracle.to_pylist())
