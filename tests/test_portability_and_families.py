"""Portability (JSON export/import roundtrip) + serve-engine coverage for
the non-dense families (SSM state caches, MoE routing under decode)."""
import numpy as np
import pytest

from repro.core import ParquetDB
from repro.core.portability import export_jsonl, import_jsonl
from repro.models import AttnCfg, Model, ModelConfig, MoECfg, SSMCfg
from repro.serve.engine import ServeEngine

import jax


class TestPortability:
    def test_jsonl_roundtrip_nested(self, tmp_path):
        db = ParquetDB(str(tmp_path / "a"), "a")
        recs = [
            {"name": "x", "data": {"spg": 4, "gap": 0.5},
             "sites": [[0.0, 1.0], [2.0, 3.0]], "tags": ["m", "n"]},
            {"name": "y", "data": {"spg": 9, "gap": 0.0}, "note": None},
        ]
        db.create(recs)
        p = str(tmp_path / "dump.jsonl")
        n = export_jsonl(db, p)
        assert n == 2
        db2 = ParquetDB(str(tmp_path / "b"), "b")
        assert import_jsonl(db2, p) == 2
        a = db.read().to_pylist(rebuild_nested=True)
        b = db2.read().to_pylist(rebuild_nested=True)

        def norm(r):
            out = {k: v for k, v in r.items() if k != "id"}
            return {k: (v.tolist() if isinstance(v, np.ndarray) else v)
                    for k, v in out.items()}
        assert [norm(r) for r in a] == [norm(r) for r in b]

    def test_bytes_column_survives(self, tmp_path):
        db = ParquetDB(str(tmp_path / "a"), "a")
        db.create([{"blob": b"\x00\x01\xffhello"}])
        p = str(tmp_path / "d.jsonl")
        export_jsonl(db, p)
        db2 = ParquetDB(str(tmp_path / "b"), "b")
        import_jsonl(db2, p)
        assert db2.read().to_pylist()[0]["blob"] == b"\x00\x01\xffhello"


FAMILY_CFGS = [
    ModelConfig("eng-ssm", "ssm", 2, 64, 0, 128,
                ssm=SSMCfg(d_state=16, headdim=16, chunk=8), remat=False),
    ModelConfig("eng-moe", "moe", 2, 64, 128, 128, attn=AttnCfg(4, 2, 16),
                moe=MoECfg(4, 2, 96, capacity_factor=4.0), remat=False),
    ModelConfig("eng-hybrid", "hybrid", 2, 64, 128, 128,
                attn=AttnCfg(4, 4, 16),
                ssm=SSMCfg(d_state=16, headdim=16, chunk=8),
                hybrid_share_period=1, remat=False),
    ModelConfig("eng-swa", "dense", 2, 64, 128, 128,
                attn=AttnCfg(4, 2, 16, window=8), remat=False),
]


@pytest.mark.parametrize("cfg", FAMILY_CFGS, ids=lambda c: c.name)
def test_engine_all_families(cfg):
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, slots=2, max_seq=48)
    rng = np.random.default_rng(0)
    for _ in range(3):
        eng.submit(rng.integers(0, cfg.vocab, 4).astype(np.int32),
                   max_new_tokens=4)
    done = eng.run_to_completion()
    assert len(done) == 3
    assert all(len(r.out_tokens) == 4 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out_tokens)


@pytest.mark.parametrize("cfg", FAMILY_CFGS[:2], ids=lambda c: c.name)
def test_engine_batching_invariance_nondense(cfg):
    """Same request alone vs batched with others must decode identically."""
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    prompt = np.array([3, 5, 7], np.int32)
    solo = ServeEngine(model, params, slots=1, max_seq=32)
    solo.submit(prompt, max_new_tokens=4)
    ref = solo.run_to_completion()[0].out_tokens

    rng = np.random.default_rng(2)
    eng = ServeEngine(model, params, slots=2, max_seq=32)
    eng.submit(rng.integers(0, cfg.vocab, 5).astype(np.int32),
               max_new_tokens=4)
    rid = eng.submit(prompt, max_new_tokens=4)
    got = {r.rid: r.out_tokens for r in eng.run_to_completion()}[rid]
    assert got == ref, cfg.name
