import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    config.addinivalue_line(
        "markers", "perf_smoke: wall-clock performance assertion; needs an "
        "unloaded multi-core box (CI runs these in the dedicated perf job)")
    config.addinivalue_line(
        "markers", "concurrency: multi-process writer stress; needs >= 2 "
        "cpus and skips loudly on 1-vCPU boxes (CI concurrency job)")
    config.addinivalue_line(
        "markers", "faults: IO fault-injection matrix (bit flips, "
        "truncation, ENOSPC, worker kills) — tests/test_integrity.py; "
        "CI runs these in the dedicated faults job")
