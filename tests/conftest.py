import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    config.addinivalue_line(
        "markers", "perf_smoke: wall-clock performance assertion; needs an "
        "unloaded multi-core box (CI runs these in the dedicated perf job)")
