#!/usr/bin/env python
"""Generate docs/API.md from the public docstrings of repro.core.

Usage:
    PYTHONPATH=src python scripts/gen_api_docs.py          # rewrite docs/API.md
    PYTHONPATH=src python scripts/gen_api_docs.py --check  # fail if stale

The reference is generated, not hand-written, so it cannot drift from the
code: CI runs ``--check`` (see .github/workflows/ci.yml, docs job).
"""
from __future__ import annotations

import dataclasses
import inspect
import os
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.core import (aggregate, compaction, integrity,  # noqa: E402
                        partition, query, scan, store, transactions)
from repro.serve import cache as serve_cache  # noqa: E402
from repro.serve import dbserver, protocol  # noqa: E402

OUT = os.path.join(REPO, "docs", "API.md")

HEADER = """\
# API reference

<!-- GENERATED FILE — do not edit by hand.
     Regenerate with: PYTHONPATH=src python scripts/gen_api_docs.py -->

Generated from the docstrings of `repro.core` and `repro.serve`. The
classes below are the public surface of the database and serving layers;
see [ARCHITECTURE.md](ARCHITECTURE.md) for how they fit together,
[TRANSACTIONS.md](TRANSACTIONS.md) for the transaction/maintenance
lifecycle and [SERVING.md](SERVING.md) for the query server.
"""

# (class, members); None = every public method, () = class docstring only
SECTIONS = [
    (store.ParquetDB,
     ["create", "query", "read", "aggregate", "update", "delete",
      "normalize", "compact", "verify", "maintenance_stats", "explain",
      "wait_for_maintenance", "set_metadata", "set_field_metadata"]),
    (query.Query,
     ["where", "select", "group_by", "order_by", "limit", "offset",
      "distinct", "to_table", "iter_batches", "to_pylist", "count", "agg",
      "explain", "plan_fingerprint", "plan_key"]),
    (query.GroupedQuery, ["agg"]),
    (query.QueryReport, ()),
    (store.Dataset, ["query", "schema", "iter_batches", "to_table",
                     "scan_plan", "explain", "aggregate"]),
    (store.NormalizeConfig, ()),
    (store.LoadConfig, ()),
    (partition.PartitionSpec, ()),
    (partition.Partitioning, ["dir_of", "key_of", "split", "pruner"]),
    (compaction.CompactionPolicy, ()),
    (compaction.MaintenanceStats, ()),
    (compaction.CompactionResult, ()),
    (scan.ScanPlan, ["fragments", "execute", "explain"]),
    (aggregate.AggregatePlan, ["execute", "report"]),
    (scan.ScanCounters, ()),
    (scan.ScanReport, ()),
    (scan.DeltaOverlay, ()),
    (integrity.IntegrityError, ()),
    (integrity.TruncatedFileError, ()),
    (integrity.CorruptFooterError, ()),
    (integrity.CorruptPageError, ()),
    (integrity.IntegrityReport, ()),
    (integrity.FileCheck, ()),
    (transactions.Manifest, ()),
    (transactions.DeltaEntry, ()),
    (transactions.Transaction,
     ["snapshot", "stage", "validate", "publish"]),
    (transactions.CommitConflict, ()),
    (transactions.WriteLockTimeout, ()),
    (scan.MorselBudget, ["acquire", "try_acquire", "release", "stats"]),
    (dbserver.DBServer, ["start", "stop", "serve_forever"]),
    (protocol.DBClient,
     ["query", "count", "agg", "update", "delete", "explain", "stats",
      "ping", "close"]),
    (serve_cache.PlanCache, ["get", "put"]),
    (serve_cache.ResultCache,
     ["get", "put", "invalidate_below", "clear"]),
    (serve_cache.ServerStats, ["record", "bump", "snapshot"]),
]


def _clean_doc(obj) -> str:
    doc = inspect.getdoc(obj) or "*(undocumented)*"
    return doc.strip()


def _signature(obj) -> str:
    try:
        return str(inspect.signature(obj))
    except (TypeError, ValueError):
        return "(...)"


def _dataclass_fields(cls) -> str:
    lines = ["| field | default |", "|---|---|"]
    for f in dataclasses.fields(cls):
        if f.default is not dataclasses.MISSING:
            default = repr(f.default)
        elif f.default_factory is not dataclasses.MISSING:  # type: ignore
            default = f.default_factory.__name__ + "()"
        else:
            default = "—"
        lines.append(f"| `{f.name}` | `{default}` |")
    return "\n".join(lines)


def render() -> str:
    parts = [HEADER]
    for cls, members in SECTIONS:
        parts.append(f"## `{cls.__module__}.{cls.__qualname__}`\n")
        parts.append(_clean_doc(cls) + "\n")
        if dataclasses.is_dataclass(cls) and not members:
            parts.append(_dataclass_fields(cls) + "\n")
        for name in (members or []):
            member = inspect.getattr_static(cls, name)
            if isinstance(member, property):
                parts.append(f"### `{name}` *(property)*\n")
                parts.append(_clean_doc(member.fget) + "\n")
                continue
            fn = member.__func__ if isinstance(member, (classmethod,
                                                        staticmethod)) \
                else member
            parts.append(f"### `{name}{_signature(fn)}`\n")
            parts.append(_clean_doc(fn) + "\n")
    return "\n".join(parts).rstrip() + "\n"


def main(argv) -> int:
    text = render()
    if "--check" in argv:
        try:
            with open(OUT) as fh:
                current = fh.read()
        except FileNotFoundError:
            current = ""
        if current != text:
            sys.stderr.write(
                "docs/API.md is stale — regenerate with:\n"
                "  PYTHONPATH=src python scripts/gen_api_docs.py\n")
            return 1
        print("docs/API.md up to date")
        return 0
    with open(OUT, "w") as fh:
        fh.write(text)
    print(f"wrote {os.path.relpath(OUT, REPO)} "
          f"({len(text.splitlines())} lines)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
