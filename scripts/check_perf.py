"""CI perf-smoke gate: fail when the hot paths regress vs the committed baseline.

Compares a fresh ``benchmarks.run --json`` artifact directory against the
committed ``BENCH_baseline.json`` (recorded from the pre-engine seed code) on
the headline paths:

- fig5 create   (bulk ingest)
- fig7 needle   (index-free selective read)
- fig11 agg     (stats-answered aggregates, zero pages decoded)
- fig11 mtread  (morsel-parallel full read-scan at num_threads=2)

Raw wall-clock is not portable across CI machines, so each ParquetDB timing
is normalized by the SQLite timing *from the same run* (same machine, same
load); the gate trips when the normalized ratio regresses more than
``--factor`` (default 2x) over the baseline's ratio.

``--baseline`` may be a single JSON file or a directory of
``BENCH_*.json`` artifacts.  CI gates against ``bench/`` (artifacts
recorded from the execution engine itself, so a trip means the engine's
own win regressed >2x); the root ``BENCH_baseline.json`` keeps the
pre-engine seed numbers as the trajectory record.

Usage:
    python scripts/check_perf.py --current DIR [--baseline bench]
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys

# (label, parquetdb row prefix, reference row prefix)
GATES = [
    ("fig5 create", "fig5/create/parquetdb/", "fig5/create/sqlite/"),
    ("fig7 needle", "fig7/parquetdb/", "fig7/sqlite-noindex/"),
    # stats-answered aggregates (count/min/max/sum/mean from footers) vs
    # SQLite's un-indexed aggregate over the same rows
    ("fig11 agg", "fig11/aggregate/parquetdb/", "fig11/aggregate/sqlite/"),
    # parallel read-scan at num_threads=2 (what CI runners actually have)
    # vs SQLite full-table fetch from the same run
    ("fig11 mtread", "fig11/mt-read/parquetdb/", "fig11/mt-read/sqlite/"),
]


def _rows(doc: dict) -> dict:
    return {r["name"]: float(r["us_per_call"]) for r in doc.get("rows", [])}


def _load_rows(path: str) -> dict:
    with open(path) as fh:
        return _rows(json.load(fh))


def _load_dir(directory: str) -> dict:
    rows: dict = {}
    for fn in sorted(os.listdir(directory)):
        if fn.startswith("BENCH_") and fn.endswith(".json"):
            rows.update(_load_rows(os.path.join(directory, fn)))
    return rows


def _n_of(name: str) -> int:
    m = re.search(r"n=(\d+)$", name)
    return int(m.group(1)) if m else -1


def _ns_of(rows: dict, prefix: str) -> set:
    return {_n_of(k) for k in rows if k.startswith(prefix) and _n_of(k) > 0}


def _ratio_at(rows: dict, pdb_prefix: str, ref_prefix: str, n: int):
    pdb = rows.get(f"{pdb_prefix}n={n}")
    ref = rows.get(f"{ref_prefix}n={n}")
    return pdb / ref if pdb and ref else None


def _common_largest_n(base: dict, cur: dict, pdb_p: str, ref_p: str):
    """Largest n with pdb+reference rows in BOTH baseline and current run."""
    ns = (_ns_of(base, pdb_p) & _ns_of(base, ref_p)
          & _ns_of(cur, pdb_p) & _ns_of(cur, ref_p))
    return max(ns) if ns else None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="bench",
                    help="baseline BENCH json file or artifact directory")
    ap.add_argument("--current", required=True,
                    help="directory of fresh BENCH_<fig>.json artifacts")
    ap.add_argument("--factor", type=float, default=2.0)
    args = ap.parse_args(argv)

    base = (_load_dir(args.baseline) if os.path.isdir(args.baseline)
            else _load_rows(args.baseline))
    cur = _load_dir(args.current)
    failures = []
    for label, pdb_p, ref_p in GATES:
        n = _common_largest_n(base, cur, pdb_p, ref_p)
        bratio = _ratio_at(base, pdb_p, ref_p, n) if n else None
        cratio = _ratio_at(cur, pdb_p, ref_p, n) if n else None
        if bratio is None or cratio is None:
            failures.append(f"{label}: no common n with both parquetdb and "
                            f"reference rows (baseline vs current)")
            continue
        verdict = "OK" if cratio <= args.factor * bratio else "REGRESSED"
        print(f"{label:12s} n={n}  baseline pdb/sqlite={bratio:.3f}  "
              f"current pdb/sqlite={cratio:.3f}  "
              f"gate={args.factor:.1f}x  {verdict}")
        if verdict != "OK":
            failures.append(
                f"{label}: normalized time {cratio:.3f} exceeds "
                f"{args.factor:.1f}x baseline {bratio:.3f}")
    if failures:
        print("PERF GATE FAILED:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        return 1
    print("perf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
