"""CI perf-smoke gate: fail when the hot paths regress vs the committed baseline.

Compares a fresh ``benchmarks.run --json`` artifact directory against the
committed ``BENCH_baseline.json`` (recorded from the pre-engine seed code) on
the headline paths:

- fig5 create   (bulk ingest)
- fig7 needle   (index-free selective read)
- fig11 agg     (stats-answered aggregates, zero pages decoded)
- fig11 mtread  (morsel-parallel full read-scan at num_threads=2)

Additionally ``SCALING_GATES`` asserts self-relative scaling laws on the
current run alone — e.g. ``fig11 mt4-read`` requires mt4 >= 3x mt1 on the
zlib-compressed (GIL-releasing) fixture, and ``fig9 partition-prune``
requires a one-partition query over the hive-partitioned Alexandria
fixture to beat the full scan >= 5x — but only when the artifact's
``cpus`` field says the recording box had enough cores (skipped loudly
otherwise, so a 2-core runner never fails a 4-core scaling law).

Raw wall-clock is not portable across CI machines, so each ParquetDB timing
is normalized by the SQLite timing *from the same run* (same machine, same
load); the gate trips when the normalized ratio regresses more than
``--factor`` (default 2x) over the baseline's ratio.

``--baseline`` may be a single JSON file or a directory of
``BENCH_*.json`` artifacts.  CI gates against ``bench/`` (artifacts
recorded from the execution engine itself, so a trip means the engine's
own win regressed >2x); the root ``BENCH_baseline.json`` keeps the
pre-engine seed numbers as the trajectory record.

Usage:
    python scripts/check_perf.py --current DIR [--baseline bench]
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys

# (label, parquetdb row prefix, reference row prefix)
GATES = [
    ("fig5 create", "fig5/create/parquetdb/", "fig5/create/sqlite/"),
    ("fig7 needle", "fig7/parquetdb/", "fig7/sqlite-noindex/"),
    # stats-answered aggregates (count/min/max/sum/mean from footers) vs
    # SQLite's un-indexed aggregate over the same rows
    ("fig11 agg", "fig11/aggregate/parquetdb/", "fig11/aggregate/sqlite/"),
    # parallel read-scan at num_threads=2 (what CI runners actually have)
    # vs SQLite full-table fetch from the same run
    ("fig11 mtread", "fig11/mt-read/parquetdb/", "fig11/mt-read/sqlite/"),
]

# Self-relative scaling gates on the *current* run only:
# (label, fast row prefix, slow row prefix, required speedup, min cpus).
# Unlike GATES these don't compare against the baseline — they assert a
# scaling law that must hold wherever the hardware permits, and are
# skipped (loudly) when the artifact records fewer than ``min cpus``,
# because a speedup measured on a starved box is noise, not signal.
SCALING_GATES = [
    # fused morsel decode over GIL-releasing zlib inflate: 4 scan workers
    # must deliver >= 3x over 1 worker on the compressed fixture
    ("fig11 mt4-read", "fig11/read-scan-zlib-mt4/parquetdb/",
     "fig11/read-scan-zlib-mt1/parquetdb/", 3.0, 4),
    # hive partition pruning: a one-partition query over the 16-way
    # partitioned Alexandria fixture must beat the full scan >= 5x —
    # pruned partitions cost zero footer opens, so this holds even on a
    # single-core box (min cpus 1)
    ("fig9 partition-prune", "fig9/scan-selective/",
     "fig9/scan-full/", 5.0, 1),
    # serving tier result cache: a warm (plan key + generation) hit must
    # answer >= 5x faster than the cold plan+scan of the same query —
    # a cache hit skips the scan entirely, so this holds on any box
    # (min cpus 1); see benchmarks/fig12_serve.py
    ("fig12 result-cache", "fig12/query-warm/parquetdb/",
     "fig12/query-cold/parquetdb/", 5.0, 1),
]

# Overhead gates on the *current* run only:
# (label, measured row prefix, reference row prefix, max ratio).
# The measured path must cost at most ``max ratio`` x the reference path
# from the same run — e.g. page-checksum verification (the LoadConfig
# default) must stay under 10% on the read-scan path, or the integrity
# layer has started costing more than it is worth.
OVERHEAD_GATES = [
    ("fig5 verify-page", "fig5/read-scan-verify-page/parquetdb/",
     "fig5/read-scan-verify-off/parquetdb/", 1.10),
]


def _rows(doc: dict) -> dict:
    return {r["name"]: float(r["us_per_call"]) for r in doc.get("rows", [])}


def _load_rows(path: str) -> tuple:
    """-> (rows, cpus-or-None) from one BENCH json artifact."""
    with open(path) as fh:
        doc = json.load(fh)
    return _rows(doc), doc.get("cpus")


def _load_dir(directory: str) -> tuple:
    """-> (rows, cpus-or-None) merged over a BENCH_*.json directory.

    ``cpus`` is the minimum recorded across artifacts (they normally come
    from one run of one machine, so this is just defensive)."""
    rows: dict = {}
    cpus = None
    for fn in sorted(os.listdir(directory)):
        if fn.startswith("BENCH_") and fn.endswith(".json"):
            r, c = _load_rows(os.path.join(directory, fn))
            rows.update(r)
            if c is not None:
                cpus = c if cpus is None else min(cpus, c)
    return rows, cpus


def _n_of(name: str) -> int:
    m = re.search(r"n=(\d+)$", name)
    return int(m.group(1)) if m else -1


def _ns_of(rows: dict, prefix: str) -> set:
    return {_n_of(k) for k in rows if k.startswith(prefix) and _n_of(k) > 0}


def _ratio_at(rows: dict, pdb_prefix: str, ref_prefix: str, n: int):
    pdb = rows.get(f"{pdb_prefix}n={n}")
    ref = rows.get(f"{ref_prefix}n={n}")
    return pdb / ref if pdb and ref else None


def _common_largest_n(base: dict, cur: dict, pdb_p: str, ref_p: str):
    """Largest n with pdb+reference rows in BOTH baseline and current run."""
    ns = (_ns_of(base, pdb_p) & _ns_of(base, ref_p)
          & _ns_of(cur, pdb_p) & _ns_of(cur, ref_p))
    return max(ns) if ns else None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="bench",
                    help="baseline BENCH json file or artifact directory")
    ap.add_argument("--current", required=True,
                    help="directory of fresh BENCH_<fig>.json artifacts")
    ap.add_argument("--factor", type=float, default=2.0)
    args = ap.parse_args(argv)

    base, _ = (_load_dir(args.baseline) if os.path.isdir(args.baseline)
               else _load_rows(args.baseline))
    cur, cur_cpus = _load_dir(args.current)
    failures = []
    for label, pdb_p, ref_p in GATES:
        n = _common_largest_n(base, cur, pdb_p, ref_p)
        bratio = _ratio_at(base, pdb_p, ref_p, n) if n else None
        cratio = _ratio_at(cur, pdb_p, ref_p, n) if n else None
        if bratio is None or cratio is None:
            failures.append(f"{label}: no common n with both parquetdb and "
                            f"reference rows (baseline vs current)")
            continue
        verdict = "OK" if cratio <= args.factor * bratio else "REGRESSED"
        print(f"{label:12s} n={n}  baseline pdb/sqlite={bratio:.3f}  "
              f"current pdb/sqlite={cratio:.3f}  "
              f"gate={args.factor:.1f}x  {verdict}")
        if verdict != "OK":
            failures.append(
                f"{label}: normalized time {cratio:.3f} exceeds "
                f"{args.factor:.1f}x baseline {bratio:.3f}")
    for label, fast_p, slow_p, need, min_cpus in SCALING_GATES:
        ns = _ns_of(cur, fast_p) & _ns_of(cur, slow_p)
        if not ns:
            failures.append(f"{label}: current run has no n with both "
                            f"{fast_p} and {slow_p} rows")
            continue
        n = max(ns)
        if cur_cpus is None or cur_cpus < min_cpus:
            print(f"{label:12s} n={n}  SKIPPED (artifact cpus={cur_cpus}, "
                  f"scaling gate needs >= {min_cpus})")
            continue
        got = cur[f"{slow_p}n={n}"] / cur[f"{fast_p}n={n}"]
        verdict = "OK" if got >= need else "REGRESSED"
        print(f"{label:12s} n={n}  speedup={got:.2f}x  "
              f"required>={need:.1f}x  cpus={cur_cpus}  {verdict}")
        if verdict != "OK":
            failures.append(f"{label}: speedup {got:.2f}x is below the "
                            f"required {need:.1f}x (cpus={cur_cpus})")
    for label, over_p, ref_p, max_ratio in OVERHEAD_GATES:
        ns = _ns_of(cur, over_p) & _ns_of(cur, ref_p)
        if not ns:
            failures.append(f"{label}: current run has no n with both "
                            f"{over_p} and {ref_p} rows")
            continue
        n = max(ns)
        got = cur[f"{over_p}n={n}"] / cur[f"{ref_p}n={n}"]
        verdict = "OK" if got <= max_ratio else "REGRESSED"
        print(f"{label:12s} n={n}  overhead={got:.3f}x  "
              f"allowed<={max_ratio:.2f}x  {verdict}")
        if verdict != "OK":
            failures.append(f"{label}: overhead {got:.3f}x exceeds the "
                            f"allowed {max_ratio:.2f}x")
    if failures:
        print("PERF GATE FAILED:\n  " + "\n  ".join(failures),
              file=sys.stderr)
        return 1
    print("perf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
