#!/usr/bin/env python
"""Docs checker: validate markdown links and execute python code blocks.

Usage:  PYTHONPATH=src python scripts/check_docs.py [files...]

Defaults to README.md and docs/*.md. Two checks keep the examples honest:

1. **Links** — every relative markdown link target must exist on disk
   (anchors are stripped; http(s)/mailto links are skipped).
2. **Code blocks** — every ```python fence is executed, blocks of the same
   file sharing one namespace (so a later block can use ``db`` from an
   earlier one), with the working directory set to a throwaway tempdir.
   Blocks containing ``>>>`` prompts are console transcripts and are only
   syntax-checked via doctest parsing; a block preceded by an
   ``<!-- docs-check: skip -->`` comment is skipped entirely.

CI runs this in the docs job so examples cannot rot.
"""
from __future__ import annotations

import doctest
import os
import re
import sys
import tempfile
import traceback

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(\w*)\s*$")
SKIP_MARK = "<!-- docs-check: skip -->"


def iter_code_blocks(text: str):
    """Yield (start_line, lang, code, skipped) for each fenced block."""
    lines = text.splitlines()
    i, pending_skip = 0, False
    while i < len(lines):
        stripped = lines[i].strip()
        m = FENCE_RE.match(stripped)
        if m:
            lang, start = m.group(1).lower(), i + 1
            body = []
            i += 1
            while i < len(lines) and not lines[i].strip().startswith("```"):
                body.append(lines[i])
                i += 1
            yield start, lang, "\n".join(body), pending_skip
            pending_skip = False
        elif stripped:
            pending_skip = stripped == SKIP_MARK
        i += 1


def check_links(path: str, text: str) -> list:
    errors = []
    base = os.path.dirname(path)
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.join(base, rel)):
            line = text[:m.start()].count("\n") + 1
            errors.append(f"{path}:{line}: broken link -> {target}")
    return errors


def run_code_blocks(path: str, text: str) -> list:
    errors = []
    ns = {"__name__": "__docs__"}
    cwd = os.getcwd()
    with tempfile.TemporaryDirectory(prefix="docs_check_") as tmp:
        os.chdir(tmp)
        try:
            for line, lang, code, skipped in iter_code_blocks(text):
                if lang != "python" or skipped or not code.strip():
                    continue
                if ">>>" in code:
                    # console transcript: parse-only (outputs are prose)
                    try:
                        doctest.DocTestParser().get_examples(code)
                    except ValueError as e:
                        errors.append(f"{path}:{line}: bad doctest block: {e}")
                    continue
                try:
                    exec(compile(code, f"{path}:{line}", "exec"), ns)
                except Exception:
                    tb = traceback.format_exc(limit=2)
                    errors.append(f"{path}:{line}: code block raised:\n{tb}")
        finally:
            os.chdir(cwd)
    return errors


def main(argv) -> int:
    files = argv or [os.path.join(REPO, "README.md")] + sorted(
        os.path.join(REPO, "docs", f)
        for f in os.listdir(os.path.join(REPO, "docs"))
        if f.endswith(".md"))
    errors = []
    n_blocks = 0
    for path in files:
        with open(path) as fh:
            text = fh.read()
        errors += check_links(path, text)
        before = len(errors)
        errors += run_code_blocks(path, text)
        n_blocks += sum(1 for _, lang, code, skip in iter_code_blocks(text)
                        if lang == "python" and not skip and code.strip())
        status = "ok" if len(errors) == before else "FAIL"
        print(f"{os.path.relpath(path, REPO)}: {status}")
    if errors:
        print("\n".join(errors), file=sys.stderr)
        return 1
    print(f"checked {len(files)} files, {n_blocks} python blocks: all good")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
