"""Paper Fig. 5: create + read times vs row count — ParquetDB / SQLite / DocDB.

100 integer columns; create = bulk insert committed; read = full dataset into
an array-like structure (nothing left in cursors).
"""
from __future__ import annotations

import os
from typing import List

from repro.core import ParquetDB

from .common import TmpDir, gen_rows_pylist, row, sqlite_create, timeit
from .docdb import DocDB


def run(scale: str = "small") -> List[dict]:
    counts = {"small": [100, 1_000, 10_000],
              "medium": [100, 1_000, 10_000, 100_000],
              "paper": [1, 100, 10_000, 100_000, 1_000_000]}[scale]
    out: List[dict] = []
    for n in counts:
        rows = gen_rows_pylist(n)
        with TmpDir() as tmp:
            # --- ParquetDB
            db = ParquetDB(os.path.join(tmp, "pdb"), "bench")
            t_create = timeit(lambda: db.create(rows))
            t_read = timeit(lambda: db.read().to_pydict())
            out.append(row(f"fig5/create/parquetdb/n={n}", t_create, rows=n))
            out.append(row(f"fig5/read/parquetdb/n={n}", t_read, rows=n))
            # --- SQLite (paper Listing 1 incl. PRAGMAs)
            conn_holder = {}
            t_create = timeit(lambda: conn_holder.setdefault(
                "c", sqlite_create(os.path.join(tmp, "s.db"), rows)))
            conn = conn_holder["c"]
            t_read = timeit(
                lambda: conn.execute("SELECT * FROM test_table").fetchall())
            conn.close()
            out.append(row(f"fig5/create/sqlite/n={n}", t_create, rows=n))
            out.append(row(f"fig5/read/sqlite/n={n}", t_read, rows=n))
            # --- DocDB (embedded document baseline)
            ddb = DocDB(os.path.join(tmp, "docs.jsonl"))
            t_create = timeit(lambda: ddb.insert_many(rows))
            t_read = timeit(lambda: ddb.find_all())
            out.append(row(f"fig5/create/docdb/n={n}", t_create, rows=n))
            out.append(row(f"fig5/read/docdb/n={n}", t_read, rows=n))
    return out
