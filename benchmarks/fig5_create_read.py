"""Paper Fig. 5: create + read times vs row count — ParquetDB / SQLite / DocDB.

100 integer columns; create = bulk insert committed; read = full dataset into
an array-like structure (nothing left in cursors).

The ParquetDB read is reported in two phases — ``read-scan`` (file pages ->
columnar Table: the engine's decode cost) and ``read-materialize`` (Table ->
python dict-of-lists: fixed CPython object-building cost, identical for any
engine producing python values) — plus their sum as ``read`` for
comparability with the one-number SQLite/DocDB rows.  A single timer over
``read().to_pydict()`` hid decode wins behind the materialization floor.
"""
from __future__ import annotations

import os
from typing import List

from repro.core import LoadConfig, NormalizeConfig, ParquetDB

from .common import (TmpDir, gen_rows_pylist, row, sqlite_create, timeit,
                     timeit_median)
from .docdb import DocDB


def run(scale: str = "small") -> List[dict]:
    counts = {"quick": [100, 1_000],
              "small": [100, 1_000, 10_000],
              "medium": [100, 1_000, 10_000, 100_000],
              "paper": [1, 100, 10_000, 100_000, 1_000_000]}[scale]
    out: List[dict] = []
    for n in counts:
        rows = gen_rows_pylist(n)
        with TmpDir() as tmp:
            # --- ParquetDB
            db = ParquetDB(os.path.join(tmp, "pdb"), "bench")
            t_create = timeit(lambda: db.create(rows))
            t_scan = timeit_median(lambda: db.read(), k=3)
            scanned = db.read()
            t_mat = timeit_median(lambda: scanned.to_pydict(), k=3)
            out.append(row(f"fig5/create/parquetdb/n={n}", t_create, rows=n))
            out.append(row(f"fig5/read/parquetdb/n={n}", t_scan + t_mat,
                           rows=n))
            out.append(row(f"fig5/read-scan/parquetdb/n={n}", t_scan, rows=n))
            out.append(row(f"fig5/read-materialize/parquetdb/n={n}", t_mat,
                           rows=n))
            # --- page-checksum verification cost on the scan path: crc32
            # over stored bytes already in cache; check_perf gates the
            # overhead at < 10% (verify="page" is the default, so this IS
            # the cost every reader pays for end-to-end integrity)
            t_voff = timeit_median(lambda: db.read(
                load_config=LoadConfig(verify="off")), k=5)
            t_vpage = timeit_median(lambda: db.read(
                load_config=LoadConfig(verify="page")), k=5)
            out.append(row(f"fig5/read-scan-verify-off/parquetdb/n={n}",
                           t_voff, rows=n))
            out.append(row(f"fig5/read-scan-verify-page/parquetdb/n={n}",
                           t_vpage, rows=n,
                           overhead_vs_off=t_vpage / t_voff))
            # --- parallel read-scan: multi-fragment layout, 1 vs 4 morsel
            # workers (a single-file dataset is one morsel — nothing to
            # parallelize — so re-partition like a grown database first)
            db.normalize(NormalizeConfig(max_rows_per_file=max(n // 8, 1_000),
                                         max_rows_per_group=2_048))
            t_mt1 = timeit_median(lambda: db.read(
                load_config=LoadConfig(num_threads=1)), k=3)
            t_mt4 = timeit_median(lambda: db.read(
                load_config=LoadConfig(num_threads=4)), k=3)
            out.append(row(f"fig5/read-scan-mt1/parquetdb/n={n}", t_mt1,
                           rows=n))
            out.append(row(f"fig5/read-scan-mt4/parquetdb/n={n}", t_mt4,
                           rows=n, speedup_vs_mt1=t_mt1 / t_mt4))
            # same layout through the process executor: the decode half
            # runs in spawn workers, so GIL-held entropy decode scales too
            t_mt4p = timeit_median(lambda: db.read(
                load_config=LoadConfig(num_threads=4, executor="process")),
                k=3)
            out.append(row(f"fig5/read-scan-mt4-process/parquetdb/n={n}",
                           t_mt4p, rows=n, speedup_vs_mt1=t_mt1 / t_mt4p))
            # --- SQLite (paper Listing 1 incl. PRAGMAs)
            conn_holder = {}
            t_create = timeit(lambda: conn_holder.setdefault(
                "c", sqlite_create(os.path.join(tmp, "s.db"), rows)))
            conn = conn_holder["c"]
            t_read = timeit(
                lambda: conn.execute("SELECT * FROM test_table").fetchall())
            conn.close()
            out.append(row(f"fig5/create/sqlite/n={n}", t_create, rows=n))
            out.append(row(f"fig5/read/sqlite/n={n}", t_read, rows=n))
            # --- DocDB (embedded document baseline)
            ddb = DocDB(os.path.join(tmp, "docs.jsonl"))
            t_create = timeit(lambda: ddb.insert_many(rows))
            t_read = timeit(lambda: ddb.find_all())
            out.append(row(f"fig5/create/docdb/n={n}", t_create, rows=n))
            out.append(row(f"fig5/read/docdb/n={n}", t_read, rows=n))
    return out
