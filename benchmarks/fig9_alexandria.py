"""Paper Fig. 9: JSON load time vs ParquetDB create time per shard for the
(synthetic) Alexandria materials dataset."""
from __future__ import annotations

import json
import os
from typing import List

from repro.core import ParquetDB

from .alexandria import write_json_shards
from .common import TmpDir, row, timeit


def run(scale: str = "small") -> List[dict]:
    n_total, per_file = {"small": (2_000, 500),
                         "medium": (20_000, 5_000),
                         "paper": (500_000, 100_000)}[scale]
    out: List[dict] = []
    with TmpDir() as tmp:
        shards = write_json_shards(os.path.join(tmp, "json"), n_total,
                                   per_file)
        db = ParquetDB(os.path.join(tmp, "pdb"), "alexandria")
        for i, p in enumerate(shards):
            holder = {}
            t_load = timeit(lambda: holder.setdefault(
                "d", json.load(open(p))))
            data = holder["d"]["entries"]
            t_create = timeit(lambda: db.create(
                data, treat_fields_as_ragged=["data.elements"]))
            out.append(row(f"fig9/json_load/shard={i}", t_load,
                           rows=len(data)))
            out.append(row(f"fig9/create/shard={i}", t_create,
                           rows=len(data)))
        out.append(row("fig9/total_rows", 0.0, rows=db.n_rows))
    return out
