"""Paper Fig. 9: the (synthetic) Alexandria materials dataset, two ways.

Phase 1 (the paper's figure): JSON load time vs ParquetDB create time per
shard, into one flat dataset.

Phase 2 (this repo's partitioned layout): the same records re-created into
a hive-partitioned dataset (``part = spg % N_PARTS``), then

- ``fig9/scan-full/n=...``       full materializing read,
- ``fig9/scan-selective/n=...``  one-partition query — the manifest prunes
  every other partition before a single footer is opened (the pruning
  counters ride along in the derived fields), and
- ``fig9/scan-sharded-w<k>/n=...``  a multi-process shard-per-worker scan:
  partitions are placed onto worker processes with the mesh-placement
  rules from :mod:`repro.distributed.sharding` when jax is importable
  (``NamedSharding.devices_indices_map`` over a 1-D data mesh), falling
  back to contiguous blocks on jax-free boxes; each worker opens the
  dataset itself and reads only its partitions.

``scripts/check_perf.py`` gates ``fig9 partition-prune`` on the
selective-vs-full ratio of this suite's artifact.
"""
from __future__ import annotations

import json
import math
import os
from typing import List

from repro.core import ParquetDB
from repro.core.expressions import IsIn, field

from .alexandria import write_json_shards
from .common import TmpDir, row, timeit, timeit_median

N_PARTS = 16  # hive partitions: part = spg % N_PARTS
SELECTIVE_PART = 3


def _placement(n_parts: int, n_workers: int) -> tuple:
    """-> (assignment, mode): partition indices per worker.

    Reuses the distributed mesh-placement rules when jax is available: a
    1-D ``("pod", "data", "model")`` mesh over the host's devices, the
    ``batch`` logical axis sharded across it, and the partition index
    range split by ``NamedSharding.devices_indices_map`` — the same
    placement a data-parallel loader would get.  Jax-free (or too few
    devices): contiguous blocks, which is what the mesh degenerates to on
    one host anyway.
    """
    try:
        import jax
        import numpy as np
        from jax.sharding import Mesh, NamedSharding

        from repro.distributed.sharding import spec_for

        devs = jax.devices()
        if len(devs) >= n_workers and n_parts % n_workers == 0:
            mesh = Mesh(np.array(devs[:n_workers]).reshape(1, n_workers, 1),
                        ("pod", "data", "model"))
            spec = spec_for((n_parts,), ("batch",), mesh)
            imap = NamedSharding(mesh, spec).devices_indices_map((n_parts,))
            assign = []
            seen = set()
            for dev in devs[:n_workers]:
                sl = imap[dev][0]
                block = [i for i in range(*sl.indices(n_parts))
                         if i not in seen]
                seen.update(block)
                assign.append(block)
            if seen == set(range(n_parts)):
                return assign, "mesh"
    except Exception:
        pass
    step = math.ceil(n_parts / n_workers)
    return [list(range(i, min(i + step, n_parts)))
            for i in range(0, n_parts, step)], "blocks"


def _scan_shard(args) -> int:
    """Worker: open the dataset and read only this worker's partitions."""
    path, parts = args
    db = ParquetDB(path, "alexandria_part")
    return db.read(filters=[IsIn("part", parts)]).num_rows


def _sharded_scan(path: str, n_workers: int, assign) -> int:
    import multiprocessing as mp
    from concurrent.futures import ProcessPoolExecutor
    ctx = mp.get_context("spawn")
    with ProcessPoolExecutor(max_workers=n_workers, mp_context=ctx) as ex:
        return sum(ex.map(_scan_shard,
                          [(path, parts) for parts in assign if parts]))


def _assert_verified(pdb) -> None:
    report = pdb.verify(deep=True)
    assert report.ok, f"integrity scrub failed:\n{report}"


def run(scale: str = "small") -> List[dict]:
    n_total, per_file = {"quick": (4_000, 2_000),
                         "small": (2_000, 500),
                         "medium": (20_000, 5_000),
                         "paper": (500_000, 100_000)}[scale]
    out: List[dict] = []
    with TmpDir() as tmp:
        shards = write_json_shards(os.path.join(tmp, "json"), n_total,
                                   per_file)
        db = ParquetDB(os.path.join(tmp, "pdb"), "alexandria")
        shard_data = []
        for i, p in enumerate(shards):
            holder = {}
            t_load = timeit(lambda: holder.setdefault(
                "d", json.load(open(p))))
            data = holder["d"]["entries"]
            for r in data:
                r["part"] = r["data"]["spg"] % N_PARTS
            shard_data.append(data)
            t_create = timeit(lambda: db.create(
                data, treat_fields_as_ragged=["data.elements"]))
            out.append(row(f"fig9/json_load/shard={i}", t_load,
                           rows=len(data)))
            out.append(row(f"fig9/create/shard={i}", t_create,
                           rows=len(data)))
        out.append(row("fig9/total_rows", 0.0, rows=db.n_rows))

        # ---- phase 2: the same records, hive-partitioned by spg bucket
        ppath = os.path.join(tmp, "pdb_part")
        pdb = ParquetDB(ppath, "alexandria_part", partition_by=["part"])

        def create_part():
            for data in shard_data:
                pdb.create(data, treat_fields_as_ragged=["data.elements"])
        t_create_part = timeit(create_part)
        out.append(row(f"fig9/create-part/n={n_total}", t_create_part,
                       rows=n_total, partitions=N_PARTS))

        t_full = timeit_median(lambda: pdb.read(), k=3)
        sel = field("part") == SELECTIVE_PART
        t_sel = timeit_median(lambda: pdb.read(filters=[sel]), k=3)
        rep = pdb.explain(filters=[sel], execute=True)
        c = rep.counters
        out.append(row(f"fig9/scan-full/n={n_total}", t_full, rows=n_total))
        out.append(row(f"fig9/scan-selective/n={n_total}", t_sel,
                       rows=c.rows_matched,
                       partitions_total=c.partitions_total,
                       partitions_pruned=c.partitions_pruned,
                       partitions_scanned=c.partitions_scanned,
                       speedup_vs_full=round(t_full / t_sel, 2)))

        # ---- integrity scrub of the real-data fixture: every committed
        # file's footer + page checksums must hold (the --quick CI smoke
        # runs this, so a writer bug that commits damaged bytes trips here)
        t_verify = timeit(lambda: _assert_verified(pdb))
        out.append(row(f"fig9/verify-deep/n={n_total}", t_verify,
                       rows=n_total))

        n_workers = min(4, os.cpu_count() or 1)
        if n_workers > 1:
            assign, mode = _placement(N_PARTS, n_workers)
            holder = {}
            t_shard = timeit(lambda: holder.setdefault(
                "n", _sharded_scan(ppath, n_workers, assign)))
            assert holder["n"] == n_total, (holder["n"], n_total)
            out.append(row(f"fig9/scan-sharded-w{n_workers}/n={n_total}",
                           t_shard, rows=n_total, workers=n_workers,
                           placement=mode))
    return out
