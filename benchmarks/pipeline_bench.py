"""Training-data-path benchmarks: TokenStore throughput, pushdown savings,
bitpacked device feed (bytes over 'PCIe'), and loader work stealing."""
from __future__ import annotations

import os
from typing import List

import numpy as np

from repro.core import TPQReader, field
from repro.core import encodings as enc
from repro.data.sharded_loader import ShardedLoader, device_feed
from repro.data.tokenstore import TokenStore

from .common import TmpDir, row, timeit


def run(scale: str = "small") -> List[dict]:
    n_tokens = {"quick": 500_000, "small": 2_000_000,
                "medium": 20_000_000, "paper": 200_000_000}[scale]
    seq, vocab = 1024, 151_936
    out: List[dict] = []
    rng = np.random.default_rng(0)
    with TmpDir() as tmp:
        ts = TokenStore(os.path.join(tmp, "tok"), seq_len=seq, vocab=vocab)
        docs = [rng.integers(0, vocab, 100_000) for _ in range(n_tokens // 100_000)]
        t = timeit(lambda: ts.append_documents(docs))
        out.append(row("pipeline/ingest", t, tokens=n_tokens,
                       tokens_per_s=n_tokens / t))

        # raw sequential read throughput
        def read_all():
            total = 0
            for b in ts.read_batches(64):
                total += b.size
            return total
        t = timeit(read_all)
        out.append(row("pipeline/read_all", t, tokens_per_s=n_tokens / t))

        # loader with prefetch + steal
        ld = ShardedLoader(ts.db, batch_size=64, prefetch=4)
        t = timeit(lambda: sum(b.size for b in ld.epoch(0)))
        out.append(row("pipeline/sharded_loader", t,
                       tokens_per_s=n_tokens / t))

        # storage efficiency: bitpacked tokens vs raw int32
        man = ts.db._dir.load()
        stored = sum(os.path.getsize(ts.db._dir.file_path(f))
                     for f in man.files)
        raw = ts.n_sequences * seq * 4
        out.append(row("pipeline/storage_bytes", 0.0, stored=stored, raw=raw,
                       ratio=stored / raw))

        # device feed: bytes shipped bitpacked vs int32
        tok = rng.integers(0, vocab, (8, seq)).astype(np.int32)
        k = int(vocab - 1).bit_length()
        packed_bytes = 8 * seq * k / 8
        t = timeit(lambda: np.asarray(device_feed(tok, vocab)), repeat=2)
        out.append(row("pipeline/device_feed_bitpack", t,
                       bytes_packed=packed_bytes, bytes_raw=tok.nbytes,
                       pcie_ratio=packed_bytes / tok.nbytes))
    return out
