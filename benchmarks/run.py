"""Benchmark harness — one module per paper table/figure.

``python -m benchmarks.run [--scale small|medium|paper] [--only fig5,...]``
prints ``name,us_per_call,derived`` CSV (paper protocol) and writes the rows
into a ParquetDB results store so they are queryable like everything else.

``--json [DIR]`` additionally writes one ``BENCH_<fig>.json`` artifact per
suite (median-of-k timings in the rows, plus rows/sec where applicable) —
the machine-readable trajectory that ``scripts/check_perf.py`` gates CI on.
The canonical artifact directory is ``bench/`` (the bare ``--json``
default); the committed engine artifacts CI gates on live there.  (The
root ``BENCH_baseline.json`` is different: it records the pre-engine
*seed* numbers as a trajectory record — see scripts/check_perf.py.)
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import platform
import sys
import time

SUITES = ["fig5_create_read", "fig6_formats", "fig7_needle", "fig8_update",
          "fig9_alexandria", "fig10_ops", "fig11_aggregate", "fig12_serve",
          "pipeline_bench", "kernels_bench", "ckpt_bench"]


def _suite_tag(suite: str) -> str:
    """``fig5_create_read`` -> ``fig5``; non-figure suites keep their name."""
    head = suite.split("_", 1)[0]
    return head if head.startswith("fig") else suite


def write_json_artifact(directory: str, suite: str, scale: str,
                        rows: list) -> str:
    path = os.path.join(directory, f"BENCH_{_suite_tag(suite)}.json")
    doc = {
        "suite": suite,
        "scale": scale,
        "unit": "us_per_call (median-of-k for read/needle paths)",
        "machine": platform.machine(),
        "python": platform.python_version(),
        # scaling gates (check_perf SCALING_GATES) only make sense when
        # the recording box actually had the cores: stamp the count
        "cpus": os.cpu_count(),
        "generated_unix": int(time.time()),
        "rows": rows,
    }
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small",
                    choices=["quick", "small", "medium", "paper"])
    ap.add_argument("--quick", action="store_true",
                    help="shorthand for --scale quick: tiny-n smoke runs "
                         "of every suite, the CI regression signal")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite prefixes")
    ap.add_argument("--store", default=None,
                    help="optional ParquetDB dir for results")
    ap.add_argument("--json", nargs="?", const="bench", default=None,
                    metavar="DIR",
                    help="write BENCH_<fig>.json artifacts into DIR "
                         "(default: the canonical bench/ directory)")
    args = ap.parse_args(argv)
    if args.quick:
        args.scale = "quick"

    only = args.only.split(",") if args.only else None
    all_rows = []
    errors = 0
    print("name,us_per_call,derived")
    for suite in SUITES:
        if only and not any(suite.startswith(o) for o in only):
            continue
        try:
            # import inside the guard: a suite with an unavailable
            # accelerator dep reports one ERROR row instead of killing
            # the whole run
            mod = importlib.import_module(f".{suite}", package=__package__)
            rows = mod.run(args.scale)
        except Exception as e:
            print(f"{suite}/ERROR,0,\"{e!r}\"")
            errors += 1
            continue
        for r in rows:
            derived = {k: v for k, v in r.items()
                       if k not in ("name", "us_per_call")}
            print(f"{r['name']},{r['us_per_call']:.1f},"
                  f"\"{json.dumps(derived)}\"")
        sys.stdout.flush()
        if args.json is not None:
            os.makedirs(args.json, exist_ok=True)
            path = write_json_artifact(args.json, suite, args.scale, rows)
            print(f"# wrote {path}", file=sys.stderr)
        all_rows.extend(rows)
    if args.store and all_rows:
        from repro.core import ParquetDB
        db = ParquetDB(args.store, "bench_results")
        db.create([{k: (float(v) if isinstance(v, (int, float)) else str(v))
                    for k, v in r.items()} for r in all_rows])
    # ERROR rows keep the other suites running but still fail the exit
    # code, so CI smoke runs catch a broken suite
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
