"""Paper Fig. 10 / §6.2: the operation suite on the (synthetic) Alexandria
database — normalization, projections, filtered reads, nested access,
rebuild-nested, updates, and the band-gap classification query."""
from __future__ import annotations

import os
from typing import List

import numpy as np

from repro import compute as pc
from repro.core import NormalizeConfig, ParquetDB, field

from .alexandria import make_records
from .common import TmpDir, row, timeit


def run(scale: str = "small") -> List[dict]:
    n = {"quick": 1_000, "small": 5_000, "medium": 50_000,
         "paper": 1_000_000}[scale]
    out: List[dict] = []
    with TmpDir() as tmp:
        db = ParquetDB(os.path.join(tmp, "pdb"), "alexandria")
        for s in range(0, n, 10_000):
            db.create(make_records(min(10_000, n - s), seed=s),
                      treat_fields_as_ragged=["data.elements"])

        # 6.2.1 normalization
        t = timeit(lambda: db.normalize(NormalizeConfig(
            max_rows_per_file=max(n // 4, 1000),
            max_rows_per_group=max(n // 8, 500))))
        out.append(row("fig10/normalize", t, rows=n))
        # 6.2.2 single column
        t = timeit(lambda: db.read(columns=["id"]), repeat=3)
        out.append(row("fig10/read_id_column", t, rows=n))
        # 6.2.3 query 10 ids
        ids = list(np.linspace(0, n - 1, 10).astype(int))
        t = timeit(lambda: db.read(ids=ids), repeat=3)
        out.append(row("fig10/query_10_ids", t, rows=10))
        # 6.2.4 min/max energy
        def minmax():
            tbl = db.read(columns=["energy"])
            return pc.min_max(tbl["energy"])
        t = timeit(minmax, repeat=3)
        out.append(row("fig10/energy_min_max", t, rows=n))
        # 6.2.5 filter energies above -1 eV
        t = timeit(lambda: db.read(columns=["id", "energy"],
                                   filters=[field("energy") > -1.0]),
                   repeat=3)
        out.append(row("fig10/filter_energy", t, rows=n))
        # 6.2.6 space-group equality on a nested field
        t = timeit(lambda: db.read(columns=["id", "data.spg"],
                                   filters=[field("data.spg") == 204]),
                   repeat=3)
        out.append(row("fig10/filter_spg", t, rows=n))
        # 6.2.7 batched space-group query
        def batched():
            gen = db.read(columns=["id", "data.spg"],
                          filters=[field("data.spg") == 204],
                          load_format="batches", batch_size=1_000)
            return sum(b.num_rows for b in gen)
        t = timeit(batched, repeat=3)
        out.append(row("fig10/filter_spg_batched", t, rows=n))
        # 6.2.8 nested subfield (list-of-dicts) read
        t = timeit(lambda: db.read(columns=["id", "structure.sites"]))
        out.append(row("fig10/read_sites", t, rows=n))
        # 6.2.9 rebuild nested from scratch / 6.2.10 cached
        t = timeit(lambda: db.read(columns=["id", "structure", "data"],
                                   ids=[0], rebuild_nested_struct=True,
                                   rebuild_nested_from_scratch=True))
        out.append(row("fig10/rebuild_nested_scratch", t, rows=n))
        t = timeit(lambda: db.read(columns=["id", "structure", "data"],
                                   ids=[0], rebuild_nested_struct=True))
        out.append(row("fig10/rebuild_nested_cached", t, rows=1))
        # 6.2.11 single-record update (+normalize config, as in the paper)
        t = timeit(lambda: db.update(
            [{"id": 0, "data.spg": 210}],
            normalize_config=NormalizeConfig(
                max_rows_per_file=max(n // 4, 1000))))
        out.append(row("fig10/update_1", t, rows=1))
        # 6.2.12 bulk update
        k = min(10_000, n)
        t = timeit(lambda: db.update(
            {"id": np.arange(k), "data.spg": np.full(k, 123)}))
        out.append(row("fig10/update_bulk", t, rows=k))
        # 6.2.13 read nd lattice matrix filtered by spg
        def lattice():
            tbl = db.read(columns=["structure.lattice.matrix"],
                          filters=[field("data.spg") == 123])
            return tbl["structure.lattice.matrix"].to_numpy()
        t = timeit(lattice, repeat=3)
        out.append(row("fig10/read_lattice_nd", t, rows=n))
        # 6.2.14 band-gap classification (paper's if_else query)
        def classify():
            expr = pc.if_else(
                (field("data.band_gap_ind") != 0)
                & (field("data.band_gap_ind") < field("data.band_gap_dir")),
                (field("data.band_gap_ind") > 0.1)
                & (field("data.band_gap_ind") < 3),
                (field("data.band_gap_dir") > 0.1)
                & (field("data.band_gap_dir") < 3))
            return db.read(columns=["id"], filters=[expr]).num_rows
        t = timeit(classify, repeat=3)
        out.append(row("fig10/band_gap_semiconductors", t,
                       semiconductors=classify(), rows=n))
        # element distribution over semiconductors (paper's manual loop)
        def element_hist():
            tbl = db.read(columns=["data.elements"])
            flat = pc.list_flatten(tbl["data.elements"])
            vals = flat.to_pylist()
            from collections import Counter
            return Counter(vals)
        t = timeit(element_hist)
        out.append(row("fig10/element_distribution", t, rows=n))
    return out
