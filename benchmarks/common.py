"""Shared benchmark scaffolding: timing, dataset generators, SQLite helper."""
from __future__ import annotations

import shutil
import sqlite3
import tempfile
import time
from typing import Callable, Dict, List

import numpy as np

N_COLS = 100  # paper: synthetic datasets of 100 integer columns


def timeit(fn: Callable, *, repeat: int = 1) -> float:
    """Seconds for one call (best of `repeat`)."""
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def timeit_median(fn: Callable, *, k: int = 5) -> float:
    """Seconds for one call (median of ``k`` — the --json artifact protocol;
    medians absorb one-off GC/page-cache outliers that min/mean don't)."""
    times = []
    for _ in range(k):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    mid = len(times) // 2
    return times[mid] if len(times) % 2 else (times[mid - 1] + times[mid]) / 2


def gen_rows_pylist(n_rows: int, seed: int = 0) -> List[dict]:
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 1_000_000, (n_rows, N_COLS))
    return [{f"col{i}": int(row[i]) for i in range(N_COLS)} for row in data]


def gen_rows_pydict(n_rows: int, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    return {f"col{i}": rng.integers(0, 1_000_000, n_rows)
            for i in range(N_COLS)}


def sqlite_create(db_path: str, rows: List[dict]) -> sqlite3.Connection:
    """Paper Listing 1: PRAGMA-optimized bulk insert."""
    conn = sqlite3.connect(db_path)
    conn.execute("PRAGMA synchronous = OFF")
    conn.execute("PRAGMA journal_mode = MEMORY")
    cols = ", ".join(f"col{i} INTEGER" for i in range(N_COLS))
    conn.execute(f"CREATE TABLE IF NOT EXISTS test_table (rowid_ INTEGER, {cols})")
    ph = ", ".join("?" for _ in range(N_COLS + 1))
    data = [(j, *[r[f"col{i}"] for i in range(N_COLS)])
            for j, r in enumerate(rows)]
    conn.executemany(f"INSERT INTO test_table VALUES ({ph})", data)
    conn.commit()
    return conn


class TmpDir:
    def __enter__(self):
        self.path = tempfile.mkdtemp(prefix="repro_bench_")
        return self.path

    def __exit__(self, *exc):
        shutil.rmtree(self.path, ignore_errors=True)


def row(name: str, seconds: float, **derived) -> dict:
    d = {"name": name, "us_per_call": seconds * 1e6}
    if derived.get("rows") and seconds > 0:
        d["rows_per_sec"] = derived["rows"] / seconds
    d.update(derived)
    return d
