"""DocDB — an embedded document-database baseline standing in for MongoDB.

MongoDB needs a server process this container can't run; the paper's
comparisons need a document-model opponent, so this is an honest embedded
one: JSON-lines storage (schema-less documents), full-scan queries, optional
hash indexes (field -> byte offsets) mirroring MongoDB's indexed/non-indexed
split in the paper's Fig. 7/8.  Deliberately simple — it plays the role of
"document database with/without index", not a Mongo re-implementation.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterable, List, Optional


class DocDB:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        if not os.path.exists(path):
            open(path, "w").close()
        self._indexes: Dict[str, Dict[Any, List[int]]] = {}

    # -- write -------------------------------------------------------------------
    def insert_many(self, docs: Iterable[dict]) -> int:
        n = 0
        with open(self.path, "a") as fh:
            for d in docs:
                off = fh.tell()
                fh.write(json.dumps(d) + "\n")
                for field, idx in self._indexes.items():
                    if field in d:
                        idx.setdefault(d[field], []).append(off)
                n += 1
        return n

    # -- index -------------------------------------------------------------------
    def create_index(self, field: str) -> None:
        idx: Dict[Any, List[int]] = {}
        with open(self.path) as fh:
            off = 0
            for line in fh:
                d = json.loads(line)
                if field in d:
                    idx.setdefault(d[field], []).append(off)
                off += len(line.encode())
        self._indexes[field] = idx

    # -- read --------------------------------------------------------------------
    def find_all(self) -> List[dict]:
        with open(self.path) as fh:
            return [json.loads(line) for line in fh]

    def find_eq(self, field: str, value: Any) -> List[dict]:
        idx = self._indexes.get(field)
        if idx is not None:
            offs = idx.get(value, [])
            out = []
            with open(self.path) as fh:
                for off in offs:
                    fh.seek(off)
                    out.append(json.loads(fh.readline()))
            return out
        return [d for d in self.find_all() if d.get(field) == value]

    # -- update ------------------------------------------------------------------
    def update_many(self, updates: Dict[Any, dict], key: str = "_id") -> int:
        """Rewrite the file applying {key_value: partial_doc} updates."""
        tmp = self.path + ".tmp"
        n = 0
        with open(self.path) as src, open(tmp, "w") as dst:
            for line in src:
                d = json.loads(line)
                u = updates.get(d.get(key))
                if u is not None:
                    d.update(u)
                    n += 1
                dst.write(json.dumps(d) + "\n")
        os.replace(tmp, self.path)
        for f in list(self._indexes):
            self.create_index(f)
        return n
