"""Decode-kernel benchmarks: host numpy codecs vs the Pallas kernels
(interpret mode on CPU — correctness-bearing; the derived column reports the
encoded:decoded byte ratio, which is the PCIe/DMA win the kernels buy on
real hardware)."""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core import encodings as enc
from repro.kernels import ops

from .common import row, timeit


def run(scale: str = "small") -> List[dict]:
    n = {"quick": 50_000, "small": 200_000, "medium": 1_000_000,
         "paper": 10_000_000}[scale]
    rng = np.random.default_rng(0)
    out: List[dict] = []
    cases = [
        ("bitpack_tokens_v152k", rng.integers(0, 151_936, n).astype(np.int64),
         enc.BITPACK, np.int32),
        ("dict_lowcard", rng.integers(0, 30, n).astype(np.int64) * 7,
         enc.DICT, np.int64),
        ("delta_sorted_ids", np.cumsum(rng.integers(0, 5, n)).astype(np.int64),
         enc.DELTA, np.int32),
        ("bss_f32", rng.standard_normal(n).astype(np.float32),
         enc.BSS, np.float32),
    ]
    for name, arr, encoding, dev_dt in cases:
        chosen, meta, payload = enc.encode(arr, encoding)
        t_host = timeit(
            lambda: enc.decode(chosen, meta, payload, len(arr), arr.dtype),
            repeat=2)
        out.append(row(f"kernels/host_decode/{name}", t_host,
                       encoded_bytes=len(payload), raw_bytes=arr.nbytes,
                       compression=len(payload) / arr.nbytes))
        # device path in interpret mode (CPU) — correctness + plumbing cost
        t_dev = timeit(lambda: np.asarray(ops.decode_on_device(
            chosen, meta, payload, len(arr), dev_dt)), repeat=2)
        out.append(row(f"kernels/pallas_interpret/{name}", t_dev,
                       encoded_bytes=len(payload)))
    return out
