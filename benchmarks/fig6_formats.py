"""Paper Fig. 6: impact of input data format on ParquetDB update time.

Formats: python list-of-dicts (pylist), dict of python lists (pydict),
dict of numpy arrays (columns — our pandas stand-in), repro Table (the
PyArrow-Table analogue).  Updates target a preloaded dataset.
"""
from __future__ import annotations

import os
from typing import List

import numpy as np

from repro.core import ParquetDB, Table

from .common import N_COLS, TmpDir, gen_rows_pydict, gen_rows_pylist, row, \
    timeit


def _update_payload(n: int, fmt: str):
    rng = np.random.default_rng(1)
    ids = np.arange(n, dtype=np.int64)
    vals = {f"col{i}": rng.integers(0, 1_000_000, n) for i in range(10)}
    if fmt == "pylist":
        return [{"id": int(i), **{k: int(v[j]) for k, v in vals.items()}}
                for j, i in enumerate(ids)]
    if fmt == "pydict":
        return {"id": ids.tolist(), **{k: v.tolist() for k, v in vals.items()}}
    if fmt == "columns":
        return {"id": ids, **vals}
    if fmt == "table":
        return Table.from_pydict({"id": ids, **vals})
    raise ValueError(fmt)


def run(scale: str = "small") -> List[dict]:
    base_n = {"quick": 2_000, "small": 20_000, "medium": 100_000,
              "paper": 1_000_000}[scale]
    upd_counts = {"quick": [100, 500],
                  "small": [100, 1_000, 10_000],
                  "medium": [100, 10_000, 100_000],
                  "paper": [100, 10_000, 100_000, 1_000_000]}[scale]
    out: List[dict] = []
    with TmpDir() as tmp:
        db = ParquetDB(os.path.join(tmp, "pdb"), "bench")
        db.create(gen_rows_pydict(base_n))
        for n in upd_counts:
            for fmt in ("pylist", "pydict", "columns", "table"):
                payload = _update_payload(n, fmt)
                t = timeit(lambda: db.update(payload))
                out.append(row(f"fig6/update/{fmt}/n={n}", t, rows=n))
    return out
