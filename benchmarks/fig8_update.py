"""Paper Fig. 8: bulk update of K rows in a preloaded dataset —
ParquetDB vs SQLite (indexed id) vs DocDB (indexed _id).

The paper's ParquetDB rewrites every affected data file, so update cost
scales with *dataset* size (its worst write-amplification hot spot).  Here
updates are merge-on-read: one upsert delta file is staged per call, so the
``fig8/parquetdb`` rows should scale with K (the delta size), not with
``base_n``.  Each row reports the staged delta-chain length and the planner's
delta counters; a final ``fig8/parquetdb/compact`` row times folding the
chain back into sorted base files.
"""
from __future__ import annotations

import os
from typing import List

import numpy as np

from repro.core import ParquetDB

from .common import TmpDir, gen_rows_pylist, row, sqlite_create, timeit
from .docdb import DocDB


def run(scale: str = "small") -> List[dict]:
    base_n = {"quick": 2_000, "small": 20_000, "medium": 200_000,
              "paper": 1_000_000}[scale]
    ks = {"quick": [10, 500],
          "small": [10, 1_000, 10_000],
          "medium": [10, 1_000, 100_000],
          "paper": [10, 1_000, 100_000, 1_000_000]}[scale]
    rows = gen_rows_pylist(base_n)
    out: List[dict] = []
    rng = np.random.default_rng(2)
    with TmpDir() as tmp:
        # auto_compact off: we time the delta path and the compaction
        # separately instead of letting the background trigger interleave
        db = ParquetDB(os.path.join(tmp, "pdb"), "bench", auto_compact=False)
        db.create(rows)
        conn = sqlite_create(os.path.join(tmp, "s.db"), rows)
        conn.execute("CREATE INDEX idx_id ON test_table(rowid_)")
        ddb = DocDB(os.path.join(tmp, "d.jsonl"))
        ddb.insert_many([{"_id": i, **r} for i, r in enumerate(rows)])
        ddb.create_index("_id")

        for k in ks:
            ids = rng.choice(base_n, size=min(k, base_n), replace=False)
            vals = rng.integers(0, 1_000_000, len(ids))
            # ParquetDB update (pylist input — paper's conservative choice):
            # O(delta) — stages one upsert file, rewrites no base file
            payload = [{"id": int(i), "col1": int(v)}
                       for i, v in zip(ids, vals)]
            t = timeit(lambda: db.update(payload))
            st = db.maintenance_stats()
            out.append(row(f"fig8/parquetdb/k={k}", t, rows=k,
                           delta_files=st.delta_files,
                           delta_rows=st.upsert_rows + st.tombstone_rows))
            # SQLite
            pairs = [(int(v), int(i)) for i, v in zip(ids, vals)]
            def sql_upd():
                conn.executemany(
                    "UPDATE test_table SET col1 = ? WHERE rowid_ = ?", pairs)
                conn.commit()
            t = timeit(sql_upd)
            out.append(row(f"fig8/sqlite/k={k}", t, rows=k))
            # DocDB
            updates = {int(i): {"col1": int(v)} for i, v in zip(ids, vals)}
            t = timeit(lambda: ddb.update_many(updates))
            out.append(row(f"fig8/docdb/k={k}", t, rows=k))

        # maintenance: fold the accumulated delta chain back into sorted
        # base files (the amortized cost the merge-on-read path defers)
        n_deltas = db.n_delta_files
        t = timeit(lambda: db.compact())
        out.append(row("fig8/parquetdb/compact", t, rows=sum(ks),
                       delta_files=n_deltas))
        rep = db.explain(execute=True)
        assert rep.counters.delta_files == 0, "compaction must clear deltas"
        conn.close()
    return out
