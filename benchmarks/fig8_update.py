"""Paper Fig. 8: bulk update of K rows in a preloaded dataset —
ParquetDB vs SQLite (indexed id) vs DocDB (indexed _id)."""
from __future__ import annotations

import os
from typing import List

import numpy as np

from repro.core import ParquetDB

from .common import TmpDir, gen_rows_pylist, row, sqlite_create, timeit
from .docdb import DocDB


def run(scale: str = "small") -> List[dict]:
    base_n = {"small": 20_000, "medium": 200_000, "paper": 1_000_000}[scale]
    ks = {"small": [10, 1_000, 10_000],
          "medium": [10, 1_000, 100_000],
          "paper": [10, 1_000, 100_000, 1_000_000]}[scale]
    rows = gen_rows_pylist(base_n)
    out: List[dict] = []
    rng = np.random.default_rng(2)
    with TmpDir() as tmp:
        db = ParquetDB(os.path.join(tmp, "pdb"), "bench")
        db.create(rows)
        conn = sqlite_create(os.path.join(tmp, "s.db"), rows)
        conn.execute("CREATE INDEX idx_id ON test_table(rowid_)")
        ddb = DocDB(os.path.join(tmp, "d.jsonl"))
        ddb.insert_many([{"_id": i, **r} for i, r in enumerate(rows)])
        ddb.create_index("_id")

        for k in ks:
            ids = rng.choice(base_n, size=min(k, base_n), replace=False)
            vals = rng.integers(0, 1_000_000, len(ids))
            # ParquetDB update (pylist input — paper's conservative choice)
            payload = [{"id": int(i), "col1": int(v)}
                       for i, v in zip(ids, vals)]
            t = timeit(lambda: db.update(payload))
            out.append(row(f"fig8/parquetdb/k={k}", t, rows=k))
            # SQLite
            pairs = [(int(v), int(i)) for i, v in zip(ids, vals)]
            def sql_upd():
                conn.executemany(
                    "UPDATE test_table SET col1 = ? WHERE rowid_ = ?", pairs)
                conn.commit()
            t = timeit(sql_upd)
            out.append(row(f"fig8/sqlite/k={k}", t, rows=k))
            # DocDB
            updates = {int(i): {"col1": int(v)} for i, v in zip(ids, vals)}
            t = timeit(lambda: ddb.update_many(updates))
            out.append(row(f"fig8/docdb/k={k}", t, rows=k))
        conn.close()
    return out
