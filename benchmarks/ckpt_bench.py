"""Checkpoint-as-database benchmarks: save/restore/partial-restore throughput
for a ~100M-parameter tree (the columnar checkpoint store's claims from
DESIGN.md §7.4 made measurable)."""
from __future__ import annotations

from typing import List

import jax
import numpy as np

from repro.train.checkpoint import CheckpointStore

from .common import TmpDir, row, timeit


def _tree(n_leaves: int, leaf_elems: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {f"layer_{i:03d}/w": rng.standard_normal(leaf_elems)
            .astype(np.float32) for i in range(n_leaves)}


def run(scale: str = "small") -> List[dict]:
    n_leaves, elems = {"quick": (8, 100_000),       # ~3 MB
                       "small": (48, 250_000),      # ~48 MB
                       "medium": (96, 1_000_000),   # ~384 MB
                       "paper": (96, 4_000_000)}[scale]
    tree = _tree(n_leaves, elems)
    total = sum(v.nbytes for v in tree.values())
    out: List[dict] = []
    with TmpDir() as tmp:
        st = CheckpointStore(tmp, keep=2)
        t = timeit(lambda: st.save(1, tree))
        out.append(row("ckpt/save", t, bytes=total, mb_per_s=total / t / 1e6))

        like = {k: np.zeros_like(v) for k, v in tree.items()}
        t = timeit(lambda: st.restore(1, like=like), repeat=2)
        out.append(row("ckpt/restore_full", t, mb_per_s=total / t / 1e6))

        # partial restore: one leaf via predicate pushdown on `path`
        t = timeit(lambda: st.restore(1, paths=["layer_000/w"]), repeat=3)
        out.append(row("ckpt/restore_one_leaf", t,
                       fraction=1.0 / n_leaves))

        # async save overlap: submission latency vs full write
        def async_save():
            th = st.async_save(2, tree)
            submit = True
            th.join()
            return submit
        t_async = timeit(async_save)
        out.append(row("ckpt/async_save_total", t_async, bytes=total))
    return out
