"""Fig. 12: the DB serving tier under mixed concurrent traffic.

Three measurements over one served dataset:

- ``fig12/query-cold/parquetdb`` vs ``fig12/query-warm/parquetdb`` — the
  same selective read planned+scanned fresh (cold: every request is a new
  plan, so both caches miss) vs answered from the snapshot-consistent
  result cache (warm).  ``check_perf.py`` gates warm >= 5x cold.
- ``fig12/mixed/c=<k>`` — closed-loop clients (each waits for its
  response) driving a read/agg/update mix at increasing client counts;
  derived fields carry QPS, p50/p99 latency and the shed count.  QPS
  grows with clients until the admission window (``max_concurrent +
  max_queue``) is full; beyond that the server *sheds* new work with
  immediate 503s — visible as ``shed > 0`` at high client counts while
  p99 of *served* requests stays bounded.
- snapshot-consistency oracle: while updates commit mid-traffic, every
  read of the written span must be uniform in ``v`` (one manifest
  generation per response, never a torn or stale mix) and generations
  must be non-decreasing per connection; after the traffic stops, server
  responses are compared field-for-field against direct ``db.query()``
  results.  Any violation raises — the suite then reports an ERROR row
  and the benchmark run fails.
"""
from __future__ import annotations

import threading
import time
from typing import List

import numpy as np

from repro.core import ParquetDB, field
from repro.serve.dbserver import DBServer
from repro.serve.protocol import DBClient

from .common import TmpDir, row

SPAN = 500  # rows [0, SPAN) are the write/oracle span


def _gen_rows(n: int) -> List[dict]:
    rng = np.random.default_rng(7)
    b = rng.integers(0, 5, n)
    return [{"a": i, "b": int(b[i]), "v": 0, "s": f"tag{i % 11}"}
            for i in range(n)]


def _mixed_client(host: str, port: int, cid: int, requests: int,
                  base_n: int, out: dict) -> None:
    """One closed-loop client; records latencies, sheds, oracle checks."""
    rng = np.random.default_rng(100 + cid)
    lats, shed, oracle_checks = [], 0, 0
    last_gen = 0
    c = DBClient(host, port)
    try:
        for i in range(requests):
            roll = rng.random()
            t0 = time.perf_counter()
            if roll < 0.50:    # cached selective read
                r = c.query(where=field("b") == int(rng.integers(5)),
                            select=["a", "v"], limit=100)
            elif roll < 0.70:  # oracle read over the written span
                r = c.query(where=field("a") < SPAN, select=["v"])
            elif roll < 0.80:  # stats-path aggregate
                r = c.agg({"a": ["min", "max"], "*": "count"})
            elif roll < 0.90:  # count
                r = c.count(where=field("b") == int(rng.integers(5)))
            else:              # write: bump the span's v
                k = int(rng.integers(1, 1 << 30))
                r = c.update([{"id": j, "v": k} for j in range(SPAN)])
            lat = time.perf_counter() - t0
            if r["status"] == 503:
                shed += 1
                time.sleep(0.002)
                continue
            if r["status"] != 200:
                raise RuntimeError(f"request failed: {r}")
            lats.append(lat)
            gen = r.get("generation", last_gen)
            if gen < last_gen:
                raise RuntimeError(
                    f"generation went backwards: {last_gen} -> {gen}")
            last_gen = gen
            if roll >= 0.50 and roll < 0.70:
                vs = {rw["v"] for rw in r["rows"]}
                if len(r["rows"]) != SPAN or len(vs) != 1:
                    raise RuntimeError(
                        f"torn/stale read at generation {gen}: "
                        f"{len(r['rows'])} rows, v values {sorted(vs)[:5]}")
                oracle_checks += 1
    finally:
        c.close()
    out[cid] = {"lats": lats, "shed": shed, "oracle": oracle_checks}


def _final_oracle(db: ParquetDB, client: DBClient) -> int:
    """After traffic stops: server answers == direct db.query() answers."""
    checks = 0
    pairs = [
        (client.query(where=field("a") < SPAN, select=["a", "v"],
                      order_by=["a"])["rows"],
         db.query().where(field("a") < SPAN).select("a", "v")
           .order_by("a").to_pylist()),
        (client.count(where=field("b") == 3)["count"],
         db.query().where(field("b") == 3).count()),
        (client.agg({"a": ["min", "max"], "*": "count"})["values"],
         db.query().agg({"a": ["min", "max"], "*": "count"})),
    ]
    for got, want in pairs:
        if got != want:
            raise RuntimeError(f"server diverged from direct query: "
                               f"{str(got)[:120]} != {str(want)[:120]}")
        checks += 1
    return checks


def run(scale: str = "small") -> List[dict]:
    base_n = {"quick": 5_000, "small": 40_000, "medium": 200_000,
              "paper": 1_000_000}[scale]
    client_counts = {"quick": [1, 2, 8], "small": [1, 2, 4, 8, 16],
                     "medium": [1, 2, 4, 8, 16, 32],
                     "paper": [1, 4, 16, 64]}[scale]
    reqs_per_client = {"quick": 12, "small": 25, "medium": 25,
                       "paper": 40}[scale]
    out: List[dict] = []
    with TmpDir() as tmp:
        db = ParquetDB(f"{tmp}/pdb", "bench", auto_compact=False)
        db.create(_gen_rows(base_n))
        # a deliberately small admission window so the largest client
        # counts demonstrably shed instead of queueing without bound
        srv = DBServer(db, max_concurrent=2, max_queue=2, morsel_budget=4)
        host, port = srv.start()
        c = DBClient(host, port)
        try:
            # -- cold: a fresh plan every call (unique limit -> unique
            # plan key), so plan+scan run end to end each time.  The
            # query is scan-heavy (filter + sort over the full dataset)
            # with a top-k payload, so the timing contrasts executing the
            # plan against skipping it — not payload serialization.
            k = 5
            cold = []
            for j in range(k):
                t0 = time.perf_counter()
                r = c.query(where=field("b") == 3, select=["a", "v"],
                            order_by=[["a", True]], limit=10 + k - j)
                cold.append(time.perf_counter() - t0)
                assert r["status"] == 200 and r["cache"] == "miss"
            cold.sort()
            out.append(row(f"fig12/query-cold/parquetdb/n={base_n}",
                           cold[k // 2], rows=base_n))
            # -- warm: same plan, served from the result cache
            warm_kw = dict(where=field("b") == 3, select=["a", "v"],
                           order_by=[["a", True]], limit=10 + k)
            assert c.query(**warm_kw)["cache"] == "hit"  # primed above
            warm = []
            for _ in range(k):
                t0 = time.perf_counter()
                r = c.query(**warm_kw)
                warm.append(time.perf_counter() - t0)
                assert r["cache"] == "hit"
            warm.sort()
            out.append(row(f"fig12/query-warm/parquetdb/n={base_n}",
                           warm[k // 2], rows=base_n,
                           speedup_vs_cold=cold[k // 2] / warm[k // 2]))

            # -- mixed closed-loop traffic at increasing client counts
            for nc in client_counts:
                results: dict = {}
                threads = [threading.Thread(
                    target=_mixed_client,
                    args=(host, port, cid, reqs_per_client, base_n,
                          results))
                    for cid in range(nc)]
                t0 = time.perf_counter()
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
                wall = time.perf_counter() - t0
                lats = sorted(lat for rr in results.values()
                              for lat in rr["lats"])
                served = len(lats)
                shed = sum(rr["shed"] for rr in results.values())
                oracle = sum(rr["oracle"] for rr in results.values())
                p50 = lats[int(0.50 * (served - 1))] if served else 0.0
                p99 = lats[int(0.99 * (served - 1))] if served else 0.0
                out.append(row(
                    f"fig12/mixed/c={nc}/parquetdb/n={base_n}",
                    wall / max(1, served),
                    qps=round(served / wall, 1),
                    p50_us=round(p50 * 1e6, 1),
                    p99_us=round(p99 * 1e6, 1),
                    served=served, shed=shed,
                    oracle_checks=oracle, clients=nc))

            # -- post-traffic oracle + server counters
            checks = _final_oracle(db, c)
            st = c.stats()
            out.append(row(f"fig12/stats/parquetdb/n={base_n}", 0.0,
                           oracle_final_checks=checks,
                           queries=st["stats"]["queries"],
                           writes=st["stats"]["writes"],
                           shed=st["stats"]["shed"],
                           result_hits=st["stats"]["result_hits"],
                           result_misses=st["stats"]["result_misses"],
                           plan_hits=st["stats"]["plan_hits"],
                           budget_waits=st["budget"]["waits"],
                           budget_peak=st["budget"]["peak_in_flight"]))
        finally:
            c.close()
            srv.stop()
    return out
