"""Paper Fig. 7: needle-in-a-haystack — retrieve one unique value from a
column; ParquetDB (stats pushdown, no index) vs SQLite / DocDB with and
without B-tree/hash indexes.

The ParquetDB rows also report the scan planner's pruning counters
(``db.explain``): row groups scanned vs. total, bytes decoded vs. stored —
the measurable form of the paper's "statistics replace indexes" claim.  A
built-in oracle check asserts the pruned read returns exactly the rows an
unpruned full scan would.
"""
from __future__ import annotations

import os
from typing import List

import numpy as np

from repro.core import NormalizeConfig, ParquetDB, field

from .common import (TmpDir, gen_rows_pylist, row, sqlite_create, timeit,
                     timeit_median)
from .docdb import DocDB

NEEDLE = 77_777_777


def run(scale: str = "small") -> List[dict]:
    counts = {"quick": [1_000, 5_000],
              "small": [1_000, 10_000, 50_000],
              "medium": [1_000, 10_000, 100_000],
              "paper": [1_000, 10_000, 100_000, 1_000_000]}[scale]
    out: List[dict] = []
    for n in counts:
        rows = gen_rows_pylist(n)
        pos = n // 2
        rows[pos]["col0"] = NEEDLE
        with TmpDir() as tmp:
            db = ParquetDB(os.path.join(tmp, "pdb"), "bench")
            db.create(rows)
            # database-like layout: several fragments, small row groups —
            # the granularity at which the planner can prune
            db.normalize(NormalizeConfig(
                max_rows_per_file=max(n // 8, 1_000),
                max_rows_per_group=2_048))
            expr = field("col0") == NEEDLE
            t = timeit_median(lambda: db.read(filters=[expr]).num_rows, k=5)
            rep = db.explain(filters=[expr], execute=True)
            c = rep.counters
            # oracle: pruned read is row-identical to an unpruned full scan
            full = db.read()
            oracle_ids = full.filter_mask(expr.evaluate(full))["id"].values
            pruned_ids = db.read(filters=[expr])["id"].values
            assert np.array_equal(np.sort(pruned_ids), np.sort(oracle_ids)), \
                "pruned read diverged from full scan"
            assert c.row_groups_scanned < c.row_groups_total or n <= 2_048, \
                "needle query failed to prune any row group"
            out.append(row(
                f"fig7/parquetdb/n={n}", t, rows=n,
                files_scanned=c.files_scanned, files_total=c.files_total,
                rg_scanned=c.row_groups_scanned, rg_total=c.row_groups_total,
                bytes_decoded=c.bytes_decoded, bytes_total=c.bytes_total,
                rows_skipped_late=c.rows_skipped_late,
                bytes_saved_late=c.bytes_saved_late))

            conn = sqlite_create(os.path.join(tmp, "s.db"), rows)
            q = f"SELECT * FROM test_table WHERE col0 = {NEEDLE}"
            t = timeit(lambda: conn.execute(q).fetchall(), repeat=3)
            out.append(row(f"fig7/sqlite-noindex/n={n}", t, rows=n))
            conn.execute("CREATE INDEX idx_col0 ON test_table(col0)")
            t = timeit(lambda: conn.execute(q).fetchall(), repeat=3)
            out.append(row(f"fig7/sqlite-indexed/n={n}", t, rows=n))
            conn.close()

            ddb = DocDB(os.path.join(tmp, "d.jsonl"))
            ddb.insert_many(rows)
            t = timeit(lambda: ddb.find_eq("col0", NEEDLE), repeat=3)
            out.append(row(f"fig7/docdb-noindex/n={n}", t, rows=n))
            ddb.create_index("col0")
            t = timeit(lambda: ddb.find_eq("col0", NEEDLE), repeat=3)
            out.append(row(f"fig7/docdb-indexed/n={n}", t, rows=n))
    return out
