"""Paper Fig. 7: needle-in-a-haystack — retrieve one unique value from a
column; ParquetDB (stats pushdown, no index) vs SQLite / DocDB with and
without B-tree/hash indexes."""
from __future__ import annotations

import os
from typing import List

import numpy as np

from repro.core import ParquetDB, field

from .common import TmpDir, gen_rows_pylist, row, sqlite_create, timeit
from .docdb import DocDB

NEEDLE = 77_777_777


def run(scale: str = "small") -> List[dict]:
    counts = {"small": [1_000, 10_000, 50_000],
              "medium": [1_000, 10_000, 100_000],
              "paper": [1_000, 10_000, 100_000, 1_000_000]}[scale]
    out: List[dict] = []
    for n in counts:
        rows = gen_rows_pylist(n)
        pos = n // 2
        rows[pos]["col0"] = NEEDLE
        with TmpDir() as tmp:
            db = ParquetDB(os.path.join(tmp, "pdb"), "bench")
            db.create(rows)
            t = timeit(lambda: db.read(filters=[field("col0") == NEEDLE])
                       .num_rows, repeat=3)
            out.append(row(f"fig7/parquetdb/n={n}", t, rows=n))

            conn = sqlite_create(os.path.join(tmp, "s.db"), rows)
            q = f"SELECT * FROM test_table WHERE col0 = {NEEDLE}"
            t = timeit(lambda: conn.execute(q).fetchall(), repeat=3)
            out.append(row(f"fig7/sqlite-noindex/n={n}", t, rows=n))
            conn.execute("CREATE INDEX idx_col0 ON test_table(col0)")
            t = timeit(lambda: conn.execute(q).fetchall(), repeat=3)
            out.append(row(f"fig7/sqlite-indexed/n={n}", t, rows=n))
            conn.close()

            ddb = DocDB(os.path.join(tmp, "d.jsonl"))
            ddb.insert_many(rows)
            t = timeit(lambda: ddb.find_eq("col0", NEEDLE), repeat=3)
            out.append(row(f"fig7/docdb-noindex/n={n}", t, rows=n))
            ddb.create_index("col0")
            t = timeit(lambda: ddb.find_eq("col0", NEEDLE), repeat=3)
            out.append(row(f"fig7/docdb-indexed/n={n}", t, rows=n))
    return out
