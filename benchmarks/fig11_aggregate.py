"""Fig. 11 (beyond-paper): aggregate pushdown + parallel scan.

Three claims, one suite:

- ``fig11/aggregate/*`` — count/min/max/sum/mean over the whole dataset,
  answered from footer statistics (zero pages decoded) vs. the same
  aggregate computed by fully materializing the table
  (``aggregate-full-mat``) and vs. SQLite's un-indexed ``SELECT
  COUNT/MIN/MAX/SUM/AVG``.  The derived ``speedup_vs_full_mat`` is the
  order-of-magnitude headline; a built-in oracle asserts the pushed-down
  answer equals the materialized one exactly.
- ``fig11/aggregate-filtered/*`` — the same aggregate under a range
  predicate that splits a row group, exercising the covered/partial
  classification (most groups answered from stats, one decoded).
- ``fig11/read-scan-mt*`` + ``fig11/mt-read/*`` — full-table read-scan at
  1/2/4 morsel workers over a multi-fragment layout; ``mt-read`` (2
  workers, SQLite-normalized) is the row CI's perf gate tracks, since 2
  workers is what CI runners actually have.
- ``fig11/read-scan-mt4-process/*`` — the same scan through the process
  executor (``LoadConfig(executor="process")``): entropy-coded decode
  holds the GIL, so this is the fixture where processes beat threads.
- ``fig11/read-scan-zlib-mt*`` — a second dataset written with
  ``encoding="plain", codec="zlib"`` so reads are decompress-dominated
  and zlib *releases* the GIL.  Its mt4-vs-mt1 speedup is the
  self-relative "fig11 mt4-read" scaling gate in scripts/check_perf.py
  (enforced only when the artifact records >= 4 cpus).
"""
from __future__ import annotations

import os
from typing import List

import numpy as np

from repro.core import LoadConfig, NormalizeConfig, ParquetDB, field

from .common import (TmpDir, gen_rows_pylist, row, sqlite_create, timeit,
                     timeit_median)

# filtered aggregate: predicate on the sorted id column, cut mid-row-group
# so the planner must produce all three classes (pruned/covered/partial);
# a random-valued column would make every group partial and show nothing
FILTER_FRACTION = 3  # keep ids >= n // 3 (+7 to land inside a group)


def run(scale: str = "small") -> List[dict]:
    counts = {"quick": [5_000, 10_000],
              "small": [10_000, 50_000],
              "medium": [10_000, 100_000],
              "paper": [100_000, 1_000_000]}[scale]
    out: List[dict] = []
    spec = {"*": "count", "col0": ["min", "max", "sum", "mean"]}
    for n in counts:
        rows = gen_rows_pylist(n)
        with TmpDir() as tmp:
            db = ParquetDB(os.path.join(tmp, "pdb"), "bench")
            db.create(rows)
            # database-like layout: several fragments and row groups — the
            # granularity statistics answer at and morsels parallelize over
            db.normalize(NormalizeConfig(max_rows_per_file=max(n // 8, 1_000),
                                         max_rows_per_group=2_048))

            # --- aggregate pushdown vs full materialization
            t_agg = timeit_median(lambda: db.aggregate(spec), k=5)

            def full_mat():
                t = db.read(columns=["col0"])
                v = t["col0"].values
                return (t.num_rows, int(v.min()), int(v.max()),
                        int(v.sum()), float(v.mean()))

            t_mat = timeit_median(full_mat, k=3)
            got, rep = db.aggregate(spec, explain=True)
            nr, mn, mx, sm, mean = full_mat()
            assert (got["*"]["count"], got["col0"]["min"], got["col0"]["max"],
                    got["col0"]["sum"]) == (nr, mn, mx, sm), \
                "aggregate pushdown diverged from materialized reduction"
            assert rep.counters.groups_answered_by_stats > 0, \
                "no row group was answered from footer statistics"
            assert rep.counters.pages_scanned == 0, \
                "unfiltered aggregate decoded pages despite full stats cover"
            out.append(row(f"fig11/aggregate/parquetdb/n={n}", t_agg, rows=n,
                           speedup_vs_full_mat=t_mat / t_agg,
                           groups_stats=rep.counters.groups_answered_by_stats,
                           bytes_skipped=rep.counters.bytes_skipped_agg))
            out.append(row(f"fig11/aggregate-full-mat/parquetdb/n={n}", t_mat,
                           rows=n))

            # --- filtered aggregate (covered + partial classification)
            expr = field("id") >= n // FILTER_FRACTION + 7
            t_fagg = timeit_median(
                lambda: db.aggregate({"*": "count", "col0": "sum"},
                                     filters=[expr]), k=5)
            fa, frep = db.aggregate({"*": "count", "col0": "sum"},
                                    filters=[expr], explain=True)
            full = db.read(columns=["col0"], filters=[expr])
            assert fa["*"]["count"] == full.num_rows
            assert fa["col0"]["sum"] == (int(full["col0"].values.sum())
                                         if full.num_rows else None)
            assert frep.counters.groups_answered_by_stats > 0, \
                "filtered aggregate answered nothing from stats"
            assert frep.counters.rows_scanned > 0, \
                "mid-group cut should force at least one partial group"
            out.append(row(
                f"fig11/aggregate-filtered/parquetdb/n={n}", t_fagg, rows=n,
                groups_stats=frep.counters.groups_answered_by_stats,
                rows_decoded=frep.counters.rows_scanned))

            # --- parallel read-scan (morsel scheduler)
            t_mt = {}
            for nt in (1, 2, 4):
                cfg = LoadConfig(num_threads=nt)
                t_mt[nt] = timeit_median(
                    lambda: db.read(load_config=cfg), k=3)
                out.append(row(f"fig11/read-scan-mt{nt}/parquetdb/n={n}",
                               t_mt[nt], rows=n,
                               speedup_vs_mt1=t_mt[1] / t_mt[nt]))
            # process executor over the same (entropy-coded) layout: the
            # per-page decode holds the GIL, so threads convoy and only
            # sidestepping the GIL entirely can scale this fixture
            cfg_proc = LoadConfig(num_threads=4, executor="process")
            t_proc = timeit_median(lambda: db.read(load_config=cfg_proc),
                                   k=3)
            out.append(row(f"fig11/read-scan-mt4-process/parquetdb/n={n}",
                           t_proc, rows=n,
                           speedup_vs_mt1=t_mt[1] / t_proc))
            # parity oracle: threaded + process scans identical to serial
            s1 = db.read(load_config=LoadConfig(num_threads=1))
            s4 = db.read(load_config=LoadConfig(num_threads=4))
            sp = db.read(load_config=cfg_proc)
            assert np.array_equal(s1["id"].values, s4["id"].values) and \
                np.array_equal(s1["col0"].values, s4["col0"].values), \
                "parallel scan diverged from serial scan"
            assert np.array_equal(s1["id"].values, sp["id"].values) and \
                np.array_equal(s1["col0"].values, sp["col0"].values), \
                "process-executor scan diverged from serial scan"
            out.append(row(f"fig11/mt-read/parquetdb/n={n}", t_mt[2], rows=n))

            # --- compressed fixture: PLAIN pages under zlib are
            # decompress-dominated, and zlib inflate releases the GIL —
            # the fixture where mt4 can genuinely reach >= 3x mt1 on a
            # >= 4-core box (the "fig11 mt4-read" scaling gate)
            zdb = ParquetDB(os.path.join(tmp, "pdb_zlib"), "bench",
                            encoding="plain", codec="zlib",
                            compression_level=6)
            zdb.create(rows)
            zdb.normalize(NormalizeConfig(
                max_rows_per_file=max(n // 8, 1_000),
                max_rows_per_group=2_048))
            t_z = {}
            for nt in (1, 4):
                zcfg = LoadConfig(num_threads=nt)
                t_z[nt] = timeit_median(
                    lambda: zdb.read(load_config=zcfg), k=3)
                out.append(row(f"fig11/read-scan-zlib-mt{nt}/parquetdb/n={n}",
                               t_z[nt], rows=n,
                               speedup_vs_mt1=t_z[1] / t_z[nt]))
            z1 = zdb.read(load_config=LoadConfig(num_threads=1))
            z4 = zdb.read(load_config=LoadConfig(num_threads=4))
            assert np.array_equal(z1["col0"].values, z4["col0"].values), \
                "parallel zlib scan diverged from serial scan"

            # --- SQLite reference (same machine, same run: normalizes CI)
            conn = sqlite_create(os.path.join(tmp, "s.db"), rows)
            q = ("SELECT COUNT(*), MIN(col0), MAX(col0), SUM(col0), "
                 "AVG(col0) FROM test_table")
            t = timeit(lambda: conn.execute(q).fetchone(), repeat=3)
            out.append(row(f"fig11/aggregate/sqlite/n={n}", t, rows=n))
            t = timeit(lambda: conn.execute(
                "SELECT * FROM test_table").fetchall(), repeat=3)
            out.append(row(f"fig11/mt-read/sqlite/n={n}", t, rows=n))
            conn.close()
    return out
