"""Synthetic Alexandria-3D-like generator: the paper's §6 record shape
(nested materials documents) at configurable scale."""
from __future__ import annotations

import json
import os
from typing import List

import numpy as np

ELEMENTS = ["H", "Li", "B", "C", "N", "O", "F", "Na", "Mg", "Al", "Si", "P",
            "S", "Cl", "K", "Ca", "Ti", "V", "Cr", "Mn", "Fe", "Co", "Ni",
            "Cu", "Zn", "Ga", "Ge", "As", "Se", "Sr", "Y", "Zr", "Nb", "Mo"]


def make_records(n: int, seed: int = 0) -> List[dict]:
    rng = np.random.default_rng(seed)
    recs = []
    for i in range(n):
        n_sites = int(rng.integers(1, 12))
        els = [ELEMENTS[j] for j in rng.integers(0, len(ELEMENTS), n_sites)]
        gap_dir = float(np.round(rng.exponential(0.8), 4))
        gap_ind = float(np.round(max(gap_dir - rng.exponential(0.3), 0.0), 4))
        recs.append({
            "@class": "ComputedStructureEntry",
            "@module": "pymatgen.entries.computed_entries",
            "composition": {el: els.count(el) for el in set(els)},
            "data": {
                "spg": int(rng.integers(1, 231)),
                "band_gap_dir": gap_dir,
                "band_gap_ind": gap_ind,
                "elements": sorted(set(els)),
                "e_form": float(np.round(rng.normal(-1.0, 1.0), 5)),
            },
            "energy": float(np.round(rng.normal(-30, 10), 5)),
            "energy_adjustments": [],
            "entry_id": f"agm{i:09d}",
            "parameters": {},
            "structure": {
                "lattice": {"matrix": (np.round(
                    rng.normal(0, 3, (3, 3)), 5)).tolist(),
                    "volume": float(np.round(abs(rng.normal(50, 20)), 3))},
                "sites": [{"species": [{"element": el, "occu": 1}],
                           "xyz": np.round(rng.uniform(0, 10, 3), 5).tolist(),
                           "label": el}
                          for el in els],
            },
        })
    return recs


def write_json_shards(dirpath: str, n_total: int, per_file: int,
                      seed: int = 0) -> List[str]:
    os.makedirs(dirpath, exist_ok=True)
    paths = []
    done = 0
    i = 0
    while done < n_total:
        n = min(per_file, n_total - done)
        p = os.path.join(dirpath, f"alexandria_{i:03d}.json")
        with open(p, "w") as fh:
            json.dump({"entries": make_records(n, seed=seed + i)}, fh)
        paths.append(p)
        done += n
        i += 1
    return paths
