"""Batched serving example: continuous-batching engine over a small model.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    sys.exit(serve_main(["--arch", "qwen2.5-3b", "--reduced",
                         "--requests", "8", "--slots", "4",
                         "--max-new", "12"]))
