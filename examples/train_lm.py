"""End-to-end driver (deliverable b): train the ~100M `repro-100m` LM for a
few hundred steps from a columnar TokenStore, with checkpoints + metrics in
columnar stores.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(CPU-sized by default: reduced config; pass --full for the real 100M.)
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="full 100M config (slow on CPU)")
    ap.add_argument("--workdir", default="/tmp/repro_train_example")
    args = ap.parse_args()
    argv = ["--arch", "repro-100m", "--steps", str(args.steps),
            "--batch", "8", "--seq", "256", "--workdir", args.workdir]
    if not args.full:
        argv.append("--reduced")
    sys.exit(train_main(argv))
