"""Quickstart: the paper's §4.7 walkthrough, verbatim against repro.core.

Run:  PYTHONPATH=src python examples/quickstart.py

Also demonstrates ``db.explain()`` — the scan planner's pruning report
(files/row groups skipped via footer statistics, no index needed).  See
README.md and docs/ARCHITECTURE.md for the full picture.
"""
import os
import shutil
import tempfile

from repro.core import NormalizeConfig, ParquetDB, field

workdir = tempfile.mkdtemp(prefix="parquetdb_quickstart_")

# Initialize the database.  auto_compact=False so this walkthrough can
# drive the maintenance lifecycle by hand — by default a cost-based
# background trigger runs compact() for you after update/delete.
db = ParquetDB(os.path.join(workdir, "parquetdb"), auto_compact=False)

# Create data
data = [
    {"name": "Alice", "age": 30, "occupation": "Engineer"},
    {"name": "Bob", "age": 25, "occupation": "Data Scientist"},
]
db.create(data)

# Read data from the database
employees = db.read()
print(employees.to_pylist())

# Add another record with a NEW field -> schema evolves, old rows get null
db.create([{"name": "Jimmy", "age": 30, "state": "West Virginia"}])
print(db.read().to_pylist())

# Update Alice by id; adding a brand-new field on the fly
db.update([{"id": 0, "state": "Maryland", "zip": 26709}])
print(db.read(columns=["name", "state", "zip"]).to_pylist())

# Delete Jimmy (id=2)
db.delete(ids=[2])
print(db.read(columns=["name"]).to_pylist())

# Filters: predicate pushdown via field expressions (AND-combined list)
adults = db.read(columns=["name", "age"], filters=[field("age") >= 30])
print("age>=30:", adults.to_pylist())

# The same read as a composable lazy Query — read() is a thin shim over
# this: where/select/order_by/limit build one plan the scan engine
# optimizes end to end (filter fusion, projection pushdown, early stop)
adults2 = (db.query()
             .where(field("age") >= 30)
             .select("name", "age")
             .order_by("age", desc=True)
             .to_table())
print("query() same rows:", adults2.to_pylist())

# Computed columns and grouped aggregation (morsel-parallel hash groups)
by_age = (db.query()
            .group_by("age")
            .agg({"*": "count"})
            .order_by("age")
            .to_table())
print("rows per age:", by_age.to_pylist())

# explain(): how would this read be pruned?  Footer stats only — no decode.
print(db.explain(columns=["name", "age"], filters=[field("age") >= 30]))

# Query.explain() renders the whole operator tree around the scan report
print(db.query().where(field("age") >= 30).select("name").limit(1).explain())

# An impossible predicate scans almost nothing — but note the file count
# is not 0: the update above staged an upsert delta, and a fragment that
# may hold upserted rows cannot be pruned from its (stale) stored stats.
report = db.explain(filters=[field("age") > 200])
print("files scanned for age>200:", report.counters.files_scanned)

# Updates/deletes above were merge-on-read: they staged small delta files
# instead of rewriting data files.  maintenance_stats() reports the delta
# chain and whether the cost-based trigger recommends compacting it.
stats = db.maintenance_stats()
print(stats)

# compact() folds the delta chain back into sorted base files...
result = db.compact()
print("compacted:", result.compacted,
      "| deltas folded:", result.deltas_merged,
      "| delta files now:", db.n_delta_files)

# ...which restores full stats pruning: now nothing is scanned
report = db.explain(filters=[field("age") > 200])
print("files scanned for age>200 after compact:",
      report.counters.files_scanned)

# Normalize file/row-group layout
db.normalize(NormalizeConfig(max_rows_per_file=500))
print("files after normalize:", db.n_files, "rows:", db.n_rows)

# verify(): scrub every committed file — footer checksums, then every
# page's crc32 (deep=True).  Every TPQ file carries checksums, so bit rot
# or torn writes surface as typed errors with exact coordinates instead of
# silently wrong rows.  (Scans verify pages inline too: LoadConfig(verify=)
# with "page" as the default.)
report = db.verify(deep=True)
print(report)
assert report.ok

shutil.rmtree(workdir)
print("OK")
