"""The paper's §6 real-world workflow on a synthetic Alexandria-like dataset:
ingest nested materials records, normalize, run the query suite including the
band-gap classification (paper Fig. 11a) and the element distribution.

Run:  PYTHONPATH=src python examples/alexandria_workflow.py [--rows 20000]
"""
import argparse
import collections
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.alexandria import make_records
from repro import compute as pc
from repro.core import NormalizeConfig, ParquetDB, field


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=20_000)
    args = ap.parse_args()

    workdir = tempfile.mkdtemp(prefix="alexandria_")
    db = ParquetDB(os.path.join(workdir, "alexandria"))
    t0 = time.perf_counter()
    for s in range(0, args.rows, 10_000):
        db.create(make_records(min(10_000, args.rows - s), seed=s),
                  treat_fields_as_ragged=["data.elements"])
    print(f"ingested {db.n_rows} nested records in "
          f"{time.perf_counter()-t0:.2f}s across {db.n_files} files")

    db.normalize(NormalizeConfig(max_rows_per_file=50_000,
                                 max_rows_per_group=25_000))

    # single column projection
    t0 = time.perf_counter()
    ids = db.read(columns=["id"])
    print(f"read id column ({ids.num_rows} rows): "
          f"{(time.perf_counter()-t0)*1e3:.1f}ms")

    # energy extremes via compute fns
    tbl = db.read(columns=["energy"])
    print("energy min/max:", pc.min_max(tbl["energy"]))

    # band-gap classification (paper's if_else pattern)
    def gap_filter(lo, hi):
        return pc.if_else(
            (field("data.band_gap_ind") != 0)
            & (field("data.band_gap_ind") < field("data.band_gap_dir")),
            (field("data.band_gap_ind") > lo) & (field("data.band_gap_ind") < hi),
            (field("data.band_gap_dir") > lo) & (field("data.band_gap_dir") < hi))

    metals = db.read(columns=["id"], filters=[
        (field("data.band_gap_dir") == 0.0) & (field("data.band_gap_ind") == 0.0)
    ]).num_rows
    small = db.read(columns=["id"], filters=[gap_filter(0.0, 0.1)]).num_rows
    semi = db.read(columns=["id"], filters=[gap_filter(0.1, 3.0)]).num_rows
    insul = db.read(columns=["id"], filters=[gap_filter(3.0, 1e9)]).num_rows
    print(f"metals={metals} small-gap={small} semiconductors={semi} "
          f"insulators={insul}")

    # periodic-table distribution over semiconductors
    sel = db.read(columns=["data.elements"], filters=[gap_filter(0.1, 3.0)])
    flat = pc.list_flatten(sel["data.elements"])
    hist = collections.Counter(flat.to_pylist())
    print("top elements in semiconductors:", hist.most_common(8))

    # nested rebuild of one record
    rec = db.read(columns=["id", "structure", "data"], ids=[0],
                  rebuild_nested_struct=True).to_pylist(rebuild_nested=True)[0]
    print("rebuilt nested record keys:", sorted(rec["structure"].keys()))
    print("OK")


if __name__ == "__main__":
    main()
