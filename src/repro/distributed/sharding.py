"""Logical-axis sharding rules → PartitionSpec / NamedSharding.

Every parameter declares logical axes (see ``repro.models.layers.P``); this
module maps them onto the physical mesh:

  vocab/heads/ffn/exp/inner → "model"   (tensor / expert parallel)
  embed (d_model)           → "data"    (ZeRO-3/FSDP: weights gathered per
                                         layer inside the scan — XLA SPMD
                                         overlaps the all-gather with compute)
  batch                     → ("pod", "data")   (pure DP across pods)

Assignment is divisibility-aware with a second pass: if "model" could not be
placed on its preferred axis (e.g. phi4's 24 heads on a 16-wide model axis),
it stacks onto the FSDP dim instead (embed gets ("data", "model")) so the
weights stay fully distributed rather than silently replicating.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

# primary mesh axis per logical axis
PRIMARY = {
    "vocab": "model", "heads": "model", "ffn": "model", "exp": "model",
    "inner": "model", "kv": "model",
    "embed": "data",
    "batch": ("pod", "data"),
    "seq": None, "hdim": None, "layers": None, "state": None,
    "conv": None,
}
# fallback hosts for "model" if its primary placement failed (in priority
# order) — e.g. phi4's 24 heads or a GQA kv=2 cache on a 16-wide model axis:
# the model axis stacks onto the FSDP dim (weights) or the sequence dim
# (KV caches) instead of silently replicating.
MODEL_FALLBACK = ("embed", "ffn", "vocab", "inner", "seq")


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[a] for a in name if a in mesh.shape]))
    return mesh.shape.get(name, 1) if hasattr(mesh.shape, "get") else mesh.shape[name]


def _mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def spec_for(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
             mesh: Mesh) -> PS:
    """PartitionSpec for one array; every dim divisible or left replicated."""
    assert len(shape) == len(axes), (shape, axes)
    names = set(_mesh_axes(mesh))
    parts: list = [None] * len(axes)
    used: set = set()

    def fits(dim: int, mesh_axis) -> bool:
        if isinstance(mesh_axis, tuple):
            mesh_axis = tuple(a for a in mesh_axis if a in names)
            if not mesh_axis:
                return False
            sz = int(np.prod([mesh.shape[a] for a in mesh_axis]))
        else:
            if mesh_axis not in names:
                return False
            sz = mesh.shape[mesh_axis]
        return dim % sz == 0 and sz > 1

    for i, ax in enumerate(axes):
        pref = PRIMARY.get(ax)
        if pref is None:
            continue
        if isinstance(pref, tuple):
            avail = tuple(a for a in pref if a in names and a not in used)
            if avail and fits(shape[i], avail):
                parts[i] = avail if len(avail) > 1 else avail[0]
                used.update(avail)
        elif pref not in used and fits(shape[i], pref):
            parts[i] = pref
            used.add(pref)

    # second pass: place an unused "model" axis onto a fallback dim
    if "model" in names and "model" not in used:
        for fb in MODEL_FALLBACK:
            for i, ax in enumerate(axes):
                if ax != fb:
                    continue
                cur = parts[i]
                cur_t = (cur,) if isinstance(cur, str) else (cur or ())
                combined = cur_t + ("model",)
                sz = int(np.prod([mesh.shape[a] for a in combined]))
                if shape[i] % sz == 0:
                    parts[i] = combined if len(combined) > 1 else combined[0]
                    used.add("model")
                    break
            if "model" in used:
                break
    return PS(*parts)


def tree_specs(abstract_tree: Any, axes_tree: Any, mesh: Mesh) -> Any:
    """Map (shapes, logical axes) trees -> PartitionSpec tree."""
    leaves, treedef = jax.tree.flatten(abstract_tree)
    axes_leaves, _ = jax.tree.flatten(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))
    assert len(leaves) == len(axes_leaves), \
        f"params/axes tree mismatch: {len(leaves)} vs {len(axes_leaves)}"
    specs = [spec_for(l.shape, a, mesh) for l, a in zip(leaves, axes_leaves)]
    return jax.tree.unflatten(treedef, specs)


def tree_shardings(abstract_tree: Any, axes_tree: Any, mesh: Mesh) -> Any:
    specs = tree_specs(abstract_tree, axes_tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, PS))


def batch_spec(mesh: Mesh, ndim: int = 2, batch_dim: Optional[int] = None) -> PS:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if batch_dim is not None:
        sz = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        while axes and batch_dim % sz != 0:
            axes = axes[:-1]     # drop trailing axis until divisible
            sz = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    return PS(lead, *([None] * (ndim - 1)))


def batch_shardings(mesh: Mesh, specs: Dict[str, Any]) -> Dict[str, Any]:
    """Shardings for an input-batch dict of ShapeDtypeStructs
    (divisibility-aware: a batch of 1 stays replicated)."""
    return {k: NamedSharding(mesh, batch_spec(mesh, len(v.shape),
                                              batch_dim=v.shape[0]))
            for k, v in specs.items()}


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, PS())
