"""Distribution: logical-axis sharding rules and mesh helpers."""
from . import sharding

__all__ = ["sharding"]
