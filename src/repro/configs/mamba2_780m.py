"""mamba2-780m [ssm] 48L d=1536 (attention-free) vocab=50280 ssm_state=128
SSD (state-space duality)  [arXiv:2405.21060]
d_inner = 2*d = 3072, headdim 64 -> 48 SSM heads."""
from ..models import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm", n_layers=48, d_model=1536,
    d_ff=0, vocab=50280,
    ssm=SSMCfg(d_state=128, headdim=64, expand=2, ngroups=1, chunk=128),
    supports_long_context=True)

REDUCED = ModelConfig(
    name="mamba2-780m-reduced", family="ssm", n_layers=2, d_model=64,
    d_ff=0, vocab=512,
    ssm=SSMCfg(d_state=16, headdim=16, expand=2, chunk=8),
    supports_long_context=True, remat=False)
