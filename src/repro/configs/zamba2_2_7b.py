"""zamba2-2.7b [hybrid] 54L d=2560 32H (GQA kv=32) d_ff=10240 vocab=32000
ssm_state=64 — Mamba2 backbone + SHARED attention block (one set of attention
weights applied every hybrid_share_period layers)  [arXiv:2411.15242]"""
from ..models import AttnCfg, ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    d_ff=10240, vocab=32000,
    attn=AttnCfg(n_heads=32, n_kv_heads=32, head_dim=80),
    ssm=SSMCfg(d_state=64, headdim=64, expand=2, chunk=128),
    hybrid_share_period=6,   # 9 groups of 6 mamba layers + shared attn
    supports_long_context=True)

REDUCED = ModelConfig(
    name="zamba2-2.7b-reduced", family="hybrid", n_layers=4, d_model=64,
    d_ff=160, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv_heads=4, head_dim=16),
    ssm=SSMCfg(d_state=16, headdim=16, chunk=8),
    hybrid_share_period=2, supports_long_context=True, remat=False)
