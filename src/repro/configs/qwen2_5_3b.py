"""qwen2.5-3b [dense] 36L d=2048 16H (GQA kv=2) d_ff=11008 vocab=151936
GQA + QKV bias  [hf:Qwen/Qwen2.5-3B]"""
from ..models import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense", n_layers=36, d_model=2048,
    d_ff=11008, vocab=151936,
    attn=AttnCfg(n_heads=16, n_kv_heads=2, head_dim=128, qkv_bias=True,
                 rope_theta=1_000_000.0))

REDUCED = ModelConfig(
    name="qwen2.5-3b-reduced", family="dense", n_layers=2, d_model=64,
    d_ff=160, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv_heads=2, head_dim=16, qkv_bias=True),
    remat=False)
