"""moonshot-v1-16b-a3b [moe] 48L d=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64e top-6 (kimi/moonlight lineage: first layer dense,
2 shared experts = shared_ff 2816)  [hf:moonshotai/Moonlight-16B-A3B]"""
from ..models import AttnCfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    d_ff=1408, vocab=163840,
    attn=AttnCfg(n_heads=16, n_kv_heads=16, head_dim=128),
    moe=MoECfg(num_experts=64, top_k=6, d_ff_expert=1408, shared_ff=2816,
               first_dense=1))

REDUCED = ModelConfig(
    name="moonshot-reduced", family="moe", n_layers=3, d_model=64,
    d_ff=96, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv_heads=4, head_dim=16),
    moe=MoECfg(num_experts=8, top_k=2, d_ff_expert=48, shared_ff=96,
               first_dense=1), remat=False)
