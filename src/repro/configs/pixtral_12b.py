"""pixtral-12b [vlm] 40L d=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
pixtral-ViT + mistral-nemo decoder — vision frontend is a STUB
(input_specs provides precomputed patch embeddings, 1024 patches prepended)
[hf:mistralai/Pixtral-12B-2409]"""
from ..models import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
    d_ff=14336, vocab=131072,
    attn=AttnCfg(n_heads=32, n_kv_heads=8, head_dim=128),
    frontend="vision", frontend_seq=1024)

REDUCED = ModelConfig(
    name="pixtral-reduced", family="vlm", n_layers=2, d_model=64,
    d_ff=160, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv_heads=2, head_dim=16),
    frontend="vision", frontend_seq=8, remat=False)
