"""llama4-scout-17b-a16e [moe] 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16e top-1 + shared expert, MoE every other layer
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from ..models import AttnCfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe", n_layers=48, d_model=5120,
    d_ff=8192, vocab=202048,
    attn=AttnCfg(n_heads=40, n_kv_heads=8, head_dim=128),
    moe=MoECfg(num_experts=16, top_k=1, d_ff_expert=8192, shared_ff=8192,
               every_k_layers=2))

REDUCED = ModelConfig(
    name="llama4-scout-reduced", family="moe", n_layers=4, d_model=64,
    d_ff=128, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv_heads=2, head_dim=16),
    moe=MoECfg(num_experts=4, top_k=1, d_ff_expert=96, shared_ff=96,
               every_k_layers=2), remat=False)
