"""h2o-danube-3-4b [dense] 24L d=3840 32H (GQA kv=8) d_ff=10240 vocab=32000
llama+mistral mix with sliding-window attention  [arXiv:2401.16818]
SWA window 4096 => sub-quadratic long context (ring KV cache), so the
long_500k decode cell RUNS for this arch."""
from ..models import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense", n_layers=24, d_model=3840,
    d_ff=10240, vocab=32000,
    attn=AttnCfg(n_heads=32, n_kv_heads=8, head_dim=120, window=4096),
    supports_long_context=True)

REDUCED = ModelConfig(
    name="h2o-danube-3-4b-reduced", family="dense", n_layers=2, d_model=64,
    d_ff=160, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv_heads=2, head_dim=16, window=16),
    supports_long_context=True, remat=False)
