"""repro-100m — the end-to-end example model (deliverable b): a ~100M dense
LM trained for a few hundred steps from the columnar TokenStore."""
from ..models import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="repro-100m", family="dense", n_layers=12, d_model=768,
    d_ff=2048, vocab=32000,
    attn=AttnCfg(n_heads=12, n_kv_heads=4, head_dim=64), remat=False)

REDUCED = ModelConfig(
    name="repro-100m-reduced", family="dense", n_layers=2, d_model=64,
    d_ff=128, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv_heads=2, head_dim=16), remat=False)
