"""Assigned-architecture configs.  ``registry.get(name)`` / ``--arch <id>``."""
from .registry import ARCH_NAMES, SHAPES, cells_for, get, get_reduced

__all__ = ["ARCH_NAMES", "SHAPES", "cells_for", "get", "get_reduced"]
