"""phi4-mini-3.8b [dense] 32L d=3072 24H (GQA kv=8) d_ff=8192 vocab=200064
RoPE SwiGLU GQA  [arXiv:2412.08905]"""
from ..models import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="dense", n_layers=32, d_model=3072,
    d_ff=8192, vocab=200064,
    attn=AttnCfg(n_heads=24, n_kv_heads=8, head_dim=128))

REDUCED = ModelConfig(
    name="phi4-mini-3.8b-reduced", family="dense", n_layers=2, d_model=48,
    d_ff=128, vocab=512,
    attn=AttnCfg(n_heads=3, n_kv_heads=1, head_dim=16), remat=False)
