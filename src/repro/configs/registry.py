"""Architecture registry + assigned input shapes (the 40 dry-run cells)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional

from ..models.config import ModelConfig

_MODULES = {
    "qwen2.5-3b": "qwen2_5_3b",
    "qwen3-32b": "qwen3_32b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "mamba2-780m": "mamba2_780m",
    "zamba2-2.7b": "zamba2_2_7b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "pixtral-12b": "pixtral_12b",
    "repro-100m": "repro_100m",
}
ARCH_NAMES = [n for n in _MODULES if n != "repro-100m"]


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    return importlib.import_module(f".{_MODULES[name]}", package=__package__)


def get(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_reduced(name: str) -> ModelConfig:
    return _module(name).REDUCED


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}


def cells_for(arch: str) -> List[Shape]:
    """Applicable shapes per the assignment's skip rules."""
    cfg = get(arch)
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"]]
    if cfg.supports_decode:
        out.append(SHAPES["decode_32k"])
        if cfg.supports_long_context:
            out.append(SHAPES["long_500k"])
    return out


def all_cells() -> List[tuple]:
    return [(a, s.name) for a in ARCH_NAMES for s in cells_for(a)]
