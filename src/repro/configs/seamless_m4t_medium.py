"""seamless-m4t-medium [audio] 12L d=1024 16H (kv=16) d_ff=4096 vocab=256206
Encoder-decoder, multimodal — audio frontend is a STUB (input_specs provides
precomputed frame embeddings, src_seq=1024 frames)  [arXiv:2308.11596]
Full attention enc-dec => long_500k SKIPPED (see DESIGN.md)."""
from ..models import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec", n_layers=12, d_model=1024,
    d_ff=4096, vocab=256206,
    attn=AttnCfg(n_heads=16, n_kv_heads=16, head_dim=64),
    enc_layers=12, src_seq=1024, frontend="audio")

REDUCED = ModelConfig(
    name="seamless-reduced", family="encdec", n_layers=2, d_model=64,
    d_ff=128, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv_heads=4, head_dim=16),
    enc_layers=2, src_seq=16, frontend="audio", remat=False)
