"""qwen3-32b [dense] 64L d=5120 64H (GQA kv=8) d_ff=25600 vocab=151936
qk_norm + GQA  [hf:Qwen/Qwen3-32B]"""
from ..models import AttnCfg, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense", n_layers=64, d_model=5120,
    d_ff=25600, vocab=151936,
    attn=AttnCfg(n_heads=64, n_kv_heads=8, head_dim=128, qk_norm=True,
                 rope_theta=1_000_000.0))

REDUCED = ModelConfig(
    name="qwen3-32b-reduced", family="dense", n_layers=2, d_model=64,
    d_ff=192, vocab=512,
    attn=AttnCfg(n_heads=4, n_kv_heads=2, head_dim=16, qk_norm=True),
    remat=False)
