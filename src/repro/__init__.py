"""repro — ParquetDB-on-TPU: columnar data substrate + multi-pod JAX framework."""

__version__ = "0.1.0"
