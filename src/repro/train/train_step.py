"""Jitted train/serve step builders with explicit in/out shardings.

``build_train_step`` returns a pjit'd function
    (params, opt_state, batch) -> (params, opt_state, metrics)
with:
  * microbatch gradient accumulation (a lax.scan over the batch's leading
    split — activation memory scales with the microbatch, not the batch),
  * optional bf16 gradient "compression": the model is differentiated w.r.t.
    a bf16 parameter cast, so the gradient all-reduce XLA inserts moves half
    the bytes across the (slow) cross-pod links,
  * donated params/opt_state buffers.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from ..distributed import sharding as shd
from ..models.model import Model
from . import optimizer as opt


def _split_microbatches(batch: Dict[str, jnp.ndarray], n: int):
    def split(x):
        return x.reshape(n, x.shape[0] // n, *x.shape[1:])
    return {k: split(v) for k, v in batch.items()}


def make_loss_and_grad(model: Model, mesh, microbatches: int,
                       grad_dtype: str = "float32"):
    cast = jnp.bfloat16 if grad_dtype == "bfloat16" else None

    def loss_fn(p, mb):
        loss, metrics = model.loss(p, mb, mesh=mesh)
        return loss, metrics

    def loss_and_grad(params, batch):
        diff_params = (jax.tree.map(lambda x: x.astype(cast), params)
                       if cast else params)
        if microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(diff_params, batch)
        else:
            mbs = _split_microbatches(batch, microbatches)
            g0 = jax.tree.map(jnp.zeros_like, diff_params)

            def body(carry, mb):
                acc, lsum = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    diff_params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, lsum + l), m

            (grads, lsum), metrics = jax.lax.scan(
                body, (g0, jnp.float32(0.0)), mbs)
            inv = 1.0 / microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = lsum * inv
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
        if cast:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return loss, grads, metrics

    return loss_and_grad


def build_train_step(model: Model, mesh, opt_cfg: opt.OptConfig,
                     *, microbatches: int = 1, donate: bool = True):
    """Returns (step_fn, shardings) — step_fn is jitted with shardings."""
    axes = model.params_axes()
    abstract = model.init_abstract()
    p_shard = shd.tree_shardings(abstract, axes, mesh)
    o_shard = {"m": p_shard, "v": p_shard,
               "step": NamedSharding(mesh, PS())}
    loss_and_grad = make_loss_and_grad(model, mesh, microbatches,
                                       opt_cfg.grad_dtype)

    def step(params, opt_state, batch):
        loss, grads, metrics = loss_and_grad(params, batch)
        params, opt_state, stats = opt.apply_updates(params, grads, opt_state,
                                                     opt_cfg)
        metrics = {"loss": loss, **metrics, **stats}
        return params, opt_state, metrics

    def batch_shardings(batch_specs):
        return shd.batch_shardings(mesh, batch_specs)

    def jit_step(batch_specs):
        b_shard = batch_shardings(batch_specs)
        m_shard = NamedSharding(mesh, PS())
        return jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard,
                           jax.tree.map(lambda _: m_shard,
                                        {"loss": 0, "ce": 0, "aux": 0,
                                         "grad_norm": 0, "lr": 0})),
            donate_argnums=(0, 1) if donate else (),
        )

    return step, jit_step, {"params": p_shard, "opt": o_shard}


def build_serve_step(model: Model, mesh):
    """Returns jit-able decode step with cache shardings."""
    axes = model.params_axes()
    abstract = model.init_abstract()
    p_shard = shd.tree_shardings(abstract, axes, mesh)

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, mesh=mesh)

    def jit_serve(batch: int, max_seq: int):
        cache_abs = model.cache_abstract(batch, max_seq)
        c_shard = shd.tree_shardings(cache_abs, model.cache_axes(), mesh)
        t_shard = NamedSharding(mesh, shd.batch_spec(mesh, 2, batch_dim=batch))
        pos_shard = NamedSharding(mesh, PS())
        out_logits = NamedSharding(mesh,
                                   shd.batch_spec(mesh, 3, batch_dim=batch))
        return jax.jit(
            serve_step,
            in_shardings=(p_shard, c_shard, t_shard, pos_shard),
            out_shardings=(out_logits, c_shard),
            donate_argnums=(1,),
        ), c_shard

    def jit_prefill(batch_specs, cache_len: int):
        b_shard = shd.batch_shardings(mesh, batch_specs)
        batch = next(iter(batch_specs.values())).shape[0]
        cache_abs = model.cache_abstract(batch, cache_len)
        c_shard = shd.tree_shardings(cache_abs, model.cache_axes(), mesh)
        out_logits = NamedSharding(mesh,
                                   shd.batch_spec(mesh, 3, batch_dim=batch))

        def prefill_fn(params, batch):
            return model.prefill(params, batch, mesh=mesh, cache_len=cache_len)

        return jax.jit(prefill_fn, in_shardings=(p_shard, b_shard),
                       out_shardings=(out_logits, c_shard))

    return jit_serve, jit_prefill, p_shard
