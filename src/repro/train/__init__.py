"""Training substrate: optimizer, pjit train step, checkpointing, trainer."""
