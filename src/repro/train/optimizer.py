"""AdamW with warmup+cosine schedule, global-norm clipping, sharded states.

Optimizer state mirrors the parameter tree (m, v get the parameters' logical
axes, so FSDP shards them identically — ZeRO style).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # beyond-paper distributed trick: cast grads to bf16 so the cross-pod
    # all-reduce moves half the bytes (set via train config)
    grad_dtype: str = "float32"


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_axes(params_axes: Any) -> Dict[str, Any]:
    return {"m": params_axes, "v": params_axes, "step": ()}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(params: Any, grads: Any, state: Dict[str, Any],
                  cfg: OptConfig) -> Tuple[Any, Dict[str, Any], Dict[str, Any]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = {"m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
