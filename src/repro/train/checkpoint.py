"""Checkpoint-as-database: training state stored in the paper's columnar store.

Every checkpoint step is one ParquetDB dataset whose rows are parameter
leaves: {path, shape, dtype, part, data(bytes)}.  This buys exactly what the
paper claims for data (DESIGN.md §7.4):

* projection/predicate pushdown → *partial restores*: a single tensor (or the
  optimizer state alone) can be read without touching the rest of the bytes;
* schema evolution → adding/removing parameters (e.g. changing MoE expert
  count) appends/deletes rows, never rewrites the remainder;
* elastic resharding → restore takes target NamedShardings; arrays are read
  once on host and device_put to ANY mesh, so a 512-chip checkpoint restores
  onto 256 chips (or 8 CPU devices) unchanged.

Large tensors are chunked into CHUNK_BYTES rows ("part" column) so row-group
statistics stay useful and restores stream.  Saves are atomic via the store's
manifest commit; ``async_save`` snapshots to host then writes on a thread.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..core import ParquetDB, field
from ..core.store import NormalizeConfig

CHUNK_BYTES = 64 << 20


def _flatten(tree: Any) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[name] = leaf
    return flat


class CheckpointStore:
    def __init__(self, root: str, *, keep: int = 3, codec: str = "none"):
        self.root = root
        self.keep = keep
        self.codec = codec
        os.makedirs(root, exist_ok=True)

    # -- paths -------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.root, d, "_manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save --------------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None) -> None:
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}
        self._write(step, host, metadata or {})

    def async_save(self, step: int, tree: Any,
                   metadata: Optional[dict] = None) -> threading.Thread:
        """Snapshot to host synchronously; serialize+write on a thread."""
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}  # device->host copy

        th = threading.Thread(target=self._write,
                              args=(step, host, metadata or {}), daemon=True)
        th.start()
        return th

    def _write(self, step: int, host: Dict[str, np.ndarray],
               metadata: dict) -> None:
        # one leaf-part per page: partial restores (predicate pushdown on
        # `path`) read exactly the bytes of the requested tensors
        db = ParquetDB(self._step_dir(step), f"ckpt_{step}",
                       codec=self.codec, with_bloom=False,
                       page_rows=1, row_group_rows=256)
        rows = []
        for name, arr in sorted(host.items()):
            raw = np.ascontiguousarray(arr)
            buf = raw.tobytes()
            nparts = max(-(-len(buf) // CHUNK_BYTES), 1)
            for part in range(nparts):
                rows.append({
                    "path": name,
                    "shape": json.dumps(list(arr.shape)),
                    "dtype": str(arr.dtype),
                    "part": part,
                    "nparts": nparts,
                    "data": buf[part * CHUNK_BYTES:(part + 1) * CHUNK_BYTES],
                })
        db.create(rows, metadata={"step": step, **metadata})
        self.gc()

    # -- restore -----------------------------------------------------------------
    def restore(self, step: Optional[int] = None, *, like: Any = None,
                shardings: Any = None, paths: Optional[List[str]] = None
                ) -> Any:
        """Restore a (possibly partial) tree.

        like       a tree with the target structure (required to unflatten)
        shardings  matching tree of NamedShardings (elastic resharding)
        paths      restrict to these leaf paths (projection pushdown)
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        db = ParquetDB(self._step_dir(step), f"ckpt_{step}")
        filters = [field("path").isin(paths)] if paths else None
        t = db.read(columns=["path", "shape", "dtype", "part", "data"],
                    filters=filters)
        rows = t.to_pydict()
        by_path: Dict[str, list] = {}
        for i, name in enumerate(rows["path"]):
            by_path.setdefault(name, []).append(i)
        arrays: Dict[str, np.ndarray] = {}
        for name, idxs in by_path.items():
            idxs.sort(key=lambda i: rows["part"][i])
            buf = b"".join(rows["data"][i] for i in idxs)
            shape = tuple(json.loads(rows["shape"][idxs[0]]))
            arrays[name] = np.frombuffer(
                buf, dtype=rows["dtype"][idxs[0]]).reshape(shape)
        if like is None:
            return arrays
        flat_like = _flatten(like)
        leaves, treedef = jax.tree.flatten(like)
        names = list(_flatten(like).keys())
        shard_flat = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(leaves))
        out = []
        for name, leaf, sh in zip(names, leaves, shard_flat):
            if name in arrays:
                arr = arrays[name]
                if sh is not None:
                    out.append(jax.device_put(arr, sh))
                else:
                    out.append(jax.numpy.asarray(arr))
            else:
                out.append(leaf)   # schema evolution: new leaf keeps init value
        return jax.tree.unflatten(treedef, out)

    def read_metadata(self, step: int) -> dict:
        db = ParquetDB(self._step_dir(step), f"ckpt_{step}")
        return {k: v for k, v in db.schema.metadata.items()}

    # -- gc ----------------------------------------------------------------------
    def gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            import shutil
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
