"""Fault-tolerant training loop.

Production behaviours implemented (and exercised by tests at toy scale):
  * checkpoint/restart — periodic async checkpoints into the columnar
    CheckpointStore; on any step failure the trainer restores the last
    committed checkpoint and replays (data loader is seeded+stateless, so
    replay is deterministic);
  * bounded retries per step, then re-raise (a real launcher would reschedule
    the job / evict the bad host);
  * metrics stream into a ParquetDB dataset (the experiment store — queryable
    with the same pushdown machinery as everything else).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from ..core import ParquetDB
from . import optimizer as opt
from .checkpoint import CheckpointStore
from .train_step import build_train_step

# test hook: raised exceptions simulate preemption/node failure
FAULT_HOOK: Optional[Callable[[int], None]] = None


class Trainer:
    def __init__(self, model, mesh, opt_cfg: opt.OptConfig, *,
                 ckpt_dir: str, metrics_dir: Optional[str] = None,
                 microbatches: int = 1, ckpt_every: int = 50,
                 max_retries: int = 2):
        self.model, self.mesh, self.opt_cfg = model, mesh, opt_cfg
        self.store = CheckpointStore(ckpt_dir)
        self.metrics_db = (ParquetDB(metrics_dir, "metrics")
                           if metrics_dir else None)
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        _, self._jit_step, self.shardings = build_train_step(
            model, mesh, opt_cfg, microbatches=microbatches)
        self._fns: Dict[Any, Any] = {}
        self._pending_save = None

    # -- state -------------------------------------------------------------------
    def init_state(self, rng):
        params = jax.device_put(self.model.init(rng), self.shardings["params"])
        state = jax.device_put(opt.init_opt_state(params), self.shardings["opt"])
        return params, state

    def restore_or_init(self, rng):
        step = self.store.latest_step()
        params, state = self.init_state(rng)
        if step is None:
            return params, state, 0
        tree = self.store.restore(
            step, like={"params": params, "opt": state},
            shardings={"params": self.shardings["params"],
                       "opt": self.shardings["opt"]})
        return tree["params"], tree["opt"], int(step)

    def _step_fn(self, batch: Dict[str, Any]):
        key = tuple((k, v.shape, str(v.dtype)) for k, v in sorted(batch.items()))
        if key not in self._fns:
            specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                     for k, v in batch.items()}
            self._fns[key] = self._jit_step(specs)
        return self._fns[key]

    # -- loop --------------------------------------------------------------------
    def run(self, batches: Iterator[Dict[str, np.ndarray]], steps: int,
            rng=None, log_every: int = 10) -> Dict[str, float]:
        rng = rng if rng is not None else jax.random.key(0)
        params, state, start = self.restore_or_init(rng)
        history = []
        it = iter(batches)
        step = start
        retries = 0
        while step < steps:
            batch = next(it)
            try:
                if FAULT_HOOK is not None:
                    FAULT_HOOK(step)
                t0 = time.perf_counter()
                fn = self._step_fn(batch)
                params, state, metrics = fn(params, state, batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}")
                dt = time.perf_counter() - t0
            except (FloatingPointError, RuntimeError, ValueError) as e:
                retries += 1
                if retries > self.max_retries:
                    raise
                # node-failure recovery path: reload last good state, replay
                params, state, step = self.restore_or_init(rng)
                continue
            retries = 0
            step += 1
            history.append(loss)
            if self.metrics_db is not None and step % log_every == 0:
                self.metrics_db.create([{
                    "step": step, "loss": loss,
                    "grad_norm": float(metrics["grad_norm"]),
                    "lr": float(metrics["lr"]),
                    "step_time_s": dt,
                }])
            if step % self.ckpt_every == 0 or step == steps:
                self._checkpoint(step, params, state)
        if self._pending_save is not None:
            self._pending_save.join()
        return {"final_loss": history[-1] if history else float("nan"),
                "steps": step, "history": history}

    def _checkpoint(self, step, params, state):
        if self._pending_save is not None:
            self._pending_save.join()   # one in flight at a time
        tree = {"params": params, "opt": state}
        self._pending_save = self.store.async_save(step, tree)

    # convenience for tests
    def save_now(self, step, params, state):
        self.store.save(step, {"params": params, "opt": state})


def restore_elastic(store: CheckpointStore, model, mesh, opt_cfg=None,
                    step: Optional[int] = None):
    """Elastic restart: restore a checkpoint onto a DIFFERENT mesh.

    The columnar store is mesh-agnostic (full arrays, row-per-leaf), so this
    is just: rebuild shardings for the new mesh, device_put each leaf.
    """
    from ..distributed import sharding as shd
    abstract = model.init_abstract()
    p_shard = shd.tree_shardings(abstract, model.params_axes(), mesh)
    params_like = jax.tree.map(
        lambda s: jax.numpy.zeros(s.shape, s.dtype), abstract)
    tree = store.restore(step, like={"params": params_like},
                         shardings={"params": p_shard})
    return tree["params"], p_shard
