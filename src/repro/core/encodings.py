"""Field-level encodings + compression for the TPQ columnar format.

Implements the encodings the paper names for Parquet (§4.1): PLAIN, DICTIONARY,
RLE, BITPACK (bit-packing with frame-of-reference), DELTA (zigzag'd deltas,
bit-packed) and BYTE_STREAM_SPLIT, plus an AUTO selector driven by a small cost
model over the page's actual values.  Compression (``none``/``zlib``/``lzma``)
applies after encoding, per column chunk, exactly as Parquet layers codec over
encoding.

All encoders work on 1-D little-endian numpy arrays and return
``(meta: dict, payload: bytes)``; decoders invert from ``(meta, payload, n,
dtype)``.  These numpy paths are the *reference* implementations — the Pallas
kernels in :mod:`repro.kernels` implement the decode hot paths for TPU and are
validated against these.
"""
from __future__ import annotations

import lzma
import zlib
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

PLAIN = "plain"
DICT = "dict"
RLE = "rle"
BITPACK = "bitpack"
DELTA = "delta"
BSS = "bss"
AUTO = "auto"

CODEC_NONE = "none"
CODEC_ZLIB = "zlib"
CODEC_LZMA = "lzma"


# ---------------------------------------------------------------------------
# bit packing primitives (LSB-first within a little-endian bit stream)
# ---------------------------------------------------------------------------
def bit_width(max_value: int) -> int:
    return int(max_value).bit_length()


def pack_bits(vals: np.ndarray, k: int) -> bytes:
    """Pack non-negative ints (< 2**k) into a dense k-bit little-endian
    stream.  Vectorized via uint64 word scatter (bitwise_or.at is unbuffered,
    so overlapping word indices accumulate correctly)."""
    if k == 0 or len(vals) == 0:
        return b""
    if k > 57:  # value may straddle 3 words; fall back to the simple path
        v = vals.astype(np.uint64, copy=False)
        shifts = np.arange(k, dtype=np.uint64)
        bits = ((v[:, None] >> shifts) & np.uint64(1)).astype(np.uint8)
        return np.packbits(bits.reshape(-1), bitorder="little").tobytes()
    n = len(vals)
    total_bits = n * k
    nwords = (total_bits + 63) // 64 + 1
    w = np.zeros(nwords, np.uint64)
    bit = np.arange(n, dtype=np.uint64) * np.uint64(k)
    w0 = (bit >> np.uint64(6)).astype(np.int64)
    sh = bit & np.uint64(63)
    mask = np.uint64((1 << k) - 1) if k < 64 else np.uint64(0xFFFFFFFFFFFFFFFF)
    v = vals.astype(np.uint64, copy=False) & mask
    np.bitwise_or.at(w, w0, v << sh)
    spill = (sh.astype(np.int64) + k) > 64
    if spill.any():
        np.bitwise_or.at(w, w0[spill] + 1,
                         v[spill] >> (np.uint64(64) - sh[spill]))
    return w.tobytes()[: (total_bits + 7) // 8]


def unpack_bits(buf: bytes, n: int, k: int) -> np.ndarray:
    """Inverse of :func:`pack_bits` -> uint64 array of length n.
    Vectorized word-gather: each value is read from a 64-bit window."""
    if k == 0 or n == 0:
        return np.zeros(n, np.uint64)
    if k > 57:
        bits = np.unpackbits(np.frombuffer(buf, np.uint8), count=n * k,
                             bitorder="little").reshape(n, k).astype(np.uint64)
        shifts = np.arange(k, dtype=np.uint64)
        return (bits << shifts).sum(axis=1, dtype=np.uint64)
    need = (n * k + 7) // 8
    padded = memoryview(buf)[:need].tobytes() + b"\x00" * 16
    nwords = (len(padded)) // 8
    w = np.frombuffer(padded[:nwords * 8], "<u8")
    bit = np.arange(n, dtype=np.uint64) * np.uint64(k)
    w0 = (bit >> np.uint64(6)).astype(np.int64)
    sh = bit & np.uint64(63)
    lo = w[w0] >> sh
    shift_hi = (np.uint64(64) - sh) & np.uint64(63)   # avoid UB shift-by-64
    hi = np.where(sh == 0, np.uint64(0), w[w0 + 1] << shift_hi)
    mask = np.uint64((1 << k) - 1) if k < 64 else np.uint64(0xFFFFFFFFFFFFFFFF)
    return (lo | hi) & mask


def zigzag(v: np.ndarray) -> np.ndarray:
    s = v.astype(np.int64, copy=False)
    return ((s >> np.int64(63)) ^ (s << np.int64(1))).astype(np.uint64)


def unzigzag(u: np.ndarray) -> np.ndarray:
    u = u.astype(np.uint64, copy=False)
    return ((u >> np.uint64(1)) ^ -(u & np.uint64(1)).astype(np.int64).astype(np.uint64)).astype(np.int64)


def _le(arr: np.ndarray) -> np.ndarray:
    dt = arr.dtype.newbyteorder("<")
    return arr.astype(dt, copy=False)


# ---------------------------------------------------------------------------
# encoders  (meta, payload)
# ---------------------------------------------------------------------------
def _enc_plain(arr: np.ndarray) -> Tuple[dict, np.ndarray]:
    # uint8 view, not .tobytes(): the writer consumes the buffer protocol,
    # so plain pages go encoder -> compressor/file with zero copies
    return {}, np.ascontiguousarray(_le(arr)).view(np.uint8)


def _dec_plain(meta, payload, n, dtype) -> np.ndarray:
    # copy=False: on little-endian hosts this is a zero-copy (read-only)
    # view straight into the reader's file mapping
    return np.frombuffer(payload, np.dtype(dtype).newbyteorder("<"),
                         count=n).astype(dtype, copy=False)


def _enc_dict(arr: np.ndarray) -> Tuple[dict, bytes]:
    uniq, inv = np.unique(arr, return_inverse=True)
    k = max(bit_width(len(uniq) - 1), 1) if len(uniq) > 1 else 0
    dict_bytes = _le(uniq).tobytes()
    idx_bytes = pack_bits(inv.astype(np.uint64), k)
    meta = {"dict_n": int(len(uniq)), "bits": k, "dict_len": len(dict_bytes)}
    return meta, dict_bytes + idx_bytes


def _dec_dict(meta, payload, n, dtype) -> np.ndarray:
    dl = meta["dict_len"]
    uniq = np.frombuffer(payload[:dl], np.dtype(dtype).newbyteorder("<")).astype(dtype)
    idx = unpack_bits(payload[dl:], n, meta["bits"]).astype(np.int64)
    return uniq[idx]


def _enc_rle(arr: np.ndarray) -> Tuple[dict, bytes]:
    if len(arr) == 0:
        return {"runs": 0, "len_bits": 0, "vals_len": 0}, b""
    change = np.empty(len(arr), bool)
    change[0] = True
    np.not_equal(arr[1:], arr[:-1], out=change[1:])
    starts = np.nonzero(change)[0]
    run_vals = arr[starts]
    run_lens = np.diff(np.append(starts, len(arr))).astype(np.uint64)
    k = max(bit_width(int(run_lens.max())), 1)
    vals_bytes = _le(run_vals).tobytes()
    meta = {"runs": int(len(starts)), "len_bits": k, "vals_len": len(vals_bytes)}
    return meta, vals_bytes + pack_bits(run_lens, k)


def _dec_rle(meta, payload, n, dtype) -> np.ndarray:
    r, vl = meta["runs"], meta["vals_len"]
    if r == 0:
        return np.empty(0, dtype)
    vals = np.frombuffer(payload[:vl], np.dtype(dtype).newbyteorder("<")).astype(dtype)
    lens = unpack_bits(payload[vl:], r, meta["len_bits"]).astype(np.int64)
    return np.repeat(vals, lens)


def _enc_bitpack(arr: np.ndarray) -> Tuple[dict, bytes]:
    if arr.dtype == np.bool_:
        return ({"ref": 0, "bits": 1},
                pack_bits(arr.astype(np.uint64), 1))
    lo = int(arr.min()) if len(arr) else 0
    hi = int(arr.max()) if len(arr) else 0
    k = bit_width(hi - lo)
    shifted = (arr.astype(np.int64) - lo).astype(np.uint64)
    return {"ref": lo, "bits": k}, pack_bits(shifted, k)


def _dec_bitpack(meta, payload, n, dtype) -> np.ndarray:
    u = unpack_bits(payload, n, meta["bits"])
    if np.dtype(dtype) == np.bool_:
        return u.astype(np.bool_)
    return (u.astype(np.int64) + meta["ref"]).astype(dtype)


def _enc_delta(arr: np.ndarray) -> Tuple[dict, bytes]:
    v = arr.astype(np.int64)
    first = int(v[0]) if len(v) else 0
    deltas = np.diff(v)
    zz = zigzag(deltas)
    k = bit_width(int(zz.max())) if len(zz) and zz.max() > 0 else 0
    return {"first": first, "bits": k}, pack_bits(zz, k)


def _dec_delta(meta, payload, n, dtype) -> np.ndarray:
    if n == 0:
        return np.empty(0, dtype)
    zz = unpack_bits(payload, n - 1, meta["bits"])
    deltas = unzigzag(zz)
    out = np.empty(n, np.int64)
    out[0] = meta["first"]
    np.cumsum(deltas, out=out[1:])
    out[1:] += meta["first"]
    return out.astype(dtype)


def _enc_bss(arr: np.ndarray) -> Tuple[dict, np.ndarray]:
    b = np.ascontiguousarray(_le(arr)).view(np.uint8).reshape(
        len(arr), arr.dtype.itemsize)
    return {}, np.ascontiguousarray(b.T).reshape(-1)


def _dec_bss(meta, payload, n, dtype) -> np.ndarray:
    dt = np.dtype(dtype)
    b = np.frombuffer(payload, np.uint8).reshape(dt.itemsize, n)
    return np.ascontiguousarray(b.T).reshape(-1).view(dt.newbyteorder("<")).astype(dtype)


_ENCODERS = {PLAIN: _enc_plain, DICT: _enc_dict, RLE: _enc_rle,
             BITPACK: _enc_bitpack, DELTA: _enc_delta, BSS: _enc_bss}
_DECODERS = {PLAIN: _dec_plain, DICT: _dec_dict, RLE: _dec_rle,
             BITPACK: _dec_bitpack, DELTA: _dec_delta, BSS: _dec_bss}


# ---------------------------------------------------------------------------
# AUTO selector — a small cost model over actual page values
# ---------------------------------------------------------------------------
_SAMPLE = 4096


def choose_encoding(arr: np.ndarray) -> str:
    n = len(arr)
    if n == 0:
        return PLAIN
    if arr.dtype == np.bool_:
        return BITPACK
    if arr.dtype.kind == "f":
        return BSS
    if arr.dtype.kind not in "iu":
        return PLAIN
    itemsize = arr.dtype.itemsize
    sample = arr if n <= _SAMPLE else arr[:: max(n // _SAMPLE, 1)]
    lo, hi = int(sample.min()), int(sample.max())
    nuniq = len(np.unique(sample))
    est: Dict[str, float] = {PLAIN: n * itemsize}
    if hi - lo >= 0:
        est[BITPACK] = n * bit_width(hi - lo) / 8 + 16
    if nuniq <= max(64, len(sample) // 8):
        kd = max(bit_width(nuniq - 1), 1)
        # scale unique count conservatively when sampling
        scale = 2 if n > _SAMPLE else 1
        est[DICT] = nuniq * scale * itemsize + n * kd / 8 + 16
    if n > 1:
        d = np.diff(sample.astype(np.int64))
        if len(d):
            zmax = int(zigzag(d).max())
            est[DELTA] = n * (bit_width(zmax) if zmax else 0) / 8 + 16
        runs = int((d != 0).sum()) + 1
        if runs <= len(sample) // 4:
            est[RLE] = (runs / len(sample)) * n * (itemsize + 4) + 16
    return min(est, key=est.get)


def encode(arr: np.ndarray, encoding: str = AUTO) -> Tuple[str, dict, bytes]:
    if encoding == AUTO:
        encoding = choose_encoding(arr)
    if encoding == DELTA and len(arr) == 0:
        encoding = PLAIN
    meta, payload = _ENCODERS[encoding](arr)
    return encoding, meta, payload


def decode(encoding: str, meta: dict, payload: bytes, n: int, dtype,
           out: Optional[np.ndarray] = None) -> np.ndarray:
    """Decode a page payload; ``out`` (length-n, matching dtype) lets the
    reader decode page-by-page into one preallocated chunk array instead of
    concatenating per-page temporaries."""
    if out is not None and encoding == BITPACK and meta["bits"] < 63 \
            and np.dtype(dtype).kind in "iu" and out.dtype == np.int64:
        u = unpack_bits(payload, n, meta["bits"])
        np.add(u.view(np.int64), meta["ref"], out=out, casting="unsafe")
        return out
    res = _DECODERS[encoding](meta, payload, n, dtype)
    if out is not None:
        out[:] = res
        return out
    return res


# ---------------------------------------------------------------------------
# fused multi-page (morsel) decode — the parallel-scan hot path
# ---------------------------------------------------------------------------
# Bit widths above this use pack/unpack's np.unpackbits slow path; segmented
# decode keeps the same cutoff so batched and per-page results share one code
# path for the wide tail.
SEG_MAX_BITS = 57


def _seg_concat_words(payloads, needs) -> Tuple[np.ndarray, np.ndarray]:
    """Concatenate page payloads 8-byte-aligned; -> (uint64 words, base bits).

    Each page's packed stream is copied to a 64-bit-aligned base so one flat
    word array serves every page: value *i* of page *p* lives at bit
    ``base_bits[p] + i * k[p]``, exactly as if the page were unpacked alone.
    Two guard words of zero padding keep the ``w[w0 + 1]`` high-word gather
    in bounds for the last value.
    """
    bases = np.zeros(len(payloads), np.int64)
    off = 0
    for p, nb in enumerate(needs):
        bases[p] = off
        off += (nb + 7) // 8 * 8
    buf = np.zeros(off + 16, np.uint8)
    for base, pl, nb in zip(bases, payloads, needs):
        if nb:
            buf[base:base + nb] = np.frombuffer(pl, np.uint8, count=nb)
    return buf.view("<u8"), (bases * 8).astype(np.uint64)


def _seg_unpack(payloads, ns: np.ndarray, ks: np.ndarray) -> np.ndarray:
    """Segmented :func:`unpack_bits`: all pages in ONE vectorized pass.

    ``payloads[p]`` holds ``ns[p]`` values packed at ``ks[p]`` bits (every
    ``ks[p] <= SEG_MAX_BITS``).  Returns the uint64 value stream of all
    pages concatenated — bit-identical to per-page ``unpack_bits``, but the
    word gather / shift / mask run once over the whole morsel instead of
    once per page.
    """
    total = int(ns.sum())
    if total == 0:
        return np.zeros(0, np.uint64)
    needs = [(int(n) * int(k) + 7) // 8 for n, k in zip(ns, ks)]
    w, base_bits = _seg_concat_words(payloads, needs)
    pid = np.repeat(np.arange(len(ns)), ns)
    starts = np.zeros(len(ns), np.int64)
    np.cumsum(ns[:-1], out=starts[1:])
    idx = (np.arange(total, dtype=np.uint64)
           - np.repeat(starts, ns).astype(np.uint64))
    ks64 = ks.astype(np.uint64)
    bit = base_bits[pid] + idx * ks64[pid]
    w0 = (bit >> np.uint64(6)).astype(np.int64)
    sh = bit & np.uint64(63)
    lo = w[w0] >> sh
    shift_hi = (np.uint64(64) - sh) & np.uint64(63)  # avoid UB shift-by-64
    hi = np.where(sh == 0, np.uint64(0), w[w0 + 1] << shift_hi)
    masks = ((np.uint64(1) << ks64) - np.uint64(1))[pid]
    return (lo | hi) & masks


def _batch_groups(specs) -> Dict[str, list]:
    groups: Dict[str, list] = {}
    for i, (encoding, _, _, n) in enumerate(specs):
        if n:
            groups.setdefault(encoding, []).append(i)
    return groups


def _spec_slices(specs) -> np.ndarray:
    """Start offset of each page in the concatenated output."""
    starts = np.zeros(len(specs) + 1, np.int64)
    np.cumsum([n for _, _, _, n in specs], out=starts[1:])
    return starts


def decode_batch(specs: Sequence[Tuple[str, dict, Any, int]], dtype,
                 out: Optional[np.ndarray] = None) -> np.ndarray:
    """Fused decode of many pages of one column: one vectorized dispatch per
    encoding group instead of one Python-level decode per page.

    ``specs`` is a sequence of ``(encoding, meta, payload, n)`` — the same
    arguments per-page :func:`decode` takes, in output order; all pages
    share ``dtype``.  Returns the concatenated values (written into ``out``
    when given), **byte-identical** to decoding each page and concatenating
    (the property suite in ``tests/test_decode_batch.py`` proves this across
    encodings × dtypes × ragged page sizes).

    BITPACK / DICT / DELTA pages decode through :func:`_seg_unpack` — a
    single word-gather pass over the whole morsel — then one vectorized
    reference-add / dictionary-gather / segmented-cumsum.  PLAIN / RLE /
    BSS pages (and bit widths beyond ``SEG_MAX_BITS``) fall back to the
    per-page decoders, still written straight into their output slice.
    """
    dt = np.dtype(dtype)
    starts = _spec_slices(specs)
    total = int(starts[-1])
    if out is None:
        out = np.empty(total, dt)
    for encoding, idxs in _batch_groups(specs).items():
        fused = _SEG_DECODERS.get(encoding)
        seg = [i for i in idxs
               if _seg_bits(specs[i]) <= SEG_MAX_BITS] if fused else []
        if fused and len(seg) > 1:
            fused([specs[i] for i in seg],
                  [out[starts[i]:starts[i + 1]] for i in seg], dt)
            idxs = [i for i in idxs if i not in set(seg)]
        for i in idxs:  # per-page fallback, decoded into its slice
            e, meta, payload, n = specs[i]
            decode(e, meta, payload, n, dt, out=out[starts[i]:starts[i + 1]])
    return out


def _seg_bits(spec) -> int:
    return spec[1].get("bits", 0)


def _seg_dec_bitpack(specs, outs, dt) -> None:
    ns = np.array([n for _, _, _, n in specs], np.int64)
    ks = np.array([m["bits"] for _, m, _, _ in specs], np.int64)
    u = _seg_unpack([p for _, _, p, _ in specs], ns, ks)
    if dt == np.bool_:
        vals = u.astype(np.bool_)
    else:
        refs = np.repeat(np.array([m["ref"] for _, m, _, _ in specs],
                                  np.int64), ns)
        vals = (u.astype(np.int64) + refs).astype(dt)
    _seg_scatter(vals, ns, outs)


def _seg_dec_dict(specs, outs, dt) -> None:
    ns = np.array([n for _, _, _, n in specs], np.int64)
    ks = np.array([m["bits"] for _, m, _, _ in specs], np.int64)
    le = np.dtype(dt).newbyteorder("<")
    dicts = [np.frombuffer(p[:m["dict_len"]], le).astype(dt)
             for _, m, p, _ in specs]
    idx = _seg_unpack([memoryview(p)[m["dict_len"]:] for _, m, p, _ in specs],
                      ns, ks).astype(np.int64)
    doff = np.zeros(len(dicts), np.int64)
    np.cumsum([len(d) for d in dicts[:-1]], out=doff[1:])
    vals = np.concatenate(dicts)[idx + np.repeat(doff, ns)]
    _seg_scatter(vals, ns, outs)


def _seg_dec_delta(specs, outs, dt) -> None:
    # per page the encoder stores n-1 zigzag'd deltas; the batch decodes all
    # delta streams in one _seg_unpack, then recovers values with ONE global
    # cumsum: page-start slots carry 0, so `c[i] - c[start(p)] + first[p]`
    # is the page-local prefix sum.  int64 wrap (mod 2^64) commutes with the
    # subtraction, so even overflowing inputs match per-page decode exactly.
    ns = np.array([n for _, _, _, n in specs], np.int64)
    ks = np.array([m["bits"] for _, m, _, _ in specs], np.int64)
    deltas = unzigzag(_seg_unpack([p for _, _, p, _ in specs],
                                  ns - 1, ks))
    total = int(ns.sum())
    starts = np.zeros(len(ns), np.int64)
    np.cumsum(ns[:-1], out=starts[1:])
    d_full = np.zeros(total, np.int64)
    mask = np.ones(total, bool)
    mask[starts] = False
    d_full[mask] = deltas
    c = np.cumsum(d_full)
    firsts = np.array([m["first"] for _, m, _, _ in specs], np.int64)
    vals = (c - np.repeat(c[starts], ns)
            + np.repeat(firsts, ns)).astype(dt)
    _seg_scatter(vals, ns, outs)


def _seg_scatter(vals: np.ndarray, ns: np.ndarray, outs) -> None:
    pos = 0
    for n, o in zip(ns, outs):
        o[:] = vals[pos:pos + int(n)]
        pos += int(n)


_SEG_DECODERS = {BITPACK: _seg_dec_bitpack, DICT: _seg_dec_dict,
                 DELTA: _seg_dec_delta}


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------
def compress(payload: bytes, codec: str, level: int = 1) -> bytes:
    if codec == CODEC_NONE:
        return payload
    if codec == CODEC_ZLIB:
        return zlib.compress(payload, level)
    if codec == CODEC_LZMA:
        return lzma.compress(payload, preset=min(level, 6))
    raise ValueError(f"unknown codec {codec}")


def decompress(payload: bytes, codec: str) -> bytes:
    if codec == CODEC_NONE:
        return payload
    if codec == CODEC_ZLIB:
        return zlib.decompress(payload)
    if codec == CODEC_LZMA:
        return lzma.decompress(payload)
    raise ValueError(f"unknown codec {codec}")
