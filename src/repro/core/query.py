"""Lazy composable Query API: one plan-builder behind every read path.

The paper's interface grew four parallel entrypoints — ``read``,
``aggregate``, ``explain`` and the filter halves of ``update``/``delete`` —
that each re-spell columns/filters/threads and cannot be composed.  This
module unifies them behind a DuckDB-style *relational builder*:

    db.query()
      .where(field("age") >= 30)            # fused with later wheres
      .select("name", "age", bonus=field("salary") * 0.1)
      .order_by("age", desc=True)
      .limit(10)
      .to_table()

A :class:`Query` is **immutable** and **lazy**: every builder method
returns a new Query, nothing touches disk until a terminal
(``to_table`` / ``iter_batches`` / ``to_pylist`` / ``count`` / ``agg`` /
``explain``) runs.  Column names are validated at plan-build time against
the dataset schema — a typo raises a clear ``KeyError`` naming the column
and the schema instead of failing deep inside the scan.

Compilation pushes work down as far as statistics allow:

  - adjacent ``where`` calls fuse into one AND predicate, pushed into
    :class:`~repro.core.scan.ScanPlan` (file/row-group/page pruning);
  - the projection pushed to the scan is the union of selected columns,
    computed-expression inputs, group keys and aggregate columns — nothing
    else is decoded;
  - an ungrouped ``agg`` routes through the footer-statistics fast path in
    :class:`~repro.core.aggregate.AggregatePlan` (identical results and
    counters to the legacy ``db.aggregate``);
  - ``group_by(...).agg(...)`` runs a hash aggregation: numpy
    factorize-style grouping of each decoded batch into **partial** group
    states *inside the morsel workers* (``ScanPlan.execute(map_fn=...)``),
    merged single-threaded on the consumer — aggregation overlaps decode;
  - ``limit(n)`` / ``offset(n)`` on an un-ordered query terminate the scan
    early: once ``limit + offset`` rows survive the residual filter the
    result generator is closed, which stops submitting morsels — a needle
    query with ``limit(1)`` decodes a fraction of the full scan (visible
    in ``explain(execute=True)`` counters);
  - ``order_by`` with a ``limit`` keeps a running top-``limit+offset``
    accumulator per batch instead of materializing the full result.

The legacy surface (``ParquetDB.read/aggregate/explain``, ``Dataset``, and
the probe scans inside ``update``/``delete``) is a set of thin shims over
this module — one plan-construction code path, byte-identical results.

Semantics notes (SQL-flavored, matching :mod:`repro.core.aggregate`):
``count(col)`` counts non-null values, ``count(*)`` counts rows,
``min/max/sum/mean`` reduce over non-null non-NaN values and yield None
for empty groups.  Grouping treats null as one group and (float) NaN as
another; sorts are stable with nulls last (NaN sorts after all values).
Grouped integer sums accumulate in int64 (the footer fast path keeps
arbitrary precision).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, Generator, List, Optional, Sequence, Tuple

import numpy as np

from . import nested
from .aggregate import AggregatePlan, _normalize_spec
from .dtypes import DType, KIND_NULL, KIND_NUMERIC, KIND_STRING
from .expressions import (And, Arith, Comparison, Expr, FieldRef, IsIn,
                          IsNaN, IsNull, Not, Or)
from .scan import ScanCounters, ScanPlan, ScanReport, rechunk
from .schema import Field, ID_COLUMN, Schema
from .table import (Column, Table, concat_tables, infer_column,
                    null_column_of)

__all__ = ["Query", "GroupedQuery", "QueryReport", "canonical_expr"]

# Singleton NaN used as a grouping key: dict lookups on tuples hit the
# identity fast path, so every NaN row lands in ONE group even though
# nan != nan.
_NAN_KEY = float("nan")

_GROUPABLE_KINDS = (KIND_NUMERIC, KIND_STRING, KIND_NULL)


def _no_such_column(name: str, schema: Schema) -> KeyError:
    return KeyError(f"unknown column {name!r}; schema columns are "
                    f"{schema.names}")


def _resolve_names(schema: Schema, cols: Sequence[str]) -> List[str]:
    """Expand dotted parents against ``schema``; KeyError names the typo."""
    out: List[str] = []
    for c in cols:
        kids = nested.children_of(schema.names, c)
        if not kids:
            raise _no_such_column(c, schema)
        out.extend(kids)
    return out


# ---------------------------------------------------------------------------
# plan canonicalization: fused-expression fingerprints for plan caches
# ---------------------------------------------------------------------------
def _canon_value(v: Any) -> str:
    """Type-tagged scalar rendering so ``1`` and ``1.0`` and ``True`` key
    differently (they filter differently on mixed columns)."""
    if isinstance(v, FieldRef):
        return f"field({v.name})"
    if isinstance(v, (np.integer, np.floating, np.bool_)):
        v = v.item()
    return f"{type(v).__name__}:{v!r}"


def canonical_expr(e: Optional[Expr]) -> str:
    """Canonical text for a predicate tree, stable under the rewrites that
    don't change its meaning: AND/OR chains are flattened, their operands
    sorted and deduped (commutative + associative + idempotent), and
    ``isin`` value lists are sorted and deduped.  Two ``where`` chains that
    ask the same question — ``where(a).where(b)`` vs ``where(b).where(a)``
    — render identically, which is what lets a plan cache key on the fused
    expression instead of its construction order.  ``None`` (no filter)
    renders as the empty string."""
    if e is None:
        return ""
    if isinstance(e, (And, Or)):
        op = "and" if isinstance(e, And) else "or"
        parts: List[str] = []
        stack: List[Expr] = [e]
        while stack:
            node = stack.pop()
            if type(node) is type(e):
                stack.append(node.a)  # type: ignore[attr-defined]
                stack.append(node.b)  # type: ignore[attr-defined]
            else:
                parts.append(canonical_expr(node))
        parts = sorted(set(parts))
        if len(parts) == 1:  # a & a
            return parts[0]
        return f"{op}({','.join(parts)})"
    if isinstance(e, Not):
        return f"not({canonical_expr(e.a)})"
    if isinstance(e, Comparison):
        return f"cmp({e.name},{e.op},{_canon_value(e.value)})"
    if isinstance(e, IsIn):
        vals = sorted(set(_canon_value(v) for v in e.values))
        return f"isin({e.name},[{','.join(vals)}])"
    if isinstance(e, IsNull):
        return f"{'isvalid' if e._negated else 'isnull'}({e.name})"
    if isinstance(e, IsNaN):
        return f"isnan({e.name})"
    # unknown Expr subclass: fall back to repr — correct (never conflates
    # distinct plans) just not order-insensitive
    return repr(e)


def _canon_computed(ve: Any) -> str:
    """Structural rendering of a value expression (computed column)."""
    if isinstance(ve, FieldRef):
        return f"field({ve.name})"
    if isinstance(ve, Arith):
        return (f"arith({ve.op},{_canon_computed(ve.a)},"
                f"{_canon_computed(ve.b)})")
    return _canon_value(ve)


# ---------------------------------------------------------------------------
# hash grouping: numpy factorize + segmented reduction
# ---------------------------------------------------------------------------
def _factorize(col: Column) -> Tuple[np.ndarray, List[Any]]:
    """Per-column dictionary encoding: (codes[n], keys) with keys[codes[i]]
    the python key value of row i.  Null rows form one group, float-NaN
    rows another (keyed by the ``_NAN_KEY`` singleton)."""
    n = len(col)
    k = col.dtype.kind
    if k == KIND_NULL:
        return np.zeros(n, np.int64), [None]
    if k == KIND_NUMERIC:
        vals = col.values
        valid = np.ones(n, bool) if col.validity is None else col.validity
        nan = (np.isnan(vals) & valid if vals.dtype.kind == "f"
               else np.zeros(n, bool))
        ok = valid & ~nan
        u, inv = np.unique(vals[ok], return_inverse=True)
        codes = np.zeros(n, np.int64)
        codes[ok] = inv
        keys: List[Any] = [v.item() for v in u]
        if nan.any():
            codes[nan] = len(keys)
            keys.append(_NAN_KEY)
        if not valid.all():
            codes[~valid] = len(keys)
            keys.append(None)
        return codes, keys
    if k == KIND_STRING:
        pl = col.to_pylist()
        valid = np.array([v is not None for v in pl], bool)
        present = np.array([v for v in pl if v is not None], dtype=object)
        u, inv = np.unique(present, return_inverse=True)
        codes = np.zeros(n, np.int64)
        codes[valid] = inv
        keys = list(u)
        if not valid.all():
            codes[~valid] = len(keys)
            keys.append(None)
        return codes, keys
    raise TypeError(f"cannot group/dedupe on a {col.dtype} column")


def _row_codes(t: Table, key_cols: Sequence[str]
               ) -> Tuple[np.ndarray, List[tuple]]:
    """Row-wise group codes over ``key_cols``; keys are python tuples.

    No keys means one global group (the ungrouped-aggregate fallback).
    """
    if not key_cols:
        return np.zeros(t.num_rows, np.int64), [()]
    per = [_factorize(t.column(k)) for k in key_cols]
    if len(per) == 1:
        codes, keys = per[0]
        return codes, [(kv,) for kv in keys]
    # mixed-radix combine, re-compacted after every key so the running
    # value stays < (distinct rows so far) * (next cardinality) <= n^2 —
    # no int64 overflow however many near-unique keys are combined
    codes, keys0 = per[0]
    codes = codes.astype(np.int64, copy=True)
    keys_out: List[tuple] = [(kv,) for kv in keys0]
    for codes_i, keys_i in per[1:]:
        card = max(len(keys_i), 1)
        combined = codes * card + codes_i
        u, inv = np.unique(combined, return_inverse=True)
        keys_out = [keys_out[c // card] + (keys_i[c % card],)
                    for c in u.tolist()]
        codes = inv.astype(np.int64, copy=False)
    return codes, keys_out


class _GroupPartial:
    """Per-morsel partial aggregation state (built inside scan workers)."""
    __slots__ = ("keys", "rows", "cols")

    def __init__(self, keys: List[tuple], rows: np.ndarray,
                 cols: Dict[str, dict]):
        self.keys, self.rows, self.cols = keys, rows, cols


def _partial_groups(t: Table, key_cols: Sequence[str],
                    spec: Dict[str, List[str]]) -> _GroupPartial:
    """Factorize one batch and reduce every aggregate column per group.

    Vectorized: group codes from :func:`_row_codes`, then per column one
    stable sort + ``ufunc.reduceat`` segmented reduction (sum keeps the
    source dtype, so int64 sums do not round-trip through float).
    """
    codes, keys = _row_codes(t, key_cols)
    g = len(keys)
    rows = np.bincount(codes, minlength=g)
    cols: Dict[str, dict] = {}
    for col, ops in spec.items():
        if col == "*":
            continue
        c = t.column(col)
        entry: Dict[str, Any] = {}
        need_sum = "sum" in ops or "mean" in ops
        need_mm = "min" in ops or "max" in ops
        if c.dtype.kind == KIND_NUMERIC:
            vals = c.values
            if vals.dtype.kind == "b":
                vals = vals.astype(np.int64)
            valid = (np.ones(len(c), bool) if c.validity is None
                     else c.validity)
            nn = valid.copy()
            if vals.dtype.kind == "f":
                nn &= ~np.isnan(vals)
            entry["count"] = np.bincount(codes[valid], minlength=g)
            entry["vcount"] = np.bincount(codes[nn], minlength=g)
            if need_sum:
                entry["sum"] = np.zeros(g, vals.dtype)
            if need_mm:
                entry["min"] = np.zeros(g, vals.dtype)
                entry["max"] = np.zeros(g, vals.dtype)
            sel = np.nonzero(nn)[0]
            if len(sel) and (need_sum or need_mm):
                order = np.argsort(codes[sel], kind="stable")
                cc = codes[sel][order]
                xx = vals[sel][order]
                starts = np.nonzero(np.r_[True, cc[1:] != cc[:-1]])[0]
                gids = cc[starts]
                if need_sum:
                    entry["sum"][gids] = np.add.reduceat(xx, starts)
                if need_mm:
                    entry["min"][gids] = np.minimum.reduceat(xx, starts)
                    entry["max"][gids] = np.maximum.reduceat(xx, starts)
        elif c.dtype.kind == KIND_STRING:
            pl = c.to_pylist()
            valid = np.array([v is not None for v in pl], bool)
            entry["count"] = np.bincount(codes[valid], minlength=g)
            entry["vcount"] = entry["count"]
            if need_mm:
                amn = np.full(g, None, object)
                amx = np.full(g, None, object)
                sel = np.nonzero(valid)[0]
                if len(sel):
                    order = np.argsort(codes[sel], kind="stable")
                    cc = codes[sel][order]
                    ss = [pl[i] for i in sel[order]]
                    starts = np.nonzero(np.r_[True, cc[1:] != cc[:-1]])[0]
                    bounds = list(starts) + [len(ss)]
                    for j, s in enumerate(starts):
                        seg = ss[s:bounds[j + 1]]
                        amn[cc[s]] = min(seg)
                        amx[cc[s]] = max(seg)
                entry["min"], entry["max"] = amn, amx
        else:
            # null column (schema-evolved rows) or count over exotic types:
            # only validity-derived facts are defined
            valid = (np.zeros(len(c), bool) if c.dtype.kind == KIND_NULL
                     else np.ones(len(c), bool) if c.validity is None
                     else c.validity)
            entry["count"] = np.bincount(codes[valid], minlength=g)
            entry["vcount"] = entry["count"]
            if need_mm:
                entry["min"] = np.full(g, None, object)
                entry["max"] = np.full(g, None, object)
        cols[col] = entry
    return _GroupPartial(keys, rows, cols)


class _GroupedAcc:
    """Merged (global) group state; fed partials in plan order.

    The merge is the single-threaded half of the morsel-parallel
    aggregation: workers build :class:`_GroupPartial` objects, the
    consumer folds them here — no accumulator is ever shared across
    threads.
    """

    def __init__(self, spec: Dict[str, List[str]]):
        self.spec = spec
        self.index: Dict[tuple, int] = {}
        self.keys: List[tuple] = []
        self.rows: List[int] = []
        self.cols: Dict[str, Dict[str, list]] = {
            col: {"count": [], "vcount": [], "sum": [], "min": [], "max": []}
            for col in spec if col != "*"}

    def merge(self, p: _GroupPartial) -> None:
        idx_map: List[int] = []
        for k in p.keys:
            j = self.index.get(k)
            if j is None:
                j = len(self.keys)
                self.index[k] = j
                self.keys.append(k)
                self.rows.append(0)
                for st in self.cols.values():
                    st["count"].append(0)
                    st["vcount"].append(0)
                    st["sum"].append(0)
                    st["min"].append(None)
                    st["max"].append(None)
            idx_map.append(j)
        for gi, j in enumerate(idx_map):
            self.rows[j] += int(p.rows[gi])
            for col, entry in p.cols.items():
                st = self.cols[col]
                st["count"][j] += int(entry["count"][gi])
                vc = int(entry["vcount"][gi])
                if not vc:
                    continue
                st["vcount"][j] += vc
                if "sum" in entry:
                    st["sum"][j] = st["sum"][j] + entry["sum"][gi].item()
                if "min" in entry:
                    mn, mx = entry["min"][gi], entry["max"][gi]
                    mn = mn.item() if isinstance(mn, np.generic) else mn
                    mx = mx.item() if isinstance(mx, np.generic) else mx
                    st["min"][j] = (mn if st["min"][j] is None
                                    else min(st["min"][j], mn))
                    st["max"][j] = (mx if st["max"][j] is None
                                    else max(st["max"][j], mx))

    # -- shaping ------------------------------------------------------------
    def _op_value(self, col: str, op: str, j: int) -> Any:
        if col == "*":
            return self.rows[j]
        st = self.cols[col]
        if op == "count":
            return st["count"][j]
        if st["vcount"][j] == 0:
            return None
        if op == "sum":
            return st["sum"][j]
        if op == "mean":
            return st["sum"][j] / st["vcount"][j]
        return st[op][j]  # min / max

    def scalars(self) -> Dict[str, Dict[str, Any]]:
        """Ungrouped (zero-key) shape: ``{column: {op: value}}``."""
        out: Dict[str, Dict[str, Any]] = {}
        have = len(self.keys) > 0
        for col, ops in self.spec.items():
            vals: Dict[str, Any] = {}
            for op in ops:
                if have:
                    vals[op] = self._op_value(col, op, 0)
                else:
                    vals[op] = 0 if op == "count" else None
            out[col] = vals
        return out

    def to_table(self, key_cols: Sequence[str], schema: Schema) -> Table:
        """Grouped result: key columns + one ``{col}_{op}`` column per agg."""
        fields: List[Field] = []
        cols: Dict[str, Column] = {}
        n = len(self.keys)
        for i, kc in enumerate(key_cols):
            if n:
                col, _ = infer_column([k[i] for k in self.keys],
                                      dtype_hint=schema[kc].dtype)
            else:
                col = null_column_of(schema[kc].dtype, 0)
            cols[kc] = col
            fields.append(Field(kc, col.dtype))
        for col_name, ops in self.spec.items():
            for op in ops:
                out_name = agg_column_name(col_name, op)
                if n:
                    vals = [self._op_value(col_name, op, j) for j in range(n)]
                    c, _ = infer_column(vals)
                else:
                    c = null_column_of(_agg_dtype(schema, col_name, op), 0)
                cols[out_name] = c
                fields.append(Field(out_name, c.dtype))
        return Table(Schema(fields), cols)


def agg_column_name(col: str, op: str) -> str:
    """Output column name of one aggregate: ``count`` for ``("*",
    "count")``, else ``{col}_{op}``."""
    return "count" if col == "*" else f"{col}_{op}"


def _agg_dtype(schema: Schema, col: str, op: str) -> DType:
    if op == "count" or col == "*":
        return DType.numeric("i8")
    if op == "mean":
        return DType.numeric("f8")
    return schema[col].dtype  # min/max/sum keep the source dtype


# ---------------------------------------------------------------------------
# ordering
# ---------------------------------------------------------------------------
def _order_codes(col: Column, desc: bool) -> Tuple[np.ndarray, np.ndarray]:
    """(null_marker, rank_codes) for one sort key: sortable int64 arrays.

    Rank-based so int64 never rounds through float and strings sort
    without materializing per-comparison; nulls always sort last (the
    marker outranks the code), NaN ranks above every value.
    """
    codes, keys = _factorize(col)
    n = len(codes)
    # keys order from _factorize: sorted values, then NaN, then None —
    # exactly ascending rank order with NaN greatest, so codes ARE ranks
    # except the null code, which the marker handles.
    null_code = len(keys) - 1 if keys and keys[-1] is None else None
    null_m = np.zeros(n, np.int64)
    rank = codes.astype(np.int64, copy=True)
    if null_code is not None:
        is_null = codes == null_code
        null_m[is_null] = 1
        rank[is_null] = 0
    if desc:
        rank = -rank
    return null_m, rank


def _sort_indices(t: Table, order: Sequence[Tuple[str, bool]]) -> np.ndarray:
    """Stable row permutation for ``ORDER BY`` (ties keep arrival order)."""
    arrays: List[np.ndarray] = []
    for col, desc in order:  # most significant first
        null_m, rank = _order_codes(t.column(col), desc)
        arrays.append(null_m)
        arrays.append(rank)
    return np.lexsort(tuple(reversed(arrays)))


def _distinct_batch(t: Table, seen: set) -> Table:
    """Drop rows whose full output tuple was already emitted (stateful)."""
    codes, keys = _row_codes(t, t.column_names)
    u, first = np.unique(codes, return_index=True)
    keep: List[int] = []
    for code, fi in zip(u.tolist(), first.tolist()):
        k = keys[code]
        if k not in seen:
            seen.add(k)
            keep.append(fi)
    if len(keep) == t.num_rows:
        return t
    keep.sort()
    return t.take(np.array(keep, np.int64))


# ---------------------------------------------------------------------------
# compiled plan + report
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Compiled:
    man: Any                     # Manifest snapshot
    schema: Schema
    plan: ScanPlan
    scan_cols: List[str]         # projection pushed into the scan
    out_pre: List[str]           # pre-aggregation output columns
    computed: List[Tuple[str, Any]]


@dataclasses.dataclass
class QueryReport:
    """What :meth:`Query.explain` returns: the operator tree + scan report.

    ``ops`` lists the operators outermost-first (Limit → OrderBy →
    Distinct → Aggregate → Project → Filter → Scan) with a human-readable
    detail string each; ``scan`` is the underlying
    :class:`~repro.core.scan.ScanReport` whose :class:`ScanCounters`
    carry the pruning/decoding/pushdown counters.  When ``executed`` is
    True the query actually ran, so the counters reflect observed work —
    including the effect of early-terminating ``limit`` scans (fewer
    pages/rows decoded than the plan selected), and the integrity /
    degraded-mode counters (``files_quarantined`` delta files skipped under
    ``on_corruption="quarantine"``, ``pool_rebuilds`` and
    ``morsels_decoded_inline`` after a process-pool worker crash).
    """
    ops: List[Tuple[str, str]]
    scan: ScanReport
    executed: bool

    @property
    def counters(self) -> ScanCounters:
        return self.scan.counters

    def to_dict(self) -> dict:
        return {"ops": [{"op": o, "detail": d} for o, d in self.ops],
                "scan": self.scan.to_dict(),
                "executed": self.executed}

    def __str__(self) -> str:
        lines = ["Query"]
        depth = 1
        for op, detail in self.ops:
            pad = "  " * depth
            lines.append(f"{pad}{op}[{detail}]" if detail else f"{pad}{op}")
            depth += 1
        pad = "  " * depth
        lines.extend(pad + ln for ln in str(self.scan).splitlines())
        return "\n".join(lines)


class GroupedQuery:
    """Intermediate of ``Query.group_by(*cols)`` — call :meth:`agg`."""

    def __init__(self, query: "Query", keys: List[str]):
        self._query, self._keys = query, keys

    def agg(self, spec) -> "Query":
        """Aggregate each group; ``spec`` maps column (or ``"*"``) to one
        op or a list of ops from ``("count", "min", "max", "sum",
        "mean")``.  The result relation has the group-key columns plus one
        ``{col}_{op}`` column per aggregate (``count`` for ``"*"``), and
        composes with ``order_by`` / ``limit`` / ``offset``."""
        q = self._query
        norm = _normalize_spec(spec, q._schema())
        return q._replace(group_keys=list(self._keys), agg_spec=norm)


class Query:
    """Immutable, lazily-evaluated query over one ParquetDB dataset.

    Build with :meth:`ParquetDB.query` / :meth:`Dataset.query`; chain
    ``where`` / ``select`` / ``group_by().agg()`` / ``order_by`` /
    ``limit`` / ``offset`` / ``distinct``; finish with a terminal —
    ``to_table()``, ``iter_batches()``, ``to_pylist()``, ``count()``,
    ``agg(spec)`` or ``explain()``.  Every builder step validates column
    names against the dataset schema immediately.  See the module
    docstring for what the compiler pushes into the scan.
    """

    def __init__(self, db, cfg=None, man=None):
        self._db = db
        self._cfg = cfg
        self._man = man          # bound manifest (write paths); None = committed
        self._where: Optional[Expr] = None
        self._nwhere = 0
        self._select: Optional[List[str]] = None
        self._computed: List[Tuple[str, Any]] = []
        self._group_keys: Optional[List[str]] = None
        self._agg_spec: Optional[Dict[str, List[str]]] = None
        self._order: List[Tuple[str, bool]] = []
        self._limit: Optional[int] = None
        self._offset = 0
        self._distinct = False

    # ------------------------------------------------------------- plumbing
    def _replace(self, **kw) -> "Query":
        q = Query.__new__(Query)
        for slot in ("_db", "_cfg", "_man", "_where", "_nwhere", "_select",
                     "_computed", "_group_keys", "_agg_spec", "_order",
                     "_limit", "_offset", "_distinct"):
            setattr(q, slot, getattr(self, slot))
        for name, val in kw.items():
            setattr(q, "_" + name, val)
        return q

    def _snapshot(self):
        if self._man is not None:
            return self._man, self._db._manifest_schema(self._man)
        return self._db._load_snapshot()

    def _schema(self) -> Schema:
        return self._snapshot()[1]

    def _aggregated(self) -> bool:
        return self._agg_spec is not None

    def _agg_out_names(self) -> List[str]:
        names = list(self._group_keys or [])
        for col, ops in (self._agg_spec or {}).items():
            names.extend(agg_column_name(col, op) for op in ops)
        return names

    def _output_names(self, schema: Schema) -> List[str]:
        if self._aggregated():
            return self._agg_out_names()
        computed = [n for n, _ in self._computed]
        if self._select is not None:
            return list(self._select)
        return schema.names + computed

    # ------------------------------------------------------- fingerprinting
    def plan_fingerprint(self) -> str:
        """Canonical one-line description of this plan, stable under
        meaning-preserving rewrites: commutative ``where`` conjuncts,
        ``isin`` value order and projection order all render identically
        (rows come back as name-addressed records, so projection order
        is not part of the question being asked).  Order-sensitive parts
        — ``order_by`` keys, ``limit``/``offset``, ``distinct`` — stay
        order-sensitive.  This is the payload behind :meth:`plan_key`."""
        sel = "*" if self._select is None else ",".join(sorted(self._select))
        computed = ";".join(f"{n}={_canon_computed(ve)}"
                            for n, ve in sorted(self._computed))
        agg = ""
        if self._agg_spec is not None:
            agg = ";".join(f"{c}:{'+'.join(sorted(ops))}"
                           for c, ops in sorted(self._agg_spec.items()))
        order = ";".join(f"{c}:{'desc' if d else 'asc'}"
                         for c, d in self._order)
        return "|".join([
            f"where={canonical_expr(self._where)}",
            f"select={sel}",
            f"computed={computed}",
            f"group={','.join(self._group_keys) if self._group_keys is not None else ''}",
            f"agg={agg}",
            f"order={order}",
            f"limit={self._limit}",
            f"offset={self._offset}",
            f"distinct={self._distinct}",
        ])

    def plan_key(self) -> str:
        """Stable hex digest of :meth:`plan_fingerprint` — the cache key
        used by the serving tier's normalized-plan and result caches.
        Equivalent plans share a key; plans that can answer differently
        (different ``limit``/``offset``/``order_by``) never do."""
        return hashlib.blake2b(self.plan_fingerprint().encode(),
                               digest_size=16).hexdigest()

    # ------------------------------------------------------------- builders
    def _require_before_window(self, what: str) -> None:
        """Filters/projections execute below OrderBy/Limit in the fixed
        operator tree, so allowing them after would silently answer a
        different question than the chain reads — reject, like group_by."""
        if self._order or self._limit is not None or self._offset:
            raise ValueError(f"{what} must come before order_by()/limit()/"
                             f"offset(); it executes below them")

    def where(self, expr: Expr) -> "Query":
        """Filter rows; consecutive calls fuse into one AND predicate that
        is pushed down to footer statistics (file/row-group/page pruning).
        Must precede ``group_by().agg()`` and ``order_by``/``limit``."""
        if self._aggregated():
            raise ValueError("where() must precede group_by().agg(); "
                             "filter the rows before aggregating them")
        self._require_before_window("where()")
        if not isinstance(expr, Expr):
            raise TypeError(f"where() expects an Expr (e.g. field('x') > 0),"
                            f" got {type(expr).__name__}")
        schema = self._schema()
        for c in expr.columns():
            if c not in schema:
                raise _no_such_column(c, schema)
        fused = expr if self._where is None else (self._where & expr)
        return self._replace(where=fused, nwhere=self._nwhere + 1)

    def select(self, *cols: str, **computed) -> "Query":
        """Project and/or add computed columns.

        Positional names project (dotted parents expand to their nested
        children); keyword arguments define computed columns from value
        expressions — ``select("name", bonus=field("salary") * 0.1)``.
        With no positional names the current projection is kept and the
        computed columns are appended.  Unknown names raise ``KeyError``
        at plan-build time.
        """
        if self._aggregated():
            raise ValueError("select() must precede group_by().agg(); "
                             "aggregate output columns are defined by the "
                             "agg spec")
        self._require_before_window("select()")
        schema = self._schema()
        prev_computed = dict(self._computed)
        new_computed = list(self._computed)
        for name, ve in computed.items():
            if not isinstance(ve, (FieldRef, Arith)):
                raise TypeError(
                    f"computed column {name!r} must be a value expression "
                    f"(field(...) arithmetic), got {type(ve).__name__}")
            for c in ve.columns():
                if c not in schema:
                    raise _no_such_column(c, schema)
            if name in prev_computed:
                new_computed = [(n, v) if n != name else (name, ve)
                                for n, v in new_computed]
            else:
                new_computed.append((name, ve))
        computed_names = {n for n, _ in new_computed}
        if cols:
            out: List[str] = []
            for c in cols:
                if c in computed_names:
                    out.append(c)
                else:
                    out.extend(_resolve_names(schema, [c]))
            out.extend(n for n in computed.keys() if n not in out)
            return self._replace(select=out, computed=new_computed)
        if self._select is not None:
            out = list(self._select)
            out.extend(n for n in computed.keys() if n not in out)
            return self._replace(select=out, computed=new_computed)
        return self._replace(computed=new_computed)

    def _project_exact(self, names: Sequence[str]) -> "Query":
        """Internal: set the projection to exactly ``names`` (already
        resolved/validated by the caller — the legacy ``read`` shim, whose
        ``columns=[]`` means *no* data columns, unlike ``select()``)."""
        return self._replace(select=list(names))

    def group_by(self, *cols: str) -> GroupedQuery:
        """Start a grouped aggregation (follow with ``.agg(spec)``).

        Group keys must be physical numeric/string columns.  Null keys
        form one group, float-NaN keys another.  ``group_by`` must come
        before ``order_by``/``limit``/``offset``/``distinct`` (those apply
        to the aggregated result)."""
        if self._aggregated():
            raise ValueError("group_by() cannot follow another agg()")
        if self._order or self._limit is not None or self._offset \
                or self._distinct:
            raise ValueError("group_by() must come before order_by()/"
                             "limit()/offset()/distinct()")
        schema = self._schema()
        keys: List[str] = []
        for c in cols:
            if c not in schema:
                raise _no_such_column(c, schema)
            if schema[c].dtype.kind not in _GROUPABLE_KINDS:
                raise TypeError(f"cannot group by {c!r} of type "
                                f"{schema[c].dtype}")
            keys.append(c)
        return GroupedQuery(self, keys)

    def order_by(self, col: str, desc: bool = False) -> "Query":
        """Sort the result by ``col`` (stable; nulls last, NaN greatest).
        Repeated calls append secondary sort keys.  With ``limit`` the
        executor keeps a running top-k instead of a full materialize."""
        avail = self._output_names(self._schema())
        if col not in avail:
            raise KeyError(f"unknown order_by column {col!r}; output "
                           f"columns are {avail}")
        return self._replace(order=self._order + [(col, bool(desc))])

    def limit(self, n: int) -> "Query":
        """Keep at most ``n`` rows.  Without ``order_by`` the scan stops
        early: once ``limit + offset`` rows survive, pending morsels are
        cancelled (observable in ``explain(execute=True)``)."""
        if n < 0:
            raise ValueError("limit must be >= 0")
        return self._replace(limit=int(n))

    def offset(self, n: int) -> "Query":
        """Skip the first ``n`` result rows."""
        if n < 0:
            raise ValueError("offset must be >= 0")
        return self._replace(offset=int(n))

    def distinct(self) -> "Query":
        """Drop duplicate output rows (first occurrence wins, order kept).
        Must come before ``order_by``/``limit`` (it executes below them)."""
        if not self._aggregated():
            self._require_before_window("distinct()")
        return self._replace(distinct=True)

    # -------------------------------------------------------------- compile
    def _compile(self) -> _Compiled:
        man, schema = self._snapshot()
        out_pre = ([] if self._aggregated()
                   else self._output_names(schema))
        # a computed column dropped by a later positional select() is dead:
        # don't decode its inputs or evaluate it per batch (order_by keys
        # are always output columns, so this can never drop a sort key)
        computed = [(n, ve) for n, ve in self._computed if n in out_pre]
        computed_names = {n for n, _ in computed}
        scan_cols: List[str] = []

        def need(name: str) -> None:
            if name not in scan_cols:
                scan_cols.append(name)

        if self._aggregated():
            for kcol in self._group_keys:
                need(kcol)
            for col in self._agg_spec:
                if col != "*":
                    need(col)
            if not scan_cols:
                # count(*)-only grouped spec still needs one physical
                # column to carry row counts: the fixed-width id, never a
                # wide var-len column
                need(ID_COLUMN if ID_COLUMN in schema else schema.names[0])
        else:
            for name in out_pre:
                if name in computed_names:
                    continue
                if name not in schema:
                    raise _no_such_column(name, schema)
                need(name)
            for _, ve in computed:
                for c in ve.columns():
                    if c not in schema:
                        raise _no_such_column(c, schema)
                    need(c)
            if self._distinct:
                for name in out_pre:
                    if name in schema \
                            and schema[name].dtype.kind not in _GROUPABLE_KINDS:
                        raise TypeError(
                            f"distinct() cannot compare column {name!r} "
                            f"of type {schema[name].dtype}")
        if self._where is not None:
            for c in self._where.columns():
                if c not in schema:
                    raise _no_such_column(c, schema)
        avail = self._agg_out_names() if self._aggregated() else out_pre
        for c, _ in self._order:
            if c not in avail:
                raise KeyError(f"unknown order_by column {c!r}; output "
                               f"columns are {avail}")
        plan = ScanPlan(man.files, self._db._reader_of, schema,
                        columns=scan_cols, filter_expr=self._where,
                        cfg=self._cfg, deltas=man.deltas,
                        partitioning=self._db._partitioning_of(man))
        return _Compiled(man, schema, plan, scan_cols, out_pre, computed)

    # ------------------------------------------------------------ execution
    def _batches(self, cp: _Compiled, counters: Optional[ScanCounters]
                 ) -> Generator[Table, None, None]:
        """Scan → computed columns → projection → distinct (streaming)."""
        gen = cp.plan.execute(counters=counters)
        seen: Optional[set] = set() if self._distinct else None
        try:
            for t in gen:
                for name, ve in cp.computed:
                    t = t.set_column(name, ve.evaluate_column(t))
                t = t.select(cp.out_pre)
                if seen is not None:
                    t = _distinct_batch(t, seen)
                yield t
        finally:
            gen.close()

    def _empty_out(self, cp: _Compiled) -> Table:
        t = Table.empty(cp.schema.select(cp.scan_cols))
        for name, ve in cp.computed:
            t = t.set_column(name, ve.evaluate_column(t))
        return t.select(cp.out_pre)

    def _slice_limit(self, t: Table) -> Table:
        if self._offset == 0 and self._limit is None:
            return t
        start = min(self._offset, t.num_rows)  # clamp: offset may overshoot
        stop = (t.num_rows if self._limit is None
                else min(start + self._limit, t.num_rows))
        return t.slice(start, stop)

    def _run_plain(self, cp: _Compiled, counters: Optional[ScanCounters],
                   opstats: Optional[dict] = None) -> Table:
        stream = self._batches(cp, counters)
        if self._order:
            cap = (None if self._limit is None
                   else self._limit + self._offset)
            if cap is None:
                # full sort: collect once, concat once (no per-batch copy)
                parts = list(stream)
                acc = concat_tables(parts) if parts else self._empty_out(cp)
            else:
                # top-k: fold each batch into a pruned accumulator
                acc = None
                for t in stream:
                    acc = t if acc is None else concat_tables([acc, t])
                    if acc.num_rows > cap:
                        idx = _sort_indices(acc, self._order)[:cap]
                        acc = acc.take(np.sort(idx))  # keep arrival order
                if acc is None:
                    acc = self._empty_out(cp)
            acc = acc.take(_sort_indices(acc, self._order))
            if opstats is not None:
                opstats["rows_sorted"] = acc.num_rows
            out = self._slice_limit(acc)
        else:
            cap = (None if self._limit is None
                   else self._limit + self._offset)
            parts: List[Table] = []
            got = 0
            if cap == 0:
                stream.close()
            else:
                for t in stream:
                    parts.append(t)
                    got += t.num_rows
                    if cap is not None and got >= cap:
                        stream.close()  # early stop: cancels queued morsels
                        break
            table = concat_tables(parts) if parts else self._empty_out(cp)
            out = self._slice_limit(table)
        if opstats is not None:
            opstats["rows_out"] = out.num_rows
        return out

    def _run_grouped(self, cp: _Compiled,
                     counters: Optional[ScanCounters],
                     opstats: Optional[dict] = None) -> Table:
        key_cols, spec = self._group_keys, self._agg_spec
        acc = _GroupedAcc(spec)
        # partial aggregation runs inside the morsel workers (map_fn);
        # the merge below is the single-threaded consumer half
        for partial in cp.plan.execute(
                counters=counters,
                map_fn=lambda t: _partial_groups(t, key_cols, spec)):
            acc.merge(partial)
        table = acc.to_table(key_cols, cp.schema)
        if opstats is not None:
            opstats["groups"] = table.num_rows
        if self._order:
            table = table.take(_sort_indices(table, self._order))
        out = self._slice_limit(table)
        if opstats is not None:
            opstats["rows_out"] = out.num_rows
        return out

    def _run(self, cp: _Compiled, counters: Optional[ScanCounters] = None,
             opstats: Optional[dict] = None) -> Table:
        if self._aggregated():
            return self._run_grouped(cp, counters, opstats)
        return self._run_plain(cp, counters, opstats)

    # ------------------------------------------------------------ terminals
    def to_table(self) -> Table:
        """Execute and materialize the full result as one Table."""
        return self._run(self._compile())

    def to_pylist(self) -> List[dict]:
        """Execute and materialize as a list of row dicts."""
        return self.to_table().to_pylist()

    def iter_batches(self, batch_size: Optional[int] = None
                     ) -> Generator[Table, None, None]:
        """Stream the result as Tables of ``batch_size`` rows (lazy).

        Ordered or grouped queries materialize first (a sort/aggregation
        is a pipeline breaker); everything else streams, honoring
        ``limit``/``offset`` with early scan termination.
        """
        bs = batch_size or int(getattr(self._cfg, "batch_size", 131_072))
        if self._aggregated() or self._order:
            yield from rechunk(iter([self.to_table()]), bs)
            return
        cp = self._compile()
        stream = self._batches(cp, None)

        def limited() -> Generator[Table, None, None]:
            togo_skip, togo = self._offset, self._limit
            if togo is not None and togo <= 0:
                stream.close()
                return
            for t in stream:
                if togo_skip:
                    if t.num_rows <= togo_skip:
                        togo_skip -= t.num_rows
                        continue
                    t = t.slice(togo_skip, t.num_rows)
                    togo_skip = 0
                if togo is not None:
                    if t.num_rows >= togo:
                        yield t.slice(0, togo)
                        stream.close()
                        return
                    togo -= t.num_rows
                yield t

        yield from rechunk(limited(), bs)

    def count(self) -> int:
        """Number of result rows.

        For a plain filtered query this is answered through the aggregate
        fast path (footer statistics — typically zero pages decoded) with
        ``limit``/``offset`` applied arithmetically; computed columns and
        projections don't change the row count, so they stay on the fast
        path too.  Grouped and ``distinct`` queries run the pipeline.
        """
        if self._aggregated():
            return self.to_table().num_rows
        if not self._distinct:
            man, schema = self._snapshot()
            plan = AggregatePlan(man.files, self._db._reader_of, schema,
                                 {"*": "count"}, filter_expr=self._where,
                                 cfg=self._cfg, deltas=man.deltas,
                                 partitioning=self._db._partitioning_of(man))
            total = plan.execute()["*"]["count"]
            total = max(0, total - self._offset)
            return total if self._limit is None else min(total, self._limit)
        return self.to_table().num_rows

    def agg(self, spec, explain: bool = False):
        """Ungrouped aggregate terminal — ``{column: {op: value}}``.

        A simple query (where/select only) routes through the footer-
        statistics fast path and returns results and (with
        ``explain=True``) the same :class:`ScanReport` as the legacy
        ``db.aggregate`` — including ``groups_answered_by_stats`` /
        ``bytes_skipped_agg`` counters.  Queries with computed columns,
        ``distinct``, ``order_by`` or ``limit`` aggregate their
        materialized output instead, in one execution (explain then
        returns a :class:`QueryReport`).

        On both paths the spec may reference any physical column —
        matching the legacy surface, where projections never restrict
        ``aggregate`` — plus, on the materialized path, any computed
        output column.  ``distinct()`` is the exception: its spec is
        restricted to the distinct output columns (aggregating a column
        that did not participate in deduplication would be ill-defined).
        """
        if self._aggregated():
            raise ValueError("agg() cannot follow group_by().agg(); the "
                             "query is already aggregated")
        simple = (not self._computed and not self._distinct
                  and not self._order and self._limit is None
                  and self._offset == 0)
        man, schema = self._snapshot()
        if simple:
            _normalize_spec(spec, schema)  # plan-build-time validation
            plan = AggregatePlan(man.files, self._db._reader_of, schema,
                                 spec, filter_expr=self._where,
                                 cfg=self._cfg, deltas=man.deltas,
                                 partitioning=self._db._partitioning_of(man))
            values = plan.execute()
            return (values, plan.report()) if explain else values
        q = self
        if not self._distinct and self._select is not None:
            # keep fast-path semantics: a projection does not hide
            # physical columns from the aggregate
            out = set(self._output_names(schema))
            missing = [c for c in spec if c != "*" and c not in out
                       and c in schema]
            if missing:
                q = self._replace(select=self._select + missing)
        if explain:
            table, report = q._run_reported()
        else:
            table = q.to_table()
        norm = _normalize_spec(spec, table.schema)
        acc = _GroupedAcc(norm)
        if table.num_rows:
            acc.merge(_partial_groups(table, [], norm))
        values = acc.scalars()
        return (values, report) if explain else values

    # -------------------------------------------------------------- explain
    def _op_descriptions(self) -> List[Tuple[str, str]]:
        ops: List[Tuple[str, str]] = []
        if self._limit is not None or self._offset:
            detail = []
            if self._limit is not None:
                detail.append(f"limit={self._limit}")
            if self._offset:
                detail.append(f"offset={self._offset}")
            ops.append(("Limit", " ".join(detail)))
        if self._order:
            detail = ", ".join(f"{c} {'DESC' if d else 'ASC'}"
                               for c, d in self._order)
            ops.append(("OrderBy", detail))
        if self._distinct and not self._aggregated():
            ops.append(("Distinct", ""))
        if self._aggregated():
            aggs = ", ".join(agg_column_name(c, op)
                             for c, o in self._agg_spec.items() for op in o)
            keys = ", ".join(self._group_keys) or "<global>"
            ops.append(("Aggregate", f"group_by=[{keys}] → {aggs}"))
        elif self._select is not None or self._computed:
            parts = []
            for n in self._output_names(self._schema()):
                ve = dict(self._computed).get(n)
                parts.append(f"{n}={ve!r}" if ve is not None else n)
            ops.append(("Project", ", ".join(parts)))
        if self._where is not None:
            fused = f"  ({self._nwhere} predicates fused)" \
                if self._nwhere > 1 else ""
            ops.append(("Filter", f"{self._where!r}{fused}"))
        return ops

    def explain(self, execute: bool = False) -> QueryReport:
        """Render the operator tree plus the scan's pruning report.

        ``execute=True`` actually runs the query, so the counters show
        observed decode work — including how much an early-terminating
        ``limit`` scan *didn't* decode — and per-operator row counts are
        appended to the tree.
        """
        if execute:
            return self._run_reported()[1]
        cp = self._compile()
        return QueryReport(ops=self._op_descriptions(),
                           scan=cp.plan.explain(execute=False),
                           executed=False)

    def _run_reported(self) -> Tuple[Table, QueryReport]:
        """One execution that yields both the result and the full report."""
        cp = self._compile()
        ops = self._op_descriptions()
        cp.plan.fragments()  # force planning so the counters exist
        counters = dataclasses.replace(cp.plan._plan_counters)
        counters.bytes_total, counters.bytes_selected = \
            cp.plan._bytes_accounting()
        opstats: dict = {}
        table = self._run(cp, counters, opstats)
        decorated: List[Tuple[str, str]] = []
        for op, detail in ops:
            extra = ""
            if op == "Limit" and "rows_out" in opstats:
                extra = f" → {opstats['rows_out']} rows"
            elif op == "Aggregate" and "groups" in opstats:
                extra = f" → {opstats['groups']} groups"
            elif op == "OrderBy" and "rows_sorted" in opstats:
                extra = f" → {opstats['rows_sorted']} rows sorted"
            decorated.append((op, detail + extra))
        scan_rep = ScanReport(
            counters=counters, fragments=cp.plan.fragments(),
            columns=list(cp.plan._out_schema.names),
            filter=repr(self._where) if self._where is not None else None,
            executed=True)
        return table, QueryReport(ops=decorated, scan=scan_rep,
                                  executed=True)

    def __repr__(self) -> str:
        bits = []
        if self._where is not None:
            bits.append(f"where={self._where!r}")
        if self._select is not None:
            bits.append(f"select={self._select}")
        if self._computed:
            bits.append(f"computed={[n for n, _ in self._computed]}")
        if self._aggregated():
            bits.append(f"group_by={self._group_keys} agg={self._agg_spec}")
        if self._order:
            bits.append(f"order_by={self._order}")
        if self._limit is not None:
            bits.append(f"limit={self._limit}")
        if self._offset:
            bits.append(f"offset={self._offset}")
        if self._distinct:
            bits.append("distinct")
        return f"Query({', '.join(bits)})"
