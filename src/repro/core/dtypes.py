"""Logical/physical dtype system for the TPQ columnar format.

Mirrors the role of Parquet's physical+logical type split (paper §4.1 / SI §1.4.2):
a *physical* type says how bytes are laid out, a *logical* type carries semantic
meaning (string, list, fixed-shape tensor, ...).  Kept deliberately small: the set
below covers everything the paper's workloads (numeric tables, nested materials
records) and our training substrate (token/embedding columns) need.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Logical type kinds
# ---------------------------------------------------------------------------
KIND_NUMERIC = "numeric"     # ints, floats, bool — stored as fixed-width LE
KIND_STRING = "string"       # UTF-8, offsets + bytes
KIND_BINARY = "binary"       # raw bytes, offsets + bytes
KIND_TENSOR = "tensor"       # fixed-shape nd tensor per row (shape in dtype)
KIND_LIST = "list"           # ragged list per row (offsets + child values)
KIND_NULL = "null"           # all-null placeholder column

_NUMPY_TO_CODE = {
    np.dtype("bool"): "b1",
    np.dtype("int8"): "i1",
    np.dtype("int16"): "i2",
    np.dtype("int32"): "i4",
    np.dtype("int64"): "i8",
    np.dtype("uint8"): "u1",
    np.dtype("uint16"): "u2",
    np.dtype("uint32"): "u4",
    np.dtype("uint64"): "u8",
    np.dtype("float16"): "f2",
    np.dtype("float32"): "f4",
    np.dtype("float64"): "f8",
}
_CODE_TO_NUMPY = {v: k for k, v in _NUMPY_TO_CODE.items()}

# promotion lattice for schema evolution (paper §4.4.2 "Schema Alignment")
_PROMOTION_ORDER = [
    "b1", "i1", "u1", "i2", "u2", "i4", "u4", "i8", "u8", "f2", "f4", "f8",
]


@dataclasses.dataclass(frozen=True)
class DType:
    """A logical column type.

    kind       one of KIND_*.
    code       physical element code for numeric/tensor/list-child ("i8", "f4", ...).
    shape      per-row tensor shape for KIND_TENSOR (e.g. (3, 3) lattice matrices).
    child      element DType for KIND_LIST.
    """

    kind: str
    code: Optional[str] = None
    shape: Optional[Tuple[int, ...]] = None
    child: Optional["DType"] = None

    # -- constructors -------------------------------------------------------
    @staticmethod
    def numeric(code: str) -> "DType":
        assert code in _CODE_TO_NUMPY, code
        return DType(KIND_NUMERIC, code=code)

    @staticmethod
    def string() -> "DType":
        return DType(KIND_STRING)

    @staticmethod
    def binary() -> "DType":
        return DType(KIND_BINARY)

    @staticmethod
    def tensor(code: str, shape: Tuple[int, ...]) -> "DType":
        return DType(KIND_TENSOR, code=code, shape=tuple(int(s) for s in shape))

    @staticmethod
    def list_(child: "DType") -> "DType":
        return DType(KIND_LIST, child=child)

    @staticmethod
    def null() -> "DType":
        return DType(KIND_NULL)

    @staticmethod
    def from_numpy(dt: np.dtype) -> "DType":
        return DType.numeric(_NUMPY_TO_CODE[np.dtype(dt)])

    # -- accessors ----------------------------------------------------------
    @property
    def np(self) -> np.dtype:
        if self.kind in (KIND_NUMERIC, KIND_TENSOR):
            return _CODE_TO_NUMPY[self.code]
        if self.kind == KIND_NULL:
            return np.dtype("float64")
        raise TypeError(f"no numpy dtype for {self}")

    @property
    def is_numeric(self) -> bool:
        return self.kind == KIND_NUMERIC

    @property
    def is_integer(self) -> bool:
        return self.kind == KIND_NUMERIC and self.code[0] in ("i", "u", "b")

    @property
    def is_float(self) -> bool:
        return self.kind == KIND_NUMERIC and self.code[0] == "f"

    # -- (de)serialization for the footer -----------------------------------
    def to_dict(self) -> dict:
        d: dict[str, Any] = {"kind": self.kind}
        if self.code is not None:
            d["code"] = self.code
        if self.shape is not None:
            d["shape"] = list(self.shape)
        if self.child is not None:
            d["child"] = self.child.to_dict()
        return d

    @staticmethod
    def from_dict(d: dict) -> "DType":
        return DType(
            kind=d["kind"],
            code=d.get("code"),
            shape=tuple(d["shape"]) if d.get("shape") is not None else None,
            child=DType.from_dict(d["child"]) if d.get("child") else None,
        )

    def __str__(self) -> str:  # compact, for error messages
        if self.kind == KIND_NUMERIC:
            return self.code
        if self.kind == KIND_TENSOR:
            return f"tensor<{self.code},{self.shape}>"
        if self.kind == KIND_LIST:
            return f"list<{self.child}>"
        return self.kind


def promote(a: DType, b: DType) -> DType:
    """Least common supertype used during schema evolution.

    Numeric types promote along a widening lattice; a NULL column promotes to
    anything; everything else must match exactly (the paper casts or errors —
    we error, with the cast path living in table.cast_column).
    """
    if a == b:
        return a
    if a.kind == KIND_NULL:
        return b
    if b.kind == KIND_NULL:
        return a
    if a.kind == KIND_NUMERIC and b.kind == KIND_NUMERIC:
        ia, ib = _PROMOTION_ORDER.index(a.code), _PROMOTION_ORDER.index(b.code)
        hi = _PROMOTION_ORDER[max(ia, ib)]
        # mixed signed/unsigned of same width widen to next signed, like numpy
        if a.code[0] != b.code[0] and {a.code[0], b.code[0]} == {"i", "u"}:
            width = max(int(a.code[1]), int(b.code[1]))
            hi = "i8" if width >= 8 else f"i{min(width * 2, 8)}"
        if "f" in (a.code[0], b.code[0]) and hi[0] != "f":
            hi = "f8"
        return DType.numeric(hi)
    if a.kind == KIND_LIST and b.kind == KIND_LIST:
        return DType.list_(promote(a.child, b.child))
    if a.kind == KIND_TENSOR and b.kind == KIND_TENSOR and a.shape == b.shape:
        return DType.tensor(promote(DType.numeric(a.code), DType.numeric(b.code)).code, a.shape)
    raise TypeError(f"cannot unify column types {a} and {b}")
