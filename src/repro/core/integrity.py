"""End-to-end data integrity: typed corruption errors, scrub reports, fault hooks.

TPQ files carry crc32 checksums (format v2, :mod:`repro.core.fileformat`):
one per stored page payload and one over the compressed footer blob.  This
module owns the pieces every layer shares:

- the **typed error hierarchy** raised when verification fails.  All of them
  subclass :class:`IOError` (so pre-existing ``except IOError`` handling and
  tests keep working) and carry coordinates — file path, and for page errors
  the row group / column / page indices — so a corrupt byte is reported as
  *where*, not as a cryptic ``zlib.error`` or ``struct.error``;
- the **scrub report** types returned by ``db.verify()``
  (:class:`IntegrityReport` / :class:`FileCheck`);
- the **fault-injection hooks** the test harness uses to provoke ENOSPC
  mid-write and transient EIO on read (mirroring the PR 7 commit crash
  hooks in :mod:`repro.core.transactions`), plus the bounded-backoff read
  retry helper built on them.

Nothing here imports the rest of the package at module scope, so any layer
(writer, reader, scan, store) can import it without cycles.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional


# ---------------------------------------------------------------------------
# Typed corruption errors
# ---------------------------------------------------------------------------
class IntegrityError(IOError):
    """A TPQ file failed verification.

    Carries the file ``path``, a human ``detail``, and — for page-level
    failures — the ``row_group`` / ``column`` / ``page`` coordinates of the
    corrupt buffer.  Subclasses :class:`IOError` so callers that guard file
    reads with ``except (IOError, OSError)`` already catch it.
    """

    def __init__(self, path: str, detail: str, *,
                 row_group: Optional[int] = None,
                 column: Optional[str] = None,
                 page: Optional[int] = None):
        self.path = path
        self.detail = detail
        self.row_group = row_group
        self.column = column
        self.page = page
        where = path
        if row_group is not None:
            where += f" rg={row_group}"
        if column is not None:
            where += f" col={column}"
        if page is not None:
            where += f" page={page}"
        super().__init__(f"{where}: {detail}")

    def __reduce__(self):
        # survive pickling across process-pool workers with coordinates
        # intact (IOError's default reduce would re-init with errno args)
        return (_rebuild_error, (self.__class__, self.path, self.detail,
                                 self.row_group, self.column, self.page))


def _rebuild_error(cls, path, detail, row_group, column, page):
    # pickle helper (module-level so it resolves in pool workers)
    return cls(path, detail, row_group=row_group, column=column, page=page)


class TruncatedFileError(IntegrityError):
    """File is shorter than its own framing claims (torn write, cut copy)."""


class CorruptFooterError(IntegrityError):
    """Footer blob failed its checksum or cannot be parsed (bad magic,
    garbage JSON, zlib error, wrong shape)."""


class CorruptPageError(IntegrityError):
    """A page payload failed its checksum or could not be decompressed."""


# ---------------------------------------------------------------------------
# Scrub report (what db.verify() returns)
# ---------------------------------------------------------------------------
@dataclass
class FileCheck:
    """Verification outcome for one file of a dataset snapshot."""
    name: str                    # manifest-relative file name
    kind: str = "base"           # base | upsert | tombstone
    status: str = "ok"           # ok | corrupt | missing
    checksummed: bool = True     # False for legacy (v1) files
    rows: int = 0
    pages_verified: int = 0
    error: Optional[str] = None  # str(first IntegrityError) when corrupt
    exc: Optional[BaseException] = None  # the typed error, coordinates intact

    def __str__(self) -> str:
        tag = self.status if self.checksummed else f"{self.status} (legacy)"
        s = f"{self.name} [{self.kind}] {tag}"
        if self.status == "ok":
            s += f" rows={self.rows} pages_verified={self.pages_verified}"
        elif self.error:
            s += f" — {self.error}"
        return s


@dataclass
class IntegrityReport:
    """Structured result of ``db.verify()`` — the dataset scrubber.

    Walks manifest → partitions → files → footers → pages.  ``ok`` is True
    iff every referenced file opened, parsed, and (when ``deep``) every page
    passed its checksum.  ``first_error`` keeps the first typed error (with
    its file/row-group/page coordinates) for direct triage.
    """
    dataset: str = ""
    generation: int = 0
    deep: bool = False
    files: List[FileCheck] = field(default_factory=list)
    files_ok: int = 0
    files_corrupt: int = 0
    files_missing: int = 0
    files_legacy: int = 0        # readable but unchecksummed (format v1)
    pages_verified: int = 0
    first_error: Optional[BaseException] = None

    @property
    def ok(self) -> bool:
        return self.files_corrupt == 0 and self.files_missing == 0

    def add(self, check: FileCheck) -> None:
        self.files.append(check)
        if check.status == "ok":
            self.files_ok += 1
        elif check.status == "missing":
            self.files_missing += 1
        else:
            self.files_corrupt += 1
        if not check.checksummed:
            self.files_legacy += 1
        self.pages_verified += check.pages_verified
        if check.exc is not None and self.first_error is None:
            self.first_error = check.exc

    def __str__(self) -> str:
        mode = "deep" if self.deep else "shallow"
        head = (f"IntegrityReport({self.dataset!r} gen={self.generation} "
                f"{mode}): {'OK' if self.ok else 'CORRUPT'} — "
                f"{self.files_ok} ok, {self.files_corrupt} corrupt, "
                f"{self.files_missing} missing / {len(self.files)} files; "
                f"{self.pages_verified} pages verified")
        if self.files_legacy:
            head += f"; {self.files_legacy} legacy unchecksummed"
        lines = [head]
        for c in self.files:
            if c.status != "ok":
                lines.append(f"  ! {c}")
        if self.first_error is not None:
            lines.append(f"  first error: {self.first_error}")
        return "\n".join(lines)


def verify_file(path: str, name: str = "", deep: bool = True) -> FileCheck:
    """Scrub one TPQ file: open (footer checksum + parse), then page sweep.

    ``deep`` checks every page payload's crc without decoding; legacy files
    (no checksums) are instead fully decoded so corruption still surfaces as
    a decode failure rather than passing silently.  Never raises for
    corruption — the outcome lands in the returned :class:`FileCheck`.
    """
    from .fileformat import TPQReader  # lazy: avoid import cycle
    check = FileCheck(name=name or path)
    try:
        rd = TPQReader(path)
    except FileNotFoundError:
        check.status = "missing"
        check.error = "file not found"
        return check
    except IntegrityError as e:
        check.status = "corrupt"
        check.error = str(e)
        check.exc = e
        return check
    check.kind = rd.file_kind
    check.rows = rd.num_rows
    check.checksummed = rd.checksummed
    if deep:
        try:
            if rd.checksummed:
                check.pages_verified = rd.verify_pages()
            else:
                # legacy file: no crcs to sweep — decode everything and let
                # structural damage surface as a (typed) decode error
                for _ in rd.iter_row_group_tables():
                    pass
        except Exception as e:
            # decode of a damaged legacy file can raise nearly anything
            check.status = "corrupt"
            check.error = f"{type(e).__name__}: {e}"
            check.exc = e
    return check


# ---------------------------------------------------------------------------
# Fault injection hooks + bounded read retry
# ---------------------------------------------------------------------------
# WRITE_FAULT_HOOK(path, nbytes): called by TPQWriter before each disk write
# (pages and footer).  Tests raise OSError(ENOSPC) from it to simulate the
# disk filling after K bytes; the write paths must then clean up partial
# files and never publish a manifest referencing them.
WRITE_FAULT_HOOK: Optional[Callable[[str, int], None]] = None

# READ_FAULT_HOOK(path): called before each attempt of a retried read.
# Tests raise OSError(EIO) a bounded number of times to simulate transient
# media errors; with_read_retries must absorb up to READ_RETRIES of them.
READ_FAULT_HOOK: Optional[Callable[[str], None]] = None

READ_RETRIES = 3            # attempts per read before giving up
READ_RETRY_BACKOFF = 0.01   # seconds; doubles per retry (bounded: 3 tries)


def with_read_retries(fn: Callable[[], object], path: str):
    """Run ``fn`` with bounded-backoff retries on transient ``OSError``.

    Corruption (:class:`IntegrityError`) and :class:`FileNotFoundError` are
    *not* transient — they re-raise immediately.  Everything else OS-level
    (EIO, EAGAIN from flaky network mounts, ...) retries up to
    ``READ_RETRIES`` attempts with exponential backoff, then re-raises.
    """
    delay = READ_RETRY_BACKOFF
    for attempt in range(READ_RETRIES):
        try:
            if READ_FAULT_HOOK is not None:
                READ_FAULT_HOOK(path)
            return fn()
        except (IntegrityError, FileNotFoundError):
            raise
        except OSError:
            if attempt + 1 >= READ_RETRIES:
                raise
            time.sleep(delay)
            delay *= 2
