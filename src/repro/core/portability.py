"""Portability helpers (paper Table 1: "File-based storage, allows for easy
transfer"): lossless JSON-lines export/import of a dataset, including nested
structure reconstruction — the interchange path between ParquetDB instances
or out to other tools."""
from __future__ import annotations

import json
from typing import Optional

import numpy as np

from .store import ParquetDB


def _jsonable(v):
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, bytes):
        return {"__bytes__": v.hex()}
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, list):
        return [_jsonable(x) for x in v]
    return v


def _unjson(v):
    if isinstance(v, dict):
        if set(v) == {"__bytes__"}:
            return bytes.fromhex(v["__bytes__"])
        return {k: _unjson(x) for k, x in v.items()}
    if isinstance(v, list):
        return [_unjson(x) for x in v]
    return v


def export_jsonl(db: ParquetDB, path: str, *, batch_size: int = 10_000,
                 keep_ids: bool = False) -> int:
    """Stream the dataset to JSON-lines (nested structure rebuilt)."""
    n = 0
    with open(path, "w") as fh:
        for t in db.read(load_format="batches", batch_size=batch_size):
            for rec in t.to_pylist(rebuild_nested=True):
                if not keep_ids:
                    rec.pop("id", None)
                fh.write(json.dumps(_jsonable(rec)) + "\n")
                n += 1
    return n


def import_jsonl(db: ParquetDB, path: str, *, batch_size: int = 10_000,
                 treat_fields_as_ragged=()) -> int:
    """Create records from a JSON-lines file (batched)."""
    n = 0
    batch = []
    with open(path) as fh:
        for line in fh:
            batch.append(_unjson(json.loads(line)))
            if len(batch) >= batch_size:
                db.create(batch, treat_fields_as_ragged=treat_fields_as_ragged)
                n += len(batch)
                batch = []
    if batch:
        db.create(batch, treat_fields_as_ragged=treat_fields_as_ragged)
        n += len(batch)
    return n
