"""Hive-style partitioning: spec, directory layout, and manifest-level pruning.

A partitioned dataset writes each :meth:`~repro.core.store.ParquetDB.create`
batch into ``col=value/`` subdirectories (the hive layout), one file per
partition per create.  The partition *values* of every base file are
recorded as typed JSON in the manifest metadata — never parsed back out of
directory names — which is what lets :class:`~repro.core.scan.ScanPlan`
prune whole partitions **before touching any footer**: a pruned partition
costs zero ``open()``/``stat()`` calls, not just zero decoded pages.

Two modes:

``value``
    One directory per distinct tuple of partition-column values,
    ``a=1/b=x/``; ``None`` maps to ``__HIVE_DEFAULT_PARTITION__`` (the
    hive convention).  Pruning synthesizes a single-value
    :class:`~repro.core.statistics.ColumnStats` per partition column and
    reuses ``Expr.prune`` — so every filter shape the row-group pruner
    understands prunes partitions too, conservatively.

``hash``
    ``buckets`` directories ``bucket=<i>``, ``i = crc32(encoded values)
    % buckets``.  Only equality shapes (``==`` / ``isin`` on a
    single-column spec) are prunable; everything else scans every bucket.

Soundness notes (enforced by the store):

- Partition columns are **immutable** per row: ``update`` rejects writes
  to them and ``delete(columns=...)`` cannot drop them.  That makes a
  row's partition a function of its id, which is what makes both
  partition-disjoint MVCC commits and per-partition compaction sound.
- Upsert deltas carry *new* column values that the partition values
  cannot bound for non-partition columns, so the scan planner disables
  partition pruning while any upsert delta is pending (compaction
  restores it).  Tombstones are fine: dropping rows commutes with
  filtering.
"""
from __future__ import annotations

import dataclasses
import urllib.parse
import zlib
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .dtypes import KIND_NUMERIC
from .expressions import And, Comparison, Expr, FieldRef, IsIn, Or
from .statistics import ColumnStats
from .table import Table

__all__ = ["PartitionSpec", "Partitioning", "HIVE_NULL",
           "PARTITION_META_KEY", "hash_bucket"]

# manifest.metadata key holding {"by", "mode", "buckets", "files"}
PARTITION_META_KEY = "partitioning"
# hive's spelling for a null partition value
HIVE_NULL = "__HIVE_DEFAULT_PARTITION__"
MODES = ("value", "hash")


def _encode_value(v: Any) -> str:
    """Deterministic, filesystem-safe spelling of one partition value.

    Integral floats normalize to their int spelling and bools to 0/1 so
    that ``hash_bucket`` agrees between a column's storage dtype and the
    literal a filter happens to use (``f('k') == 5`` vs a float column).
    """
    if v is None:
        return HIVE_NULL
    if isinstance(v, (bool, np.bool_)):
        return str(int(v))
    if isinstance(v, (float, np.floating)) and float(v).is_integer():
        return str(int(v))
    return urllib.parse.quote(str(v), safe="")


def hash_bucket(values: Sequence[Any], buckets: int) -> int:
    """Stable bucket of one partition-value tuple (crc32, process-stable)."""
    key = "/".join(_encode_value(v) for v in values)
    return zlib.crc32(key.encode("utf-8")) % buckets


def _json_value(v: Any) -> Any:
    """Typed JSON spelling of a partition value (numpy scalars unwrapped)."""
    return v.item() if isinstance(v, np.generic) else v


@dataclasses.dataclass(frozen=True)
class PartitionSpec:
    """What a dataset is partitioned by: columns + mode (+ bucket count)."""
    by: Tuple[str, ...]
    mode: str = "value"
    buckets: int = 16

    def __post_init__(self):
        if not self.by:
            raise ValueError("partition_by is empty")
        if self.mode not in MODES:
            raise ValueError(f"partition mode {self.mode!r} "
                             f"(expected one of {MODES})")
        if self.mode == "hash" and self.buckets < 1:
            raise ValueError("partition_buckets must be >= 1")

    def to_dict(self) -> dict:
        return {"by": list(self.by), "mode": self.mode,
                "buckets": int(self.buckets)}

    @classmethod
    def from_dict(cls, d: dict) -> "PartitionSpec":
        return cls(by=tuple(d["by"]), mode=d.get("mode", "value"),
                   buckets=int(d.get("buckets", 16)))


def _group_indices(inv: np.ndarray, k: int) -> List[np.ndarray]:
    """Row indices per group code, original order preserved within a group."""
    order = np.argsort(inv, kind="stable")
    bounds = np.searchsorted(inv[order], np.arange(k + 1))
    return [order[bounds[i]:bounds[i + 1]] for i in range(k)]


def _candidate_buckets(expr: Expr, spec: PartitionSpec) -> Optional[set]:
    """Upper bound on the hash buckets ``expr`` can match, or None.

    Only single-column hash specs are decidable (a multi-column bucket
    needs every component pinned); only ``==``/``isin`` shapes pin a
    value.  ``None`` means "any bucket" — no pruning.
    """
    if len(spec.by) != 1:
        return None
    col = spec.by[0]

    def cand(e: Expr) -> Optional[set]:
        if isinstance(e, And):
            a, b = cand(e.a), cand(e.b)
            if a is None:
                return b
            return a if b is None else (a & b)
        if isinstance(e, Or):
            a, b = cand(e.a), cand(e.b)
            return None if (a is None or b is None) else (a | b)
        if isinstance(e, Comparison) and e.op == "==" and e.name == col \
                and not isinstance(e.value, FieldRef):
            return {hash_bucket((e.value,), spec.buckets)}
        if isinstance(e, IsIn) and e.name == col:
            return {hash_bucket((v,), spec.buckets) for v in e.values}
        return None

    return cand(expr)


class Partitioning:
    """A :class:`PartitionSpec` plus the per-file partition values.

    Persisted inside ``Manifest.metadata["partitioning"]`` as::

        {"by": [...], "mode": "value"|"hash", "buckets": N,
         "files": {file_name: [typed values...]}}   # hash mode: [bucket]

    Files absent from the map (e.g. written before the spec existed) are
    treated as unpartitioned: never pruned, always scanned.
    """

    def __init__(self, spec: PartitionSpec,
                 files: Optional[Dict[str, list]] = None):
        self.spec = spec
        self.files: Dict[str, list] = dict(files or {})

    # ------------------------------------------------------------ persistence
    @classmethod
    def from_manifest(cls, man) -> Optional["Partitioning"]:
        meta = (man.metadata or {}).get(PARTITION_META_KEY)
        if not meta:
            return None
        return cls(PartitionSpec.from_dict(meta),
                   {k: list(v) for k, v in meta.get("files", {}).items()})

    def store(self, man) -> None:
        d = self.spec.to_dict()
        d["files"] = {k: list(v) for k, v in self.files.items()}
        man.metadata[PARTITION_META_KEY] = d

    # ------------------------------------------------------------ layout
    def dir_of(self, values: Sequence[Any]) -> str:
        """Relative partition directory ("a=1/b=x" or "bucket=3")."""
        if self.spec.mode == "hash":
            return f"bucket={int(values[0])}"
        return "/".join(f"{urllib.parse.quote(str(c), safe='')}"
                        f"={_encode_value(v)}"
                        for c, v in zip(self.spec.by, values))

    def key_of(self, name: str) -> Optional[str]:
        """Canonical partition key of a base file, None when unknown."""
        vals = self.files.get(name)
        return None if vals is None else self.dir_of(vals)

    def record(self, name: str, values: Sequence[Any]) -> None:
        self.files[name] = [_json_value(v) for v in values]

    def forget(self, name: str) -> None:
        self.files.pop(name, None)

    def rename(self, old: str, new: str) -> None:
        if old in self.files:
            self.files[new] = self.files.pop(old)

    # ------------------------------------------------------------ splitting
    def split(self, table: Table) -> List[Tuple[list, np.ndarray]]:
        """Group a table's rows by partition.

        Returns ``[(values, row_indices), ...]`` sorted by partition
        directory; row order is preserved within each group (ids stay
        ascending per partition file).  ``values`` is the JSON-typed
        value list recorded in the manifest ([bucket] in hash mode).
        """
        for c in self.spec.by:
            if c not in table:
                raise KeyError(f"partition column {c!r} missing from batch")
        cols = [table.column(c) for c in self.spec.by]
        n = table.num_rows
        if n == 0:
            return []
        if self.spec.mode == "hash":
            rows = zip(*[c.to_pylist() for c in cols])
            codes = np.fromiter(
                (hash_bucket(tup, self.spec.buckets) for tup in rows),
                np.int64, count=n)
            uniq, inv = np.unique(codes, return_inverse=True)
            groups = _group_indices(inv, len(uniq))
            return [([int(u)], idx) for u, idx in zip(uniq, groups)]
        c0 = cols[0]
        if len(cols) == 1 and c0.dtype.kind == KIND_NUMERIC \
                and c0.validity is None and not c0.dtype.is_float:
            # fast path: single non-null integer column, fully vectorized
            uniq, inv = np.unique(c0.values, return_inverse=True)
            groups = _group_indices(inv, len(uniq))
            return [([u.item()], idx) for u, idx in zip(uniq, groups)]
        seen: Dict[tuple, int] = {}
        vals_out: List[list] = []
        inv = np.empty(n, np.int64)
        for i, tup in enumerate(zip(*[c.to_pylist() for c in cols])):
            code = seen.get(tup)
            if code is None:
                code = len(seen)
                seen[tup] = code
                vals_out.append([_json_value(v) for v in tup])
            inv[i] = code
        groups = _group_indices(inv, len(seen))
        out = list(zip(vals_out, groups))
        out.sort(key=lambda g: self.dir_of(g[0]))
        return out

    def keys_of_table(self, table: Table) -> List[str]:
        """Distinct partition keys a (full-width) staged batch touches."""
        return sorted({self.dir_of(v) for v, _ in self.split(table)})

    # ------------------------------------------------------------ pruning
    def _may_match_values(self, values: Sequence[Any], expr: Expr) -> bool:
        stats: Dict[str, ColumnStats] = {}
        for col, v in zip(self.spec.by, values):
            if v is None:
                stats[col] = ColumnStats(num_values=1, null_count=1)
            else:
                stats[col] = ColumnStats(num_values=1, min=v, max=v)
        return expr.prune(stats)

    def pruner(self, expr: Optional[Expr]) -> Callable[[str], bool]:
        """Per-plan closure: ``may_scan(file_name) -> bool``.

        False only when the file's recorded partition values *prove* no
        row can match ``expr``; unknown files always scan.  Candidate
        buckets (hash mode) and per-tuple verdicts (value mode) are
        computed once per plan, not per file.
        """
        if expr is None:
            return lambda name: True
        if self.spec.mode == "hash":
            cand = _candidate_buckets(expr, self.spec)
            if cand is None:
                return lambda name: True

            def may_hash(name: str) -> bool:
                vals = self.files.get(name)
                return vals is None or int(vals[0]) in cand
            return may_hash
        memo: Dict[tuple, bool] = {}

        def may_value(name: str) -> bool:
            vals = self.files.get(name)
            if vals is None:
                return True
            key = tuple(vals)
            v = memo.get(key)
            if v is None:
                v = memo[key] = self._may_match_values(vals, expr)
            return v
        return may_value
