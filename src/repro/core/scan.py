"""Scan planner: fragment/row-group pruning, projection pushdown, explain().

This is the read-path query planner behind :meth:`ParquetDB.read` (see
docs/ARCHITECTURE.md for the full data-flow diagram).  The paper's central
performance claim is that footer statistics *replace* indexes ("reduced
dependency on indexing through predicate pushdown filtering", ParquetDB
§4.5); this module is where that claim is implemented end to end:

    plan   — for each manifest file (a *fragment*), consult whole-file
             ``ColumnStats`` (min/max + bloom, merged from row-group stats)
             via ``Expr.prune``; a fragment that provably cannot contain a
             matching row is never opened for data.  Surviving fragments are
             narrowed to the row groups whose stats may match.
    prune  — inside a scanned row group the reader additionally prunes at
             page granularity (aligned page stats) before touching bytes.
    decode — only the projected-plus-filter columns of surviving pieces are
             decoded; the two-phase reader decodes filter columns first so a
             non-matching page never decodes the payload columns.
    filter — the residual ``Expr`` mask is applied to decoded rows.
    project— filter-only columns are dropped; output schema == projection.

All pruning is *sound*: ``Expr.prune`` returns False only when statistics
prove no row can match, so a planned scan is row-identical to a full scan.
Every stage records counters (:class:`ScanCounters`); ``ScanPlan.explain``
returns them as a :class:`ScanReport` so pruning decisions are observable
and testable — ``db.explain(filters=...)`` from user code.

Execution reuses the threaded readahead of the original read path
(:func:`prefetch`): fragments decode on a background thread while the
consumer drains already-decoded tables.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import (Callable, Dict, Generator, Iterable, List, Optional,
                    Sequence)

from .expressions import Expr
from .fileformat import TPQReader
from .schema import Schema
from .table import Table, concat_tables

__all__ = ["ScanCounters", "FragmentPlan", "ScanReport", "ScanPlan",
           "file_may_match", "prefetch"]


@dataclasses.dataclass
class ScanCounters:
    """Per-stage pruning/decoding counters for one scan.

    Planning fills the file/row-group fields; ``explain()`` fills the byte
    totals (a footer walk plain reads skip); execution (the reader) fills
    pages/rows/bytes-decoded.  ``rows_matched`` counts rows surviving the
    residual filter — i.e. the rows the caller actually receives.
    """
    files_total: int = 0
    files_scanned: int = 0
    files_skipped: int = 0
    row_groups_total: int = 0
    row_groups_scanned: int = 0
    row_groups_skipped: int = 0
    pages_scanned: int = 0
    pages_skipped: int = 0
    rows_scanned: int = 0
    rows_matched: int = 0
    bytes_total: int = 0        # stored bytes of every chunk in every file
    bytes_selected: int = 0     # projected columns of surviving row groups
    bytes_decoded: int = 0      # actually decoded (after page pruning)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class FragmentPlan:
    """Planning outcome for one manifest file."""
    file: str
    num_row_groups: int
    row_groups: List[int]       # surviving row-group indices
    pushdown: bool              # filter evaluated inside the reader
    pruned: bool                # whole file eliminated by stats

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ScanReport:
    """What ``explain()`` returns: counters + per-fragment decisions.

    When ``executed`` is False the counters describe the *plan* (row groups
    selected for scanning); page/row/bytes-decoded fields are zero because
    nothing was decoded.  When True, the scan ran and all counters reflect
    observed work.
    """
    counters: ScanCounters
    fragments: List[FragmentPlan]
    columns: List[str]
    filter: Optional[str]
    executed: bool

    def to_dict(self) -> dict:
        return {"counters": self.counters.to_dict(),
                "fragments": [f.to_dict() for f in self.fragments],
                "columns": list(self.columns),
                "filter": self.filter,
                "executed": self.executed}

    def __str__(self) -> str:
        c = self.counters
        lines = [
            f"ScanPlan  filter={self.filter or '<none>'}  "
            f"columns={len(self.columns)}",
            f"  files:      {c.files_scanned} scanned, "
            f"{c.files_skipped} pruned (of {c.files_total})",
            f"  row groups: {c.row_groups_scanned} scanned, "
            f"{c.row_groups_skipped} pruned (of {c.row_groups_total})",
            f"  bytes:      {c.bytes_selected} selected "
            f"of {c.bytes_total} stored",
        ]
        if self.executed:
            lines.append(
                f"  executed:   {c.pages_scanned} pages decoded "
                f"({c.pages_skipped} pruned), {c.rows_scanned} rows scanned, "
                f"{c.rows_matched} matched, {c.bytes_decoded} bytes decoded")
        else:
            lines.append("  (planned only — pass execute=True for decode "
                         "counters)")
        return "\n".join(lines)


class ScanPlan:
    """Plan + execute a pruned, projected scan over a set of TPQ files.

    Parameters
    ----------
    files:       manifest file names, in scan order.
    reader_of:   ``name -> TPQReader`` (the store injects its footer cache).
    schema:      unified dataset schema; files may each hold a subset.
    columns:     output column names (already resolved), None = all.
    filter_expr: AND-combined predicate, or None.
    cfg:         duck-typed config — ``use_threads`` / ``fragment_readahead``
                 (both ``LoadConfig`` and ``NormalizeConfig`` qualify).
    prune:       set False to disable all stats pruning (oracle/testing).
    """

    def __init__(self, files: Sequence[str],
                 reader_of: Callable[[str], TPQReader],
                 schema: Schema,
                 columns: Optional[Sequence[str]] = None,
                 filter_expr: Optional[Expr] = None,
                 cfg=None, prune: bool = True):
        self._files = list(files)
        self._reader_of = reader_of
        self._schema = schema
        self._expr = filter_expr
        self._prune = prune
        self._use_threads = bool(getattr(cfg, "use_threads", True))
        self._readahead = int(getattr(cfg, "fragment_readahead", 4))
        out_names = list(columns) if columns is not None else schema.names
        self._out_schema = schema.select(out_names)
        self._filter_cols = [c for c in dict.fromkeys(
            filter_expr.columns() if filter_expr is not None else [])]
        read_names = out_names + [c for c in self._filter_cols
                                  if c in schema and c not in out_names]
        self._read_schema = schema.select(read_names)
        self._fragments: Optional[List[FragmentPlan]] = None
        self._plan_counters: Optional[ScanCounters] = None
        self._byte_totals: Optional[tuple] = None
        self.last_counters: Optional[ScanCounters] = None

    # ------------------------------------------------------------------ plan
    def fragments(self) -> List[FragmentPlan]:
        self._build()
        return list(self._fragments)

    def _build(self) -> None:
        """Footer-only planning: no data page is read here."""
        if self._fragments is not None:
            return
        c = ScanCounters()
        frags: List[FragmentPlan] = []
        for fn in self._files:
            rd = self._reader_of(fn)
            n = rd.num_row_groups
            have = set(rd.schema.names)
            c.files_total += 1
            c.row_groups_total += n
            # pushdown is only sound when the file has every filter column;
            # otherwise missing columns align to null *after* decode and the
            # residual filter runs there (null semantics differ per Expr).
            # prune=False forces the residual path: full decode, no stats.
            pushdown = self._prune and self._expr is not None and all(
                col in have for col in self._filter_cols)
            selected = list(range(n))
            if pushdown:
                if not self._expr.prune(rd.file_stats()):
                    selected = []          # fragment pruned outright
                else:
                    selected = [i for i in range(n)
                                if self._expr.prune(rd.row_group_stats(i))]
            c.row_groups_skipped += n - len(selected)
            if selected:
                c.files_scanned += 1
            else:
                c.files_skipped += 1
            frags.append(FragmentPlan(fn, n, selected, pushdown,
                                      pruned=not selected))
        self._fragments, self._plan_counters = frags, c

    # --------------------------------------------------------------- execute
    def execute(self, batch_size: Optional[int] = None,
                counters: Optional[ScanCounters] = None
                ) -> Generator[Table, None, None]:
        """Yield result tables; decoding runs on a readahead thread.

        Counters accumulate into ``counters`` (or a fresh copy of the plan
        counters, exposed as ``self.last_counters``).
        """
        self._build()
        if counters is None:
            counters = dataclasses.replace(self._plan_counters)
        self.last_counters = counters

        def pieces() -> Generator[Table, None, None]:
            for frag in self._fragments:
                if frag.row_groups:
                    yield from self._fragment_tables(frag, counters)

        stream = (prefetch(pieces(), self._readahead)
                  if self._use_threads else pieces())
        if batch_size is None:
            yield from stream
        else:
            yield from rechunk(stream, batch_size)

    def _fragment_tables(self, frag: FragmentPlan, counters: ScanCounters
                         ) -> Generator[Table, None, None]:
        rd = self._reader_of(frag.file)
        have = set(rd.schema.names)
        cols_here = [n for n in self._read_schema.names if n in have]
        pushdown = self._expr if frag.pushdown else None
        for t in rd.iter_row_group_tables(cols_here, pushdown,
                                          row_groups=frag.row_groups,
                                          counters=counters):
            t = t.align_to_schema(self._read_schema)
            if self._expr is not None and pushdown is None:
                mask = self._expr.evaluate(t)
                if not mask.all():
                    t = t.filter_mask(mask)
            if t.num_rows:
                counters.rows_matched += t.num_rows
                yield t.select(self._out_schema.names)

    def _bytes_accounting(self) -> tuple:
        """(bytes_total, bytes_selected) — footer walk, lazy: explain() only.

        Plain reads skip this; it touches every page dict of every file.
        """
        if self._byte_totals is None:
            self._build()
            total = selected = 0
            for frag in self._fragments:
                rd = self._reader_of(frag.file)
                have = set(rd.schema.names)
                cols_here = [x for x in self._read_schema.names if x in have]
                total += sum(rd.read_row_group_bytes(i)
                             for i in range(frag.num_row_groups))
                selected += sum(rd.read_row_group_bytes(i, cols_here)
                                for i in frag.row_groups)
            self._byte_totals = (total, selected)
        return self._byte_totals

    # --------------------------------------------------------------- explain
    def explain(self, execute: bool = False) -> ScanReport:
        """Report pruning decisions; optionally run the scan for decode stats."""
        self._build()
        c = dataclasses.replace(self._plan_counters)
        c.bytes_total, c.bytes_selected = self._bytes_accounting()
        if execute:
            for _ in self.execute(counters=c):
                pass
        else:
            c.row_groups_scanned = c.row_groups_total - c.row_groups_skipped
        return ScanReport(counters=c, fragments=list(self._fragments),
                          columns=self._out_schema.names,
                          filter=repr(self._expr) if self._expr is not None
                          else None,
                          executed=execute)


# ---------------------------------------------------------------------------
# shared helpers (also used by the write paths in store.py)
# ---------------------------------------------------------------------------
def file_may_match(rd: TPQReader, expr: Expr) -> bool:
    """Fragment-level pruning check: can this file contain a matching row?

    Conservative (True = must read).  Used by ``update``/``delete`` to skip
    rewriting files that provably hold no affected rows.  Checks merged
    whole-file stats first (cheap reject), then per-row-group stats, which
    are strictly stronger: merging widens min/max ranges and drops blooms of
    mismatched sizes.
    """
    if not all(c in rd.schema for c in expr.columns()):
        return True
    if not expr.prune(rd.file_stats()):
        return False
    return any(expr.prune(rd.row_group_stats(i))
               for i in range(rd.num_row_groups))


def rechunk(stream: Iterable[Table], batch_size: int
            ) -> Generator[Table, None, None]:
    """Re-slice a table stream into exact ``batch_size``-row batches."""
    buf: List[Table] = []
    count = 0
    for t in stream:
        while t.num_rows:
            take = min(batch_size - count, t.num_rows)
            buf.append(t.slice(0, take))
            t = t.slice(take, t.num_rows)
            count += take
            if count == batch_size:
                yield concat_tables(buf)
                buf, count = [], 0
    if buf:
        yield concat_tables(buf)


def prefetch(gen: Iterable[Table], depth: int) -> Generator[Table, None, None]:
    """Background-thread readahead (LoadConfig.fragment_readahead)."""
    q: "queue.Queue" = queue.Queue(maxsize=max(depth, 1))
    DONE = object()

    def worker():
        try:
            for item in gen:
                q.put(item)
            q.put(DONE)
        except BaseException as e:  # propagate
            q.put(e)

    th = threading.Thread(target=worker, daemon=True)
    th.start()
    while True:
        item = q.get()
        if item is DONE:
            return
        if isinstance(item, BaseException):
            raise item
        yield item
