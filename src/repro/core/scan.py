"""Scan planner: fragment/row-group pruning, projection pushdown, explain().

This is the read-path query planner behind :meth:`ParquetDB.read` (see
docs/ARCHITECTURE.md for the full data-flow diagram).  The paper's central
performance claim is that footer statistics *replace* indexes ("reduced
dependency on indexing through predicate pushdown filtering", ParquetDB
§4.5); this module is where that claim is implemented end to end:

    plan   — for each manifest file (a *fragment*), consult whole-file
             ``ColumnStats`` (min/max + bloom, merged from row-group stats)
             via ``Expr.prune``; a fragment that provably cannot contain a
             matching row is never opened for data.  Surviving fragments are
             narrowed to the row groups whose stats may match.
    prune  — inside a scanned row group the reader additionally prunes at
             page granularity (aligned page stats) before touching bytes.
    decode — only the projected-plus-filter columns of surviving pieces are
             decoded; the two-phase reader decodes filter columns first so a
             non-matching page never decodes the payload columns.
    filter — the residual ``Expr`` mask is applied to decoded rows.
    project— filter-only columns are dropped; output schema == projection.

All pruning is *sound*: ``Expr.prune`` returns False only when statistics
prove no row can match, so a planned scan is row-identical to a full scan.
Every stage records counters (:class:`ScanCounters`); ``ScanPlan.explain``
returns them as a :class:`ScanReport` so pruning decisions are observable
and testable — ``db.explain(filters=...)`` from user code.

**Parallel execution.**  Surviving fragments are split into *morsels* —
contiguous runs of row groups capped at ``MORSEL_ROWS`` rows — and decoded
on a shared, process-wide :class:`~concurrent.futures.ThreadPoolExecutor`
(work-stealing: idle workers pull the next morsel from the shared queue).
The pool is sized from ``LoadConfig.num_threads`` (default
``os.cpu_count()``); each worker obtains its own per-thread ``TPQReader``
handle over the shared file mapping (see ``store._get_reader``), decodes
its morsel into Tables, and records work into a **morsel-local**
:class:`ScanCounters`.  The consumer merges results with an
order-preserving bounded merge: morsel outputs are yielded strictly in
plan order (so ``read()`` output is byte-identical to the serial scan,
order included) and at most ``num_threads + fragment_readahead`` morsels
are in flight, bounding memory.  Counters are merged single-threaded in
the consumer (:meth:`ScanCounters.merge_from`), so no increment is ever
lost to a data race.  ``num_threads=1`` (or ``use_threads=False``) falls
back to the serial path with the classic readahead thread
(:func:`prefetch`).

**Process executor.**  Threads only overlap while the GIL is released
(codec decompression); raw and entropy-coded pages decode in pure numpy
*under* the GIL, where a thread pool convoys.  ``LoadConfig.executor=
"process"`` decodes morsels on a shared spawn-context
:class:`~concurrent.futures.ProcessPoolExecutor` instead: workers run the
*decode half* of a morsel (prune → pushdown → decode) against their own
stat-validated reader cache and ship results back through one
shared-memory segment per morsel (:mod:`repro.core.shm`, pickle-5
out-of-band buffers); the parent runs the *finish half* (overlay,
residual filter, ``map_fn``) and the same order-preserving bounded merge,
so output is byte-identical to the serial scan.  The default
``executor=None`` is AUTO: the footer's codec split picks threads for
codec-compressed read sets and processes for GIL-bound ones big enough to
amortize worker spawn (``PROCESS_MIN_ROWS``).

**Merge-on-read deltas.**  A manifest may carry a chain of delta files
(:class:`repro.core.transactions.DeltaEntry`) — *upsert* files holding
full-width replacement rows and *tombstone* files holding deleted ids.
:class:`DeltaOverlay` resolves the chain once per scan (last commit wins
per id) and the planner overlays it on the base fragments **in place**:

  - a base row whose id has a live upsert is substituted with the upsert
    row at its original position (row order is preserved, and the residual
    filter sees the *merged* values);
  - a base row whose final state is a tombstone is dropped;
  - fragments whose id range can contain an upserted row lose stats
    pruning and reader pushdown (their stored statistics describe stale
    values), are decoded fully, and are filtered after substitution —
    soundness over speed.  Compaction folds the chain back into base files
    and restores full pruning; ``maintenance_stats()`` reports the decay.

Tombstones never disable pruning: dropping rows commutes with filtering,
so a fragment shadowed only by deletes keeps its pushdown.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import multiprocessing
import os
import queue
import threading
import warnings
from concurrent.futures import (BrokenExecutor, ProcessPoolExecutor,
                                ThreadPoolExecutor)
from typing import (Any, Callable, Dict, Generator, Iterable, List, Optional,
                    Sequence, Tuple)

import numpy as np

from . import shm
from .expressions import Expr
from .fileformat import TPQReader, page_codec_split
from .integrity import CorruptFooterError, IntegrityError, with_read_retries
from .schema import ID_COLUMN, Schema
from .table import Table, concat_tables
from .transactions import DELTA_TOMBSTONE, DeltaEntry

__all__ = ["ScanCounters", "FragmentPlan", "ScanReport", "ScanPlan",
           "DeltaOverlay", "MorselBudget", "file_may_match", "prefetch",
           "scan_pool", "process_scan_pool", "resolve_num_threads",
           "MORSEL_ROWS", "PROCESS_MIN_ROWS"]

# Target rows per morsel: small enough that a handful of fragments yields
# enough parallelism, large enough that per-task overhead (submit, counter
# merge) stays invisible next to decode cost.  A row group larger than the
# target is one morsel (morsels never split a row group: page pruning,
# two-phase decode and selection vectors all operate per row group).
MORSEL_ROWS = 65_536

# AUTO executor selection sends GIL-bound scans to worker *processes* only
# past this many planned rows: below it the spawn + result-shipping constant
# outweighs what the GIL convoy costs.
PROCESS_MIN_ROWS = 200_000

# multiprocessing start method for the scan workers.  "spawn" by default:
# fork would duplicate whatever threads/jax state the parent holds (a
# classic deadlock with the shared thread pool warm); override for
# experiments via the environment.
ENV_MP_CONTEXT = "REPRO_SCAN_MP_CONTEXT"

_POOL_LOCK = threading.Lock()
_POOL: Optional[ThreadPoolExecutor] = None
_POOL_WORKERS = 0

_PPOOL_LOCK = threading.Lock()
_PPOOL: Optional[ProcessPoolExecutor] = None
_PPOOL_WORKERS = 0


def resolve_num_threads(cfg) -> int:
    """Worker count for a scan config (duck-typed, like the readahead knob).

    ``use_threads=False`` forces 1; ``num_threads=None`` (the default)
    means ``os.cpu_count()``.  Always >= 1.
    """
    if not getattr(cfg, "use_threads", True):
        return 1
    nt = getattr(cfg, "num_threads", None)
    if nt is None:
        nt = os.cpu_count() or 1
    return max(1, int(nt))


class MorselBudget:
    """Cooperative cap on in-flight morsels shared across concurrent scans.

    Attach one instance to several ``LoadConfig``s (``morsel_budget=...``)
    and every scan using them charges one permit per *submitted* morsel,
    releasing it when the morsel's result is consumed.  With the budget
    exhausted, further submission **blocks** — concurrent scans throttle
    each other to a bounded total of decoded-but-unconsumed work instead
    of racing the shared pool into memory bloat.  This is the
    backpressure primitive behind the serving tier's admission control.

    Progress guarantee (no deadlock): every executor loop follows the
    discipline *block for a permit only while holding none* — refills of
    an already-primed window use :meth:`try_acquire` and simply skip the
    refill when the budget is dry (the scan then drains its own in-flight
    morsels, releasing as it goes).  So any charged permit is always held
    by a scan that is actively consuming, and a scan blocked in
    :meth:`acquire` holds nothing anyone is waiting on.  ``limit >= 1`` is
    enforced, so even a budget of one serializes morsels rather than
    stalling them.

    Counters (read via :meth:`stats`): ``in_flight`` (currently charged),
    ``peak_in_flight``, ``total_acquired`` and ``waits`` (acquisitions
    that blocked or were denied — the saturation signal a server sheds
    on).
    """

    def __init__(self, limit: int):
        if int(limit) < 1:
            raise ValueError(f"morsel budget must be >= 1, got {limit}")
        self.limit = int(limit)
        self._cv = threading.Condition()
        self.in_flight = 0
        self.peak_in_flight = 0
        self.total_acquired = 0
        self.waits = 0

    def acquire(self) -> None:
        """Charge one permit, blocking while the budget is exhausted.
        Callers must hold no other permit (see the class docstring)."""
        with self._cv:
            if self.in_flight >= self.limit:
                self.waits += 1
                while self.in_flight >= self.limit:
                    self._cv.wait()
            self._charge()

    def try_acquire(self) -> bool:
        """Charge one permit if available; never blocks.  A ``False``
        counts toward ``waits`` — denial is the same saturation signal."""
        with self._cv:
            if self.in_flight >= self.limit:
                self.waits += 1
                return False
            self._charge()
            return True

    def _charge(self) -> None:
        self.in_flight += 1
        self.total_acquired += 1
        if self.in_flight > self.peak_in_flight:
            self.peak_in_flight = self.in_flight

    def release(self) -> None:
        """Return one permit and wake one blocked acquirer."""
        with self._cv:
            self.in_flight -= 1
            self._cv.notify()

    @property
    def saturated(self) -> bool:
        """True while every permit is charged (admission-control signal)."""
        with self._cv:
            return self.in_flight >= self.limit

    def stats(self) -> Dict[str, int]:
        with self._cv:
            return {"limit": self.limit,
                    "in_flight": self.in_flight,
                    "peak_in_flight": self.peak_in_flight,
                    "total_acquired": self.total_acquired,
                    "waits": self.waits}


def scan_pool(num_threads: int) -> ThreadPoolExecutor:
    """The shared scan/compaction worker pool, grown to >= ``num_threads``.

    One process-wide pool serves every concurrent scan (morsels from
    different scans interleave on the same workers — work stealing across
    queries, not just within one).  Workers never submit work back to the
    pool, so sharing cannot deadlock.  The pool only ever grows: when a
    larger size is requested a bigger executor replaces the global slot,
    but the old one is **not** shut down — an in-flight scan that cached
    it keeps submitting refill morsels to it until that scan completes
    (shutting it down would make those submits raise).  Abandoned
    executors idle until interpreter exit; growth is monotonic, so at
    most a handful ever exist.
    """
    global _POOL, _POOL_WORKERS
    with _POOL_LOCK:
        if _POOL is None or _POOL_WORKERS < num_threads:
            _POOL = ThreadPoolExecutor(max_workers=num_threads,
                                       thread_name_prefix="tpq-scan")
            _POOL_WORKERS = num_threads
    return _POOL


def _ensure_child_import_path() -> None:
    """Make ``repro`` importable in spawned workers.

    Spawn children resolve :func:`_process_morsel` by qualified name, so the
    package root must be on *their* ``sys.path``; when the parent imported
    it off a source tree (tests, benchmarks) rather than site-packages, the
    child only inherits that via ``PYTHONPATH``.  Prepending is idempotent.
    """
    root = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    pp = os.environ.get("PYTHONPATH", "")
    parts = pp.split(os.pathsep) if pp else []
    if root not in parts:
        os.environ["PYTHONPATH"] = os.pathsep.join([root] + parts)


def process_scan_pool(num_workers: int) -> ProcessPoolExecutor:
    """The shared morsel worker *process* pool, grown to >= ``num_workers``.

    Same grow-only contract as :func:`scan_pool` (an in-flight scan that
    cached a smaller pool keeps it; growth is monotonic so at most a
    handful ever exist), but workers are spawn-context processes — each
    decodes with its own GIL, which is the whole point: entropy-coded and
    raw pages decode in pure Python/numpy and convoy on a thread pool.
    Workers are started lazily by the executor on first submit and are
    reaped by ``concurrent.futures``'s atexit hook, so a completed scan
    leaves idle workers, never orphans.
    """
    global _PPOOL, _PPOOL_WORKERS
    with _PPOOL_LOCK:
        # a pool whose workers died (BrokenProcessPool) rejects every
        # future submit — replace it instead of caching the corpse
        broken = _PPOOL is not None and getattr(_PPOOL, "_broken", False)
        if _PPOOL is None or broken or _PPOOL_WORKERS < num_workers:
            _ensure_child_import_path()
            ctx = multiprocessing.get_context(
                os.environ.get(ENV_MP_CONTEXT, "spawn"))
            _PPOOL = ProcessPoolExecutor(max_workers=num_workers,
                                         mp_context=ctx)
            _PPOOL_WORKERS = num_workers
    return _PPOOL


def _warn_broken_pool(state: dict) -> None:
    """Flag a scan as degraded (once) when its process pool dies."""
    if not state["broken"]:
        state["broken"] = True
        warnings.warn(
            "scan process pool broke mid-scan (worker died — commonly a "
            "script using executor='process' without an "
            "`if __name__ == '__main__':` guard under the spawn start "
            "method); finishing this scan with inline decode",
            RuntimeWarning, stacklevel=3)


# Per-process reader cache for morsel workers, validated by (size,
# mtime_ns): data files are immutable-by-name within a dataset generation,
# but a worker can outlive many scans, so stale paths must re-open.
_WORKER_READERS: Dict[str, tuple] = {}
_WORKER_READERS_MAX = 64


def _worker_reader(path: str) -> TPQReader:
    st = os.stat(path)
    sig = (st.st_size, st.st_mtime_ns)
    hit = _WORKER_READERS.get(path)
    if hit is None or hit[0] != sig:
        hit = (sig, with_read_retries(lambda: TPQReader(path), path))
        _WORKER_READERS[path] = hit
        if len(_WORKER_READERS) > _WORKER_READERS_MAX:
            _WORKER_READERS.pop(next(iter(_WORKER_READERS)))
    return hit[1]


# Fault-injection switch for the worker-crash tests: module-level hooks do
# not survive the spawn boundary, so the kill order rides the environment
# (inherited by pool workers).  A worker seeing it dies before decoding —
# deterministically producing the BrokenProcessPool path.
ENV_TEST_KILL_WORKER = "REPRO_TEST_KILL_WORKER"


def _process_morsel(path: str, row_groups: tuple, columns: tuple,
                    expr: Optional[Expr],
                    verify: Optional[str] = None) -> shm.Envelope:
    """Decode one morsel inside a worker process (the *decode half*).

    Runs page pruning, pushdown filtering and decode exactly like a thread
    worker; overlay substitution, residual filters and ``map_fn`` stay in
    the parent (closures and overlay state don't cross a pickle boundary).
    The decoded tables + morsel-local counters ship back through
    :mod:`repro.core.shm` as one out-of-band envelope.  ``verify`` is the
    scan's ``LoadConfig.verify`` mode; a :class:`CorruptPageError` raised
    here pickles back to the parent with its coordinates intact.
    """
    if os.environ.get(ENV_TEST_KILL_WORKER):
        os._exit(1)
    local = ScanCounters()
    rd = _worker_reader(path)
    tables = list(rd.iter_row_group_tables(list(columns), expr,
                                           row_groups=list(row_groups),
                                           counters=local, verify=verify))
    return shm.pack((tables, local))


@dataclasses.dataclass
class ScanCounters:
    """Per-stage pruning/decoding counters for one scan.

    Planning fills the file/row-group fields; ``explain()`` fills the byte
    totals (a footer walk plain reads skip); execution (the reader) fills
    pages/rows/bytes-decoded.  ``rows_matched`` counts rows surviving the
    residual filter — i.e. the rows the caller actually receives.
    """
    files_total: int = 0
    files_scanned: int = 0
    files_skipped: int = 0
    # hive partitioning (planning fills these from manifest metadata):
    # a *pruned* partition was eliminated before any footer was opened —
    # partition-pruned files count into files_skipped but their row groups
    # are unknown (footer never read) and excluded from row_groups_total.
    # A partition whose every file was pruned by footer stats instead
    # counts in neither pruned nor scanned.
    partitions_total: int = 0
    partitions_scanned: int = 0
    partitions_pruned: int = 0
    row_groups_total: int = 0
    row_groups_scanned: int = 0
    row_groups_skipped: int = 0
    pages_scanned: int = 0
    pages_skipped: int = 0
    rows_scanned: int = 0
    rows_matched: int = 0
    bytes_total: int = 0        # stored bytes of every chunk in every file
    bytes_selected: int = 0     # projected columns of surviving row groups
    bytes_decoded: int = 0      # actually decoded (after page pruning)
    # late materialization (two-phase reader): payload rows the selection
    # vector kept out of result batches, and the bytes of their values —
    # var-len bytes are never copied out of the page buffer; fixed-width
    # pages decode to a transient and only the selection is kept
    rows_skipped_late: int = 0
    bytes_saved_late: int = 0
    # merge-on-read delta work (planning fills the first three from the
    # delta chain; execution fills applied/shadowed as rows are merged)
    delta_files: int = 0            # delta files in the overlaid chain
    delta_upsert_rows: int = 0      # rows staged in upsert files
    delta_tombstone_rows: int = 0   # ids staged in tombstone files
    delta_rows_applied: int = 0     # base rows substituted with upsert rows
    rows_shadowed: int = 0          # base rows dropped by tombstones
    # aggregate pushdown (AggregatePlan): row groups whose contribution was
    # answered from footer statistics alone, and the stored bytes of their
    # read set that were therefore never decoded
    groups_answered_by_stats: int = 0
    bytes_skipped_agg: int = 0
    # integrity / fault tolerance (LoadConfig.verify / on_corruption):
    # delta files dropped from the overlay because they failed
    # verification (on_corruption="quarantine" only — base files raise),
    # process-pool rebuilds after a worker crash (at most one per scan),
    # and morsels that fell back to inline decode (broken pool or a
    # compaction race GC'ing a planned file)
    files_quarantined: int = 0
    pool_rebuilds: int = 0
    morsels_decoded_inline: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def merge_from(self, other: "ScanCounters") -> None:
        """Fold another counter set into this one (all fields are sums).

        This is the single-threaded merge point of the parallel scan:
        every worker increments a morsel-local ``ScanCounters`` and the
        consumer merges, so no ``+=`` ever races another thread.
        """
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))


@dataclasses.dataclass
class FragmentPlan:
    """Planning outcome for one manifest file."""
    file: str
    num_row_groups: int
    row_groups: List[int]       # surviving row-group indices
    pushdown: bool              # filter evaluated inside the reader
    pruned: bool                # whole file eliminated by stats
    delta_overlap: bool = False  # may hold upserted rows: full decode
    partition: Optional[str] = None   # hive partition key ("a=1/b=x")
    # eliminated from manifest metadata alone — footer never opened, so
    # num_row_groups is 0 (unknown) and byte accounting skips the file
    partition_pruned: bool = False

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ScanReport:
    """What ``explain()`` returns: counters + per-fragment decisions.

    When ``executed`` is False the counters describe the *plan* (row groups
    selected for scanning); page/row/bytes-decoded fields are zero because
    nothing was decoded.  When True, the scan ran and all counters reflect
    observed work.
    """
    counters: ScanCounters
    fragments: List[FragmentPlan]
    columns: List[str]
    filter: Optional[str]
    executed: bool

    def to_dict(self) -> dict:
        return {"counters": self.counters.to_dict(),
                "fragments": [f.to_dict() for f in self.fragments],
                "columns": list(self.columns),
                "filter": self.filter,
                "executed": self.executed}

    def __str__(self) -> str:
        c = self.counters
        lines = [
            f"ScanPlan  filter={self.filter or '<none>'}  "
            f"columns={len(self.columns)}",
            f"  files:      {c.files_scanned} scanned, "
            f"{c.files_skipped} pruned (of {c.files_total})",
            f"  row groups: {c.row_groups_scanned} scanned, "
            f"{c.row_groups_skipped} pruned (of {c.row_groups_total})",
            f"  bytes:      {c.bytes_selected} selected "
            f"of {c.bytes_total} stored",
        ]
        if c.partitions_total:
            lines.append(
                f"  partitions: {c.partitions_scanned} scanned, "
                f"{c.partitions_pruned} pruned from manifest metadata "
                f"(of {c.partitions_total})")
            lines.extend(self._partition_tree())
        if c.delta_files:
            d = (f"  deltas:     {c.delta_files} files "
                 f"({c.delta_upsert_rows} upsert rows, "
                 f"{c.delta_tombstone_rows} tombstoned ids)")
            if self.executed:
                d += (f"; {c.delta_rows_applied} applied, "
                      f"{c.rows_shadowed} rows dropped")
            lines.append(d)
        if c.groups_answered_by_stats or c.bytes_skipped_agg:
            lines.append(
                f"  aggregate:  {c.groups_answered_by_stats} row groups "
                f"answered from footer stats, {c.bytes_skipped_agg} stored "
                f"bytes never decoded")
        if c.files_quarantined:
            lines.append(
                f"  integrity:  {c.files_quarantined} corrupt delta "
                f"file(s) QUARANTINED (serving base + surviving deltas)")
        if c.pool_rebuilds or c.morsels_decoded_inline:
            lines.append(
                f"  degraded:   {c.pool_rebuilds} pool rebuild(s), "
                f"{c.morsels_decoded_inline} morsel(s) decoded inline")
        if self.executed:
            lines.append(
                f"  executed:   {c.pages_scanned} pages decoded "
                f"({c.pages_skipped} pruned), {c.rows_scanned} rows scanned, "
                f"{c.rows_matched} matched, {c.bytes_decoded} bytes decoded")
            if c.rows_skipped_late or c.bytes_saved_late:
                lines.append(
                    f"  late mat.:  {c.rows_skipped_late} payload rows "
                    f"skipped, {c.bytes_saved_late} value bytes kept out "
                    f"of result batches")
        else:
            lines.append("  (planned only — pass execute=True for decode "
                         "counters)")
        return "\n".join(lines)

    _TREE_MAX = 12  # partition-tree lines rendered before eliding

    def _partition_tree(self) -> List[str]:
        """One line per partition: files scanned / pruned, pruning source."""
        parts: Dict[str, List[FragmentPlan]] = {}
        for f in self.fragments:
            if f.partition is not None:
                parts.setdefault(f.partition, []).append(f)
        out = []
        for key in sorted(parts):
            fs = parts[key]
            if all(f.partition_pruned for f in fs):
                verdict = "pruned (manifest, 0 footers opened)"
            elif not any(f.row_groups for f in fs):
                verdict = "pruned (footer stats)"
            else:
                scanned = sum(1 for f in fs if f.row_groups)
                verdict = f"{scanned}/{len(fs)} files scanned"
            out.append(f"    {key}/  {verdict}")
            if len(out) == self._TREE_MAX and len(parts) > self._TREE_MAX:
                out.append(f"    … and {len(parts) - self._TREE_MAX} "
                           f"more partitions")
                break
        return out


class DeltaOverlay:
    """Resolved merge-on-read state of a delta chain, for one scan snapshot.

    Built once per scan from the manifest's delta entries, **in commit
    order**: for every id touched by the chain, the last delta wins —

      - final state *upsert*  → the id is in ``upsert_ids`` and its
        replacement row (aligned to the scan's read schema) is in
        ``upserts``;
      - final state *tombstone* → the id is in ``dead_ids``.

    ``apply`` overlays a decoded base-fragment table: upserted rows are
    substituted in place (row order preserved), tombstoned rows dropped.
    Upserts only take effect where their base row is scanned, which is what
    makes overlaying a *subset* of base files (compaction's merge set)
    correct: rows of untouched files stay untouched.

    ``on_corruption="quarantine"`` drops a delta file that fails
    verification (typed :class:`~repro.core.integrity.IntegrityError` on
    open or read) from the overlay instead of raising: the scan serves
    base + surviving deltas, a warning names the file, and
    ``self.quarantined`` records ``(name, error)`` pairs for the scan
    counters.  The default ``"raise"`` propagates — corruption is never
    absorbed silently either way.
    """

    def __init__(self, entries: Sequence[DeltaEntry],
                 reader_of: Callable[[str], TPQReader],
                 read_schema: Schema, on_corruption: str = "raise"):
        if on_corruption not in ("raise", "quarantine"):
            raise ValueError(f"unknown on_corruption {on_corruption!r} "
                             "(expected 'raise' or 'quarantine')")
        self.entries = list(entries)
        self.quarantined: List[Tuple[str, str]] = []
        self.upsert_rows_total = 0     # rows staged across all upsert files
        self.tombstone_rows_total = 0  # ids staged across all tombstone files
        ids_parts: List[np.ndarray] = []
        pos_parts: List[np.ndarray] = []
        row_parts: List[np.ndarray] = []
        up_tables: List[Table] = []
        up_offset = 0
        for pos, e in enumerate(self.entries):
            # every read of this entry happens before any overlay state
            # mutates, so quarantining a file that fails mid-read leaves
            # no half-applied residue from it
            try:
                rd = reader_of(e.name)
                if rd.file_kind != e.kind:
                    raise CorruptFooterError(
                        e.name, f"footer kind {rd.file_kind!r} does not "
                        f"match manifest kind {e.kind!r}")
                if e.kind == DELTA_TOMBSTONE:
                    t = None
                    ids = rd.read(columns=[ID_COLUMN]).column(ID_COLUMN) \
                            .values.astype(np.int64, copy=False)
                else:
                    cols = [n for n in read_schema.names if n in rd.schema]
                    t = rd.read(columns=cols).align_to_schema(read_schema)
                    ids = t.column(ID_COLUMN).values \
                           .astype(np.int64, copy=False)
            except IntegrityError as err:
                if on_corruption != "quarantine":
                    raise
                warnings.warn(
                    f"quarantining corrupt delta file {e.name}: {err} "
                    "(scan serves base + surviving deltas)",
                    RuntimeWarning, stacklevel=2)
                self.quarantined.append((e.name, str(err)))
                continue
            if t is None:
                self.tombstone_rows_total += len(ids)
                rows = np.full(len(ids), -1, np.int64)
            else:
                self.upsert_rows_total += len(ids)
                rows = up_offset + np.arange(len(ids), dtype=np.int64)
                up_tables.append(t)
                up_offset += len(ids)
            ids_parts.append(ids)
            pos_parts.append(np.full(len(ids), pos, np.int64))
            row_parts.append(rows)
        if ids_parts:
            ids = np.concatenate(ids_parts)
            pos = np.concatenate(pos_parts)
            rows = np.concatenate(row_parts)
            order = np.lexsort((pos, ids))   # by id, then commit position
            ids, rows = ids[order], rows[order]
            last = np.ones(len(ids), bool)   # last occurrence per id wins
            last[:-1] = ids[1:] != ids[:-1]
            self.shadow_ids = ids[last]      # sorted, unique
            win_rows = rows[last]
        else:
            self.shadow_ids = np.empty(0, np.int64)
            win_rows = np.empty(0, np.int64)
        live = win_rows >= 0
        self.upsert_ids = self.shadow_ids[live]   # sorted
        self.dead_ids = self.shadow_ids[~live]    # sorted
        if len(self.upsert_ids):
            all_up = (up_tables[0] if len(up_tables) == 1
                      else concat_tables(up_tables).align_to_schema(read_schema))
            self.upserts: Optional[Table] = all_up.take(win_rows[live])
        else:
            self.upserts = None

    @property
    def has_work(self) -> bool:
        return len(self.shadow_ids) > 0

    @staticmethod
    def _member_mask(sorted_arr: np.ndarray, ids: np.ndarray) -> np.ndarray:
        if not len(sorted_arr) or not len(ids):
            return np.zeros(len(ids), bool)
        p = np.clip(np.searchsorted(sorted_arr, ids), 0, len(sorted_arr) - 1)
        return sorted_arr[p] == ids

    def upsert_pos(self, ids: np.ndarray) -> np.ndarray:
        """Per id: row index into ``upserts``, or -1 if not upserted."""
        out = np.full(len(ids), -1, np.int64)
        if len(self.upsert_ids) and len(ids):
            p = np.clip(np.searchsorted(self.upsert_ids, ids), 0,
                        len(self.upsert_ids) - 1)
            hit = self.upsert_ids[p] == ids
            out[hit] = p[hit]
        return out

    def file_overlaps_upserts(self, rd: TPQReader) -> bool:
        """Can this base file contain a row replaced by a live upsert?

        Exact against the file's id [min, max] (ids are unique across base
        files, so range containment of any upsert id is the right test);
        conservative True when the stats are missing.
        """
        if not len(self.upsert_ids):
            return False
        st = rd.file_stats().get(ID_COLUMN)
        if st is None or st.min is None:
            return True
        lo = np.searchsorted(self.upsert_ids, st.min, "left")
        hi = np.searchsorted(self.upsert_ids, st.max, "right")
        return bool(hi > lo)

    def apply(self, t: Table, counters: ScanCounters) -> Table:
        """Overlay one decoded base table: substitute upserts, drop dead."""
        ids = t.column(ID_COLUMN).values
        up = self.upsert_pos(ids)
        upd = up >= 0
        if upd.any():
            n = t.num_rows
            need = up[upd]  # only the upsert rows this batch references
            sel = np.arange(n, dtype=np.int64)
            sel[upd] = n + np.arange(len(need), dtype=np.int64)
            t = concat_tables([t, self.upserts.take(need)]).take(sel)
            counters.delta_rows_applied += int(len(need))
        dead = self._member_mask(self.dead_ids, ids)
        if dead.any():
            counters.rows_shadowed += int(dead.sum())
            t = t.filter_mask(~dead)
        return t


class ScanPlan:
    """Plan + execute a pruned, projected scan over a set of TPQ files.

    Parameters
    ----------
    files:       manifest file names, in scan order.
    reader_of:   ``name -> TPQReader`` (the store injects its footer cache).
    schema:      unified dataset schema; files may each hold a subset.
    columns:     output column names (already resolved), None = all.
    filter_expr: AND-combined predicate, or None.
    cfg:         duck-typed config — ``use_threads`` / ``num_threads`` /
                 ``fragment_readahead`` (both ``LoadConfig`` and
                 ``NormalizeConfig`` qualify).
    prune:       set False to disable all stats pruning (oracle/testing).
    deltas:      merge-on-read chain (manifest ``DeltaEntry`` list, commit
                 order) to overlay on the base files; empty = plain scan.
    overlay:     an already-resolved :class:`DeltaOverlay` for ``deltas``
                 to reuse (compaction resolves the chain once for
                 affected-file selection and passes it through); its read
                 schema must cover this plan's read set.
    restrict:    optional ``{file: row-group indices}`` cap — planning
                 intersects its stats-selected row groups with this map
                 (files absent from the map scan nothing).  The aggregate
                 layer uses it to decode only the *partial* row groups
                 that footer statistics could not answer.
    partitioning: the dataset's :class:`~repro.core.partition.Partitioning`
                 (or None).  Enables manifest-level partition pruning —
                 whole partitions eliminated *before any footer is
                 opened* — and, when several partitions survive, the
                 order-preserving id merge that keeps the output
                 byte-identical to an unpartitioned scan (each create
                 splits one ascending id range across partitions, so
                 partition streams must be re-interleaved by id).
                 Partition pruning is disabled while the chain holds
                 upsert deltas: an upsert carries *new* non-partition
                 values the recorded partition values cannot bound
                 (tombstones are fine — dropping commutes with
                 filtering).  Compaction folds the chain and restores it.
    ordered:     set False when the caller does not need globally
                 id-ordered output (aggregation): skips the merge and the
                 implied id-column read.
    """

    def __init__(self, files: Sequence[str],
                 reader_of: Callable[[str], TPQReader],
                 schema: Schema,
                 columns: Optional[Sequence[str]] = None,
                 filter_expr: Optional[Expr] = None,
                 cfg=None, prune: bool = True,
                 deltas: Sequence[DeltaEntry] = (),
                 overlay: Optional[DeltaOverlay] = None,
                 restrict: Optional[Dict[str, Sequence[int]]] = None,
                 partitioning=None, ordered: bool = True):
        self._files = list(files)
        self._reader_of = reader_of
        self._schema = schema
        self._expr = filter_expr
        self._prune = prune
        self._deltas = list(deltas)
        self._use_threads = bool(getattr(cfg, "use_threads", True))
        self._readahead = int(getattr(cfg, "fragment_readahead", 4))
        self._num_threads = resolve_num_threads(cfg)
        self._executor = getattr(cfg, "executor", None)
        if self._executor not in (None, "thread", "process"):
            raise ValueError(f"unknown scan executor {self._executor!r} "
                             "(expected 'thread', 'process' or None)")
        self._verify = getattr(cfg, "verify", None)
        if self._verify not in (None, "page", "footer", "off"):
            raise ValueError(f"unknown verify mode {self._verify!r} "
                             "(expected 'page', 'footer' or 'off')")
        self._on_corruption = getattr(cfg, "on_corruption", "raise")
        self._budget = getattr(cfg, "morsel_budget", None)
        # num_threads=None is "auto": size from cpu_count but only engage
        # the pool when the decode work can actually overlap (see
        # _parallel_profitable); an explicit thread count always engages.
        self._threads_auto = getattr(cfg, "num_threads", None) is None
        self._restrict = ({fn: set(rgs) for fn, rgs in restrict.items()}
                          if restrict is not None else None)
        out_names = list(columns) if columns is not None else schema.names
        self._out_schema = schema.select(out_names)
        self._filter_cols = [c for c in dict.fromkeys(
            filter_expr.columns() if filter_expr is not None else [])]
        read_names = out_names + [c for c in self._filter_cols
                                  if c in schema and c not in out_names]
        if self._deltas and ID_COLUMN not in read_names:
            read_names.append(ID_COLUMN)  # overlay needs row identity
        self._partitioning = partitioning
        # the ordered merge engages only when >1 partition stream can
        # actually appear in this plan (and row identity is available)
        self._merge_parts = False
        if partitioning is not None and ordered and ID_COLUMN in schema:
            keys = {partitioning.key_of(f) for f in self._files}
            self._merge_parts = len(keys) > 1
        if self._merge_parts and ID_COLUMN not in read_names:
            read_names.append(ID_COLUMN)  # merge needs row identity
        # what _finish_table emits: output columns, plus id while an
        # ordered merge still needs it (stripped again after the merge)
        self._emit_names = list(out_names)
        if self._merge_parts and ID_COLUMN not in out_names:
            self._emit_names.append(ID_COLUMN)
        self._read_schema = schema.select(read_names)
        self._fragments: Optional[List[FragmentPlan]] = None
        self._plan_counters: Optional[ScanCounters] = None
        self._byte_totals: Optional[tuple] = None
        self._overlay_obj: Optional[DeltaOverlay] = overlay
        self.last_counters: Optional[ScanCounters] = None

    def _overlay(self) -> Optional[DeltaOverlay]:
        if not self._deltas:
            return None
        if self._overlay_obj is None:
            self._overlay_obj = DeltaOverlay(self._deltas, self._reader_of,
                                             self._read_schema,
                                             on_corruption=self._on_corruption)
        return self._overlay_obj

    # ------------------------------------------------------------------ plan
    def fragments(self) -> List[FragmentPlan]:
        self._build()
        return list(self._fragments)

    def _build(self) -> None:
        """Planning: footer-only over the base files.

        When a delta chain is overlaid, the (small, by construction) delta
        files themselves are read here to resolve the chain — base-file data
        pages are still never touched during planning.
        """
        if self._fragments is not None:
            return
        ov = self._overlay()
        c = ScanCounters()
        c.delta_files = len(self._deltas)
        if ov is not None:
            c.delta_upsert_rows = ov.upsert_rows_total
            c.delta_tombstone_rows = ov.tombstone_rows_total
            c.files_quarantined = len(ov.quarantined)
        frags: List[FragmentPlan] = []
        # manifest-level partition pruning: sound only when no upsert delta
        # is pending (an upsert's new values are unbounded by the recorded
        # partition values for non-partition columns; tombstones commute
        # with filtering).  A pruned partition opens zero footers.
        part = self._partitioning
        may_scan = None
        if part is not None and self._prune and self._expr is not None \
                and (ov is None or not len(ov.upsert_ids)):
            may_scan = part.pruner(self._expr)
        for fn in self._files:
            pk = part.key_of(fn) if part is not None else None
            if may_scan is not None and pk is not None \
                    and not may_scan(fn):
                c.files_total += 1
                c.files_skipped += 1
                frags.append(FragmentPlan(fn, 0, [], False, pruned=True,
                                          partition=pk,
                                          partition_pruned=True))
                continue
            rd = self._reader_of(fn)
            n = rd.num_row_groups
            have = set(rd.schema.names)
            c.files_total += 1
            c.row_groups_total += n
            # A fragment that may hold upserted rows cannot be pruned or
            # pushed down from its stored statistics (they describe stale
            # values): decode it fully and filter after the overlay.
            overlap = ov is not None and ov.file_overlaps_upserts(rd)
            # pushdown is only sound when the file has every filter column;
            # otherwise missing columns align to null *after* decode and the
            # residual filter runs there (null semantics differ per Expr).
            # prune=False forces the residual path: full decode, no stats.
            pushdown = (not overlap and self._prune
                        and self._expr is not None
                        and all(col in have for col in self._filter_cols))
            selected = list(range(n))
            if pushdown:
                if not self._expr.prune(rd.file_stats()):
                    selected = []          # fragment pruned outright
                else:
                    selected = [i for i in range(n)
                                if self._expr.prune(rd.row_group_stats(i))]
            if self._restrict is not None:
                allowed = self._restrict.get(fn, set())
                selected = [i for i in selected if i in allowed]
            c.row_groups_skipped += n - len(selected)
            if selected:
                c.files_scanned += 1
            else:
                c.files_skipped += 1
            frags.append(FragmentPlan(fn, n, selected, pushdown,
                                      pruned=not selected,
                                      delta_overlap=overlap,
                                      partition=pk))
        if part is not None:
            by_key: Dict[str, List[FragmentPlan]] = {}
            for f in frags:
                if f.partition is not None:
                    by_key.setdefault(f.partition, []).append(f)
            c.partitions_total = len(by_key)
            c.partitions_pruned = sum(
                1 for fs in by_key.values()
                if all(f.partition_pruned for f in fs))
            c.partitions_scanned = sum(
                1 for fs in by_key.values()
                if any(f.row_groups for f in fs))
        self._fragments, self._plan_counters = frags, c

    # --------------------------------------------------------------- execute
    def execute(self, batch_size: Optional[int] = None,
                counters: Optional[ScanCounters] = None,
                map_fn: Optional[Callable[[Table], Any]] = None
                ) -> Generator[Any, None, None]:
        """Yield result tables, decoding morsels on the shared worker pool.

        With ``num_threads > 1`` (the default is ``os.cpu_count()``) the
        surviving row groups are split into morsels and decoded in
        parallel; output order and content are byte-identical to the
        serial scan (order-preserving merge).  Counters accumulate into
        ``counters`` (or a fresh copy of the plan counters, exposed as
        ``self.last_counters``) — per-morsel counters are merged in the
        consumer, never incremented across threads.

        ``map_fn`` (exclusive with ``batch_size``) transforms each result
        table *inside the decoding worker* on the parallel path, so
        CPU-bound per-batch work (e.g. the Query layer's partial
        group-by aggregation) overlaps with decode; mapped values are
        yielded in plan order.  Closing the generator early (e.g. a
        ``limit`` that is already satisfied) cancels not-yet-started
        morsels, so an abandoned scan stops submitting work.
        """
        assert not (batch_size is not None and map_fn is not None), \
            "batch_size and map_fn are mutually exclusive"
        self._build()
        if counters is None:
            counters = dataclasses.replace(self._plan_counters)
        self.last_counters = counters

        morsels = self._morsels()
        # the ordered partition merge applies to table output only; mapped
        # values (grouped partial aggregation) are order-insensitive and
        # consumed in (deterministic) submission order
        merge = self._merge_parts and map_fn is None \
            and len({m[0].partition for m in morsels}) > 1
        tagged = self._execute_stream(morsels, counters, map_fn)
        if merge:
            stream = self._merge_streams(tagged, morsels)
        else:
            def flat() -> Generator[Any, None, None]:
                for _frag, vals in tagged:
                    yield from vals
            stream = flat()
        if map_fn is None and self._emit_names != self._out_schema.names:
            out_names = self._out_schema.names
            inner = stream

            def strip() -> Generator[Table, None, None]:
                for t in inner:
                    yield t.select(out_names)
            stream = strip()
        if batch_size is None:
            yield from stream
        else:
            yield from rechunk(stream, batch_size)

    def _execute_stream(self, morsels, counters: ScanCounters,
                        map_fn: Optional[Callable[[Table], Any]] = None
                        ) -> Generator[Any, None, None]:
        """Run the chosen executor; yields ``(frag, [values])`` per morsel
        in submission order (empty morsels included, so a merge consumer
        can account stream progress exactly)."""
        mode = self._choose_executor(morsels)
        if mode == "process":
            return self._execute_process(morsels, counters, map_fn)
        if mode == "thread":
            return self._execute_parallel(morsels, counters, map_fn)

        def pieces() -> Generator[Any, None, None]:
            for frag, rgs in morsels:
                self._budget_acquire()
                try:
                    vals = [t if map_fn is None else map_fn(t)
                            for t in self._fragment_tables(frag, counters,
                                                           row_groups=rgs)]
                finally:
                    self._budget_release()
                yield frag, vals
        return (prefetch(pieces(), self._readahead)
                if self._use_threads else pieces())

    def _budget_acquire(self) -> None:
        if self._budget is not None:
            self._budget.acquire()

    def _budget_try_acquire(self, block: bool) -> bool:
        """Charge one morsel permit; blocking only allowed when the caller
        holds no other permit (the deadlock-freedom discipline)."""
        if self._budget is None:
            return True
        if block:
            self._budget.acquire()
            return True
        return self._budget.try_acquire()

    def _budget_release(self) -> None:
        if self._budget is not None:
            self._budget.release()

    def _merge_streams(self, tagged, morsels
                       ) -> Generator[Table, None, None]:
        """K-way watermark merge: re-interleave partition streams by id.

        Every partition's files (manifest order) form an ascending id
        stream — one ``create`` splits its ascending id range across
        partitions, so reconstructing the unpartitioned row order is
        exactly a merge of those streams.  Tables buffer per stream; rows
        up to the *watermark* (the smallest last-buffered id among
        streams that may still produce rows) are provably complete and
        are emitted sorted.  Round-robin morsel submission (see
        :meth:`_morsels`) keeps every stream advancing together, so
        buffers stay ~morsel-sized.
        """
        remaining: Dict[Optional[str], int] = {}
        for frag, _rgs in morsels:
            remaining[frag.partition] = remaining.get(frag.partition, 0) + 1
        bufs: Dict[Optional[str], List[Table]] = \
            {k: [] for k in remaining}

        def flush(final: bool) -> Optional[Table]:
            if final:
                wm = None
            else:
                wm_ids = []
                for k, rem in remaining.items():
                    if not bufs[k]:
                        if rem > 0:
                            return None  # stream not bounded yet
                        continue
                    last = bufs[k][-1].column(ID_COLUMN).values
                    if rem > 0:
                        wm_ids.append(int(last[-1]))
                if not wm_ids:
                    wm = None  # every live stream exhausted: emit all
                else:
                    wm = min(wm_ids)
            parts: List[Table] = []
            for k in bufs:
                keep: List[Table] = []
                for t in bufs[k]:
                    ids = t.column(ID_COLUMN).values
                    if wm is None or ids[-1] <= wm:
                        parts.append(t)
                    else:
                        cut = int(np.searchsorted(ids, wm, "right"))
                        if cut:
                            parts.append(t.slice(0, cut))
                            keep.append(t.slice(cut, t.num_rows))
                        else:
                            keep.append(t)
                bufs[k] = keep
            if not parts:
                return None
            merged = concat_tables(parts)
            order = np.argsort(
                merged.column(ID_COLUMN).values, kind="stable")
            return merged.take(order)

        for frag, tables in tagged:
            key = frag.partition
            bufs[key].extend(t for t in tables if t.num_rows)
            remaining[key] -= 1
            out = flush(final=False)
            if out is not None and out.num_rows:
                yield out
        out = flush(final=True)
        if out is not None and out.num_rows:
            yield out

    # ------------------------------------------------------- morsel dispatch
    def _morsels(self) -> List[Tuple[FragmentPlan, List[int]]]:
        """Split surviving row groups into scan-ordered morsels.

        A morsel is a contiguous run of row groups within one fragment,
        capped at ``MORSEL_ROWS`` rows — the unit of work the shared pool
        schedules.  Never crosses a fragment boundary and never splits a
        row group.
        """
        out: List[Tuple[FragmentPlan, List[int]]] = []
        for frag in self._fragments:
            if not frag.row_groups:
                continue
            rd = self._reader_of(frag.file)
            run: List[int] = []
            rows = 0
            for i in frag.row_groups:
                run.append(i)
                rows += rd.row_group_num_rows(i)
                if rows >= MORSEL_ROWS:
                    out.append((frag, run))
                    run, rows = [], 0
            if run:
                out.append((frag, run))
        if self._merge_parts:
            # round-robin across partition streams: every stream advances
            # together, so the ordered merge's buffers stay morsel-sized
            # instead of holding whole partitions
            streams: Dict[Optional[str], List] = {}
            for m in out:
                streams.setdefault(m[0].partition, []).append(m)
            if len(streams) > 1:
                out = [m for tup in itertools.zip_longest(*streams.values())
                       for m in tup if m is not None]
        return out

    def _choose_executor(self, morsels) -> str:
        """Pick the execution strategy: ``serial`` / ``thread`` / ``process``.

        An explicit ``LoadConfig.executor`` wins.  AUTO consults the
        footer's codec split (:func:`page_codec_split`): codec-compressed
        read sets go to the shared *thread* pool (zlib &c release the GIL,
        so decode genuinely overlaps); GIL-bound read sets (raw or
        entropy-coded pages, which decode in pure numpy under the GIL and
        would convoy on threads) go to the *process* pool when the scan is
        big enough to amortize worker spawn (``PROCESS_MIN_ROWS``).  Either
        way the output stays byte-identical — only wall-clock changes.
        """
        if self._num_threads <= 1 or len(morsels) <= 1:
            return "serial"
        if self._executor is not None:
            return self._executor
        if self._parallel_profitable():
            return "thread"
        rows = 0
        for frag, rgs in morsels:
            rd = self._reader_of(frag.file)
            rows += sum(rd.row_group_num_rows(i) for i in rgs)
        if rows >= PROCESS_MIN_ROWS:
            return "process"
        return "serial" if self._threads_auto else "thread"

    def _parallel_profitable(self) -> bool:
        """Footer-only heuristic for auto mode: will threads overlap?

        CPython morsel workers only run concurrently while the GIL is
        released, which on the decode path means codec decompression
        (zlib/&c release it; raw and entropy-coded buffers decode under
        the GIL, where extra threads just convoy).  Sample the first
        surviving row group's read set: go parallel when at least half of
        its stored bytes are codec-compressed.
        """
        for frag in self._fragments:
            if not frag.row_groups:
                continue
            rd = self._reader_of(frag.file)
            have = set(rd.schema.names)
            rg = rd.row_groups[frag.row_groups[0]]
            stored = compressed = 0
            for name in self._read_schema.names:
                if name not in have:
                    continue
                for p in rg["columns"][name]["pages"]:
                    s, c = page_codec_split(p)
                    stored += s
                    compressed += c
            return stored > 0 and compressed * 2 >= stored
        return False

    def _execute_parallel(self, morsels, counters: ScanCounters,
                          map_fn: Optional[Callable[[Table], Any]] = None
                          ) -> Generator[Any, None, None]:
        """Decode morsels on the shared pool; order-preserving bounded merge.

        Up to ``num_threads + fragment_readahead`` morsels are in flight;
        completed results are consumed strictly in submission (= plan)
        order, so the output stream is identical to the serial scan.  A
        worker exception propagates to the caller with its original
        traceback (``Future.result`` re-raises), and the ``finally`` block
        cancels not-yet-started morsels so an abandoned scan leaves no
        queued work behind.  ``map_fn`` (if any) runs inside the worker,
        right after each table is decoded.
        """
        pool = scan_pool(self._num_threads)
        max_inflight = self._num_threads + max(self._readahead, 1)

        def run_morsel(frag: FragmentPlan, rgs: List[int]):
            local = ScanCounters()  # morsel-local: no cross-thread `+=`
            tables = [t if map_fn is None else map_fn(t)
                      for t in self._fragment_tables(frag, local,
                                                     row_groups=rgs)]
            return tables, local

        it = iter(morsels)
        inflight: "collections.deque" = collections.deque()

        def refill() -> None:
            # charge one budget permit per submitted morsel; block for a
            # permit only while holding none (an empty window), otherwise
            # try-acquire and let this scan drain what it already holds —
            # the discipline that keeps a shared budget deadlock-free
            while len(inflight) < max_inflight:
                if not self._budget_try_acquire(block=not inflight):
                    return
                nxt = next(it, None)
                if nxt is None:
                    self._budget_release()
                    return
                inflight.append((pool.submit(run_morsel, *nxt), nxt[0]))

        try:
            while True:
                refill()
                if not inflight:
                    break  # morsels exhausted
                fut, frag = inflight.popleft()
                try:
                    tables, local = fut.result()
                finally:
                    self._budget_release()
                counters.merge_from(local)  # single-threaded merge point
                yield frag, tables
        finally:
            for fut, _ in inflight:
                fut.cancel()
                self._budget_release()

    def _execute_process(self, morsels, counters: ScanCounters,
                         map_fn: Optional[Callable[[Table], Any]] = None
                         ) -> Generator[Any, None, None]:
        """Decode morsels in worker *processes*; finish + merge in the parent.

        Workers run only the decode half (:func:`_process_morsel`); the
        parent applies the finish half (:meth:`_finish_table`) — overlay
        substitution, residual filter, ``map_fn`` — and merges counters
        single-threaded, so results are byte-identical to the serial and
        thread paths, order included.  Three failure modes are handled:

        - a racing compaction GC'd a base file after planning: the worker's
          open raises ``FileNotFoundError`` and the parent decodes that
          morsel inline off its still-cached mapping (same bytes — data
          files are immutable);
        - the pool itself breaks mid-scan (``BrokenProcessPool`` — e.g. a
          spawn child of a ``__main__``-guard-less user script dies
          bootstrapping, or a worker is OOM-killed): morsels whose
          futures died decode inline, the pool is **rebuilt once**
          (:func:`process_scan_pool` swaps out the broken one) and the
          remaining morsels go to the fresh workers; if the rebuilt pool
          breaks too, the scan degrades to inline decode for the rest
          with a one-line warning.  ``counters.pool_rebuilds`` /
          ``morsels_decoded_inline`` record the degradation — never a
          hang, never an unexplained slowdown;
        - early termination (``limit`` satisfied, generator closed): the
          ``finally`` cancels queued morsels and *drains* already-running
          ones through :func:`shm.discard`, so no worker is orphaned
          mid-result and no shared-memory segment outlives the scan
          (``shm.live_segments()`` stays empty — regression-tested).
        """
        max_inflight = self._num_threads + max(self._readahead, 1)
        state = {"broken": False, "rebuilt": False,
                 "pool": process_scan_pool(self._num_threads)}

        def rebuild_once() -> bool:
            """Swap in a fresh pool after a worker crash — once per scan."""
            if state["rebuilt"]:
                return False
            state["rebuilt"] = True
            counters.pool_rebuilds += 1
            # process_scan_pool replaces a broken cached pool outright
            state["pool"] = process_scan_pool(self._num_threads)
            return True

        def submit(frag: FragmentPlan, rgs: List[int]):
            if not state["broken"]:
                rd = self._reader_of(frag.file)
                have = set(rd.schema.names)
                cols = tuple(n for n in self._read_schema.names if n in have)
                expr = self._expr if frag.pushdown else None
                for _attempt in range(2):
                    sub_pool = state["pool"]
                    try:
                        return (sub_pool.submit(
                            _process_morsel, rd.path, tuple(rgs),
                            cols, expr, self._verify), frag, rgs, sub_pool)
                    except BrokenExecutor:
                        if not rebuild_once():
                            break
                _warn_broken_pool(state)
            return (None, frag, rgs, None)  # degraded: inline on arrival

        it = iter(morsels)
        inflight: "collections.deque" = collections.deque()

        def refill() -> None:
            # same budget discipline as the thread path: block for a
            # permit only with an empty window, otherwise try-acquire
            while len(inflight) < max_inflight:
                if not self._budget_try_acquire(block=not inflight):
                    return
                nxt = next(it, None)
                if nxt is None:
                    self._budget_release()
                    return
                inflight.append(submit(*nxt))

        try:
            while True:
                refill()
                if not inflight:
                    break  # morsels exhausted
                fut, frag, rgs, sub_pool = inflight.popleft()
                try:
                    try:
                        if fut is None:
                            raise BrokenExecutor
                        tables, local = shm.unpack(fut.result())
                    except FileNotFoundError:
                        local = ScanCounters()
                        tables = list(self._decode_tables(frag, local, rgs))
                        local.morsels_decoded_inline += 1
                    except BrokenExecutor:
                        # this morsel's future died with its pool: decode it
                        # inline, and give the *remaining* morsels a fresh
                        # pool (once per scan) before writing the scan off.
                        # A corpse future from an already-replaced pool is
                        # expected fallout of the rebuild, not a second
                        # crash.
                        if fut is not None and sub_pool is state["pool"] \
                                and not rebuild_once() and not state["broken"]:
                            _warn_broken_pool(state)
                        local = ScanCounters()
                        tables = list(self._decode_tables(frag, local, rgs))
                        local.morsels_decoded_inline += 1
                finally:
                    self._budget_release()
                counters.merge_from(local)  # single-threaded merge point
                done = []
                for t in tables:
                    t = self._finish_table(t, frag, counters)
                    if t is not None:
                        done.append(t if map_fn is None else map_fn(t))
                yield frag, done
        finally:
            for fut, _, _, _ in inflight:
                self._budget_release()
                if fut is not None and not fut.cancel():
                    try:
                        shm.discard(fut.result())
                    except Exception:
                        pass

    def _decode_tables(self, frag: FragmentPlan, counters: ScanCounters,
                       row_groups: Optional[List[int]] = None
                       ) -> Generator[Table, None, None]:
        """The decode half: prune, pushdown-filter and decode one morsel.

        Worker-safe given any reader handle — this is exactly what
        :func:`_process_morsel` runs in a worker process.
        """
        rd = self._reader_of(frag.file)
        have = set(rd.schema.names)
        cols_here = [n for n in self._read_schema.names if n in have]
        pushdown = self._expr if frag.pushdown else None
        rgs = frag.row_groups if row_groups is None else row_groups
        return rd.iter_row_group_tables(cols_here, pushdown, row_groups=rgs,
                                        counters=counters,
                                        verify=self._verify)

    def _finish_table(self, t: Table, frag: FragmentPlan,
                      counters: ScanCounters) -> Optional[Table]:
        """The finish half: align, overlay, residual-filter, project.

        Holds all the state that cannot cross a process boundary (the
        resolved overlay, the residual ``Expr`` against merged values).
        """
        t = t.align_to_schema(self._read_schema)
        ov = self._overlay()
        if ov is not None and ov.has_work:
            # merge-on-read: substitute upserts in place, drop dead rows
            # *before* the residual filter so it sees merged values
            t = ov.apply(t, counters)
        if self._expr is not None and not frag.pushdown:
            mask = self._expr.evaluate(t)
            if not mask.all():
                t = t.filter_mask(mask)
        if t.num_rows:
            counters.rows_matched += t.num_rows
            # _emit_names keeps the id column while an ordered partition
            # merge still needs it; execute() strips it after merging
            return t.select(self._emit_names)
        return None

    def _fragment_tables(self, frag: FragmentPlan, counters: ScanCounters,
                         row_groups: Optional[List[int]] = None
                         ) -> Generator[Table, None, None]:
        for t in self._decode_tables(frag, counters, row_groups):
            t = self._finish_table(t, frag, counters)
            if t is not None:
                yield t

    def _bytes_accounting(self) -> tuple:
        """(bytes_total, bytes_selected) — footer walk, lazy: explain() only.

        Plain reads skip this; it touches every page dict of every file.
        """
        if self._byte_totals is None:
            self._build()
            total = selected = 0
            for frag in self._fragments:
                if frag.partition_pruned:
                    continue  # footer never opened: bytes unknown
                rd = self._reader_of(frag.file)
                have = set(rd.schema.names)
                cols_here = [x for x in self._read_schema.names if x in have]
                total += sum(rd.read_row_group_bytes(i)
                             for i in range(frag.num_row_groups))
                selected += sum(rd.read_row_group_bytes(i, cols_here)
                                for i in frag.row_groups)
            self._byte_totals = (total, selected)
        return self._byte_totals

    # --------------------------------------------------------------- explain
    def explain(self, execute: bool = False) -> ScanReport:
        """Report pruning decisions; optionally run the scan for decode stats."""
        self._build()
        c = dataclasses.replace(self._plan_counters)
        c.bytes_total, c.bytes_selected = self._bytes_accounting()
        if execute:
            for _ in self.execute(counters=c):
                pass
        else:
            c.row_groups_scanned = c.row_groups_total - c.row_groups_skipped
        return ScanReport(counters=c, fragments=list(self._fragments),
                          columns=self._out_schema.names,
                          filter=repr(self._expr) if self._expr is not None
                          else None,
                          executed=execute)


# ---------------------------------------------------------------------------
# shared helpers (also used by the write paths in store.py)
# ---------------------------------------------------------------------------
def file_may_match(rd: TPQReader, expr: Expr) -> bool:
    """Fragment-level pruning check: can this file contain a matching row?

    Conservative (True = must read).  Used by ``update``/``delete`` to skip
    rewriting files that provably hold no affected rows.  Checks merged
    whole-file stats first (cheap reject), then per-row-group stats, which
    are strictly stronger: merging widens min/max ranges and drops blooms of
    mismatched sizes.
    """
    if not all(c in rd.schema for c in expr.columns()):
        return True
    if not expr.prune(rd.file_stats()):
        return False
    return any(expr.prune(rd.row_group_stats(i))
               for i in range(rd.num_row_groups))


def rechunk(stream: Iterable[Table], batch_size: int
            ) -> Generator[Table, None, None]:
    """Re-slice a table stream into exact ``batch_size``-row batches."""
    buf: List[Table] = []
    count = 0
    for t in stream:
        while t.num_rows:
            take = min(batch_size - count, t.num_rows)
            buf.append(t.slice(0, take))
            t = t.slice(take, t.num_rows)
            count += take
            if count == batch_size:
                yield concat_tables(buf)
                buf, count = [], 0
    if buf:
        yield concat_tables(buf)


def prefetch(gen: Iterable[Table], depth: int) -> Generator[Table, None, None]:
    """Background-thread readahead (LoadConfig.fragment_readahead).

    Failure semantics (regression-tested in ``tests/test_parallel_scan.py``):

    - a producer exception propagates to the consumer **with its original
      traceback** (the exception object is re-raised as captured, so the
      failing frame inside ``gen`` stays visible);
    - the worker can never be left blocked on a full queue: every ``put``
      polls a stop event, and the consumer's ``finally`` (normal exit,
      error, or an early ``close()`` of the generator) sets the event,
      drains the queue, and joins the thread.
    """
    q: "queue.Queue" = queue.Queue(maxsize=max(depth, 1))
    DONE = object()
    stop = threading.Event()

    def offer(item) -> bool:
        """Put, but give up promptly once the consumer is gone."""
        while not stop.is_set():
            try:
                q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def worker():
        try:
            for item in gen:
                if not offer(item):
                    return
            offer(DONE)
        except BaseException as e:  # propagate WITH the worker traceback
            offer(e)

    th = threading.Thread(target=worker, name="tpq-prefetch", daemon=True)
    th.start()
    try:
        while True:
            item = q.get()
            if item is DONE:
                return
            if isinstance(item, BaseException):
                raise item  # __traceback__ captured in the worker survives
            yield item
    finally:
        stop.set()
        while True:  # drain so a blocked put wakes and sees the stop flag
            try:
                q.get_nowait()
            except queue.Empty:
                break
        th.join(timeout=5.0)
