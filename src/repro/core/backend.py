"""Pluggable decode backends: numpy reference vs. Pallas-kernel (jax) decode.

The TPQ reader decodes every page through :func:`active_backend`.  The
``numpy`` backend is the always-correct reference (it simply calls
:func:`repro.core.encodings.decode`); the ``jax`` backend routes the
kernelized encodings — BITPACK, DICT, DELTA, BSS — through the Pallas
kernels in :mod:`repro.kernels.ops` whenever the page is *provably safe*
to decode in 32-bit device arithmetic, and falls back to the numpy path
otherwise.  Both backends therefore produce byte-identical arrays on every
page (the parity sweep in ``tests/test_backend.py`` asserts this across
the full encoding matrix).

Selection:

- ``REPRO_DECODE_BACKEND=numpy|jax`` in the environment, or
- :func:`set_backend` at runtime (tests, benchmarks), or
- default: ``numpy``.

The jax import probe is cached process-wide (:func:`jax_available`), so a
``jax``-selected run on a machine without jax degrades to numpy after one
cheap check — CI's perf-smoke job relies on this staying fast.
"""
from __future__ import annotations

import os
from typing import Dict, Optional

import numpy as np

from . import encodings as enc

ENV_VAR = "REPRO_DECODE_BACKEND"

_INT32_MIN, _INT32_MAX = -(1 << 31), (1 << 31) - 1


class DecodeBackend:
    """Reference backend: the vectorized numpy decoders in ``encodings``."""

    name = "numpy"

    def decode(self, encoding: str, meta: dict, payload, n: int,
               np_dtype, out: Optional[np.ndarray] = None) -> np.ndarray:
        return enc.decode(encoding, meta, payload, n, np_dtype, out=out)

    def decode_batch(self, specs, np_dtype,
                     out: Optional[np.ndarray] = None) -> np.ndarray:
        """Fused decode of a whole morsel's pages of one column.

        ``specs`` is ``[(encoding, meta, payload, n), ...]`` in output order;
        returns the concatenated values — byte-identical to per-page
        :meth:`decode` + concatenate, but with one vectorized dispatch per
        encoding group instead of one Python-level decode per page (the GIL
        convoy fix: see ``enc.decode_batch``).
        """
        return enc.decode_batch(specs, np_dtype, out=out)

    def range_mask(self, values: np.ndarray, lo, hi) -> np.ndarray:
        """Boolean mask for ``lo <= values <= hi`` (fused on device backends)."""
        return (values >= lo) & (values <= hi)

    def minmax(self, values: np.ndarray):
        """(min, max) of a non-empty 1-D numeric array.

        The aggregate layer's partial-row-group reduction; the jax backend
        routes it through the Pallas ``page_minmax`` kernel when the dtype
        is exactly representable in 32-bit device lanes.
        """
        return values.min(), values.max()


class JaxDecodeBackend(DecodeBackend):
    """Routes safe pages through the Pallas decode kernels.

    Safety gate: the device kernels compute in 32-bit lanes (jax's default
    x64-disabled mode), so a page is routed only when every decoded value is
    exactly representable there — otherwise the numpy reference runs.  The
    gate keeps the backend *bit-identical* to numpy by construction.
    """

    name = "jax"

    def __init__(self):
        from repro.kernels import ops  # deferred: jax import is heavy
        self._ops = ops
        self._interpret = ops.default_interpret()

    # -- safety gates --------------------------------------------------------
    @staticmethod
    def _fits_i32(*vals) -> bool:
        return all(_INT32_MIN <= int(v) <= _INT32_MAX for v in vals)

    def _routable(self, encoding: str, meta: dict, n: int,
                  dt: np.dtype) -> bool:
        if n == 0:
            return False
        if encoding == enc.BITPACK:
            if dt == np.bool_:
                return True
            bits, ref = meta["bits"], meta["ref"]
            return (dt.kind in "iu" and bits <= 31
                    and self._fits_i32(ref, ref + (1 << bits) - 1))
        if encoding == enc.DICT:
            return meta["bits"] <= 31  # values checked against the dict below
        if encoding == enc.DELTA:
            bits, first = meta["bits"], meta["first"]
            if dt.kind not in "iu" or bits > 31:
                return False
            # worst-case partial sum: first ± n * max|delta|
            span = (n - 1) * (1 << max(bits - 1, 0))
            return self._fits_i32(first - span, first + span)
        if encoding == enc.BSS:
            return dt == np.float32
        return False

    def decode(self, encoding: str, meta: dict, payload, n: int,
               np_dtype, out: Optional[np.ndarray] = None) -> np.ndarray:
        dt = np.dtype(np_dtype)
        if not self._routable(encoding, meta, n, dt):
            return enc.decode(encoding, meta, payload, n, np_dtype, out=out)
        payload = bytes(payload)  # kernels take contiguous host bytes
        if encoding == enc.DICT:
            # gate on the dictionary's actual values: the gather runs in the
            # dictionary dtype on device, which must be 32-bit exact
            dl = meta["dict_len"]
            uniq = np.frombuffer(payload[:dl],
                                 np.dtype(dt).newbyteorder("<"))
            if dt.kind in "iu":
                if len(uniq) and not self._fits_i32(uniq.min(), uniq.max()):
                    return enc.decode(encoding, meta, payload, n, np_dtype,
                                      out=out)
            elif dt != np.float32:
                return enc.decode(encoding, meta, payload, n, np_dtype,
                                  out=out)
        # ask the device for int32 where the gate proved values fit: jax's
        # x64-disabled mode would otherwise truncate int64 with a warning
        dev_dt = (np.dtype(np.int32)
                  if encoding in (enc.BITPACK, enc.DELTA) and dt.kind in "iu"
                  else dt)
        vals = self._ops.decode_on_device(encoding, meta, payload, n, dev_dt,
                                          interpret=self._interpret)
        vals = np.asarray(vals).astype(dt, copy=False)
        if out is not None:
            out[:] = vals
            return out
        return vals

    # encodings with a fused segmented device kernel (kernels/segmented.py)
    _SEG_DEVICE = frozenset([enc.BITPACK, enc.DICT, enc.DELTA])

    def _dict_exact(self, meta: dict, payload, dt: np.dtype) -> bool:
        """Is this DICT page's dictionary 32-bit exact on device?"""
        uniq = np.frombuffer(payload[:meta["dict_len"]],
                             dt.newbyteorder("<"))
        if dt.kind in "iu":
            return not len(uniq) \
                or self._fits_i32(uniq.min(), uniq.max())
        return dt == np.float32

    def decode_batch(self, specs, np_dtype,
                     out: Optional[np.ndarray] = None) -> np.ndarray:
        """Morsel-fused decode: one device dispatch per encoding group.

        Routing is all-or-nothing *per encoding group*: a BITPACK / DICT /
        DELTA group goes to the segmented kernels only when every page in it
        passes the 32-bit gate (including the DICT dictionary-value check);
        any other group — and any group with an unroutable page — decodes
        through the numpy segmented reference, keeping the whole batch
        byte-identical to the numpy backend.
        """
        dt = np.dtype(np_dtype)
        starts = enc._spec_slices(specs)
        total = int(starts[-1])
        if out is None:
            out = np.empty(total, dt)
        handled: set = set()
        for encoding, idxs in enc._batch_groups(specs).items():
            if encoding not in self._SEG_DEVICE or len(idxs) < 2:
                continue
            sub = [specs[i] for i in idxs]
            if not all(self._routable(e, m, n, dt) for e, m, _, n in sub):
                continue
            if encoding == enc.DICT and not all(
                    self._dict_exact(m, p, dt) for _, m, p, _ in sub):
                continue
            vals = self._ops.decode_batch_on_device(
                encoding, sub, dt, interpret=self._interpret)
            pos = 0
            for i in idxs:
                n = specs[i][3]
                out[starts[i]:starts[i + 1]] = vals[pos:pos + n]
                pos += n
            handled.update(idxs)
        if len(handled) < len(specs):
            rest = [i for i in range(len(specs)) if i not in handled]
            if not handled:
                return enc.decode_batch(specs, dt, out=out)
            tmp = enc.decode_batch([specs[i] for i in rest], dt)
            pos = 0
            for i in rest:
                n = specs[i][3]
                out[starts[i]:starts[i + 1]] = tmp[pos:pos + n]
                pos += n
        return out

    def range_mask(self, values: np.ndarray, lo, hi) -> np.ndarray:
        # the device sees 32-bit lanes and the kernel casts bounds through
        # float32, so both the column VALUES and the bounds must be exactly
        # representable there — otherwise jnp.asarray would silently
        # truncate (e.g. int64 2**32+50 -> 50) and the mask diverges from
        # the numpy reference
        dt = values.dtype
        if dt == np.float32:
            exact = bool(np.float32(lo) == lo and np.float32(hi) == hi)
        elif dt.kind in "iu":
            exact = (self._fits_i32(lo, hi)
                     and max(abs(int(lo)), abs(int(hi))) < (1 << 24))
            if exact and dt.itemsize > 4 and len(values):
                # wide columns route only when the page's actual values fit
                exact = self._fits_i32(values.min(), values.max())
        else:
            exact = False
        if not exact:
            return super().range_mask(values, lo, hi)
        import jax.numpy as jnp
        mask, _ = self._ops.filter_range(jnp.asarray(values), lo, hi,
                                         interpret=self._interpret)
        return np.asarray(mask)

    # min/max are pure comparisons — no arithmetic — so the only gate is
    # that jnp.asarray must not truncate the values: <=32-bit ints and
    # float32 round-trip exactly in x64-disabled mode, wider dtypes fall
    # back to the numpy reference
    _MINMAX_SAFE = frozenset(["i1", "i2", "i4", "u1", "u2", "u4", "f4"])

    def minmax(self, values: np.ndarray):
        dt = values.dtype
        if dt.kind + str(dt.itemsize) not in self._MINMAX_SAFE \
                or len(values) == 0:
            return super().minmax(values)
        import jax.numpy as jnp
        page = min(len(values), 4096)
        mins, maxs = self._ops.page_minmax(jnp.asarray(values), page,
                                           interpret=self._interpret)
        return (np.asarray(mins).min().item(),
                np.asarray(maxs).max().item())


_jax_probe: Optional[bool] = None


def jax_available() -> bool:
    """Cached probe: can the jax backend be constructed in this process?"""
    global _jax_probe
    if _jax_probe is None:
        try:
            import jax  # noqa: F401
            _jax_probe = True
        except Exception:
            _jax_probe = False
    return _jax_probe


_instances: Dict[str, DecodeBackend] = {}
_active: Optional[str] = None


def get_backend(name: str) -> DecodeBackend:
    """Backend instance by name (constructed once per process)."""
    if name not in ("numpy", "jax"):
        raise ValueError(f"unknown decode backend {name!r} "
                         "(expected 'numpy' or 'jax')")
    be = _instances.get(name)
    if be is None:
        if name == "jax":
            if not jax_available():
                raise RuntimeError("jax backend requested but jax is not "
                                   "importable; use 'numpy'")
            be = JaxDecodeBackend()
        else:
            be = DecodeBackend()
        _instances[name] = be
    return be


def set_backend(name: Optional[str]) -> None:
    """Select the process-wide decode backend (None = back to env/default)."""
    global _active
    if name is not None:
        get_backend(name)  # validate eagerly
    _active = name


def active_backend() -> DecodeBackend:
    """The backend the reader should decode through, honoring overrides.

    Precedence: :func:`set_backend` > ``REPRO_DECODE_BACKEND`` > numpy.
    A jax selection on a jax-less machine silently degrades to numpy (the
    probe is cached, so this costs one failed import per process).
    """
    name = _active or os.environ.get(ENV_VAR, "numpy")
    if name == "jax" and not jax_available():
        name = "numpy"
    return get_backend(name)
