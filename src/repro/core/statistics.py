"""Column statistics: the paper's replacement for indexes.

Per-page and per-row-group min/max/null-count statistics (Parquet footer
statistics, SI §1.4.5) plus a "bloom-lite" membership fingerprint (SI §1.2) —
a 256-bit hash bitmap that lets equality predicates prune chunks even when the
value lies inside [min, max].  ``Expr.prune`` consumes these.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Dict, List, Optional

import numpy as np

from .dtypes import KIND_NUMERIC, KIND_STRING
from .table import Column

_BLOOM_BITS = 256           # minimum fingerprint size
_BLOOM_MAX_BITS = 1 << 16   # adaptive cap: 8 KiB per chunk
_BLOOM_MAX_DISTINCT = 8192  # beyond this skip the fingerprint entirely
_STR_STAT_MAX = 64          # truncate string min/max like Parquet writers do


def _hash2(data: bytes) -> tuple:
    h1 = zlib.crc32(data) & 0xFFFFFFFF
    h2 = zlib.crc32(data, 0x9E3779B9) & 0xFFFFFFFF
    return h1, h2


_M64 = np.uint64(0xFFFFFFFFFFFFFFFF)


def _splitmix(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 — the int-key bloom hash (write AND probe side)."""
    x = x.astype(np.uint64)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def _hash2_int(v) -> tuple:
    # mod-2^64 like the build side (_bloom_from_ints views int64 as uint64),
    # so the full uint64 domain [2^63, 2^64) probes without overflow
    x = int(_splitmix(np.array([int(v) & 0xFFFFFFFFFFFFFFFF], np.uint64))[0])
    return x & 0xFFFFFFFF, (x >> 32) & 0xFFFFFFFF


def _bloom_size_bits(n_distinct: int) -> int:
    """~8 bits/key (3 probes => ~3% fp), power-of-two, clamped."""
    bits = _BLOOM_BITS
    while bits < 8 * n_distinct and bits < _BLOOM_MAX_BITS:
        bits *= 2
    return bits


def _bloom_positions(h1: int, h2: int, nbits: int) -> List[int]:
    # three independent probes, Kirsch-Mitzenmacher style
    return [(h1 + i * h2) % nbits for i in (0, 1, 2)]


def _value_bytes(v: Any) -> bytes:
    if isinstance(v, (bool, np.bool_)):
        return b"\x01" if v else b"\x00"
    if isinstance(v, (int, np.integer)):
        return int(v).to_bytes(8, "little", signed=True)
    if isinstance(v, str):
        return v.encode("utf-8")
    if isinstance(v, bytes):
        return v
    if isinstance(v, (float, np.floating)):
        return np.float64(v).tobytes()
    return repr(v).encode()


@dataclasses.dataclass
class ColumnStats:
    num_values: int = 0
    null_count: int = 0
    nan_count: int = 0             # float chunks only; NaN is invisible to
    min: Any = None                # min/max but matches "!=" and negations
    max: Any = None
    bloom: Optional[bytes] = None  # _BLOOM_BITS//8 bytes, or None
    # sum over valid (non-null, non-NaN) numeric values — the footer fact
    # that lets ParquetDB.aggregate answer sum/mean without decoding.
    # None for non-numeric chunks and for files written before the field
    # existed (the aggregate layer then falls back to decoding).
    sum: Any = None

    # -- pruning helpers ------------------------------------------------------
    def may_contain(self, v: Any) -> bool:
        """False only when the chunk provably cannot contain value v."""
        if self.min is not None:
            try:
                if v < self.min or v > self.max:
                    return False
            except TypeError:
                return True
        if self.bloom is not None:
            if isinstance(v, (int, np.integer)) and not isinstance(
                    v, (bool, np.bool_)):
                h1, h2 = _hash2_int(v)
            elif isinstance(v, (float, np.floating)) and float(v).is_integer() \
                    and -2.0**63 <= float(v) < 2.0**64:
                # int-column blooms are built with the int hash; a float
                # literal like 1.0 must probe the same way or the chunk is
                # wrongly pruned (non-integral floats can't match int rows,
                # so any verdict for them is sound)
                h1, h2 = _hash2_int(int(v))
            else:
                h1, h2 = _hash2(_value_bytes(v))
            bits = np.frombuffer(self.bloom, np.uint8)
            nbits = len(self.bloom) * 8
            for p in _bloom_positions(h1, h2, nbits):
                if not (bits[p >> 3] >> (p & 7)) & 1:
                    return False
        return True

    def all_null(self) -> bool:
        return self.num_values > 0 and self.null_count == self.num_values

    def overlaps_range(self, lo: Any, hi: Any) -> bool:
        """False only when the chunk's [min, max] provably misses [lo, hi].

        Conservative like :meth:`may_contain` (missing stats → True).  The
        delta overlay and compaction use this on the ``id`` column to decide
        which base fragments a delta chain can touch.
        """
        if self.min is None or lo is None or hi is None:
            return True
        try:
            return not (hi < self.min or lo > self.max)
        except TypeError:
            return True

    # -- serialization --------------------------------------------------------
    def to_dict(self) -> dict:
        d = {"n": self.num_values, "nulls": self.null_count}
        if self.nan_count:
            d["nan"] = self.nan_count
        if self.min is not None:
            d["min"] = _json_safe(self.min)
            d["max"] = _json_safe(self.max)
        if self.sum is not None:
            d["sum"] = _json_safe(self.sum)
        if self.bloom is not None:
            d["bloom"] = self.bloom.hex()
        return d

    @staticmethod
    def from_dict(d: dict) -> "ColumnStats":
        return ColumnStats(
            num_values=d.get("n", 0), null_count=d.get("nulls", 0),
            nan_count=d.get("nan", 0),
            min=d.get("min"), max=d.get("max"), sum=d.get("sum"),
            bloom=bytes.fromhex(d["bloom"]) if "bloom" in d else None)


def exact_int_sum(vals: np.ndarray) -> int:
    """Sum an integer/bool array as an exact python int (no int64 wrap).

    The fast int64 reduction runs when the value bound proves it cannot
    overflow; otherwise fall back to object-dtype accumulation, which
    numpy performs with python ints (arbitrary precision).  Both the
    footer ``sum`` statistic and the aggregate decode path use this, so
    stats-answered and decoded sums agree exactly at any magnitude.
    """
    n = len(vals)
    if n == 0:
        return 0
    bound = max(abs(int(vals.min())), abs(int(vals.max())))
    if bound * n < 2 ** 62:
        return int(vals.sum())
    return int(vals.astype(object).sum())


def _json_safe(v: Any):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (np.bool_,)):
        return bool(v)
    return v


def _bloom_from_values(vals: List[bytes]) -> bytes:
    nbits = _bloom_size_bits(len(vals))
    bits = np.zeros(nbits // 8, np.uint8)
    for b in vals:
        h1, h2 = _hash2(b)
        for p in _bloom_positions(h1, h2, nbits):
            bits[p >> 3] |= 1 << (p & 7)
    return bits.tobytes()


def _bloom_from_ints(uniq: np.ndarray) -> bytes:
    """Vectorized int-key bloom build (splitmix64 + 3 K-M probes)."""
    nbits = _bloom_size_bits(len(uniq))
    x = _splitmix(uniq.astype(np.int64).view(np.uint64))
    h1 = (x & np.uint64(0xFFFFFFFF)).astype(np.uint64)
    h2 = (x >> np.uint64(32)).astype(np.uint64)
    bitarr = np.zeros(nbits, np.uint8)
    nb = np.uint64(nbits)
    for i in range(3):
        bitarr[((h1 + np.uint64(i) * h2) % nb).astype(np.int64)] = 1
    return np.packbits(bitarr, bitorder="little").tobytes()


def compute_bloom(col: Column) -> Optional[bytes]:
    """Chunk-level bloom fingerprint for an int/string column, or None.

    Skips high-cardinality chunks *before* paying for a full ``np.unique``:
    if a 2x-oversized sample is already all-distinct, the chunk almost
    surely exceeds ``_BLOOM_MAX_DISTINCT`` and the bloom would be useless —
    skipping is always sound (a missing bloom only weakens pruning).
    """
    k = col.dtype.kind
    if k == KIND_NUMERIC and col.dtype.is_integer and not col.dtype.is_float:
        vals = col.values if col.validity is None else col.values[col.validity]
        if len(vals) == 0:
            return None
        if len(vals) > 2 * _BLOOM_MAX_DISTINCT:
            sample = vals[:2 * _BLOOM_MAX_DISTINCT]
            if len(np.unique(sample)) > _BLOOM_MAX_DISTINCT:
                return None
        uniq = np.unique(vals)
        if len(uniq) <= _BLOOM_MAX_DISTINCT:
            return _bloom_from_ints(uniq)
        return None
    if k == KIND_STRING:
        n = len(col)
        if n > 2 * _BLOOM_MAX_DISTINCT:
            sample = set(col.slice(0, 2 * _BLOOM_MAX_DISTINCT).to_pylist())
            sample.discard(None)
            if len(sample) > _BLOOM_MAX_DISTINCT:
                return None  # high-cardinality: skip the full materialize
        vals = [v for v in col.to_pylist() if v is not None]
        uniq = set(vals)
        if vals and len(uniq) <= _BLOOM_MAX_DISTINCT:
            return _bloom_from_values([u.encode("utf-8") for u in uniq])
    return None


def compute_stats(col: Column, with_bloom: bool = True) -> ColumnStats:
    n = len(col)
    nulls = col.null_count
    st = ColumnStats(num_values=n, null_count=nulls)
    if n == nulls:
        return st
    k = col.dtype.kind
    if k == KIND_NUMERIC:
        vals = col.values if col.validity is None else col.values[col.validity]
        if col.dtype.is_float:
            # ±inf is orderable and must stay in min/max (excluding it would
            # let range pruning drop inf rows); NaN is unorderable, so it is
            # counted instead — "!=" and negation pruning consult nan_count
            nn = vals[~np.isnan(vals)]
            st.nan_count = int(len(vals) - len(nn))
            st.sum = float(nn.sum()) if len(nn) else 0.0
            if len(nn):
                st.min, st.max = float(nn.min()), float(nn.max())
        else:
            st.sum = exact_int_sum(vals)
            st.min = _json_safe(vals.min())
            st.max = _json_safe(vals.max())
            if with_bloom:
                uniq = np.unique(vals)
                if len(uniq) <= _BLOOM_MAX_DISTINCT:
                    st.bloom = _bloom_from_ints(uniq)
    elif k == KIND_STRING:
        vals = [v for v in col.to_pylist() if v is not None]
        if vals:
            # truncation must keep the bounds sound: a min prefix only sorts
            # lower, but a bare max prefix can sort BELOW longer values that
            # share it — pad it to an upper bound (Parquet bumps the last
            # byte; the max code point is the simplest sound equivalent)
            st.min = min(vals)[:_STR_STAT_MAX]
            mx = max(vals)
            st.max = (mx if len(mx) <= _STR_STAT_MAX
                      else mx[:_STR_STAT_MAX] + "\U0010ffff")
            if with_bloom:
                uniq = set(vals)
                if len(uniq) <= _BLOOM_MAX_DISTINCT:
                    st.bloom = _bloom_from_values(
                        [u.encode("utf-8") for u in uniq])
    # tensor/list/binary: only counts (nothing orderable to prune on)
    return st


def merge_stat_maps(maps: List[Dict[str, ColumnStats]]) -> Dict[str, ColumnStats]:
    """File-level stats from per-row-group stats maps.

    Used by the scan planner (:mod:`repro.core.scan`) for fragment-level
    pruning: one merged ``{column: ColumnStats}`` summarising a whole file.
    All maps must describe the same column set (true within one TPQ file,
    whose row groups share a schema) — a column absent from some maps would
    make the merged stats unsound for pruning.
    """
    out: Dict[str, ColumnStats] = {}
    for name in {n for m in maps for n in m}:
        out[name] = merge_stats([m[name] for m in maps if name in m])
    return out


def merge_stats(parts: List[ColumnStats]) -> ColumnStats:
    """Row-group stats from page stats (Parquet: footer aggregates pages)."""
    out = ColumnStats()
    blooms = []
    acc_sum: Any = 0
    for p in parts:
        out.num_values += p.num_values
        out.null_count += p.null_count
        out.nan_count += p.nan_count
        if p.min is not None:
            out.min = p.min if out.min is None else min(out.min, p.min)
            out.max = p.max if out.max is None else max(out.max, p.max)
        if acc_sum is not None:
            if p.sum is not None:
                acc_sum = acc_sum + p.sum
            elif p.num_values > p.null_count:
                # a part with valid values but no recorded sum (pre-sum
                # file, non-numeric chunk) poisons the merged sum; an
                # all-null/empty part just contributes 0
                acc_sum = None
        blooms.append(p.bloom)
    out.sum = acc_sum if parts and any(p.sum is not None for p in parts) \
        else None
    if (blooms and all(b is not None for b in blooms)
            and len({len(b) for b in blooms}) == 1):
        acc = np.zeros(len(blooms[0]), np.uint8)
        for b in blooms:
            acc |= np.frombuffer(b, np.uint8)
        out.bloom = acc.tobytes()
    return out
