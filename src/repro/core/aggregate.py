"""Aggregate pushdown: answer count/min/max/sum/mean from footer statistics.

The paper's scan story ("statistics replace indexes") extends naturally to
aggregation: the same per-row-group ``ColumnStats`` that prune a filtered
scan can often *answer* an aggregate outright — a ``count`` or ``min`` over
a predicate needs no decoded page when statistics already decide the
predicate for every row of a row group.  :class:`AggregatePlan` implements
that three-way classification on top of the scan planner:

  fully-pruned   — ``Expr.prune`` refutes the row group (or its whole
                   fragment): contributes nothing, costs nothing.
  fully-covered  — ``Expr.all_match`` proves every row matches (or there
                   is no filter) and no delta shadows the group: the
                   contribution is read straight from the footer
                   (``num_values``/``null_count``/``nan_count``, ``min``/
                   ``max``, and the ``sum`` statistic the writer records
                   per chunk).  **Zero pages decoded.**
  partial        — statistics cannot decide: the row group flows through
                   the normal vectorized scan (morsel-parallel, late
                   materialization, delta overlay, residual filter) and
                   the decoded batches are reduced — min/max through
                   ``active_backend().minmax`` (the Pallas ``page_minmax``
                   kernel on the jax backend).

Merge-on-read deltas fold in **exactly**: a row group whose id range
intersects any upserted or tombstoned id is never answered from its
(stale or to-be-filtered) statistics — it drops to the partial path, where
the :class:`~repro.core.scan.DeltaOverlay` substitutes/drops rows before
the reduction, and upsert-overlapped fragments are fully decoded just as
in a plain scan.

Semantics (SQL-flavored, documented in docs/ARCHITECTURE.md):

  - ``count(col)``  — non-null values (NaN counts: it is a value);
  - ``count(*)``    — rows (spec key ``"*"``);
  - ``min``/``max`` — over non-null values, NaN excluded (numeric or
                      string columns);
  - ``sum``/``mean``— over non-null, non-NaN numeric values; ``None``
                      when no such value exists.

``explain`` surfaces the win: ``ScanCounters.groups_answered_by_stats``
and ``bytes_skipped_agg`` (stored bytes of the read set that were never
decoded because footer statistics answered them).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from .backend import active_backend
from .dtypes import KIND_NUMERIC, KIND_STRING
from .expressions import Expr
from .fileformat import TPQReader
from .scan import DeltaOverlay, ScanCounters, ScanPlan, ScanReport
from .schema import ID_COLUMN, Schema
from .statistics import _STR_STAT_MAX, ColumnStats, exact_int_sum
from .table import Table
from .transactions import DeltaEntry

__all__ = ["AggregatePlan", "AGG_OPS"]

AGG_OPS = ("count", "min", "max", "sum", "mean")

AggSpec = Dict[str, Union[str, Sequence[str]]]


def _normalize_spec(spec: AggSpec, schema: Schema) -> Dict[str, List[str]]:
    if not spec:
        raise ValueError("aggregate spec is empty")
    out: Dict[str, List[str]] = {}
    for col, ops in spec.items():
        ops = [ops] if isinstance(ops, str) else list(ops)
        if not ops:
            raise ValueError(f"no aggregate ops for column {col!r}")
        for op in ops:
            if op not in AGG_OPS:
                raise ValueError(f"unknown aggregate op {op!r} "
                                 f"(expected one of {AGG_OPS})")
        if col == "*":
            if ops != ["count"]:
                raise ValueError("'*' supports only the 'count' aggregate")
        else:
            if col not in schema:
                raise KeyError(f"unknown column {col!r}")
            kind = schema[col].dtype.kind
            for op in ops:
                if op in ("sum", "mean") and kind != KIND_NUMERIC:
                    raise TypeError(f"{op}({col}): column is not numeric")
                if op in ("min", "max") and kind not in (KIND_NUMERIC,
                                                         KIND_STRING):
                    raise TypeError(f"{op}({col}): column is not orderable")
                if op == "count":
                    continue
        out[col] = ops
    return out


def _scalar(v: Any) -> Any:
    return v.item() if isinstance(v, np.generic) else v


@dataclasses.dataclass
class _ColAcc:
    """Running reduction state for one aggregated column."""
    count: int = 0       # non-null values (rows, for the "*" accumulator)
    vcount: int = 0      # non-null AND non-NaN — the sum/mean domain
    total: Any = 0       # sum over the vcount domain
    min: Any = None
    max: Any = None

    def add_minmax(self, lo: Any, hi: Any) -> None:
        self.min = lo if self.min is None else min(self.min, lo)
        self.max = hi if self.max is None else max(self.max, hi)


class AggregatePlan:
    """Plan + execute one aggregate query over a manifest snapshot.

    Parameters mirror :class:`~repro.core.scan.ScanPlan` (same
    ``reader_of`` injection, config duck-typing and delta chain); ``spec``
    maps column name — or ``"*"`` — to one op or a list of ops from
    :data:`AGG_OPS`.  ``execute`` returns ``{column: {op: value}}``;
    :meth:`report` (after execute) returns a :class:`ScanReport` whose
    counters include ``groups_answered_by_stats`` / ``bytes_skipped_agg``.
    """

    def __init__(self, files: Sequence[str],
                 reader_of: Callable[[str], TPQReader],
                 schema: Schema, spec: AggSpec,
                 filter_expr: Optional[Expr] = None,
                 cfg=None, deltas: Sequence[DeltaEntry] = (),
                 partitioning=None):
        self._reader_of = reader_of
        self._schema = schema
        self._spec = _normalize_spec(spec, schema)
        self._expr = filter_expr
        self._cfg = cfg
        self._files = list(files)
        self._deltas = list(deltas)
        self._need = [c for c in self._spec if c != "*"]
        # the decode path needs at least one physical column to carry row
        # counts for count(*); id is always present.  Aggregation is
        # order-insensitive, so the plan skips the partition id-merge
        # (ordered=False) while keeping manifest-level partition pruning.
        scan_cols = self._need or [ID_COLUMN]
        self._plan = ScanPlan(files, reader_of, schema, columns=scan_cols,
                              filter_expr=filter_expr, cfg=cfg, deltas=deltas,
                              partitioning=partitioning, ordered=False)
        self._counters: Optional[ScanCounters] = None
        self._executed = False

    # ---------------------------------------------------------------- classify
    def _shadow_free(self, rd: TPQReader, i: int,
                     ov: Optional[DeltaOverlay]) -> bool:
        """No upserted or tombstoned id can fall inside this row group."""
        if ov is None or not ov.has_work:
            return True
        st = rd.row_group_stats(i).get(ID_COLUMN)
        if st is None or st.min is None:
            return False  # cannot bound the group's ids: assume shadowed
        lo = np.searchsorted(ov.shadow_ids, st.min, "left")
        hi = np.searchsorted(ov.shadow_ids, st.max, "right")
        return not bool(hi > lo)

    def _stats_sufficient(self, rd: TPQReader,
                          stats: Dict[str, ColumnStats]) -> bool:
        """Can every requested op be answered from this group's footer?"""
        for col, ops in self._spec.items():
            if col == "*":
                continue  # row count is always in the footer
            st = stats.get(col)
            if st is None:
                continue  # column absent from this file: aligns to null,
                #           contributes nothing — answerable by definition
            all_null = st.num_values == st.null_count
            for op in ops:
                if op == "count":
                    continue
                if all_null:
                    continue  # no valid values: zero contribution
                if op in ("min", "max"):
                    if st.min is None:
                        return False  # e.g. all-NaN float group
                    if isinstance(st.min, str) and (
                            len(st.min) >= _STR_STAT_MAX
                            or len(st.max) >= _STR_STAT_MAX):
                        # long-string bounds are truncated/padded — sound
                        # for pruning, but NOT actual column values, so an
                        # aggregate must not report them: decode instead
                        return False
                if op in ("sum", "mean") and st.sum is None:
                    return False  # pre-`sum`-statistic file: decode it
        return True

    def _covered(self, frag, rd: TPQReader, i: int,
                 ov: Optional[DeltaOverlay]) -> bool:
        if frag.delta_overlap:
            return False  # stale stats: the scan decodes these fully anyway
        if not self._shadow_free(rd, i, ov):
            return False
        stats = rd.row_group_stats(i)
        if self._expr is not None:
            if not frag.pushdown:
                return False  # file is missing a filter column: residual path
            if not self._expr.all_match(stats):
                return False
        return self._stats_sufficient(rd, stats)

    # ----------------------------------------------------------------- reduce
    def _acc_stats(self, accs: Dict[str, _ColAcc], rd: TPQReader,
                   i: int) -> None:
        """Fold one fully-covered row group's footer into the accumulators."""
        stats = rd.row_group_stats(i)
        if "*" in accs:
            accs["*"].count += rd.row_group_num_rows(i)
        for col in self._need:
            st = stats.get(col)
            if st is None:
                continue  # absent column: all null after alignment
            a = accs[col]
            valid = st.num_values - st.null_count
            a.count += valid
            vc = valid - st.nan_count
            a.vcount += vc
            if vc and st.sum is not None:
                a.total = a.total + st.sum
            if st.min is not None:
                a.add_minmax(st.min, st.max)

    def _acc_table(self, accs: Dict[str, _ColAcc], t: Table) -> None:
        """Fold one decoded (filtered, delta-merged) batch into the
        accumulators — same semantics as the footer path."""
        if "*" in accs:
            accs["*"].count += t.num_rows
        for col in self._need:
            c = t.column(col)
            a = accs[col]
            if c.dtype.kind == KIND_NUMERIC:
                vals = c.values if c.validity is None else \
                    c.values[c.validity]
                a.count += int(len(vals))
                nn = vals[~np.isnan(vals)] if c.dtype.is_float else vals
                a.vcount += int(len(nn))
                if len(nn):
                    ops = self._spec[col]
                    if "sum" in ops or "mean" in ops:
                        a.total = a.total + (float(nn.sum())
                                             if c.dtype.is_float
                                             else exact_int_sum(nn))
                    if "min" in ops or "max" in ops:
                        lo, hi = active_backend().minmax(nn)
                        a.add_minmax(_scalar(lo), _scalar(hi))
            elif c.dtype.kind == KIND_STRING:
                valid = int(len(c) - c.null_count)
                a.count += valid
                a.vcount += valid
                ops = self._spec[col]
                if valid and ("min" in ops or "max" in ops):
                    # materialize only when an order statistic needs the
                    # values; a bare count comes from the validity mask
                    vals = [v for v in c.to_pylist() if v is not None]
                    a.add_minmax(min(vals), max(vals))
            else:  # null column (schema-evolved rows): nothing to add
                continue

    # ---------------------------------------------------------------- execute
    def execute(self) -> Dict[str, Dict[str, Any]]:
        """Run the aggregate; returns ``{column: {op: value}}``.

        Covered row groups are answered from footers in plan order; the
        remaining partial groups run through one restricted
        :class:`ScanPlan` (morsel-parallel, delta-exact).
        """
        frags = self._plan.fragments()
        ov = self._plan._overlay()
        c = dataclasses.replace(self._plan._plan_counters)
        accs: Dict[str, _ColAcc] = {col: _ColAcc() for col in self._spec}
        restrict: Dict[str, List[int]] = {}
        read_names = self._plan._read_schema.names
        for frag in frags:
            if frag.partition_pruned:
                # the filter provably excludes this whole partition:
                # contributes nothing, and the footer stays unopened
                continue
            rd = self._reader_of(frag.file)
            cols_here = [n for n in read_names if n in rd.schema]
            for i in frag.row_groups:
                if self._covered(frag, rd, i, ov):
                    self._acc_stats(accs, rd, i)
                    c.groups_answered_by_stats += 1
                    c.bytes_skipped_agg += rd.read_row_group_bytes(i,
                                                                   cols_here)
                else:
                    restrict.setdefault(frag.file, []).append(i)
        if restrict:
            part = ScanPlan([f for f in self._files if f in restrict],
                            self._reader_of, self._schema,
                            columns=self._need or [ID_COLUMN],
                            filter_expr=self._expr, cfg=self._cfg,
                            deltas=self._deltas, overlay=ov,
                            restrict=restrict)
            for t in part.execute(counters=c):
                self._acc_table(accs, t)
        self._counters = c
        self._executed = True
        return self._results(accs)

    def _results(self, accs: Dict[str, _ColAcc]) -> Dict[str, Dict[str, Any]]:
        out: Dict[str, Dict[str, Any]] = {}
        for col, ops in self._spec.items():
            a = accs[col]
            vals: Dict[str, Any] = {}
            for op in ops:
                if op == "count":
                    vals[op] = a.count
                elif op == "min":
                    vals[op] = _scalar(a.min)
                elif op == "max":
                    vals[op] = _scalar(a.max)
                elif op == "sum":
                    vals[op] = _scalar(a.total) if a.vcount else None
                elif op == "mean":
                    vals[op] = (_scalar(a.total) / a.vcount) if a.vcount \
                        else None
            out[col] = vals
        return out

    # ----------------------------------------------------------------- report
    def report(self) -> ScanReport:
        """Post-execution :class:`ScanReport` with the aggregate counters.

        ``groups_answered_by_stats`` / ``bytes_skipped_agg`` quantify the
        pushdown win; scan-side counters (pages/rows/bytes decoded) cover
        only the partial row groups that actually decoded.
        """
        if not self._executed:
            self.execute()
        return ScanReport(counters=self._counters,
                          fragments=self._plan.fragments(),
                          columns=list(self._need),
                          filter=repr(self._expr)
                          if self._expr is not None else None,
                          executed=True)
