"""Manifest-log transaction protocol for the dataset directory.

The paper's ParquetDB copies files to a temp dir before modifying and restores
on error — Atomicity/Consistency/Isolation with "quasi-durability" (manual
recovery after a crash).  We strengthen this (beyond-paper improvement #1,
DESIGN.md §7): the committed state of a dataset is the head of an
**append-only manifest log**.  Generation *N* is the file
``_manifest.<N>.json``; committing generation *N+1* is one atomic hard-link
of a fully-fsynced temp file into that name.  The link either exists or it
does not — a crash at any point leaves the previous generation intact, and
two racing committers cannot both create it (the link is the compare-and-
swap that serializes the log).  ``_manifest.json`` is kept as a *pointer*:
a copy of the head manifest rewritten after every commit so legacy tooling
and the stat-memoized read path keep working; the log is canonical and the
pointer is repaired on open if a crash landed between link and pointer.

A manifest references two kinds of data files (see docs/TRANSACTIONS.md):

  - **base files** (``Manifest.files``): immutable row storage, ordered;
  - **delta files** (``Manifest.deltas``): the merge-on-read layer.  Each
    entry is a :class:`DeltaEntry` — an *upsert* file (full-width replacement
    rows keyed by id) or a *tombstone* file (ids of deleted rows) — applied
    over the base files in commit order at read time.  ``update``/``delete``
    append one delta instead of rewriting base files; compaction
    (:mod:`repro.core.compaction`) folds the chain back into base files.

Every commit bumps ``generation``; readers that loaded generation *g* keep a
consistent snapshot as long as *g*'s files exist on disk (compaction defers
file deletion to the next open precisely to give in-flight readers that
grace — see ``DatasetDir.gc``).

Concurrency comes in two flavors:

  - **Structural writers** (create, normalize, compaction, column drops,
    schema/metadata edits) serialize through the exclusive
    :class:`WriteLock` as before — they rewrite file lists and cannot be
    rebased mechanically.
  - **Delta writers** (upsert/tombstone commits — the hot mutation path)
    are **optimistic**: a :class:`Transaction` snapshots a generation,
    stages its delta files lock-free under collision-free ``_stage-`` names,
    and validates at commit time against every generation committed since
    its snapshot — id-range overlap first (``ColumnStats.overlaps_range``
    on the staged footer, no page decoded), exact id intersection to
    confirm.  Non-overlapping transactions *rebase*: their entries are
    appended to the current head and published as the next generation.
    Overlapping transactions raise :class:`CommitConflict` — exactly one of
    two racing writers to the same rows wins.  Publication itself holds the
    write lock only for the short validate+link critical section, and a
    :class:`GroupCommitter` batches every transaction queued behind the
    same lock into **one** generation (group commit: N small upserts, one
    fsync+link).

Crash injection for tests: ``PRE_COMMIT_HOOK`` fires after staging, right
before the atomic link (the classic torn-commit window); ``POST_COMMIT_HOOK``
fires after the link but before the pointer rewrite (the committed-but-
stale-pointer window, repaired on next open).
"""
from __future__ import annotations

import copy
import dataclasses
import errno
import json
import os
import re
import socket
import time
import threading
import uuid
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

MANIFEST = "_manifest.json"          # pointer: copy of the log head
LOCKFILE = "_lock"

_GEN_RE = re.compile(r"^_manifest\.(\d{10})\.json$")
MANIFEST_KEEP = 64      # trailing log generations kept for validation
# Unreferenced files written under collision-free _stage- names belong to
# in-flight optimistic writers; GC may only collect them once they are
# older than this grace (a crashed transaction's leftovers), never while a
# live writer might still be about to publish them.
STAGE_MARKER = "_stage-"
STAGE_GRACE_SECONDS = 600.0

# delta kinds recorded in Manifest.deltas (and in each file's footer flag)
DELTA_UPSERT = "upsert"
DELTA_TOMBSTONE = "tombstone"

# test hooks: crash-injection tests set these to simulate power loss.
# PRE_COMMIT_HOOK: between staging and the atomic link of the next
# generation;  POST_COMMIT_HOOK: after the link, before the pointer rewrite.
PRE_COMMIT_HOOK: Optional[Callable[[], None]] = None
POST_COMMIT_HOOK: Optional[Callable[[], None]] = None

# Generation-change listeners, keyed by realpath of the dataset directory
# (same keying as the group-committer registry below).  Fired after every
# successful *in-process* publish — the serving tier's result cache hangs
# off this to invalidate superseded generations eagerly.  Cross-process
# writers never fire it, so listeners must stay a hygiene layer, not a
# correctness layer: correctness comes from keying reads on the generation
# observed at snapshot time.
_COMMIT_LISTENERS: Dict[str, List[Callable[[int], None]]] = {}
_COMMIT_LISTENERS_LOCK = threading.Lock()


def register_commit_listener(path: str,
                             fn: Callable[[int], None]) -> Callable[[], None]:
    """Subscribe ``fn(generation)`` to successful commits of the dataset
    directory at ``path``; returns an unregister callable.  Listener
    exceptions are swallowed — a subscriber must never be able to fail a
    commit that already published."""
    key = os.path.realpath(path)
    with _COMMIT_LISTENERS_LOCK:
        _COMMIT_LISTENERS.setdefault(key, []).append(fn)

    def unregister() -> None:
        with _COMMIT_LISTENERS_LOCK:
            listeners = _COMMIT_LISTENERS.get(key, [])
            if fn in listeners:
                listeners.remove(fn)
            if not listeners:
                _COMMIT_LISTENERS.pop(key, None)

    return unregister


def _notify_commit(path: str, generation: int) -> None:
    key = os.path.realpath(path)
    with _COMMIT_LISTENERS_LOCK:
        listeners = tuple(_COMMIT_LISTENERS.get(key, ()))
    for fn in listeners:
        try:
            fn(generation)
        except Exception:
            pass


class CommitConflict(Exception):
    """Optimistic commit aborted: a generation committed since this
    transaction's snapshot overlaps its staged rows (or restructured the
    dataset in a way that cannot be rebased).  The caller may re-run the
    whole operation against the new head."""


@dataclasses.dataclass(frozen=True)
class DeltaEntry:
    """One link of the merge-on-read chain: a staged delta file + its kind.

    ``partitions`` (optional) lists the hive partition keys the delta's
    rows touch — recorded when the dataset is partitioned so conflict
    validation can skip the id-intersection walk entirely for writers on
    disjoint partitions (partition columns are immutable per row, so two
    deltas in disjoint partitions cannot share an id by construction).
    ``None`` means unknown: always checked the exact way.
    """
    name: str
    kind: str  # DELTA_UPSERT | DELTA_TOMBSTONE
    partitions: Optional[Tuple[str, ...]] = None

    def to_dict(self) -> dict:
        d = {"name": self.name, "kind": self.kind}
        if self.partitions is not None:
            d["partitions"] = list(self.partitions)
        return d


@dataclasses.dataclass
class Manifest:
    dataset: str
    generation: int = 0
    next_file_id: int = 0
    next_row_id: int = 0
    files: List[str] = dataclasses.field(default_factory=list)
    deltas: List[DeltaEntry] = dataclasses.field(default_factory=list)
    metadata: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "Manifest":
        d = dict(d)
        d["deltas"] = [
            DeltaEntry(e["name"], e["kind"],
                       tuple(e["partitions"]) if e.get("partitions")
                       is not None else None)
            for e in d.get("deltas", [])]
        return Manifest(**d)

    def copy(self) -> "Manifest":
        """Independent mutable copy (lists fresh, metadata deep-copied)."""
        return Manifest(dataset=self.dataset, generation=self.generation,
                        next_file_id=self.next_file_id,
                        next_row_id=self.next_row_id,
                        files=list(self.files), deltas=list(self.deltas),
                        metadata=copy.deepcopy(self.metadata))


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
    except OSError:
        pass  # not supported on some filesystems


def atomic_write_json(path: str, obj: dict) -> None:
    tmp = path + ".tmp"
    try:
        with open(tmp, "w") as fh:
            json.dump(obj, fh)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
    except OSError:
        # ENOSPC/EIO mid-write: never leave a partial .tmp behind (and
        # never replace the target with one)
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    _fsync_dir(os.path.dirname(path) or ".")


def _stage_grace() -> float:
    """Staged-file GC grace in seconds (env-overridable for tests)."""
    v = os.environ.get("REPRO_STAGE_GC_SECONDS")
    if v is not None:
        try:
            return float(v)
        except ValueError:
            pass
    return STAGE_GRACE_SECONDS


_STAGE_PID_RE = re.compile(re.escape(STAGE_MARKER) + r"([0-9a-f]+)-")


def _stage_pid_is_dead(name: str) -> bool:
    """True when a ``_stage-`` file's embedded writer pid is provably dead.

    Conservative: unknown pids (unparseable name, permission errors, pid
    reuse) count as alive, so a live writer's staging is never collected
    early — the age grace period remains the backstop.
    """
    m = _STAGE_PID_RE.search(name)
    if not m:
        return False
    try:
        os.kill(int(m.group(1), 16), 0)
        return False
    except ProcessLookupError:
        return True
    except (OSError, ValueError, OverflowError):
        return False


class DatasetDir:
    """Owns the manifest log + lock + garbage collection for one dataset dir."""

    def __init__(self, path: str, dataset: str):
        self.path = path
        self.dataset = dataset
        os.makedirs(path, exist_ok=True)
        self._mpath = os.path.join(path, MANIFEST)

    # -- manifest log -----------------------------------------------------------
    def _gen_name(self, gen: int) -> str:
        return f"_manifest.{gen:010d}.json"

    def _gen_path(self, gen: int) -> str:
        return os.path.join(self.path, self._gen_name(gen))

    def log_generations(self) -> List[int]:
        """Generations present in the manifest log, ascending."""
        gens = []
        try:
            names = os.listdir(self.path)
        except OSError:
            return []
        for fn in names:
            m = _GEN_RE.match(fn)
            if m:
                gens.append(int(m.group(1)))
        gens.sort()
        return gens

    # A damaged manifest JSON must read as "absent", never escape untyped:
    # ValueError covers truncated/garbage JSON (json.JSONDecodeError),
    # TypeError/KeyError cover parsed-but-wrong-shape documents (``null``,
    # a list, missing fields) that break ``Manifest.from_dict``.  ``load``
    # then falls back to the log head and ``repair_pointer`` rewrites the
    # pointer — a zero-byte or torn ``_manifest.json`` self-heals on open.
    _BAD_MANIFEST = (OSError, ValueError, TypeError, KeyError)

    def load_generation(self, gen: int) -> Optional[Manifest]:
        """One specific committed generation, or None if absent/damaged."""
        try:
            with open(self._gen_path(gen)) as fh:
                return Manifest.from_dict(json.load(fh))
        except self._BAD_MANIFEST:
            return None

    def _load_pointer(self) -> Optional[Manifest]:
        try:
            with open(self._mpath) as fh:
                return Manifest.from_dict(json.load(fh))
        except self._BAD_MANIFEST:
            return None

    def load(self) -> Manifest:
        """The head of the manifest log (canonical committed state).

        The log is the truth; the ``_manifest.json`` pointer is only
        trusted when it is at least as new as the newest log file (it is a
        copy of the head, so serving it is equivalent) — a crash between
        link and pointer rewrite leaves the pointer one generation behind,
        and the newest log file wins.
        """
        gens = self.log_generations()
        pointer = self._load_pointer()
        head = gens[-1] if gens else 0
        if pointer is not None and pointer.generation >= head:
            return pointer
        # the log may be pruned concurrently by another opener; walk back
        for g in reversed(gens):
            man = self.load_generation(g)
            if man is not None:
                return man
        if pointer is not None:
            return pointer
        return Manifest(dataset=self.dataset)

    def exists(self) -> bool:
        """True when any committed generation is on disk."""
        return os.path.exists(self._mpath) or bool(self.log_generations())

    def try_commit(self, manifest: Manifest) -> bool:
        """Atomically publish ``manifest`` as generation ``generation + 1``.

        The compare-and-swap of the protocol: the fully-fsynced temp file is
        hard-linked into the generation's log name.  Exactly one committer
        can create that name — False means another writer won the race and
        the caller must re-validate against the new head.  On success the
        ``_manifest.json`` pointer is rewritten (best-effort copy of the
        head; repaired on next open if a crash lands in between).
        """
        manifest.generation += 1
        if PRE_COMMIT_HOOK is not None:
            PRE_COMMIT_HOOK()
        final = self._gen_path(manifest.generation)
        tmp = os.path.join(
            self.path,
            f"{self._gen_name(manifest.generation)}.tmp-{os.getpid():x}-"
            f"{uuid.uuid4().hex[:8]}")
        try:
            with open(tmp, "w") as fh:
                json.dump(manifest.to_dict(), fh)
                fh.flush()
                os.fsync(fh.fileno())
        except OSError:
            # ENOSPC/EIO writing the staged generation: clean up the
            # partial temp and undo the bump — nothing was published
            try:
                os.unlink(tmp)
            except OSError:
                pass
            manifest.generation -= 1
            raise
        try:
            os.link(tmp, final)
        except FileExistsError:
            os.unlink(tmp)
            manifest.generation -= 1
            return False
        except OSError as e:
            # filesystem without hard links: fall back to an existence
            # check + rename (not a true CAS, but these filesystems are
            # single-host dev setups where the write lock already
            # serializes publication)
            if e.errno not in (errno.EPERM, errno.EOPNOTSUPP, errno.ENOSYS):
                os.unlink(tmp)
                raise
            if os.path.exists(final):
                os.unlink(tmp)
                manifest.generation -= 1
                return False
            os.replace(tmp, final)
            _fsync_dir(self.path)
            tmp = None
        if tmp is not None:
            os.unlink(tmp)
            _fsync_dir(self.path)
        if POST_COMMIT_HOOK is not None:
            POST_COMMIT_HOOK()
        atomic_write_json(self._mpath, manifest.to_dict())
        self._prune_log(manifest.generation)
        _notify_commit(self.path, manifest.generation)
        return True

    def commit(self, manifest: Manifest, op: Optional[str] = None) -> None:
        """Publish the next generation; caller must hold the write lock.

        Used by the structural write paths, which serialize through
        :meth:`acquire_lock` — under the lock no cooperative writer can
        advance the head, so the CAS cannot fail; if it does, something
        outside the protocol committed and the operation must not be
        retried blindly.
        """
        if op is not None:
            manifest.metadata["op"] = op
            # txn_retries describes the delta batch that wrote it; a
            # structural commit inheriting head metadata must not carry it
            manifest.metadata.pop("txn_retries", None)
        if not self.try_commit(manifest):
            raise CommitConflict(
                f"generation {manifest.generation + 1} was committed "
                f"concurrently (outside the write lock) — dataset "
                f"{self.dataset!r} at {self.path}")

    def _prune_log(self, head: int) -> None:
        """Drop log files older than the validation window (never the head).

        A transaction whose snapshot predates the window cannot diff the
        missing generations and conservatively conflicts (it restarts from
        a fresh snapshot), so pruning trades worst-case optimism for a
        bounded directory.
        """
        floor = head - MANIFEST_KEEP
        if floor <= 0:
            return
        for g in self.log_generations():
            if g < floor:
                try:
                    os.unlink(self._gen_path(g))
                except OSError:
                    pass

    def repair_pointer(self, manifest: Optional[Manifest] = None) -> None:
        """Rewrite the pointer to the log head (crash between link and
        pointer leaves it stale; called from startup recovery)."""
        man = manifest if manifest is not None else self.load()
        if man.generation == 0 and not self.exists():
            return
        pointer = self._load_pointer()
        if pointer is None or pointer.generation < man.generation:
            atomic_write_json(self._mpath, man.to_dict())

    # -- files --------------------------------------------------------------------
    def file_path(self, name: str) -> str:
        return os.path.join(self.path, name)

    _KIND_SUFFIX = {"base": ".tpq",
                    DELTA_UPSERT: ".upsert.tpq",
                    DELTA_TOMBSTONE: ".tombstone.tpq"}

    def new_file_name(self, manifest: Manifest, kind: str = "base",
                      subdir: Optional[str] = None) -> str:
        """Allocate a fresh, never-reused data-file name (lock holders only).

        Delta files get a kind-specific suffix so a directory listing shows
        the merge-on-read chain at a glance; all three end in ``.tpq`` and
        share the garbage-collection rule.  The counter lives in the
        manifest, so only writers holding the write lock may use this —
        lock-free staging uses :meth:`stage_file_name` instead.

        ``subdir`` prefixes the name with a hive partition directory
        (``"year=2024"`` → ``"year=2024/<dataset>_000007.tpq"``); manifest
        file names are always "/"-relative to the dataset directory.
        """
        name = f"{self.dataset}_{manifest.next_file_id:06d}{self._KIND_SUFFIX[kind]}"
        manifest.next_file_id += 1
        return f"{subdir}/{name}" if subdir else name

    def stage_file_name(self, kind: str) -> str:
        """Collision-free data-file name for lock-free optimistic staging.

        No manifest counter involved: pid + random nonce make concurrent
        writers' names disjoint.  The ``_stage-`` marker is a contract with
        :meth:`gc` — unreferenced stage files younger than the grace period
        are presumed to belong to an in-flight transaction and are never
        collected (a crashed transaction's leftovers age out).
        """
        return (f"{self.dataset}{STAGE_MARKER}{os.getpid():x}-"
                f"{uuid.uuid4().hex[:10]}{self._KIND_SUFFIX[kind]}")

    def gc(self, manifest: Manifest) -> List[str]:
        """Remove data files (base + delta) not referenced by the manifest.

        Called on open (startup recovery) and after commits that orphan
        files.  Compaction deliberately does **not** call this inline: old
        generations stay on disk until the next open so that readers holding
        a pre-compaction manifest snapshot can finish (snapshot isolation).

        Concurrent-writer safety: counter-named files are only ever staged
        under the write lock (which every ``gc`` caller holds), so an
        unreferenced one is always a crash leftover.  ``_stage-`` named
        files are staged *lock-free* by optimistic writers, so an
        unreferenced one may belong to a transaction that is about to
        publish — those are skipped until they are older than the staging
        grace period (``REPRO_STAGE_GC_SECONDS``) or their embedded writer
        pid is dead, unless some retained log generation references them
        (then they were committed and are ordinary orphans, e.g. dropped by
        compaction).  Crashed commit temp files (``_manifest.*.tmp-*``)
        age out on the same clock.
        """
        live = set(manifest.files) | {d.name for d in manifest.deltas}
        committed = set(live)
        for gen in self.log_generations():
            if gen == manifest.generation:
                continue
            old = self.load_generation(gen)
            if old is not None:
                committed.update(old.files)
                committed.update(d.name for d in old.deltas)
        grace = _stage_grace()
        now = time.time()
        removed = []
        # walk partition subdirectories too (hive layout); names in the
        # manifest — and therefore in live/committed — are "/"-relative
        names = []
        for root, _dirs, fns in os.walk(self.path):
            rel = os.path.relpath(root, self.path)
            for f in fns:
                names.append(f if rel == "." else
                             f"{rel.replace(os.sep, '/')}/{f}")
        for fn in names:
            full = self.file_path(fn)
            if fn.endswith(".tpq"):
                if fn in live:
                    continue
                if STAGE_MARKER in fn and fn not in committed:
                    try:
                        if (now - os.path.getmtime(full) < grace
                                and not _stage_pid_is_dead(fn)):
                            continue  # in-flight optimistic staging
                    except OSError:
                        continue      # vanished: its writer published/aborted
                try:
                    os.remove(full)
                    removed.append(fn)
                except OSError:
                    pass
            elif fn.startswith("_manifest.") and ".tmp" in fn:
                try:
                    if now - os.path.getmtime(full) >= grace:
                        os.remove(full)
                except OSError:
                    pass
        self._prune_log(manifest.generation)
        return removed

    # -- write lock ----------------------------------------------------------------
    def acquire_lock(self, timeout: float = 30.0) -> "WriteLock":
        return WriteLock(os.path.join(self.path, LOCKFILE), timeout)


class WriteLockTimeout(TimeoutError):
    """Write-lock acquisition failed; the message names the holder."""


class WriteLock:
    """Exclusive advisory lock via O_EXCL create.

    The lock file records ``{pid, host, ts}`` so contention is diagnosable:
    a holder whose pid is dead (same host) is broken immediately instead of
    sleeping out the timeout, and a timeout raises :class:`WriteLockTimeout`
    naming the live holder.  ``timeout=0`` fast-fails on first contention.
    A very old lock (``STALE_SECONDS``) is broken even when liveness cannot
    be determined (foreign host, unreadable file).
    """

    STALE_SECONDS = 300.0

    def __init__(self, path: str, timeout: float):
        self.path = path
        self.timeout = timeout
        self._fd: Optional[int] = None

    def _holder(self) -> Optional[dict]:
        try:
            with open(self.path) as fh:
                raw = fh.read()
        except OSError:
            return None
        try:
            info = json.loads(raw)
            if isinstance(info, dict):
                return info
        except ValueError:
            pass
        try:  # pre-log lock format: bare pid
            return {"pid": int(raw.strip() or -1)}
        except ValueError:
            return {}

    def _holder_is_dead(self, info: Optional[dict]) -> bool:
        """True only when the recorded holder provably cannot be running."""
        if not info:
            return False
        host = info.get("host")
        if host is not None and host != socket.gethostname():
            return False  # foreign host: cannot probe, rely on age
        pid = info.get("pid")
        if not isinstance(pid, int) or pid <= 0:
            return False
        try:
            os.kill(pid, 0)
        except ProcessLookupError:
            return True
        except PermissionError:
            return False  # alive, owned by someone else
        except OSError:
            return False
        return False

    def _describe(self, info: Optional[dict], age: float) -> str:
        if not info:
            return f"holder unknown (unreadable lock file), age {age:.1f}s"
        pid = info.get("pid", "?")
        host = info.get("host", "?")
        return f"held by pid {pid} on {host} for {age:.1f}s"

    def __enter__(self) -> "WriteLock":
        deadline = time.time() + self.timeout
        while True:
            try:
                self._fd = os.open(self.path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                os.write(self._fd, json.dumps(
                    {"pid": os.getpid(), "host": socket.gethostname(),
                     "ts": time.time()}).encode())
                return self
            except OSError as e:
                if e.errno != errno.EEXIST:
                    raise
            info = self._holder()
            if self._holder_is_dead(info):
                # loud break: a dead writer must not serialize live ones
                try:
                    os.remove(self.path)
                except OSError:
                    pass
                continue
            try:
                age = time.time() - os.path.getmtime(self.path)
            except OSError:
                continue  # holder released between probe and stat: retry
            if age > self.STALE_SECONDS:
                try:
                    os.remove(self.path)  # stale beyond doubt-benefit window
                except OSError:
                    pass
                continue
            if time.time() >= deadline:
                raise WriteLockTimeout(
                    f"could not acquire write lock {self.path}: "
                    f"{self._describe(info, age)}; the holder is alive — "
                    f"if this persists past {self.STALE_SECONDS:.0f}s the "
                    f"lock will be considered stale and broken")
            time.sleep(0.02)

    def __exit__(self, *exc):
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None
        try:
            os.remove(self.path)
        except OSError:
            pass


# ---------------------------------------------------------------------------
# Optimistic multi-writer commit protocol
# ---------------------------------------------------------------------------
class Transaction:
    """One optimistic delta commit: snapshot → stage → validate → publish.

    The writer *snapshots* a committed generation, *stages* upsert/tombstone
    files lock-free (collision-free ``_stage-`` names), then *publishes*:
    under the write lock, the staged entries are *validated* against every
    generation committed since the snapshot and, when no staged id overlaps
    a concurrently committed delta, appended to the current head and linked
    in as the next generation (a rebase — the transaction commits on top of
    work it never saw, which is sound exactly because the id sets are
    disjoint).  Overlap raises :class:`CommitConflict`: of two writers
    racing to the same rows, exactly one wins.

    ``reader_of`` maps a data-file name to a ``TPQReader``; validation uses
    it to consult footer id statistics (``ColumnStats.overlaps_range``) and,
    only when ranges overlap, to read the small delta id column for an exact
    intersection check — range misses cost no page decode.
    """

    def __init__(self, dirobj: DatasetDir, reader_of: Callable,
                 op: str = "delta"):
        self.dir = dirobj
        self.reader_of = reader_of
        self.op = op
        self.snapshot_gen: Optional[int] = None
        self.snapshot_man: Optional[Manifest] = None
        self.entries: List[DeltaEntry] = []
        self.entry_ids: List[np.ndarray] = []
        self.committed: Optional[Manifest] = None
        # optimistic attempt index of the operation that staged this txn
        # (0 = first try); published as commit metadata ``txn_retries``
        self.retries: int = 0

    # -- protocol steps ---------------------------------------------------------
    def snapshot(self) -> Manifest:
        """Bind to the current head; returns the snapshot manifest."""
        man = self.dir.load()
        self.snapshot_gen = man.generation
        self.snapshot_man = man
        return man

    def stage(self, entry: DeltaEntry, ids: Sequence[int]) -> None:
        """Record one staged delta file and the exact ids it touches."""
        assert self.snapshot_gen is not None, "stage() before snapshot()"
        arr = np.unique(np.asarray(ids, dtype=np.int64))
        self.entries.append(entry)
        self.entry_ids.append(arr)

    def validate(self, head: Optional[Manifest] = None) -> Optional[str]:
        """Conflict description vs. generations committed since the
        snapshot, or None when a rebase onto ``head`` is sound.

        Advisory when called lock-free (the head can move right after);
        :meth:`publish` re-runs it authoritatively under the lock.
        """
        if head is None:
            head = self.dir.load()
        return self._validate_against(head)

    def publish(self) -> Manifest:
        """Validate + commit under the write lock (group-batched).

        Returns the committed manifest; raises :class:`CommitConflict` when
        a generation committed since the snapshot overlaps the staged rows.
        All transactions queued behind the same lock are folded into one
        generation (group commit).
        """
        man = group_committer(self.dir).commit(self)
        self.committed = man
        return man

    # alias: the ISSUE names the final protocol step after its mechanism
    commit = publish

    # -- validation internals ---------------------------------------------------
    def _id_bounds(self) -> Optional[Tuple[int, int]]:
        los = [int(a[0]) for a in self.entry_ids if len(a)]
        his = [int(a[-1]) for a in self.entry_ids if len(a)]
        if not los:
            return None
        return min(los), max(his)

    def _overlaps_ids(self, theirs: np.ndarray) -> bool:
        if not len(theirs):
            return False
        for mine in self.entry_ids:
            if len(mine) and len(np.intersect1d(mine, theirs,
                                                assume_unique=False)):
                return True
        return False

    def _staged_partitions(self) -> Optional[frozenset]:
        """Union of partition keys staged by this transaction, or None
        when any entry's partitions are unknown (→ no disjointness skip)."""
        parts: set = set()
        for e in self.entries:
            if e.partitions is None:
                return None
            parts.update(e.partitions)
        return frozenset(parts)

    def _conflict_with_staged(self, other_ids: List[np.ndarray]
                              ) -> Optional[str]:
        """Overlap vs. another transaction accepted into the same batch."""
        for theirs in other_ids:
            if self._overlaps_ids(theirs):
                return "staged ids overlap another transaction in the " \
                       "same commit batch"
        return None

    def _conflict_with_batch(self, others: List["Transaction"]
                             ) -> Optional[str]:
        """Overlap vs. the transactions already accepted into this batch.

        Partition fast path first: two transactions whose staged partition
        sets are disjoint cannot share an id (partition columns are
        immutable per row), so the id intersection is skipped entirely.
        """
        mine = self._staged_partitions()
        for o in others:
            if mine is not None:
                theirs_p = o._staged_partitions()
                if theirs_p is not None and not (mine & theirs_p):
                    continue  # disjoint partitions: conflict-free
            reason = self._conflict_with_staged(o.entry_ids)
            if reason is not None:
                return reason
        return None

    def _validate_against(self, head: Manifest) -> Optional[str]:
        assert self.snapshot_man is not None, "validate() before snapshot()"
        if head.generation == self.snapshot_gen:
            return None
        prev = self.snapshot_man
        for g in range(self.snapshot_gen + 1, head.generation + 1):
            cur = head if g == head.generation else self.dir.load_generation(g)
            if cur is None:
                return (f"manifest log pruned at generation {g}; snapshot "
                        f"{self.snapshot_gen} is too old to diff")
            reason = self._diff_conflict(prev, cur)
            if reason is not None:
                return reason
            prev = cur
        return None

    def _diff_conflict(self, prev: Manifest, cur: Manifest) -> Optional[str]:
        """Conflict between this transaction and one committed generation."""
        op = cur.metadata.get("op", "?")
        prev_names = [e.name for e in prev.deltas]
        cur_names = [e.name for e in cur.deltas]
        if cur_names[:len(prev_names)] != prev_names:
            # the delta chain was rewritten, not appended to: compaction and
            # normalize fold it without changing the merged view (logical
            # no-ops for a rebase); anything else restructured the data
            if op in ("compact", "normalize"):
                return None
            return (f"generation {cur.generation} ({op}) rewrote the delta "
                    f"chain; cannot rebase")
        new_entries = cur.deltas[len(prev_names):]
        if cur.files != prev.files and op not in ("create", "compact",
                                                  "normalize"):
            # appends only add rows with fresh (higher) ids and rewrites by
            # compact/normalize preserve the merged view — anything else
            # (e.g. a column drop) invalidates staged full-width rows
            return (f"generation {cur.generation} ({op}) rewrote base "
                    f"files; cannot rebase")
        if not new_entries:
            return None
        bounds = self._id_bounds()
        if bounds is None:
            return None
        mine = self._staged_partitions()
        for e in new_entries:
            # partition fast path: a committed delta whose partitions are
            # provably disjoint from everything staged here cannot share an
            # id (partition columns are immutable per row) — no footer read
            if mine is not None and e.partitions is not None \
                    and not (mine & set(e.partitions)):
                continue
            rd = self.reader_of(e.name)
            st = rd.file_stats().get("id")
            # footer fast path: provably disjoint id ranges need no decode
            if st is not None and not st.overlaps_range(*bounds):
                continue
            theirs = rd.read(columns=["id"]).column("id") \
                       .values.astype(np.int64, copy=False)
            if self._overlaps_ids(theirs):
                return (f"staged ids overlap {e.kind} delta {e.name} "
                        f"committed in generation {cur.generation}")
        return None


class _Pending:
    __slots__ = ("txn", "done", "result", "exc")

    def __init__(self, txn: Transaction):
        self.txn = txn
        self.done = False
        self.result: Optional[Manifest] = None
        self.exc: Optional[BaseException] = None


class GroupCommitter:
    """Batches concurrent optimistic publishes into single generations.

    The first thread to arrive becomes the *leader*: it takes the dataset
    write lock, drains every transaction queued meanwhile, validates each
    against the head (and against the batch accepted so far), and links
    **one** new generation carrying all accepted entries — N small upserts
    cost one fsync + one link.  Followers just wait for their verdict.
    Rejected transactions get :class:`CommitConflict`; an infrastructure
    failure (lock timeout, I/O error) propagates to every batched waiter.
    """

    LOCK_TIMEOUT = 30.0
    CAS_RETRIES = 16

    def __init__(self, dirobj: DatasetDir):
        self.dir = dirobj
        self._cv = threading.Condition()
        self._queue: List[_Pending] = []
        self._leader_active = False

    def commit(self, txn: Transaction) -> Manifest:
        p = _Pending(txn)
        with self._cv:
            self._queue.append(p)
            while self._leader_active and not p.done:
                self._cv.wait()
            if not p.done:
                self._leader_active = True
                batch, self._queue = self._queue, []
        if not p.done:
            try:
                self._publish_batch(batch)
            finally:
                with self._cv:
                    self._leader_active = False
                    for q in batch:
                        q.done = True
                    self._cv.notify_all()
        if p.exc is not None:
            raise p.exc
        assert p.result is not None
        return p.result

    def _publish_batch(self, batch: List[_Pending]) -> None:
        try:
            with self.dir.acquire_lock(timeout=self.LOCK_TIMEOUT):
                # late arrivals queued while we waited for the file lock
                # ride along in the same generation
                with self._cv:
                    if self._queue:
                        batch.extend(self._queue)
                        self._queue = []
                self._publish_locked(batch)
        except BaseException as e:
            for p in batch:
                if p.result is None and p.exc is None:
                    p.exc = e
            if not isinstance(e, Exception):
                raise

    def _publish_locked(self, batch: List[_Pending]) -> None:
        for attempt in range(self.CAS_RETRIES):
            head = self.dir.load()
            accepted: List[_Pending] = []
            rejections: Dict[int, str] = {}
            for i, p in enumerate(batch):
                reason = p.txn._validate_against(head) \
                    or p.txn._conflict_with_batch(
                        [q.txn for q in accepted])
                if reason is not None:
                    rejections[i] = reason
                else:
                    accepted.append(p)
            if accepted:
                new = head.copy()
                for p in accepted:
                    new.deltas.extend(p.txn.entries)
                new.metadata["op"] = "delta"
                # observability for the disjoint-writer guarantee: the max
                # optimistic attempt index across the batch (0 = every
                # writer in this generation committed first-try)
                new.metadata["txn_retries"] = max(
                    p.txn.retries for p in accepted)
                if not self.dir.try_commit(new):
                    # a committer outside our lock (crashed-lock break or
                    # foreign process) advanced the head: re-validate
                    time.sleep(min(0.002 * (attempt + 1), 0.05))
                    continue
                for p in accepted:
                    p.result = new
            for i, reason in rejections.items():
                batch[i].exc = CommitConflict(reason)
            return
        raise CommitConflict(
            "could not publish after "
            f"{self.CAS_RETRIES} compare-and-swap attempts (a writer "
            "outside the lock keeps advancing the manifest log)")


_COMMITTERS: Dict[str, GroupCommitter] = {}
_COMMITTERS_LOCK = threading.Lock()


def group_committer(dirobj: DatasetDir) -> GroupCommitter:
    """Process-wide committer for one dataset directory (keyed by realpath)."""
    key = os.path.realpath(dirobj.path)
    with _COMMITTERS_LOCK:
        gc = _COMMITTERS.get(key)
        if gc is None:
            gc = _COMMITTERS[key] = GroupCommitter(dirobj)
        return gc
