"""Manifest-commit transaction protocol for the dataset directory.

The paper's ParquetDB copies files to a temp dir before modifying and restores
on error — Atomicity/Consistency/Isolation with "quasi-durability" (manual
recovery after a crash).  We strengthen this (beyond-paper improvement #1,
DESIGN.md §7): the committed state of a dataset is *exactly* the file lists in
``_manifest.json``, which is replaced atomically (tmp + fsync + rename).  A
crash at any point leaves the previous manifest intact; uncommitted data files
are garbage-collected on next open.  Recovery is automatic, not manual.

A manifest references two kinds of data files (see docs/TRANSACTIONS.md):

  - **base files** (``Manifest.files``): immutable row storage, ordered;
  - **delta files** (``Manifest.deltas``): the merge-on-read layer.  Each
    entry is a :class:`DeltaEntry` — an *upsert* file (full-width replacement
    rows keyed by id) or a *tombstone* file (ids of deleted rows) — applied
    over the base files in commit order at read time.  ``update``/``delete``
    append one delta instead of rewriting base files; compaction
    (:mod:`repro.core.compaction`) folds the chain back into base files.

Every commit bumps ``generation``; readers that loaded generation *g* keep a
consistent snapshot as long as *g*'s files exist on disk (compaction defers
file deletion to the next open precisely to give in-flight readers that
grace — see ``DatasetDir.gc``).

Writers take an exclusive lock file (single writer, many readers — same
concurrency model the paper reports in Table 11).
"""
from __future__ import annotations

import dataclasses
import errno
import json
import os
import time
from typing import Callable, List, Optional

MANIFEST = "_manifest.json"
LOCKFILE = "_lock"

# delta kinds recorded in Manifest.deltas (and in each file's footer flag)
DELTA_UPSERT = "upsert"
DELTA_TOMBSTONE = "tombstone"

# test hook: called between staging new files and committing the manifest —
# crash-injection tests set this to simulate power loss.
PRE_COMMIT_HOOK: Optional[Callable[[], None]] = None


@dataclasses.dataclass(frozen=True)
class DeltaEntry:
    """One link of the merge-on-read chain: a staged delta file + its kind."""
    name: str
    kind: str  # DELTA_UPSERT | DELTA_TOMBSTONE

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Manifest:
    dataset: str
    generation: int = 0
    next_file_id: int = 0
    next_row_id: int = 0
    files: List[str] = dataclasses.field(default_factory=list)
    deltas: List[DeltaEntry] = dataclasses.field(default_factory=list)
    metadata: dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "Manifest":
        d = dict(d)
        d["deltas"] = [DeltaEntry(**e) for e in d.get("deltas", [])]
        return Manifest(**d)


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
    except OSError:
        pass  # not supported on some filesystems


def atomic_write_json(path: str, obj: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as fh:
        json.dump(obj, fh)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path) or ".")


class DatasetDir:
    """Owns the manifest + lock + garbage collection for one dataset dir."""

    def __init__(self, path: str, dataset: str):
        self.path = path
        self.dataset = dataset
        os.makedirs(path, exist_ok=True)
        self._mpath = os.path.join(path, MANIFEST)

    # -- manifest ---------------------------------------------------------------
    def load(self) -> Manifest:
        if not os.path.exists(self._mpath):
            return Manifest(dataset=self.dataset)
        with open(self._mpath) as fh:
            return Manifest.from_dict(json.load(fh))

    def commit(self, manifest: Manifest) -> None:
        manifest.generation += 1
        if PRE_COMMIT_HOOK is not None:
            PRE_COMMIT_HOOK()
        atomic_write_json(self._mpath, manifest.to_dict())

    # -- files --------------------------------------------------------------------
    def file_path(self, name: str) -> str:
        return os.path.join(self.path, name)

    _KIND_SUFFIX = {"base": ".tpq",
                    DELTA_UPSERT: ".upsert.tpq",
                    DELTA_TOMBSTONE: ".tombstone.tpq"}

    def new_file_name(self, manifest: Manifest, kind: str = "base") -> str:
        """Allocate a fresh, never-reused data-file name.

        Delta files get a kind-specific suffix so a directory listing shows
        the merge-on-read chain at a glance; all three end in ``.tpq`` and
        share the garbage-collection rule.
        """
        name = f"{self.dataset}_{manifest.next_file_id:06d}{self._KIND_SUFFIX[kind]}"
        manifest.next_file_id += 1
        return name

    def gc(self, manifest: Manifest) -> List[str]:
        """Remove data files (base + delta) not referenced by the manifest.

        Called on open (startup recovery) and after commits that orphan
        files.  Compaction deliberately does **not** call this inline: old
        generations stay on disk until the next open so that readers holding
        a pre-compaction manifest snapshot can finish (snapshot isolation).
        """
        live = set(manifest.files) | {d.name for d in manifest.deltas}
        removed = []
        for fn in os.listdir(self.path):
            if not fn.endswith(".tpq"):
                continue
            if fn not in live:
                try:
                    os.remove(self.file_path(fn))
                    removed.append(fn)
                except OSError:
                    pass
        return removed

    # -- write lock ----------------------------------------------------------------
    def acquire_lock(self, timeout: float = 30.0) -> "WriteLock":
        return WriteLock(os.path.join(self.path, LOCKFILE), timeout)


class WriteLock:
    """Exclusive advisory lock via O_EXCL create; stale locks expire."""

    STALE_SECONDS = 300.0

    def __init__(self, path: str, timeout: float):
        self.path = path
        self.timeout = timeout
        self._fd: Optional[int] = None

    def __enter__(self) -> "WriteLock":
        deadline = time.time() + self.timeout
        while True:
            try:
                self._fd = os.open(self.path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                os.write(self._fd, str(os.getpid()).encode())
                return self
            except OSError as e:
                if e.errno != errno.EEXIST:
                    raise
                try:
                    if time.time() - os.path.getmtime(self.path) > self.STALE_SECONDS:
                        os.remove(self.path)  # stale holder
                        continue
                except OSError:
                    continue
                if time.time() > deadline:
                    raise TimeoutError(f"could not acquire write lock {self.path}")
                time.sleep(0.02)

    def __exit__(self, *exc):
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None
        try:
            os.remove(self.path)
        except OSError:
            pass
