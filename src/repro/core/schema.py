"""Schema: ordered field collection with evolution/unification.

Implements the paper's schema behaviour (§4.4.2): alphabetically ordered columns
(simplifies change detection), per-field + table-level metadata, and schema
*evolution* — unify(incoming) adds new fields, promotes numeric widths and keeps
everything else stable, so old row groups stay readable (missing fields read as
null).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

from .dtypes import DType, promote

ID_COLUMN = "id"


@dataclasses.dataclass(frozen=True)
class Field:
    name: str
    dtype: DType
    nullable: bool = True
    metadata: Optional[dict] = None

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "dtype": self.dtype.to_dict(),
            "nullable": self.nullable,
            "metadata": self.metadata or {},
        }

    @staticmethod
    def from_dict(d: dict) -> "Field":
        return Field(
            name=d["name"],
            dtype=DType.from_dict(d["dtype"]),
            nullable=d.get("nullable", True),
            metadata=d.get("metadata") or None,
        )


class Schema:
    """Ordered (alphabetical) mapping of field name -> Field."""

    def __init__(self, fields: List[Field], metadata: Optional[dict] = None):
        self._fields: Dict[str, Field] = {
            f.name: f for f in sorted(fields, key=lambda f: f.name)
        }
        if len(self._fields) != len(fields):
            names = [f.name for f in fields]
            dupes = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate field names: {dupes}")
        self.metadata: dict = dict(metadata or {})

    # -- container protocol --------------------------------------------------
    def __contains__(self, name: str) -> bool:
        return name in self._fields

    def __getitem__(self, name: str) -> Field:
        return self._fields[name]

    def __iter__(self) -> Iterator[Field]:
        return iter(self._fields.values())

    def __len__(self) -> int:
        return len(self._fields)

    def __eq__(self, other) -> bool:
        return (
            isinstance(other, Schema)
            and list(self._fields.values()) == list(other._fields.values())
        )

    @property
    def names(self) -> List[str]:
        return list(self._fields.keys())

    def field(self, name: str) -> Field:
        return self._fields[name]

    # -- evolution ------------------------------------------------------------
    def unify(self, other: "Schema") -> "Schema":
        """Union of fields with numeric promotion (paper: 'Schema Alignment').

        Fields present in only one schema become nullable in the result.  Raises
        TypeError on irreconcilable types so bad writes fail loudly instead of
        corrupting the dataset.
        """
        fields: Dict[str, Field] = {}
        for f in self:
            fields[f.name] = f
        for g in other:
            if g.name in fields:
                f = fields[g.name]
                dt = promote(f.dtype, g.dtype)
                fields[g.name] = Field(
                    g.name, dt, nullable=f.nullable or g.nullable,
                    metadata={**(f.metadata or {}), **(g.metadata or {})} or None,
                )
            else:
                fields[g.name] = dataclasses.replace(g, nullable=True)
        meta = {**self.metadata, **other.metadata}
        return Schema(list(fields.values()), metadata=meta)

    def equals_names_types(self, other: "Schema") -> bool:
        return self.names == other.names and all(
            self[n].dtype == other[n].dtype for n in self.names
        )

    def select(self, names: List[str]) -> "Schema":
        return Schema([self._fields[n] for n in names], metadata=self.metadata)

    def drop(self, names: List[str]) -> "Schema":
        drop = set(names)
        return Schema(
            [f for f in self if f.name not in drop], metadata=self.metadata
        )

    def with_metadata(self, metadata: dict) -> "Schema":
        return Schema(list(self), metadata={**self.metadata, **metadata})

    # -- (de)serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "fields": [f.to_dict() for f in self],
            "metadata": self.metadata,
        }

    @staticmethod
    def from_dict(d: dict) -> "Schema":
        return Schema(
            [Field.from_dict(f) for f in d["fields"]], metadata=d.get("metadata")
        )

    def __repr__(self) -> str:
        inner = ", ".join(f"{f.name}:{f.dtype}" for f in self)
        return f"Schema({inner})"
