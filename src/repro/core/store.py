"""ParquetDB — the paper's user-facing database class, on the TPQ format.

API mirrors the paper (§4.3–§4.6): ``create`` / ``read`` / ``update`` /
``delete`` / ``normalize`` with ``NormalizeConfig`` and ``LoadConfig``,
dotted-field access to nested data, AND-combined filter lists, id generation,
schema evolution, and ``rebuild_nested_struct``.  Durability is by the
manifest-commit protocol in :mod:`repro.core.transactions` (beyond-paper: a
crash never requires manual recovery).

Every read routes through the scan planner (:mod:`repro.core.scan`), which
prunes whole files and row groups from footer statistics before decoding a
byte; ``db.explain(filters=...)`` returns the planner's
:class:`~repro.core.scan.ScanReport` so pruning is observable::

    >>> print(db.explain(filters=[field("age") > 100]))
    ScanPlan  filter=(age > 100)  columns=4
      files:      0 scanned, 3 pruned (of 3)
      ...

``update``/``delete`` are **merge-on-read**: instead of rewriting every
affected base file (the paper's O(files) hot spot, §4.5 / Fig. 8) they stage
one small delta file — full-width upsert rows or tombstoned ids — and commit
it as a new manifest generation.  The scan planner overlays the delta chain
at read time; :meth:`ParquetDB.compact` (and the cost-based background
trigger) folds it back into sorted base files.  ``db.maintenance_stats()``
reports the read-side decay that makes compaction worthwhile.

See docs/ARCHITECTURE.md for the read/write data flow and
docs/TRANSACTIONS.md for the transaction + maintenance lifecycle.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import os
import threading
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

from . import nested
from .aggregate import AggSpec
from .compaction import (CompactionPolicy, CompactionResult, MaintenanceStats,
                         compact_locked, gather_stats)
from .dtypes import DType, KIND_STRING
from .encodings import AUTO, CODEC_ZLIB
from .expressions import Expr, IsIn, combine_filters, field
from .fileformat import (DEFAULT_PAGE_ROWS, DEFAULT_ROW_GROUP_ROWS, TPQReader,
                         TPQWriter)
from .integrity import FileCheck, IntegrityReport, verify_file, \
    with_read_retries
from .partition import PartitionSpec, Partitioning
from .query import Query, _resolve_names
from .scan import DeltaOverlay, ScanPlan, ScanReport
from .schema import Field, ID_COLUMN, Schema
from .table import Column, Table, concat_tables, null_column_of
from .transactions import (CommitConflict, DELTA_TOMBSTONE, DELTA_UPSERT,
                           DatasetDir, DeltaEntry, Manifest, Transaction)

TableLike = Union[Table, List[dict], Dict[str, Any]]

# Footer-parse cache: data files are immutable (every rewrite gets a fresh
# name), so (path, size, mtime) fully identifies a footer.  Guarded by a
# lock: background compaction evicts while reader threads look up.
_READER_CACHE: "collections.OrderedDict" = collections.OrderedDict()
_READER_CACHE_MAX = 128
_READER_CACHE_LOCK = threading.Lock()

# Per-thread reader handles over the shared parse (TPQReader.dup): morsel
# workers look up readers on every row-group decode, so the hot path must
# not contend on _READER_CACHE_LOCK nor share stats-memo cells across
# threads.  Entries are validated by the same (path, size, mtime) key and
# invalidated wholesale when the eviction generation advances.
_TL_READERS = threading.local()
_TL_READERS_MAX = 64
_EVICT_GEN = 0


def _get_shared_reader(path: str) -> TPQReader:
    st = os.stat(path)
    key = (path, st.st_size, st.st_mtime_ns)
    with _READER_CACHE_LOCK:
        rd = _READER_CACHE.get(key)
        if rd is not None:
            _READER_CACHE.move_to_end(key)
            return rd
    # parse outside the lock (I/O + zlib); transient EIO from flaky media
    # retries with bounded backoff — corruption raises typed, immediately.
    # Opening also validates the footer checksum (v2 files), so a cached
    # reader implies a verified footer for the file's lifetime.
    rd = with_read_retries(lambda: TPQReader(path), path)
    with _READER_CACHE_LOCK:
        _READER_CACHE[key] = rd
        if len(_READER_CACHE) > _READER_CACHE_MAX:
            _READER_CACHE.popitem(last=False)
    return rd


def _get_reader(path: str) -> TPQReader:
    """This thread's handle for ``path`` (shared mmap, private stats memos).

    The footer is parsed once process-wide (``_get_shared_reader``); each
    thread then holds a lock-free ``dup``, so concurrent morsel workers
    touch no shared mutable reader state.  The thread cache is keyed by
    path alone — data-file names are never reused within a dataset
    (``DatasetDir.new_file_name`` is monotonic), so a path fully
    identifies content and the hot lookup skips ``os.stat`` entirely.
    Opening a dataset handle or evicting readers bumps the generation,
    which lazily flushes every thread's cache (covers the delete-and-
    recreate-directory case, where names CAN recur).  Limitation: a
    directory deleted and recreated by *another process* while this one
    keeps reading is outside the snapshot-isolation contract (the
    manifest protocol guarantees consistency only while a generation's
    files stay on disk) — that scenario could serve a stale mapping here.
    """
    cache = getattr(_TL_READERS, "cache", None)
    if cache is None or _TL_READERS.gen != _EVICT_GEN:
        cache = _TL_READERS.cache = {}
        _TL_READERS.gen = _EVICT_GEN
    rd = cache.get(path)
    if rd is None:
        rd = cache[path] = _get_shared_reader(path).dup()
        if len(cache) > _TL_READERS_MAX:
            cache.pop(next(iter(cache)))
    return rd


def _evict_readers(paths: Iterable[str]) -> None:
    """Drop cached footers for files removed by compaction/GC.

    Stale keys can never serve a wrong read (lookup re-stats the path), but
    they pin dead footers in memory until LRU pressure; compaction can drop
    a whole generation at once, so evict eagerly.  Per-thread caches are
    invalidated lazily via the generation counter (each thread clears its
    own cache on next lookup — a thread-local cannot be cleared from here).
    """
    global _EVICT_GEN
    drop = set(paths)
    with _READER_CACHE_LOCK:
        for key in [k for k in _READER_CACHE if k[0] in drop]:
            del _READER_CACHE[key]
        _EVICT_GEN += 1  # under the lock: bumps must never be lost


@dataclasses.dataclass
class NormalizeConfig:
    """Paper Table 10 (+ ``num_threads``, this repo's parallel-scan knob)."""
    load_format: str = "table"
    batch_size: Optional[int] = None
    batch_readahead: int = 16
    fragment_readahead: int = 4
    use_threads: bool = True
    num_threads: Optional[int] = None   # morsel workers; None = cpu_count()
    executor: Optional[str] = None      # "thread" | "process" | None = auto
    max_partitions: int = 1024
    max_open_files: int = 1024
    max_rows_per_file: int = 10_000
    min_rows_per_group: int = 0
    max_rows_per_group: int = 10_000


@dataclasses.dataclass
class LoadConfig:
    """Paper Table 8 (+ ``num_threads``, this repo's parallel-scan knob).

    ``num_threads`` sizes the shared morsel pool for this scan: ``None``
    (default) means ``os.cpu_count()``, ``1`` forces the serial path, and
    ``use_threads=False`` overrides everything back to serial.

    ``executor`` picks where morsels decode: ``"thread"`` (shared thread
    pool — right when codec decompression releases the GIL), ``"process"``
    (spawn-context worker processes with shared-memory result transport —
    right when decode is GIL-bound), or ``None`` (default) to let the
    planner choose from the footer's codec split.  Output is byte-identical
    (order included) at every setting of every knob here.

    ``verify`` controls data-integrity checking while decoding:
    ``"page"`` (default) crc-checks every stored page buffer before it is
    decompressed/decoded, raising
    :class:`~repro.core.integrity.CorruptPageError` with file/row-group/
    page coordinates on a mismatch; ``"footer"`` or ``"off"`` skip the
    per-page check (the footer checksum is still validated once when a
    file is first opened, amortized by the reader cache).  Legacy v1
    files carry no checksums and are never page-verified.

    ``on_corruption`` decides what a scan does when a *delta* file turns
    out corrupt: ``"raise"`` (default) propagates the typed error;
    ``"quarantine"`` drops that delta from the overlay (serving base +
    surviving deltas), warns, and counts it in
    ``ScanCounters.files_quarantined`` / ``explain()``.  Corrupt *base*
    files always raise — quarantining one would silently drop rows.

    ``morsel_budget`` (a shared :class:`~repro.core.scan.MorselBudget`, or
    ``None`` = unbounded) caps in-flight morsels *across every scan* that
    carries the same budget instance — the backpressure primitive the
    serving tier uses so concurrent queries throttle each other instead of
    racing the pool into memory bloat.
    """
    batch_size: int = 131_072
    batch_readahead: int = 16
    fragment_readahead: int = 4
    use_threads: bool = True
    num_threads: Optional[int] = None   # morsel workers; None = cpu_count()
    executor: Optional[str] = None      # "thread" | "process" | None = auto
    verify: str = "page"                # "page" | "footer" | "off"
    on_corruption: str = "raise"        # "raise" | "quarantine" (deltas)
    morsel_budget: Optional[Any] = None  # shared MorselBudget | None


class Dataset:
    """Lazy handle returned by ``read(load_format='dataset')``.

    A Dataset is a **bound Query prefix**: its columns/filter/config are a
    partial plan, and every method below delegates to the composable
    :class:`~repro.core.query.Query` it denotes — :meth:`query` hands that
    Query out so a dataset scan can keep composing
    (``ds.query().group_by("k").agg({"x": "mean"})``).
    """

    def __init__(self, db: "ParquetDB", columns, filter_expr, load_config):
        self._db, self._columns = db, columns
        self._filter, self._cfg = filter_expr, load_config

    def query(self) -> Query:
        """This dataset's plan as a composable :class:`Query` prefix."""
        names = (self._db._resolve_columns(self._columns, True)
                 if self._columns is not None else None)
        return self._db._legacy_query(names, self._filter, self._cfg)

    @property
    def schema(self) -> Schema:
        """Schema of the projected output (resolved against the dataset)."""
        names = self._db._resolve_columns(self._columns, True)
        return self._db.schema.select(names)

    def iter_batches(self, batch_size: Optional[int] = None) -> Iterable[Table]:
        """Stream the scan as Tables of ``batch_size`` rows (lazy)."""
        yield from self.query().iter_batches(
            batch_size or self._cfg.batch_size)

    def to_table(self) -> Table:
        """Materialize the whole scan into one Table."""
        return self.query().to_table()

    def scan_plan(self) -> ScanPlan:
        """The underlying planner (fresh, over the committed manifest)."""
        return self.query()._compile().plan

    def explain(self, execute: bool = False) -> ScanReport:
        """Pruning report for this dataset's scan (see ParquetDB.explain)."""
        return self.scan_plan().explain(execute=execute)

    def aggregate(self, spec, explain: bool = False):
        """Aggregate this dataset's (filtered) rows — see ParquetDB.aggregate.

        The dataset's filter applies; its ``LoadConfig`` sizes the morsel
        pool for whatever partial row groups need decoding.
        """
        return self.query().agg(spec, explain=explain)


class ParquetDB:
    """The paper's user-facing database: create/read/update/delete/normalize
    over immutable TPQ files, plus merge-on-read deltas and compaction.

    Durability is the manifest-commit protocol (docs/TRANSACTIONS.md); reads
    are planned by :mod:`repro.core.scan` and observable via :meth:`explain`.

    Parameters beyond the paper's:

    auto_compact:      when True (default) a successful ``update``/``delete``
                       checks the cost-based trigger (``compaction_policy``)
                       and, if exceeded, runs :meth:`compact` on a background
                       thread (single-flight; join with
                       :meth:`wait_for_maintenance`).
    compaction_policy: thresholds for that trigger and for the rewrite chunk
                       size — see :class:`repro.core.compaction.CompactionPolicy`.
    partition_by:      hive-partition the dataset by these columns: every
                       ``create`` splits the batch into ``col=value/``
                       subdirectories and records the partition values in
                       the manifest, which lets selective scans prune whole
                       partitions before opening a single footer
                       (docs/ARCHITECTURE.md "Partitioned layout").
                       Partition columns are immutable per row: ``update``
                       rejects writes to them and ``delete`` cannot drop
                       them.  Must be declared before the first create; the
                       spec is persisted, so reopening without it adopts
                       the committed spec (a *conflicting* spec raises).
    partition_mode:    ``"value"`` (default, one directory per distinct
                       value tuple) or ``"hash"`` (``partition_buckets``
                       directories ``bucket=<i>`` by crc32 of the values —
                       bounded directory count for high-cardinality keys;
                       only ``==``/``isin`` filters prune).
    """

    def __init__(self, db_path: str, dataset_name: Optional[str] = None,
                 initial_fields: Optional[List[Field]] = None,
                 serialize_python_objects: bool = True,
                 codec: str = CODEC_ZLIB, compression_level: int = 1,
                 encoding: str = AUTO,
                 field_encodings: Optional[Dict[str, str]] = None,
                 field_codecs: Optional[Dict[str, str]] = None,
                 eager_schema_align: bool = True,
                 with_bloom: bool = True,
                 page_rows: int = DEFAULT_PAGE_ROWS,
                 row_group_rows: int = DEFAULT_ROW_GROUP_ROWS,
                 auto_compact: bool = True,
                 compaction_policy: Optional[CompactionPolicy] = None,
                 partition_by: Optional[Sequence[str]] = None,
                 partition_mode: str = "value",
                 partition_buckets: int = 16):
        self.db_path = db_path
        self.dataset_name = dataset_name or os.path.basename(os.path.normpath(db_path))
        self._dir = DatasetDir(db_path, self.dataset_name)
        self.serialize_python_objects = serialize_python_objects
        self.codec, self.level, self.encoding = codec, compression_level, encoding
        self.field_encodings = dict(field_encodings or {})
        self.field_codecs = dict(field_codecs or {})
        self.eager_schema_align = eager_schema_align
        self.with_bloom = with_bloom
        self.page_rows = page_rows
        self.row_group_rows = row_group_rows
        self.auto_compact = auto_compact
        self.compaction_policy = compaction_policy or CompactionPolicy()
        self._maintenance_thread: Optional[threading.Thread] = None
        self._maintenance_mutex = threading.Lock()  # single-flight guard
        self._schema_hint_cache: Optional[tuple] = None
        self._snapshot_cache: Optional[tuple] = None
        # a fresh handle may sit on a recreated directory whose file names
        # collide with a previous dataset's: flush per-thread readers
        global _EVICT_GEN
        with _READER_CACHE_LOCK:
            _EVICT_GEN += 1
        # startup recovery: repair the manifest pointer if a crash landed
        # between the generation link and the pointer rewrite, then GC
        # files not in the committed manifest (also collects old
        # generations left behind by a prior compaction).  Best-effort
        # under the writer lock: if a writer is active, skip — a later
        # open will collect.  Lock-free optimistic writers may be staging
        # concurrently; their files are protected by the ``_stage-``
        # naming convention + age grace inside ``DatasetDir.gc``.
        try:
            with self._dir.acquire_lock(timeout=0):
                man = self._dir.load()
                self._dir.repair_pointer(man)
                self._gc(man)
        except TimeoutError:
            pass
        requested = (PartitionSpec(tuple(partition_by), partition_mode,
                                   partition_buckets)
                     if partition_by else None)
        if initial_fields or requested is not None:
            with self._dir.acquire_lock():
                man = self._dir.load()
                changed = False
                if initial_fields:
                    schema = self._manifest_schema(man) \
                                 .unify(Schema(initial_fields))
                    self._set_manifest_schema(man, schema)
                    changed = True
                if requested is not None:
                    existing = Partitioning.from_manifest(man)
                    if existing is None:
                        if man.files or man.deltas:
                            raise ValueError(
                                "cannot partition a dataset that already "
                                "has data; declare partition_by before the "
                                "first create")
                        Partitioning(requested).store(man)
                        changed = True
                    elif existing.spec != requested:
                        raise ValueError(
                            f"dataset is partitioned by {existing.spec}; "
                            f"conflicting spec {requested} requested")
                if changed:
                    self._dir.commit(man, op="schema")

    # ------------------------------------------------------------------ helpers
    def _partitioning_of(self, man: Manifest) -> Optional[Partitioning]:
        """The manifest's committed partition layout, or None."""
        return Partitioning.from_manifest(man)

    @property
    def partition_spec(self) -> Optional[PartitionSpec]:
        """Committed :class:`~repro.core.partition.PartitionSpec`, or None."""
        part = self._partitioning_of(self._load_snapshot()[0])
        return part.spec if part is not None else None

    def _gc(self, man: Manifest) -> None:
        """Collect unreferenced data files and evict their cached footers."""
        removed = self._dir.gc(man)
        if removed:
            _evict_readers(self._dir.file_path(f) for f in removed)

    def _manifest_schema(self, man: Manifest) -> Schema:
        d = man.metadata.get("schema")
        if d is not None:
            return Schema.from_dict(d)
        schema = Schema([Field(ID_COLUMN, DType.numeric("i8"), nullable=False)])
        for fn in man.files:
            schema = schema.unify(_get_reader(self._dir.file_path(fn)).schema)
        return schema

    def _set_manifest_schema(self, man: Manifest, schema: Schema) -> None:
        man.metadata["schema"] = schema.to_dict()

    def _load_snapshot(self) -> tuple:
        """(manifest, schema) of the committed state, for READ paths.

        Memoized on the manifest file's (size, mtime_ns), like
        ``_schema_hint``: steady-state reads skip the JSON parse and the
        schema rebuild entirely — this is what makes a footer-answered
        ``aggregate`` a sub-millisecond call.  Callers must treat the
        returned manifest as immutable; write paths keep loading their own
        mutable copy via ``self._dir.load()``.
        """
        mpath = os.path.join(self._dir.path, "_manifest.json")
        try:
            st = os.stat(mpath)
            key = (st.st_size, st.st_mtime_ns)
        except OSError:
            man = self._dir.load()
            return man, self._manifest_schema(man)
        cached = self._snapshot_cache
        if cached is not None and cached[0] == key:
            return cached[1], cached[2]
        man = self._dir.load()
        schema = self._manifest_schema(man)
        self._snapshot_cache = (key, man, schema)
        return man, schema

    @property
    def schema(self) -> Schema:
        """Unified dataset schema from the committed manifest."""
        return self._load_snapshot()[1]

    @property
    def n_files(self) -> int:
        """Number of committed *base* files (deltas not included)."""
        return len(self._dir.load().files)

    @property
    def n_delta_files(self) -> int:
        """Length of the committed merge-on-read delta chain."""
        return len(self._dir.load().deltas)

    @property
    def n_rows(self) -> int:
        """Visible (merged) row count, from footers alone.

        Exact without scanning: upserts replace rows 1:1, and tombstone
        files are pairwise disjoint sets of then-live base ids (``delete``
        matches against the merged view, so an already-dead id can never be
        staged twice) — the merged count is base rows minus tombstoned ids.
        """
        man = self._dir.load()
        base = sum(_get_reader(self._dir.file_path(f)).num_rows
                   for f in man.files)
        dead = sum(self._reader_of(d.name).num_rows for d in man.deltas
                   if d.kind == DELTA_TOMBSTONE)
        return base - dead

    @property
    def metadata(self) -> dict:
        """User metadata dict stored in the manifest."""
        return dict(self._dir.load().metadata.get("user", {}))

    def set_metadata(self, metadata: dict) -> None:
        """Merge ``metadata`` into the dataset's user metadata (committed)."""
        with self._dir.acquire_lock():
            man = self._dir.load()
            man.metadata.setdefault("user", {}).update(metadata)
            self._dir.commit(man, op="metadata")

    def set_field_metadata(self, name: str, metadata: dict) -> None:
        """Merge ``metadata`` into one field's metadata (committed)."""
        with self._dir.acquire_lock():
            man = self._dir.load()
            schema = self._manifest_schema(man)
            f = schema[name]
            new = Field(f.name, f.dtype, f.nullable,
                        {**(f.metadata or {}), **metadata})
            fields = [new if g.name == name else g for g in schema]
            self._set_manifest_schema(man, Schema(fields, schema.metadata))
            self._dir.commit(man, op="metadata")

    # ------------------------------------------------------------------ ingest
    def _to_table(self, data: TableLike, schema: Optional[Schema],
                  treat_fields_as_ragged=(), convert_to_fixed_shape=True) -> Table:
        if isinstance(data, Table):
            t = data
        else:
            # the committed schema short-circuits type inference for
            # steady-state appends; Table inputs never need it, so the
            # manifest load is skipped on that path
            hint = self._schema_hint()
            if isinstance(data, dict):
                t = Table.from_pydict(
                    data, treat_fields_as_ragged=treat_fields_as_ragged,
                    convert_to_fixed_shape=convert_to_fixed_shape,
                    schema_hint=hint)
            elif isinstance(data, list):
                t = Table.from_pylist(
                    data, treat_fields_as_ragged=treat_fields_as_ragged,
                    convert_to_fixed_shape=convert_to_fixed_shape,
                    schema_hint=hint)
            else:
                raise TypeError(f"unsupported input type {type(data)}")
        if schema is not None:
            t = t.align_to_schema(schema.unify(t.schema))
        return t

    def _schema_hint(self) -> Optional[Schema]:
        """Committed dataset schema as an ingest hint (None on first create).

        Read outside the writer lock: the hint only short-circuits type
        inference — alignment/unification still runs against the schema
        loaded under the lock, so a stale hint can never corrupt a commit.
        Memoized on the manifest file's (size, mtime): steady-state appends
        pay one ``os.stat`` here instead of a second manifest parse.
        """
        mpath = os.path.join(self._dir.path, "_manifest.json")
        try:
            st = os.stat(mpath)
            key = (st.st_size, st.st_mtime_ns)
        except OSError:
            return None
        cached = self._schema_hint_cache
        if cached is not None and cached[0] == key:
            return cached[1]
        try:
            man = self._dir.load()
        except OSError:
            return None
        hint = (None if not man.files and "schema" not in man.metadata
                else self._manifest_schema(man))
        self._schema_hint_cache = (key, hint)
        return hint

    def _write_file(self, path: str, table: Table,
                    row_group_rows: Optional[int] = None,
                    page_rows: Optional[int] = None,
                    file_kind: str = "base") -> None:
        row_group_rows = row_group_rows or self.row_group_rows
        page_rows = page_rows or self.page_rows
        os.makedirs(os.path.dirname(path), exist_ok=True)  # col=value/ dirs
        try:
            with TPQWriter(path, codec=self.codec, level=self.level,
                           encoding=self.encoding, page_rows=page_rows,
                           row_group_rows=row_group_rows,
                           with_bloom=self.with_bloom,
                           field_encodings=self.field_encodings,
                           field_codecs=self.field_codecs,
                           file_kind=file_kind) as w:
                w.write_table(table)
        except OSError:
            # ENOSPC/EIO mid-write: the writer aborted without a footer;
            # unlink the partial file so nothing on disk can be mistaken
            # for data.  The exception propagates before any commit, so no
            # manifest generation ever references this path.
            try:
                os.unlink(path)
            except OSError:
                pass
            raise

    def _stage_delta(self, man: Manifest, kind: str, table: Table,
                     partitions: Optional[tuple] = None) -> None:
        """Write one delta file and append its manifest entry (pre-commit).

        ``partitions`` records the partition keys the delta's rows belong
        to (None = unknown/unpartitioned) — concurrent writers staging
        provably disjoint partitions then commit without the id-overlap
        walk (see :class:`~repro.core.transactions.DeltaEntry`).
        """
        name = self._dir.new_file_name(man, kind=kind)
        self._write_file(self._dir.file_path(name), table, file_kind=kind)
        man.deltas.append(DeltaEntry(name, kind, partitions))

    # ------------------------------------------------------------------ create
    def create(self, data: TableLike, schema: Optional[Schema] = None,
               metadata: Optional[dict] = None,
               fields_metadata: Optional[Dict[str, dict]] = None,
               normalize_dataset: bool = False,
               normalize_config: Optional[NormalizeConfig] = None,
               treat_fields_as_ragged: Sequence[str] = (),
               convert_to_fixed_shape: bool = True) -> np.ndarray:
        """Insert records and return the assigned ids (paper §4.3).

        ``data`` may be a list of dicts, a dict of columns, or a
        :class:`~repro.core.table.Table`.  Each row gets a monotonically
        increasing ``id``.  A new field evolves the schema: by default
        (``eager_schema_align=True``) existing base files are rewritten to
        the unified schema, per the paper; otherwise old rows align to null
        at read time.  The new rows are staged as one base file and committed
        atomically; ``normalize_dataset=True`` re-partitions in the same
        transaction.
        """
        incoming = self._to_table(data, schema, treat_fields_as_ragged,
                                  convert_to_fixed_shape)
        with self._dir.acquire_lock():
            man = self._dir.load()
            current = self._manifest_schema(man)
            # id generation (paper §4.5.1)
            ids = np.arange(man.next_row_id,
                            man.next_row_id + incoming.num_rows, dtype=np.int64)
            man.next_row_id = int(man.next_row_id + incoming.num_rows)
            incoming = incoming.set_column(ID_COLUMN, Column.numeric(ids))
            unified = current.unify(incoming.schema)
            if metadata:
                unified = unified.with_metadata(metadata)
            if fields_metadata:
                unified = _apply_fields_metadata(unified, fields_metadata)
            schema_changed = not unified.equals_names_types(current) and man.files
            part = self._partitioning_of(man)
            new_files = list(man.files)
            if schema_changed and self.eager_schema_align:
                # paper: "Existing data is rewritten to align with the new schema"
                new_files = []
                for fn in man.files:
                    t = _get_reader(self._dir.file_path(fn)).read().align_to_schema(unified)
                    vals = part.files.get(fn) if part is not None else None
                    nf = self._dir.new_file_name(
                        man, subdir=part.dir_of(vals)
                        if vals is not None else None)
                    self._write_file(self._dir.file_path(nf), t)
                    if part is not None:
                        part.rename(fn, nf)
                    new_files.append(nf)
            aligned = incoming.align_to_schema(unified)
            if part is None:
                out = self._dir.new_file_name(man)
                self._write_file(self._dir.file_path(out), aligned)
                new_files.append(out)
            else:
                # hive split: one file per partition this batch touches,
                # under its col=value/ directory; ids stay ascending within
                # each file (split preserves row order per group).  An
                # empty batch stages no file but still commits the schema.
                for values, idx in part.split(aligned):
                    out = self._dir.new_file_name(
                        man, subdir=part.dir_of(values))
                    self._write_file(self._dir.file_path(out),
                                     aligned.take(idx))
                    part.record(out, values)
                    new_files.append(out)
                part.store(man)
            man.files = new_files
            self._set_manifest_schema(man, unified)
            if normalize_dataset:
                self._normalize_locked(man, normalize_config or NormalizeConfig())
            self._dir.commit(man, op="create")
            # GC only when this create orphaned files (realign/normalize
            # rewrite) — a plain append must not sweep old generations a
            # concurrent reader may still hold (see docs/TRANSACTIONS.md)
            if (schema_changed and self.eager_schema_align) or normalize_dataset:
                self._gc(man)
        return ids

    # ------------------------------------------------------------------ read
    def query(self, load_config: Optional[LoadConfig] = None) -> Query:
        """Start a lazy, composable query over this dataset.

        The fluent alternative to ``read``/``aggregate``/``explain`` —
        one plan the scan engine optimizes end to end::

            (db.query()
               .where(field("age") >= 30)
               .select("name", "age")
               .order_by("age", desc=True)
               .limit(10)
               .to_table())

        Chain ``where`` (fused, pushed to footer statistics), ``select``
        (projection pushdown + computed columns), ``group_by().agg()``
        (morsel-parallel hash aggregation), ``order_by``, ``limit`` /
        ``offset`` (early-terminating scans) and ``distinct``; finish
        with ``to_table()`` / ``iter_batches()`` / ``to_pylist()`` /
        ``count()`` / ``agg(spec)`` / ``explain()``.  See
        :class:`repro.core.query.Query` and docs/QUERY.md.
        """
        return Query(self, cfg=load_config or LoadConfig())

    def _resolve_columns(self, columns: Optional[Sequence[str]],
                         include_cols: bool) -> List[str]:
        schema = self.schema
        if columns is None:
            return schema.names
        resolved = _resolve_names(schema, columns)
        if include_cols:
            return resolved
        drop = set(resolved)
        return [n for n in schema.names if n not in drop]

    def _legacy_query(self, names: Optional[List[str]], expr: Optional[Expr],
                      cfg, man: Optional[Manifest] = None) -> Query:
        """One construction point for every legacy shim: an exact
        projection (``None`` = all columns) plus an optional pre-built
        filter, bound to ``man`` when a write path plans against its
        in-flight manifest."""
        q = Query(self, cfg=cfg, man=man)
        if expr is not None:
            q = q.where(expr)
        if names is not None:
            q = q._project_exact(names)
        return q

    def _build_filter(self, ids, filters) -> Optional[Expr]:
        parts: List[Expr] = []
        if ids is not None:
            parts.append(IsIn(ID_COLUMN, [int(i) for i in ids]))
        if filters:
            parts.extend(filters)
        return combine_filters(parts)

    def read(self, ids: Optional[Sequence[int]] = None,
             columns: Optional[Sequence[str]] = None,
             include_cols: bool = True,
             filters: Optional[Sequence[Expr]] = None,
             load_format: str = "table",
             batch_size: Optional[int] = None,
             rebuild_nested_struct: bool = False,
             rebuild_nested_from_scratch: bool = False,
             load_config: Optional[LoadConfig] = None):
        """Read records (paper §4.4), optionally filtered and projected.

        ids:            restrict to these row ids (AND-combined with
                        ``filters``).
        columns:        projection; dotted names select nested children.
        include_cols:   when False, ``columns`` lists the columns to *drop*.
        filters:        list of :class:`~repro.core.expressions.Expr`,
                        AND-combined, pushed down to footer statistics.
        load_format:    ``"table"`` (default, materialized),
                        ``"batches"`` (generator of Tables), or
                        ``"dataset"`` (lazy :class:`Dataset` handle).
        batch_size:     row count per batch for ``"batches"``.
        rebuild_nested_struct: serve from the nested companion dataset
                        (paper §4.6.1), rebuilt on demand.
        load_config:    threading/readahead knobs (paper Table 8).

        Reads see the committed manifest snapshot: base files with the
        delta chain (upserts/tombstones) overlaid at read time, so they are
        unaffected by concurrent writers or compaction.

        This is a thin shim over the composable :class:`Query` builder
        (``db.query()``) — one plan-construction code path for every read.
        """
        cfg = load_config or LoadConfig()
        if batch_size:
            cfg = dataclasses.replace(cfg, batch_size=batch_size)
        expr = self._build_filter(ids, filters)
        if rebuild_nested_struct:
            return self._read_nested(columns, expr, rebuild_nested_from_scratch)
        names = self._resolve_columns(columns, include_cols)
        q = self._legacy_query(names, expr, cfg)
        if load_format == "table":
            return q.to_table()
        if load_format == "batches":
            return q.iter_batches(cfg.batch_size)
        if load_format == "dataset":
            return Dataset(self, names, expr, cfg)
        raise ValueError(f"unknown load_format {load_format!r}")

    def _reader_of(self, fn: str) -> TPQReader:
        return _get_reader(self._dir.file_path(fn))

    def _scan_plan(self, names: Optional[List[str]], expr: Optional[Expr],
                   cfg, prune: bool = True,
                   man: Optional[Manifest] = None) -> ScanPlan:
        """Build the read-path planner over a manifest snapshot.

        ``man`` lets write paths (already holding the lock) plan against
        the manifest they are about to mutate; readers pass None and get
        the committed snapshot.
        """
        if man is None:
            man, schema = self._load_snapshot()
        else:
            schema = self._manifest_schema(man)
        return ScanPlan(man.files, self._reader_of, schema, columns=names,
                        filter_expr=expr, cfg=cfg, prune=prune,
                        deltas=man.deltas,
                        partitioning=self._partitioning_of(man))

    def explain(self, ids: Optional[Sequence[int]] = None,
                columns: Optional[Sequence[str]] = None,
                include_cols: bool = True,
                filters: Optional[Sequence[Expr]] = None,
                execute: bool = False,
                load_config: Optional[LoadConfig] = None) -> ScanReport:
        """Report how a ``read`` with these arguments would be pruned.

        Planning is footer-only over the base files (when a delta chain
        exists, the small delta files are read to resolve the overlay).
        With ``execute=True`` the scan actually runs and the report
        additionally carries page/row/bytes-decoded counters plus the
        delta-merge work (``delta_rows_applied`` upsert substitutions,
        ``rows_shadowed`` tombstone drops).  ``print(report)`` gives a
        human-readable summary; ``report.to_dict()`` a JSON-able one.
        For the full operator tree of a composed query, use
        ``db.query()...explain()`` instead.
        """
        expr = self._build_filter(ids, filters)
        names = self._resolve_columns(columns, include_cols)
        cfg = load_config or LoadConfig()
        return self._legacy_query(names, expr, cfg) \
                   ._compile().plan.explain(execute=execute)

    # ------------------------------------------------------------------ verify
    def verify(self, deep: bool = True) -> IntegrityReport:
        """Scrub the committed snapshot: manifest → files → footers → pages.

        Walks every file the committed manifest references (base files
        across all partitions, then the delta chain) and checks each one:
        the file exists, its framing and footer checksum hold, the footer
        parses — and, with ``deep=True`` (default), every stored page
        buffer matches its recorded crc32 (a pure hash sweep; no pages are
        decoded).  Legacy v1 files carry no checksums: a deep scrub fully
        decodes them instead, so structural damage still surfaces.

        Never raises for corruption — returns an
        :class:`~repro.core.integrity.IntegrityReport` with per-file
        status, counters, and the first typed error's coordinates::

            >>> report = db.verify()
            >>> report.ok, report.files_corrupt, report.pages_verified
            (True, 0, 42)

        Readers are opened fresh (not from the footer cache), so the scrub
        re-validates bytes on disk even for recently-scanned files.
        """
        man, _ = self._load_snapshot()
        report = IntegrityReport(dataset=self.dataset_name,
                                 generation=man.generation, deep=deep)
        for fn in list(man.files) + [d.name for d in man.deltas]:
            report.add(verify_file(self._dir.file_path(fn), name=fn,
                                   deep=deep))
        return report

    # ------------------------------------------------------------------ aggregate
    def aggregate(self, spec: AggSpec,
                  ids: Optional[Sequence[int]] = None,
                  filters: Optional[Sequence[Expr]] = None,
                  load_config: Optional[LoadConfig] = None,
                  explain: bool = False):
        """Aggregate (optionally filtered) rows without materializing them.

        ``spec`` maps a column name — or ``"*"`` for a row count — to one
        aggregate op or a list of ops from ``("count", "min", "max",
        "sum", "mean")``; the result is ``{column: {op: value}}``::

            >>> db.aggregate({"*": "count", "x": ["min", "max", "mean"]},
            ...              filters=[field("y") > 0])

        Row groups whose footer statistics *decide* the filter (and carry
        the needed min/max/sum facts) are answered **without decoding a
        page**; only the undecidable remainder runs through the vectorized
        scan (morsel-parallel, merge-on-read deltas folded in exactly).
        Semantics: ``count(col)`` counts non-null values, ``count(*)``
        counts rows, ``min``/``max``/``sum``/``mean`` reduce over valid
        (non-null, non-NaN) values and return ``None`` when no such value
        exists.  With ``explain=True`` returns ``(values, report)`` where
        the report's counters include ``groups_answered_by_stats`` and
        ``bytes_skipped_agg``.

        This is a thin shim over ``db.query().agg(spec)`` (grouped
        aggregation lives there too: ``db.query().group_by(...).agg(...)``).
        """
        expr = self._build_filter(ids, filters)
        return self._legacy_query(None, expr, load_config or LoadConfig()) \
                   .agg(spec, explain=explain)

    # -- nested rebuild (paper §4.6.1) -------------------------------------------
    def _nested_path(self) -> str:
        return self.db_path.rstrip("/") + "_nested"

    def _read_nested(self, columns, expr, from_scratch: bool) -> Table:
        npath = self._nested_path()
        ndb_exists = os.path.exists(os.path.join(npath, "_manifest.json"))
        if from_scratch and ndb_exists:
            import shutil
            shutil.rmtree(npath)
            ndb_exists = False
        ndb = ParquetDB(npath, self.dataset_name + "_nested",
                        codec=self.codec, encoding=self.encoding)
        if not ndb_exists:
            flat = self.read()  # full table
            rows = flat.to_pylist(rebuild_nested=True)
            for r in rows:
                r.pop(ID_COLUMN, None)
            ndb.create(rows, convert_to_fixed_shape=False)
        parents = None
        if columns is not None:
            parents = sorted({c.split(nested.SEP, 1)[0] for c in columns})
        nschema = ndb.schema
        cols = None
        if parents is not None:
            cols = []
            for p in parents:
                cols.extend(nested.children_of(nschema.names, p))
        filters = [expr] if expr is not None else None
        try:
            return ndb.read(columns=cols, filters=filters)
        except (KeyError, TypeError):
            # filter referenced a flattened-only column: filter on flat side
            keep = self.read(columns=[ID_COLUMN],
                             filters=[expr] if expr else None)
            ids = keep.column(ID_COLUMN).values.tolist()
            return ndb.read(ids=ids, columns=cols)

    # ------------------------------------------------------------------ update
    def _run_delta_txn(self, build, op: str) -> Optional[int]:
        """Drive one optimistic delta commit to completion.

        ``build(man, schema)`` stages the operation against a snapshot:
        it returns ``(kind, table, n)`` (the delta to stage and the row
        count to report), ``None`` when there is nothing to commit, or
        raises :class:`_SchemaEvolves` when the operation needs the locked
        structural path.  The protocol (docs/TRANSACTIONS.md): snapshot →
        stage (lock-free) → publish (validate + atomic link of the next
        generation, group-batched).  A :class:`CommitConflict` — another
        writer committed overlapping rows since our snapshot — aborts the
        staged file and restarts from a fresh snapshot, bounded by
        ``_OPTIMISTIC_RETRIES``; persistent conflicts return None and the
        caller serializes through the write lock instead (livelock-free).
        """
        for attempt in range(_OPTIMISTIC_RETRIES):
            d = _DeltaTxn(self, build, op)
            # published as generation metadata ``txn_retries`` so tests
            # (and operators) can assert partition-disjoint writers never
            # had to restart optimistically
            d.txn.retries = attempt
            d.snapshot()
            try:
                n = d.stage()
            except _SchemaEvolves:
                return None
            except FileNotFoundError:
                # a compaction commit + another process's startup GC raced
                # our snapshot out from under the probe scan: re-snapshot
                continue
            if n == 0:
                return 0
            try:
                d.publish()
                return n
            except CommitConflict:
                d.abort()
                continue
        return None

    def _upsert_build(self, incoming: Table, keys: List[str]):
        """Stage-step closure for an optimistic ``update`` (no schema
        change): probe the merged snapshot for matching keys and build the
        full-width upsert delta."""
        def build(man: Manifest, current: Schema):
            unified = current.unify(incoming.schema)
            if not unified.equals_names_types(current):
                raise _SchemaEvolves()  # schema evolution: locked path
            inc_aligned = incoming.align_to_schema(
                unified.select([f.name for f in unified
                                if f.name in incoming.columns]))
            key_of = _key_index(incoming, keys)
            keys_expr = _keys_expr(incoming, keys)
            snap = self._legacy_query(None, keys_expr, LoadConfig(),
                                      man=man).to_table()
            if snap.num_rows:
                snap = snap.align_to_schema(unified)
            hit_dst, hit_src = _match_rows(snap, key_of, keys)
            updated = len(hit_dst)
            if not updated:
                return None
            sub = snap.take(hit_dst)
            upsert = _apply_updates(sub, inc_aligned,
                                    np.arange(updated, dtype=np.int64),
                                    hit_src, keys)
            return (DELTA_UPSERT, upsert, updated,
                    self._delta_partitions(man, upsert))
        return build

    def _delta_partitions(self, man: Manifest,
                          table: Table) -> Optional[tuple]:
        """Partition keys of a staged delta's rows (None = unpartitioned,
        or the table lacks a partition column — conservative)."""
        part = self._partitioning_of(man)
        if part is None or any(c not in table for c in part.spec.by):
            return None
        return tuple(part.keys_of_table(table))

    def _tombstone_probe_names(self, man: Manifest) -> List[str]:
        """Projection for the delete probe: id plus the partition columns
        (when present in the schema) so the tombstone's partition keys can
        be derived without a second scan."""
        names = [ID_COLUMN]
        part = self._partitioning_of(man)
        if part is not None:
            schema = self._manifest_schema(man)
            names += [c for c in part.spec.by
                      if c in schema and c != ID_COLUMN]
        return names

    def _tombstone_build(self, expr: Expr):
        """Stage-step closure for an optimistic row ``delete``: evaluate
        the filter against the merged snapshot and build the tombstone."""
        def build(man: Manifest, current: Schema):
            dead = self._legacy_query(self._tombstone_probe_names(man), expr,
                                      LoadConfig(), man=man).to_table()
            if dead.num_rows == 0:
                return None
            dead_ids = np.sort(dead.column(ID_COLUMN).values)
            tomb = Table(current.select([ID_COLUMN]),
                         {ID_COLUMN: Column.numeric(dead_ids)})
            return (DELTA_TOMBSTONE, tomb, dead.num_rows,
                    self._delta_partitions(man, dead))
        return build

    def update(self, data: TableLike, schema: Optional[Schema] = None,
               metadata: Optional[dict] = None,
               fields_metadata: Optional[Dict[str, dict]] = None,
               update_keys: Union[str, List[str]] = ID_COLUMN,
               treat_fields_as_ragged: Sequence[str] = (),
               convert_to_fixed_shape: bool = True,
               normalize_config: Optional[NormalizeConfig] = None) -> int:
        """Update matching records; returns the number of rows updated.

        Merge-on-read (paper §4.5, without its write amplification): the
        current values of rows matching ``update_keys`` are fetched through
        the scan planner (key-pruned — untouched files are not decoded),
        the incoming columns are applied, and the resulting full-width rows
        are staged as **one upsert delta file** and committed.  Cost is
        O(matched rows + pruned probe), not O(dataset): no base file is
        rewritten.  Readers substitute the upsert rows by id at scan time;
        compaction folds them back into base files.

        Concurrency: a plain update (no schema change, metadata, or
        normalize) commits **optimistically** — it snapshots a generation,
        stages its upsert lock-free, and validates id overlap at publish
        time against any generation committed meanwhile, rebasing and
        retrying on non-overlapping commits (docs/TRANSACTIONS.md).  Only
        structural updates serialize through the write lock.

        ``update_keys`` defaults to ``id``; a list of columns forms a
        composite key.  New columns evolve the schema (old rows read as
        null).  Within one call, the last incoming row wins per key; across
        calls, the latest committed delta wins.
        """
        keys = [update_keys] if isinstance(update_keys, str) else list(update_keys)
        incoming = self._to_table(data, schema, treat_fields_as_ragged,
                                  convert_to_fixed_shape)
        for k in keys:
            if k not in incoming:
                raise ValueError(f"update data must contain key column {k!r}")
        spec = self.partition_spec
        if spec is not None:
            bad = [c for c in spec.by if c in incoming and c not in keys]
            if bad:
                raise ValueError(
                    f"cannot update partition column(s) {bad}: a row's "
                    "partition is immutable (delete and re-create instead)")
        if metadata is None and fields_metadata is None \
                and normalize_config is None:
            n = self._run_delta_txn(self._upsert_build(incoming, keys),
                                    "update")
            if n is not None:
                if n:
                    self._maybe_autocompact()
                return n
        with self._dir.acquire_lock():
            man = self._dir.load()
            current = self._manifest_schema(man)
            unified = current.unify(incoming.schema)
            if metadata:
                unified = unified.with_metadata(metadata)
            if fields_metadata:
                unified = _apply_fields_metadata(unified, fields_metadata)
            schema_changed = not unified.equals_names_types(current)
            inc_aligned = incoming.align_to_schema(
                unified.select([f.name for f in unified
                                if f.name in incoming.columns]))
            key_of = _key_index(incoming, keys)
            keys_expr = _keys_expr(incoming, keys)
            # fetch the merged current rows that may match (key-pruned scan,
            # full width: upsert rows must carry every column).  The schema
            # is set on the manifest first so the plan sees `unified`; the
            # probe is the same Query path every read uses, bound to the
            # in-flight manifest.
            self._set_manifest_schema(man, unified)
            snap = self._legacy_query(None, keys_expr, LoadConfig(),
                                      man=man).to_table()
            if snap.num_rows:
                snap = snap.align_to_schema(unified)
            hit_dst, hit_src = _match_rows(snap, key_of, keys)
            updated = len(hit_dst)
            if updated:
                sub = snap.take(hit_dst)
                upsert = _apply_updates(sub, inc_aligned,
                                        np.arange(updated, dtype=np.int64),
                                        hit_src, keys)
                self._stage_delta(man, DELTA_UPSERT, upsert,
                                  partitions=self._delta_partitions(man,
                                                                    upsert))
            elif not schema_changed and metadata is None \
                    and fields_metadata is None:
                return 0  # nothing to commit
            if normalize_config is not None:
                self._normalize_locked(man, normalize_config)
            # "update" even when normalize rewrote files: concurrent
            # optimistic transactions must treat this generation's folded
            # chain as a real data change, not a logical no-op
            self._dir.commit(man, op="update")
            if normalize_config is not None:  # append-only otherwise: no GC
                self._gc(man)
        self._maybe_autocompact()
        return updated

    # ------------------------------------------------------------------ delete
    def delete(self, ids: Optional[Sequence[int]] = None,
               columns: Optional[Sequence[str]] = None,
               filters: Optional[Sequence[Expr]] = None,
               normalize_config: Optional[NormalizeConfig] = None) -> int:
        """Delete rows (by ids/filters) or whole columns.

        Row deletion is merge-on-read: the ids of matching rows (evaluated
        against the merged view, so updated values count) are staged as one
        **tombstone delta file** and committed — O(matched rows), no base
        file rewritten.  Readers drop tombstoned rows at scan time;
        compaction removes them physically.

        Column deletion is a schema change and rewrites base files from the
        merged view, folding any pending delta chain into the same single
        pass.  Returns the number of rows (or columns) removed.

        Concurrency: plain row deletion commits **optimistically** like
        ``update`` — lock-free staging, id-overlap validation at publish
        time (docs/TRANSACTIONS.md); column deletion and normalize
        serialize through the write lock.
        """
        if columns is not None and (ids is not None or filters is not None):
            raise ValueError("row and column deletion are mutually exclusive")
        if columns is None and normalize_config is None:
            expr = self._build_filter(ids, filters)
            if expr is None:
                raise ValueError("delete needs ids, filters, or columns")
            n = self._run_delta_txn(self._tombstone_build(expr), "delete")
            if n is not None:
                if n:
                    self._maybe_autocompact()
                return n
        removed = 0
        with self._dir.acquire_lock():
            man = self._dir.load()
            current = self._manifest_schema(man)
            if columns is not None:
                cols = []
                for c in columns:
                    cols.extend(nested.children_of(current.names, c))
                if ID_COLUMN in cols:
                    raise ValueError("cannot delete the primary key column 'id'")
                missing = [c for c in cols if c not in current]
                if missing:
                    raise KeyError(f"unknown columns {missing}")
                part = self._partitioning_of(man)
                if part is not None:
                    pc = [c for c in cols if c in part.spec.by]
                    if pc:
                        raise ValueError(
                            f"cannot delete partition column(s) {pc}: the "
                            "dataset layout depends on them")
                # one pass: each base file is rewritten from the *merged*
                # view projected to the surviving columns, folding any
                # pending delta chain into the same rewrite
                keep_schema = current.drop(cols)
                ov = (DeltaOverlay(man.deltas, self._reader_of, keep_schema)
                      if man.deltas else None)
                new_files = []
                for fn in man.files:
                    plan = ScanPlan([fn], self._reader_of, keep_schema,
                                    cfg=LoadConfig(), deltas=man.deltas,
                                    overlay=ov)
                    parts = list(plan.execute())
                    if not parts:
                        # every row tombstoned: drop the file
                        if part is not None:
                            part.forget(fn)
                        continue
                    vals = part.files.get(fn) if part is not None else None
                    nf = self._dir.new_file_name(
                        man, subdir=part.dir_of(vals)
                        if vals is not None else None)
                    self._write_file(self._dir.file_path(nf),
                                     concat_tables(parts))
                    if part is not None:
                        part.rename(fn, nf)
                    new_files.append(nf)
                if part is not None:
                    part.store(man)
                man.files = new_files
                man.deltas = []
                self._set_manifest_schema(man, keep_schema)
                removed = len(cols)
            else:
                expr = self._build_filter(ids, filters)
                if expr is None:
                    raise ValueError("delete needs ids, filters, or columns")
                # merged-view match via the shared Query path: collect the
                # ids to tombstone (key-pruned, bound to this manifest)
                dead = self._legacy_query(self._tombstone_probe_names(man),
                                          expr, LoadConfig(),
                                          man=man).to_table()
                removed = dead.num_rows
                if removed == 0 and normalize_config is None:
                    return 0  # nothing to commit
                if removed:
                    dead_ids = np.sort(dead.column(ID_COLUMN).values)
                    tomb = Table(current.select([ID_COLUMN]),
                                 {ID_COLUMN: Column.numeric(dead_ids)})
                    self._stage_delta(man, DELTA_TOMBSTONE, tomb,
                                      partitions=self._delta_partitions(
                                          man, dead))
            if normalize_config is not None:
                self._normalize_locked(man, normalize_config)
            self._dir.commit(man, op="delete_columns" if columns is not None
                             else "delete")
            # row deletion is append-only (a staged tombstone): no GC, so
            # old generations survive for in-flight readers; the rewriting
            # paths (columns / normalize) collect their own orphans
            if columns is not None or normalize_config is not None:
                self._gc(man)
        self._maybe_autocompact()
        return removed

    # ------------------------------------------------------------------ normalize
    def normalize(self, normalize_config: Optional[NormalizeConfig] = None,
                  **kwargs) -> None:
        """Re-partition the dataset to the requested layout (paper Table 10).

        Rewrites every base file to ``max_rows_per_file`` /
        ``max_rows_per_group`` and folds any pending delta chain into the
        result (the rewrite reads the merged view), all in one committed
        transaction.  Keyword arguments are shorthand for
        :class:`NormalizeConfig` fields.
        """
        cfg = normalize_config or NormalizeConfig(**kwargs)
        with self._dir.acquire_lock():
            man = self._dir.load()
            self._normalize_locked(man, cfg)
            self._dir.commit(man, op="normalize")
            self._gc(man)

    def _normalize_locked(self, man: Manifest, cfg: NormalizeConfig) -> None:
        schema = self._manifest_schema(man)
        # full unfiltered *merged* scan via the planner (threaded readahead
        # per cfg); the delta chain is folded into the rewritten files
        plan = ScanPlan(man.files, self._reader_of, schema, cfg=cfg,
                        deltas=man.deltas)
        part = self._partitioning_of(man)
        batches = list(plan.execute())
        if not batches:
            man.files, man.deltas = [], []
            if part is not None:
                part.files = {}
                part.store(man)
            return
        full = concat_tables(batches)
        new_files = []
        rg = max(int(cfg.max_rows_per_group), 1)
        page = max(min(DEFAULT_PAGE_ROWS, rg), 1)
        step = max(cfg.max_rows_per_file, 1)
        if part is not None:
            # canonical order first (scan order interleaves partitions),
            # then regroup into one chunked run per partition directory
            order = np.argsort(full.column(ID_COLUMN).values, kind="stable")
            full = full.take(order)
            part.files = {}
            for values, idx in part.split(full):
                run = full.take(idx)
                for s in range(0, run.num_rows, step):
                    piece = run.slice(s, s + step)
                    nf = self._dir.new_file_name(
                        man, subdir=part.dir_of(values))
                    self._write_file(self._dir.file_path(nf), piece,
                                     row_group_rows=rg, page_rows=page)
                    part.record(nf, values)
                    new_files.append(nf)
            part.store(man)
        else:
            for s in range(0, full.num_rows, step):
                piece = full.slice(s, s + step)
                nf = self._dir.new_file_name(man)
                self._write_file(self._dir.file_path(nf), piece,
                                 row_group_rows=rg, page_rows=page)
                new_files.append(nf)
        man.files = new_files
        man.deltas = []

    # ------------------------------------------------------------------ compaction
    def compact(self, policy: Optional[CompactionPolicy] = None,
                force: bool = False) -> CompactionResult:
        """Fold the delta chain and coalesce small files into sorted bases.

        Runs under the writer lock and commits one new manifest generation.
        Only the *affected* region is rewritten — base files no delta can
        touch (by id range) and well-filled files keep their names — so the
        cost scales with delta size, not dataset size.  ``force=True``
        rewrites everything (full re-sort).

        Old-generation files are left on disk for in-flight readers and
        garbage-collected on the next open; their cached footers are
        evicted immediately.  Returns a
        :class:`~repro.core.compaction.CompactionResult` (``compacted`` is
        False when there was nothing to do).
        """
        policy = policy or self.compaction_policy
        with self._dir.acquire_lock():
            man = self._dir.load()
            schema = self._manifest_schema(man)
            result = compact_locked(self._dir, man, schema, self._reader_of,
                                    self._write_file, policy, force=force,
                                    partitioning=self._partitioning_of(man))
            if result.compacted:
                self._dir.commit(man, op="compact")
                result.generation = man.generation
                _evict_readers(self._dir.file_path(f)
                               for f in result.dropped_files)
        return result

    def maintenance_stats(self, policy: Optional[CompactionPolicy] = None
                          ) -> MaintenanceStats:
        """Footer-only dataset health report + compaction recommendation.

        Reports base/delta file counts, staged upsert/tombstone rows, the
        delta-to-base ratio, small-file count and row-group fill, and
        whether the cost-based trigger in ``policy`` (default: this
        database's ``compaction_policy``) recommends :meth:`compact`.
        """
        return gather_stats(self._dir.load(), self._reader_of,
                            policy or self.compaction_policy)

    def _maybe_autocompact(self) -> None:
        """Kick off background compaction when the cost trigger fires.

        Single-flight: at most one maintenance thread per ParquetDB handle.
        The thread takes the writer lock itself; failures are swallowed
        (maintenance must never break the write that scheduled it).
        """
        if not self.auto_compact:
            return
        try:
            if not self.maintenance_stats().should_compact:
                return
        except OSError:
            return
        with self._maintenance_mutex:
            t = self._maintenance_thread
            if t is not None and t.is_alive():
                return
            t = threading.Thread(target=self._run_maintenance,
                                 name=f"compact-{self.dataset_name}",
                                 daemon=True)
            self._maintenance_thread = t
            t.start()

    def _run_maintenance(self) -> None:
        try:
            self.compact()
        except Exception:
            pass  # best-effort; the next trigger will retry

    def wait_for_maintenance(self) -> None:
        """Block until any in-flight background compaction finishes."""
        t = self._maintenance_thread
        if t is not None:
            t.join()


# ---------------------------------------------------------------------------
# optimistic delta transactions
# ---------------------------------------------------------------------------
_OPTIMISTIC_RETRIES = 4  # conflict restarts before yielding to the lock


class _SchemaEvolves(Exception):
    """Raised by a stage-step builder when the operation changes the
    dataset schema and must take the locked structural path."""


class _DeltaTxn:
    """One optimistic merge-on-read commit, split into the four protocol
    steps — ``snapshot`` → ``stage`` → ``validate`` → ``publish`` — so the
    deterministic interleaving harness (tests/test_mvcc.py) can schedule
    concurrent writers through every step ordering.  ``update``/``delete``
    drive the same object front to back via ``ParquetDB._run_delta_txn``.
    """

    def __init__(self, db: "ParquetDB", build, op: str):
        self.db = db
        self.build = build
        self.txn = Transaction(db._dir, db._reader_of, op=op)
        self.man: Optional[Manifest] = None
        self.schema: Optional[Schema] = None
        self.staged_paths: List[str] = []
        self.result: Optional[int] = None

    def snapshot(self) -> Manifest:
        """Bind to the committed head generation (lock-free)."""
        self.man = self.txn.snapshot()
        self.schema = self.db._manifest_schema(self.man)
        return self.man

    def stage(self) -> int:
        """Probe the snapshot and write the delta file (lock-free).

        Returns the rows this transaction will affect; 0 means nothing to
        commit (no file staged).  The staged file gets a collision-free
        ``_stage-`` name so concurrent writers and the GC never trip over
        it (see ``DatasetDir.stage_file_name``).
        """
        out = self.build(self.man, self.schema)
        if out is None:
            self.result = 0
            return 0
        kind, table, n, partitions = out
        name = self.db._dir.stage_file_name(kind)
        path = self.db._dir.file_path(name)
        self.db._write_file(path, table, file_kind=kind)
        self.staged_paths.append(path)
        self.txn.stage(DeltaEntry(name, kind, partitions),
                       table.column(ID_COLUMN).values)
        self.result = n
        return n

    def validate(self) -> Optional[str]:
        """Advisory lock-free conflict check against the current head."""
        return self.txn.validate()

    def publish(self) -> Manifest:
        """Authoritative validate + atomic generation link (group-batched,
        under the write lock); raises ``CommitConflict`` on overlap."""
        return self.txn.publish()

    def abort(self) -> None:
        """Drop the staged files of a conflicted/abandoned transaction."""
        _evict_readers(self.staged_paths)
        for p in self.staged_paths:
            try:
                os.remove(p)
            except OSError:
                pass
        self.staged_paths = []


# ---------------------------------------------------------------------------
# update helpers
# ---------------------------------------------------------------------------
def _key_index(incoming: Table, keys: List[str]) -> Dict[Any, int]:
    cols = [incoming.column(k).to_pylist() for k in keys]
    out: Dict[Any, int] = {}
    for i in range(incoming.num_rows):
        kv = cols[0][i] if len(keys) == 1 else tuple(c[i] for c in cols)
        out[kv] = i  # last wins
    return out


_KEYS_EXPR_MAX_ISIN = 256  # above this, fall back to a [lo, hi] range check


def _keys_expr(incoming: Table, keys: List[str]) -> Optional[Expr]:
    """Prunable Expr matching the incoming update keys, or None.

    Feeds :func:`repro.core.scan.file_may_match` so ``update`` skips files
    whose stats prove no key is present.  Small key sets become ``IsIn``
    (bloom-prunable even inside [min, max]); large ones a min/max range.
    Conservative None for multi-key updates or keys containing nulls.
    """
    if len(keys) != 1:
        return None
    k = keys[0]
    col = incoming.column(k)
    if col.null_count:
        return None
    if col.dtype.is_numeric:
        vals = col.values
        if vals.dtype.kind == "f":
            # NaN keys never match any row (== is False for NaN) and a NaN
            # endpoint would poison the range fallback into pruning all files
            vals = vals[~np.isnan(vals)]
        uniq = np.unique(vals)
        if len(uniq) == 0:
            return None
        if len(uniq) <= _KEYS_EXPR_MAX_ISIN:
            return IsIn(k, [v.item() for v in uniq])
        return (field(k) >= uniq[0].item()) & (field(k) <= uniq[-1].item())
    if col.dtype.kind == KIND_STRING:
        uniq = sorted(set(col.to_pylist()))
        if not uniq:
            return None
        if len(uniq) <= _KEYS_EXPR_MAX_ISIN:
            return IsIn(k, uniq)
        return (field(k) >= uniq[0]) & (field(k) <= uniq[-1])
    return None


def _match_rows(t: Table, key_of: Dict[Any, int], keys: List[str]):
    if len(keys) == 1 and t.column(keys[0]).dtype.is_numeric and all(
            isinstance(k, (int, float)) for k in key_of):
        vals = t.column(keys[0]).values
        inc = np.fromiter(key_of.keys(), dtype=vals.dtype, count=len(key_of))
        src = np.fromiter(key_of.values(), dtype=np.int64, count=len(key_of))
        order = np.argsort(inc)
        inc, src = inc[order], src[order]
        pos = np.searchsorted(inc, vals)
        pos = np.clip(pos, 0, len(inc) - 1)
        hit = inc[pos] == vals
        return np.nonzero(hit)[0], src[pos[hit]]
    cols = [t.column(k).to_pylist() for k in keys]
    dst, src = [], []
    for i in range(t.num_rows):
        kv = cols[0][i] if len(keys) == 1 else tuple(c[i] for c in cols)
        j = key_of.get(kv)
        if j is not None:
            dst.append(i)
            src.append(j)
    return np.array(dst, np.int64), np.array(src, np.int64)


def _apply_updates(t: Table, incoming: Table, dst: np.ndarray,
                   src: np.ndarray, keys: List[str]) -> Table:
    for name in incoming.column_names:
        if name in keys:
            continue
        tgt = t.column(name)
        upd = incoming.column(name).take(src)
        merged = _scatter_column(tgt, dst, upd)
        t = t.set_column(name, merged, metadata=t.schema[name].metadata
                         if name in t.schema else None)
    return t


def _scatter_column(tgt: Column, dst: np.ndarray, upd: Column) -> Column:
    """Out-of-place scatter: tgt[dst] = upd (validity-aware)."""
    n = len(tgt)
    idx = np.arange(n, dtype=np.int64)
    take_from_upd = np.full(n, -1, np.int64)
    take_from_upd[dst] = np.arange(len(dst))
    # build combined via take trick: concat(tgt, upd).take(sel)
    from .table import concat_columns
    both = concat_columns([tgt, upd.cast(tgt.dtype)])
    sel = np.where(take_from_upd >= 0, take_from_upd + n, idx)
    return both.take(sel)


def _apply_fields_metadata(schema: Schema, fm: Dict[str, dict]) -> Schema:
    fields = []
    for f in schema:
        if f.name in fm:
            fields.append(Field(f.name, f.dtype, f.nullable,
                                {**(f.metadata or {}), **fm[f.name]}))
        else:
            fields.append(f)
    return Schema(fields, schema.metadata)


