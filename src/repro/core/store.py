"""ParquetDB — the paper's user-facing database class, on the TPQ format.

API mirrors the paper (§4.3–§4.6): ``create`` / ``read`` / ``update`` /
``delete`` / ``normalize`` with ``NormalizeConfig`` and ``LoadConfig``,
dotted-field access to nested data, AND-combined filter lists, id generation,
schema evolution, and ``rebuild_nested_struct``.  Durability is by the
manifest-commit protocol in :mod:`repro.core.transactions` (beyond-paper: a
crash never requires manual recovery).

Every read routes through the scan planner (:mod:`repro.core.scan`), which
prunes whole files and row groups from footer statistics before decoding a
byte; ``db.explain(filters=...)`` returns the planner's
:class:`~repro.core.scan.ScanReport` so pruning is observable::

    >>> print(db.explain(filters=[field("age") > 100]))
    ScanPlan  filter=(age > 100)  columns=4
      files:      0 scanned, 3 pruned (of 3)
      ...

See docs/ARCHITECTURE.md for the full read/write data flow.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Generator, Iterable, List, Optional, Sequence, Union

import numpy as np

from . import nested
from .dtypes import DType, KIND_STRING
from .encodings import AUTO, CODEC_ZLIB
from .expressions import Expr, IsIn, combine_filters, field
from .fileformat import (DEFAULT_PAGE_ROWS, DEFAULT_ROW_GROUP_ROWS, TPQReader,
                         TPQWriter)
from .scan import ScanPlan, ScanReport, file_may_match
from .schema import Field, ID_COLUMN, Schema
from .table import Column, Table, concat_tables, null_column_of
from .transactions import DatasetDir, Manifest

TableLike = Union[Table, List[dict], Dict[str, Any]]

# Footer-parse cache: data files are immutable (every rewrite gets a fresh
# name), so (path, size, mtime) fully identifies a footer.
_READER_CACHE: "collections.OrderedDict" = __import__("collections").OrderedDict()
_READER_CACHE_MAX = 128


def _get_reader(path: str) -> TPQReader:
    st = os.stat(path)
    key = (path, st.st_size, st.st_mtime_ns)
    rd = _READER_CACHE.get(key)
    if rd is None:
        rd = TPQReader(path)
        _READER_CACHE[key] = rd
        if len(_READER_CACHE) > _READER_CACHE_MAX:
            _READER_CACHE.popitem(last=False)
    else:
        _READER_CACHE.move_to_end(key)
    return rd


@dataclasses.dataclass
class NormalizeConfig:
    """Paper Table 10."""
    load_format: str = "table"
    batch_size: Optional[int] = None
    batch_readahead: int = 16
    fragment_readahead: int = 4
    use_threads: bool = True
    max_partitions: int = 1024
    max_open_files: int = 1024
    max_rows_per_file: int = 10_000
    min_rows_per_group: int = 0
    max_rows_per_group: int = 10_000


@dataclasses.dataclass
class LoadConfig:
    """Paper Table 8."""
    batch_size: int = 131_072
    batch_readahead: int = 16
    fragment_readahead: int = 4
    use_threads: bool = True


class Dataset:
    """Lazy handle returned by ``read(load_format='dataset')``."""

    def __init__(self, db: "ParquetDB", columns, filter_expr, load_config):
        self._db, self._columns = db, columns
        self._filter, self._cfg = filter_expr, load_config

    @property
    def schema(self) -> Schema:
        names = self._db._resolve_columns(self._columns, True)
        return self._db.schema.select(names)

    def iter_batches(self, batch_size: Optional[int] = None) -> Iterable[Table]:
        yield from self._db._iter_batches(
            self._columns, self._filter,
            batch_size or self._cfg.batch_size, self._cfg)

    def to_table(self) -> Table:
        return concat_tables(list(self.iter_batches()))

    def scan_plan(self) -> ScanPlan:
        names = self._db._resolve_columns(self._columns, True)
        return self._db._scan_plan(names, self._filter, self._cfg)

    def explain(self, execute: bool = False) -> ScanReport:
        """Pruning report for this dataset's scan (see ParquetDB.explain)."""
        return self.scan_plan().explain(execute=execute)


class ParquetDB:
    def __init__(self, db_path: str, dataset_name: Optional[str] = None,
                 initial_fields: Optional[List[Field]] = None,
                 serialize_python_objects: bool = True,
                 codec: str = CODEC_ZLIB, compression_level: int = 1,
                 encoding: str = AUTO,
                 field_encodings: Optional[Dict[str, str]] = None,
                 field_codecs: Optional[Dict[str, str]] = None,
                 eager_schema_align: bool = True,
                 with_bloom: bool = True,
                 page_rows: int = DEFAULT_PAGE_ROWS,
                 row_group_rows: int = DEFAULT_ROW_GROUP_ROWS):
        self.db_path = db_path
        self.dataset_name = dataset_name or os.path.basename(os.path.normpath(db_path))
        self._dir = DatasetDir(db_path, self.dataset_name)
        self.serialize_python_objects = serialize_python_objects
        self.codec, self.level, self.encoding = codec, compression_level, encoding
        self.field_encodings = dict(field_encodings or {})
        self.field_codecs = dict(field_codecs or {})
        self.eager_schema_align = eager_schema_align
        self.with_bloom = with_bloom
        self.page_rows = page_rows
        self.row_group_rows = row_group_rows
        # startup recovery: GC files not in the committed manifest
        man = self._dir.load()
        self._dir.gc(man)
        if initial_fields:
            with self._dir.acquire_lock():
                man = self._dir.load()
                schema = self._manifest_schema(man).unify(Schema(initial_fields))
                self._set_manifest_schema(man, schema)
                self._dir.commit(man)

    # ------------------------------------------------------------------ helpers
    def _manifest_schema(self, man: Manifest) -> Schema:
        d = man.metadata.get("schema")
        if d is not None:
            return Schema.from_dict(d)
        schema = Schema([Field(ID_COLUMN, DType.numeric("i8"), nullable=False)])
        for fn in man.files:
            schema = schema.unify(_get_reader(self._dir.file_path(fn)).schema)
        return schema

    def _set_manifest_schema(self, man: Manifest, schema: Schema) -> None:
        man.metadata["schema"] = schema.to_dict()

    @property
    def schema(self) -> Schema:
        return self._manifest_schema(self._dir.load())

    @property
    def n_files(self) -> int:
        return len(self._dir.load().files)

    @property
    def n_rows(self) -> int:
        man = self._dir.load()
        return sum(_get_reader(self._dir.file_path(f)).num_rows for f in man.files)

    @property
    def metadata(self) -> dict:
        return dict(self._dir.load().metadata.get("user", {}))

    def set_metadata(self, metadata: dict) -> None:
        with self._dir.acquire_lock():
            man = self._dir.load()
            man.metadata.setdefault("user", {}).update(metadata)
            self._dir.commit(man)

    def set_field_metadata(self, name: str, metadata: dict) -> None:
        with self._dir.acquire_lock():
            man = self._dir.load()
            schema = self._manifest_schema(man)
            f = schema[name]
            new = Field(f.name, f.dtype, f.nullable,
                        {**(f.metadata or {}), **metadata})
            fields = [new if g.name == name else g for g in schema]
            self._set_manifest_schema(man, Schema(fields, schema.metadata))
            self._dir.commit(man)

    # ------------------------------------------------------------------ ingest
    def _to_table(self, data: TableLike, schema: Optional[Schema],
                  treat_fields_as_ragged=(), convert_to_fixed_shape=True) -> Table:
        if isinstance(data, Table):
            t = data
        elif isinstance(data, dict):
            t = Table.from_pydict(data, treat_fields_as_ragged=treat_fields_as_ragged,
                                  convert_to_fixed_shape=convert_to_fixed_shape)
        elif isinstance(data, list):
            t = Table.from_pylist(data, treat_fields_as_ragged=treat_fields_as_ragged,
                                  convert_to_fixed_shape=convert_to_fixed_shape)
        else:
            raise TypeError(f"unsupported input type {type(data)}")
        if schema is not None:
            t = t.align_to_schema(schema.unify(t.schema))
        return t

    def _write_file(self, path: str, table: Table,
                    row_group_rows: Optional[int] = None,
                    page_rows: Optional[int] = None) -> None:
        row_group_rows = row_group_rows or self.row_group_rows
        page_rows = page_rows or self.page_rows
        with TPQWriter(path, codec=self.codec, level=self.level,
                       encoding=self.encoding, page_rows=page_rows,
                       row_group_rows=row_group_rows, with_bloom=self.with_bloom,
                       field_encodings=self.field_encodings,
                       field_codecs=self.field_codecs) as w:
            w.write_table(table)

    # ------------------------------------------------------------------ create
    def create(self, data: TableLike, schema: Optional[Schema] = None,
               metadata: Optional[dict] = None,
               fields_metadata: Optional[Dict[str, dict]] = None,
               normalize_dataset: bool = False,
               normalize_config: Optional[NormalizeConfig] = None,
               treat_fields_as_ragged: Sequence[str] = (),
               convert_to_fixed_shape: bool = True) -> np.ndarray:
        """Insert records; returns the assigned ids."""
        incoming = self._to_table(data, schema, treat_fields_as_ragged,
                                  convert_to_fixed_shape)
        with self._dir.acquire_lock():
            man = self._dir.load()
            current = self._manifest_schema(man)
            # id generation (paper §4.5.1)
            ids = np.arange(man.next_row_id,
                            man.next_row_id + incoming.num_rows, dtype=np.int64)
            man.next_row_id = int(man.next_row_id + incoming.num_rows)
            incoming = incoming.set_column(ID_COLUMN, Column.numeric(ids))
            unified = current.unify(incoming.schema)
            if metadata:
                unified = unified.with_metadata(metadata)
            if fields_metadata:
                unified = _apply_fields_metadata(unified, fields_metadata)
            schema_changed = not unified.equals_names_types(current) and man.files
            new_files = list(man.files)
            if schema_changed and self.eager_schema_align:
                # paper: "Existing data is rewritten to align with the new schema"
                new_files = []
                for fn in man.files:
                    t = _get_reader(self._dir.file_path(fn)).read().align_to_schema(unified)
                    nf = self._dir.new_file_name(man)
                    self._write_file(self._dir.file_path(nf), t)
                    new_files.append(nf)
            out = self._dir.new_file_name(man)
            self._write_file(self._dir.file_path(out),
                             incoming.align_to_schema(unified))
            new_files.append(out)
            man.files = new_files
            self._set_manifest_schema(man, unified)
            if normalize_dataset:
                self._normalize_locked(man, normalize_config or NormalizeConfig())
            self._dir.commit(man)
            self._dir.gc(man)
        return ids

    # ------------------------------------------------------------------ read
    def _resolve_columns(self, columns: Optional[Sequence[str]],
                         include_cols: bool) -> List[str]:
        schema = self.schema
        if columns is None:
            return schema.names
        resolved: List[str] = []
        for c in columns:
            kids = nested.children_of(schema.names, c)
            if not kids:
                raise KeyError(f"unknown column {c!r}")
            resolved.extend(kids)
        if include_cols:
            return resolved
        drop = set(resolved)
        return [n for n in schema.names if n not in drop]

    def _build_filter(self, ids, filters) -> Optional[Expr]:
        parts: List[Expr] = []
        if ids is not None:
            parts.append(IsIn(ID_COLUMN, [int(i) for i in ids]))
        if filters:
            parts.extend(filters)
        return combine_filters(parts)

    def read(self, ids: Optional[Sequence[int]] = None,
             columns: Optional[Sequence[str]] = None,
             include_cols: bool = True,
             filters: Optional[Sequence[Expr]] = None,
             load_format: str = "table",
             batch_size: Optional[int] = None,
             rebuild_nested_struct: bool = False,
             rebuild_nested_from_scratch: bool = False,
             load_config: Optional[LoadConfig] = None):
        cfg = load_config or LoadConfig()
        if batch_size:
            cfg = dataclasses.replace(cfg, batch_size=batch_size)
        expr = self._build_filter(ids, filters)
        if rebuild_nested_struct:
            return self._read_nested(columns, expr, rebuild_nested_from_scratch)
        names = self._resolve_columns(columns, include_cols)
        if load_format == "table":
            if not self._dir.load().files:
                return Table.empty(self.schema.select(names))
            parts = list(self._iter_batches(names, expr, None, cfg))
            if not parts:
                return Table.empty(self.schema.select(names))
            return concat_tables(parts)
        if load_format == "batches":
            return self._iter_batches(names, expr, cfg.batch_size, cfg)
        if load_format == "dataset":
            return Dataset(self, names, expr, cfg)
        raise ValueError(f"unknown load_format {load_format!r}")

    def _scan_plan(self, names: Optional[List[str]], expr: Optional[Expr],
                   cfg, prune: bool = True) -> ScanPlan:
        """Build the read-path planner over the committed manifest."""
        man = self._dir.load()
        return ScanPlan(man.files,
                        lambda fn: _get_reader(self._dir.file_path(fn)),
                        self._manifest_schema(man), columns=names,
                        filter_expr=expr, cfg=cfg, prune=prune)

    def explain(self, ids: Optional[Sequence[int]] = None,
                columns: Optional[Sequence[str]] = None,
                include_cols: bool = True,
                filters: Optional[Sequence[Expr]] = None,
                execute: bool = False,
                load_config: Optional[LoadConfig] = None) -> ScanReport:
        """Report how a ``read`` with these arguments would be pruned.

        Planning is footer-only (no data pages decoded).  With
        ``execute=True`` the scan actually runs and the report additionally
        carries page/row/bytes-decoded counters.  ``print(report)`` gives a
        human-readable summary; ``report.to_dict()`` a JSON-able one.
        """
        expr = self._build_filter(ids, filters)
        names = self._resolve_columns(columns, include_cols)
        cfg = load_config or LoadConfig()
        return self._scan_plan(names, expr, cfg).explain(execute=execute)

    def _iter_batches(self, columns, expr: Optional[Expr],
                      batch_size: Optional[int], cfg: LoadConfig
                      ) -> Generator[Table, None, None]:
        names = self._resolve_columns(columns, True)
        yield from self._scan_plan(names, expr, cfg).execute(
            batch_size=batch_size)

    # -- nested rebuild (paper §4.6.1) -------------------------------------------
    def _nested_path(self) -> str:
        return self.db_path.rstrip("/") + "_nested"

    def _read_nested(self, columns, expr, from_scratch: bool) -> Table:
        npath = self._nested_path()
        ndb_exists = os.path.exists(os.path.join(npath, "_manifest.json"))
        if from_scratch and ndb_exists:
            import shutil
            shutil.rmtree(npath)
            ndb_exists = False
        ndb = ParquetDB(npath, self.dataset_name + "_nested",
                        codec=self.codec, encoding=self.encoding)
        if not ndb_exists:
            flat = self.read()  # full table
            rows = flat.to_pylist(rebuild_nested=True)
            for r in rows:
                r.pop(ID_COLUMN, None)
            ndb.create(rows, convert_to_fixed_shape=False)
        parents = None
        if columns is not None:
            parents = sorted({c.split(nested.SEP, 1)[0] for c in columns})
        nschema = ndb.schema
        cols = None
        if parents is not None:
            cols = []
            for p in parents:
                cols.extend(nested.children_of(nschema.names, p))
        filters = [expr] if expr is not None else None
        try:
            return ndb.read(columns=cols, filters=filters)
        except (KeyError, TypeError):
            # filter referenced a flattened-only column: filter on flat side
            keep = self.read(columns=[ID_COLUMN],
                             filters=[expr] if expr else None)
            ids = keep.column(ID_COLUMN).values.tolist()
            return ndb.read(ids=ids, columns=cols)

    # ------------------------------------------------------------------ update
    def update(self, data: TableLike, schema: Optional[Schema] = None,
               metadata: Optional[dict] = None,
               fields_metadata: Optional[Dict[str, dict]] = None,
               update_keys: Union[str, List[str]] = ID_COLUMN,
               treat_fields_as_ragged: Sequence[str] = (),
               convert_to_fixed_shape: bool = True,
               normalize_config: Optional[NormalizeConfig] = None) -> int:
        """Update matching records; returns number of rows updated."""
        keys = [update_keys] if isinstance(update_keys, str) else list(update_keys)
        incoming = self._to_table(data, schema, treat_fields_as_ragged,
                                  convert_to_fixed_shape)
        for k in keys:
            if k not in incoming:
                raise ValueError(f"update data must contain key column {k!r}")
        updated = 0
        with self._dir.acquire_lock():
            man = self._dir.load()
            current = self._manifest_schema(man)
            unified = current.unify(incoming.schema)
            if metadata:
                unified = unified.with_metadata(metadata)
            if fields_metadata:
                unified = _apply_fields_metadata(unified, fields_metadata)
            schema_changed = not unified.equals_names_types(current)
            inc_aligned = incoming.align_to_schema(
                unified.select([f.name for f in unified
                                if f.name in incoming.columns]))
            key_of = _key_index(incoming, keys)
            keys_expr = _keys_expr(incoming, keys)
            new_files = []
            for fn in man.files:
                rd = _get_reader(self._dir.file_path(fn))
                # fragment pruning: can this file contain any incoming key?
                if (not schema_changed and keys_expr is not None
                        and not file_may_match(rd, keys_expr)):
                    new_files.append(fn)
                    continue
                t = rd.read().align_to_schema(unified)
                hit_dst, hit_src = _match_rows(t, key_of, keys)
                if len(hit_dst) == 0 and not schema_changed:
                    new_files.append(fn)
                    continue
                if len(hit_dst):
                    t = _apply_updates(t, inc_aligned, hit_dst, hit_src, keys)
                    updated += len(hit_dst)
                nf = self._dir.new_file_name(man)
                self._write_file(self._dir.file_path(nf), t)
                new_files.append(nf)
            man.files = new_files
            self._set_manifest_schema(man, unified)
            if normalize_config is not None:
                self._normalize_locked(man, normalize_config)
            self._dir.commit(man)
            self._dir.gc(man)
        return updated

    # ------------------------------------------------------------------ delete
    def delete(self, ids: Optional[Sequence[int]] = None,
               columns: Optional[Sequence[str]] = None,
               filters: Optional[Sequence[Expr]] = None,
               normalize_config: Optional[NormalizeConfig] = None) -> int:
        """Delete rows (by ids/filters) or columns.  Returns rows/cols removed."""
        if columns is not None and (ids is not None or filters is not None):
            raise ValueError("row and column deletion are mutually exclusive")
        removed = 0
        with self._dir.acquire_lock():
            man = self._dir.load()
            current = self._manifest_schema(man)
            if columns is not None:
                cols = []
                for c in columns:
                    cols.extend(nested.children_of(current.names, c))
                if ID_COLUMN in cols:
                    raise ValueError("cannot delete the primary key column 'id'")
                missing = [c for c in cols if c not in current]
                if missing:
                    raise KeyError(f"unknown columns {missing}")
                new_files = []
                for fn in man.files:
                    t = _get_reader(self._dir.file_path(fn)).read()
                    t = t.drop([c for c in cols if c in t])
                    nf = self._dir.new_file_name(man)
                    self._write_file(self._dir.file_path(nf), t)
                    new_files.append(nf)
                man.files = new_files
                self._set_manifest_schema(man, current.drop(cols))
                removed = len(cols)
            else:
                expr = self._build_filter(ids, filters)
                if expr is None:
                    raise ValueError("delete needs ids, filters, or columns")
                new_files = []
                for fn in man.files:
                    rd = _get_reader(self._dir.file_path(fn))
                    if not file_may_match(rd, expr):
                        new_files.append(fn)
                        continue
                    t = rd.read().align_to_schema(current)
                    mask = expr.evaluate(t)
                    k = int(mask.sum())
                    if k == 0:
                        new_files.append(fn)
                        continue
                    removed += k
                    t = t.filter_mask(~mask)
                    if t.num_rows == 0:
                        continue  # drop empty file
                    nf = self._dir.new_file_name(man)
                    self._write_file(self._dir.file_path(nf), t)
                    new_files.append(nf)
                man.files = new_files
            if normalize_config is not None:
                self._normalize_locked(man, normalize_config)
            self._dir.commit(man)
            self._dir.gc(man)
        return removed

    # ------------------------------------------------------------------ normalize
    def normalize(self, normalize_config: Optional[NormalizeConfig] = None,
                  **kwargs) -> None:
        cfg = normalize_config or NormalizeConfig(**kwargs)
        with self._dir.acquire_lock():
            man = self._dir.load()
            self._normalize_locked(man, cfg)
            self._dir.commit(man)
            self._dir.gc(man)

    def _normalize_locked(self, man: Manifest, cfg: NormalizeConfig) -> None:
        schema = self._manifest_schema(man)
        # full unfiltered scan via the planner (threaded readahead per cfg)
        plan = ScanPlan(man.files,
                        lambda fn: _get_reader(self._dir.file_path(fn)),
                        schema, cfg=cfg)
        batches = list(plan.execute())
        if not batches:
            return
        full = concat_tables(batches)
        new_files = []
        rg = max(int(cfg.max_rows_per_group), 1)
        page = max(min(DEFAULT_PAGE_ROWS, rg), 1)
        for s in range(0, full.num_rows, max(cfg.max_rows_per_file, 1)):
            piece = full.slice(s, s + cfg.max_rows_per_file)
            nf = self._dir.new_file_name(man)
            self._write_file(self._dir.file_path(nf), piece,
                             row_group_rows=rg, page_rows=page)
            new_files.append(nf)
        man.files = new_files


# ---------------------------------------------------------------------------
# update helpers
# ---------------------------------------------------------------------------
def _key_index(incoming: Table, keys: List[str]) -> Dict[Any, int]:
    cols = [incoming.column(k).to_pylist() for k in keys]
    out: Dict[Any, int] = {}
    for i in range(incoming.num_rows):
        kv = cols[0][i] if len(keys) == 1 else tuple(c[i] for c in cols)
        out[kv] = i  # last wins
    return out


_KEYS_EXPR_MAX_ISIN = 256  # above this, fall back to a [lo, hi] range check


def _keys_expr(incoming: Table, keys: List[str]) -> Optional[Expr]:
    """Prunable Expr matching the incoming update keys, or None.

    Feeds :func:`repro.core.scan.file_may_match` so ``update`` skips files
    whose stats prove no key is present.  Small key sets become ``IsIn``
    (bloom-prunable even inside [min, max]); large ones a min/max range.
    Conservative None for multi-key updates or keys containing nulls.
    """
    if len(keys) != 1:
        return None
    k = keys[0]
    col = incoming.column(k)
    if col.null_count:
        return None
    if col.dtype.is_numeric:
        vals = col.values
        if vals.dtype.kind == "f":
            # NaN keys never match any row (== is False for NaN) and a NaN
            # endpoint would poison the range fallback into pruning all files
            vals = vals[~np.isnan(vals)]
        uniq = np.unique(vals)
        if len(uniq) == 0:
            return None
        if len(uniq) <= _KEYS_EXPR_MAX_ISIN:
            return IsIn(k, [v.item() for v in uniq])
        return (field(k) >= uniq[0].item()) & (field(k) <= uniq[-1].item())
    if col.dtype.kind == KIND_STRING:
        uniq = sorted(set(col.to_pylist()))
        if not uniq:
            return None
        if len(uniq) <= _KEYS_EXPR_MAX_ISIN:
            return IsIn(k, uniq)
        return (field(k) >= uniq[0]) & (field(k) <= uniq[-1])
    return None


def _match_rows(t: Table, key_of: Dict[Any, int], keys: List[str]):
    if len(keys) == 1 and t.column(keys[0]).dtype.is_numeric and all(
            isinstance(k, (int, float)) for k in key_of):
        vals = t.column(keys[0]).values
        inc = np.fromiter(key_of.keys(), dtype=vals.dtype, count=len(key_of))
        src = np.fromiter(key_of.values(), dtype=np.int64, count=len(key_of))
        order = np.argsort(inc)
        inc, src = inc[order], src[order]
        pos = np.searchsorted(inc, vals)
        pos = np.clip(pos, 0, len(inc) - 1)
        hit = inc[pos] == vals
        return np.nonzero(hit)[0], src[pos[hit]]
    cols = [t.column(k).to_pylist() for k in keys]
    dst, src = [], []
    for i in range(t.num_rows):
        kv = cols[0][i] if len(keys) == 1 else tuple(c[i] for c in cols)
        j = key_of.get(kv)
        if j is not None:
            dst.append(i)
            src.append(j)
    return np.array(dst, np.int64), np.array(src, np.int64)


def _apply_updates(t: Table, incoming: Table, dst: np.ndarray,
                   src: np.ndarray, keys: List[str]) -> Table:
    for name in incoming.column_names:
        if name in keys:
            continue
        tgt = t.column(name)
        upd = incoming.column(name).take(src)
        merged = _scatter_column(tgt, dst, upd)
        t = t.set_column(name, merged, metadata=t.schema[name].metadata
                         if name in t.schema else None)
    return t


def _scatter_column(tgt: Column, dst: np.ndarray, upd: Column) -> Column:
    """Out-of-place scatter: tgt[dst] = upd (validity-aware)."""
    n = len(tgt)
    idx = np.arange(n, dtype=np.int64)
    take_from_upd = np.full(n, -1, np.int64)
    take_from_upd[dst] = np.arange(len(dst))
    # build combined via take trick: concat(tgt, upd).take(sel)
    from .table import concat_columns
    both = concat_columns([tgt, upd.cast(tgt.dtype)])
    sel = np.where(take_from_upd >= 0, take_from_upd + n, idx)
    return both.take(sel)


def _apply_fields_metadata(schema: Schema, fm: Dict[str, dict]) -> Schema:
    fields = []
    for f in schema:
        if f.name in fm:
            fields.append(Field(f.name, f.dtype, f.nullable,
                                {**(f.metadata or {}), **fm[f.name]}))
        else:
            fields.append(f)
    return Schema(fields, schema.metadata)


