"""repro.core — the paper's contribution: a Parquet-style columnar database.

Public API mirrors the paper's ParquetDB: ``ParquetDB`` with
create/read/update/delete/normalize, expression filters via ``field``, and the
config dataclasses ``NormalizeConfig`` / ``LoadConfig``.  The fluent,
composable entrypoint is ``db.query()`` (:mod:`repro.core.query`) — the
legacy methods are thin shims over it.
"""
from .dtypes import DType
from .schema import Field, ID_COLUMN, Schema
from .table import Column, Table, concat_tables
from .expressions import Arith, Expr, field
from .fileformat import TPQReader, TPQWriter, read_table, write_table
from .integrity import (CorruptFooterError, CorruptPageError, FileCheck,
                        IntegrityError, IntegrityReport, TruncatedFileError,
                        verify_file)
from .scan import (DeltaOverlay, FragmentPlan, MorselBudget, ScanCounters,
                   ScanPlan, ScanReport)
from .aggregate import AggregatePlan
from .partition import PartitionSpec, Partitioning
from .query import GroupedQuery, Query, QueryReport
from .compaction import CompactionPolicy, CompactionResult, MaintenanceStats
from .transactions import (CommitConflict, DeltaEntry, Manifest, Transaction,
                           WriteLockTimeout, register_commit_listener)
from .store import Dataset, LoadConfig, NormalizeConfig, ParquetDB

__all__ = [
    "DType", "Field", "ID_COLUMN", "Schema", "Column", "Table",
    "concat_tables", "Arith", "Expr", "field", "TPQReader", "TPQWriter",
    "read_table", "write_table",
    "IntegrityError", "TruncatedFileError", "CorruptFooterError",
    "CorruptPageError", "FileCheck", "IntegrityReport", "verify_file",
    "DeltaOverlay", "FragmentPlan", "MorselBudget",
    "ScanCounters", "ScanPlan", "ScanReport", "AggregatePlan",
    "PartitionSpec", "Partitioning",
    "GroupedQuery", "Query", "QueryReport",
    "CompactionPolicy", "CompactionResult", "MaintenanceStats",
    "CommitConflict", "DeltaEntry", "Manifest", "Transaction",
    "WriteLockTimeout", "register_commit_listener",
    "Dataset", "LoadConfig", "NormalizeConfig",
    "ParquetDB",
]
