"""Shared-memory result transport for the process-pool scan executor.

A morsel decoded in a worker process has to reach the parent somehow; the
default ``ProcessPoolExecutor`` path pickles everything through a pipe,
which re-serializes every decoded buffer byte.  Here the *structure* of the
result (tables, schemas, counters) still travels the pipe — it is tiny —
but the decoded column buffers go out-of-band (pickle protocol 5
``buffer_callback``) into ONE POSIX shared-memory segment per morsel, which
the parent maps, copies out of, and unlinks.

Ownership protocol (CPython <= 3.12 registers a segment with the process's
``resource_tracker`` on *attach* as well as on create — bpo-39959 — so both
sides unregister and lifetime is managed explicitly here):

- the worker creates the segment, unregisters it from the tracker, and
  closes its mapping: from then on the segment is owned by its *name*,
  carried in the pickled envelope;
- the parent attaches (which re-registers — the tracker then doubles as a
  crash backstop while the parent holds the mapping), copies the buffers
  out, then closes **and unlinks** — exactly once, in ``unpack`` or
  ``discard``; stdlib ``unlink()`` itself issues the balancing
  unregister, so the parent must *not* unregister manually (that would
  double-remove and crash the tracker's cache bookkeeping);
- every create/attach is recorded in a per-process registry;
  :func:`live_segments` exposes it (tests assert emptiness after scans and
  after early termination) and an ``atexit`` hook unlinks stragglers so an
  interpreter bug can never leak kernel objects past process exit.

Small results skip shared memory entirely (``REPRO_SHM_MIN_BYTES``, default
256 KiB: below that the pipe copy is cheaper than two syscalls + mmap).
"""
from __future__ import annotations

import atexit
import os
import pickle
import warnings
from multiprocessing import resource_tracker, shared_memory
from typing import Any, List, Optional, Tuple

__all__ = ["pack", "unpack", "discard", "live_segments", "shm_min_bytes",
           "Envelope"]

ENV_MIN_BYTES = "REPRO_SHM_MIN_BYTES"
_DEFAULT_MIN_BYTES = 256 * 1024

# name -> SharedMemory mappings this process has open and is responsible
# for; names created here but handed off (worker side) leave the registry
# at hand-off, so a non-empty registry at exit means a genuine leak.
_OPEN: dict = {}


def shm_min_bytes() -> int:
    try:
        return int(os.environ.get(ENV_MIN_BYTES, _DEFAULT_MIN_BYTES))
    except ValueError:
        return _DEFAULT_MIN_BYTES


def _untrack(seg: shared_memory.SharedMemory) -> None:
    """Keep this process's resource_tracker out of the segment's lifetime."""
    try:
        resource_tracker.unregister(seg._name, "shared_memory")
    except Exception:  # pragma: no cover - tracker already gone at shutdown
        pass


# (pickle bytes, out-of-band buffers or None, segment name or None)
Envelope = Tuple[bytes, Optional[List[bytes]], Optional[str]]


def pack(obj: Any) -> Envelope:
    """Worker side: pickle ``obj`` with its big buffers out-of-band.

    Returns an :data:`Envelope` that crosses the pipe cheaply: buffers
    either ride inline (small results) or live in a named shared-memory
    segment whose ownership transfers with the envelope.
    """
    buffers: List[pickle.PickleBuffer] = []
    data = pickle.dumps(obj, protocol=5, buffer_callback=buffers.append)
    raws = [b.raw() for b in buffers]
    total = sum(len(r) for r in raws)
    if total == 0 or total < shm_min_bytes():
        # nothing out-of-band (or below threshold): ride the pipe; a
        # zero-size segment is not even creatable
        return data, [bytes(r) for r in raws], None
    seg = shared_memory.SharedMemory(create=True, size=total)
    _untrack(seg)
    sizes = []
    off = 0
    for r in raws:
        seg.buf[off:off + len(r)] = r
        sizes.append(len(r))
        off += len(r)
    name = seg.name
    seg.close()  # ownership rides in the envelope now
    return pickle.dumps((data, sizes), protocol=5), None, name


def _attach(name: str) -> shared_memory.SharedMemory:
    # attaching re-registers with this process's tracker (see module
    # docstring): deliberate — if the parent dies holding the mapping the
    # tracker unlinks for us; the normal-path unlink() unregisters.
    seg = shared_memory.SharedMemory(name=name)
    _OPEN[name] = seg
    return seg


def _release(seg: shared_memory.SharedMemory) -> None:
    _OPEN.pop(seg.name, None)
    seg.close()
    try:
        seg.unlink()  # also unregisters from the resource tracker
    except FileNotFoundError:  # pragma: no cover - double-discard raced
        _untrack(seg)  # unlink() bailed before its unregister


def unpack(env: Envelope) -> Any:
    """Parent side: rebuild the object; copy out of + unlink any segment."""
    data, bufs, name = env
    if name is None:
        return pickle.loads(data, buffers=bufs)
    seg = _attach(name)
    try:
        inner, sizes = pickle.loads(data)
        need = sum(sizes)
        if need > seg.size:
            # worker died (or was killed) between creating the segment and
            # filling it: the mapping is shorter than the envelope claims.
            # Surface a typed truncation instead of a short-buffer unpickle.
            from .integrity import TruncatedFileError
            raise TruncatedFileError(
                f"shm:{name}",
                f"shared-memory segment holds {seg.size} bytes but the "
                f"envelope claims {need}")
        out: List[bytearray] = []
        off = 0
        for s in sizes:
            out.append(bytearray(seg.buf[off:off + s]))  # writable copies
            off += s
        return pickle.loads(inner, buffers=out)
    finally:
        _release(seg)


def discard(env: Envelope) -> None:
    """Release an envelope without deserializing it.

    The early-termination path (``limit()`` satisfied mid-scan) drains
    in-flight futures through here so abandoned morsels cannot leak their
    segments.
    """
    name = env[2]
    if name is None:
        return
    try:
        _release(_attach(name))
    except FileNotFoundError:  # pragma: no cover - worker died pre-create
        pass


def live_segments() -> List[str]:
    """Names of segments this process still holds open (tests want [])."""
    return sorted(_OPEN)


@atexit.register
def _sweep() -> None:  # pragma: no cover - exercised only on leak bugs
    for name in list(_OPEN):
        warnings.warn(f"leaked scan shared-memory segment {name!r}; "
                      "unlinking at exit", ResourceWarning)
        _release(_OPEN[name])
