"""TPQ file format — the repo's Parquet analogue, from scratch.

Layout (paper §4.1 / SI §1), format v2:

    b"TPQ1"
    <data section: concatenated encoded buffers>
    <footer: zlib-compressed JSON>
    <uint32 LE crc32 of footer blob> <uint64 LE footer length> b"TPQ2"

Format v1 files (no checksums) end with ``<uint64 LE footer length> b"TPQ1"``
instead; the reader dispatches on the trailing magic and reads them as
"unchecksummed" (``TPQReader.checksummed`` is False).  v2 additionally
records a crc32 per stored buffer (``"crc"`` in each buffer dict, hashed
over the on-disk — possibly compressed — bytes, so verification is a single
pass before decompression).  Verification failures raise the typed errors
from :mod:`repro.core.integrity` (``TruncatedFileError`` /
``CorruptFooterError`` / ``CorruptPageError`` with file/row-group/page
coordinates) instead of cryptic ``struct``/``zlib``/``json`` errors.

A file holds *row groups* (horizontal partitions); each row group holds one
*column chunk* per field; each chunk is split into *pages* whose row boundaries
are aligned across columns (so page-level pruning on a filter column maps
directly to page slices of every projected column — our page-index
implementation of SI §1.3).  The footer carries the schema, table metadata and
per-chunk + per-page statistics (min/max/null-count/bloom) and buffer offsets,
enabling:

  - projection pushdown: only the byte ranges of requested columns are read;
  - predicate pushdown: row groups and then pages whose stats cannot match the
    filter are never read from disk.
"""
from __future__ import annotations

import json
import math
import mmap
import os
import struct
import zlib
from typing import Any, Dict, Iterator, List, Optional, Sequence

import numpy as np

from . import encodings as enc
from . import integrity
from .backend import active_backend
from .integrity import (CorruptFooterError, CorruptPageError,
                        TruncatedFileError)
from .dtypes import (DType, KIND_BINARY, KIND_LIST, KIND_NULL, KIND_NUMERIC,
                     KIND_STRING, KIND_TENSOR)
from .expressions import Expr
from .schema import Schema
from .statistics import (ColumnStats, compute_bloom, compute_stats,
                         merge_stat_maps, merge_stats)
from .table import (Column, Table, _ragged_gather_index, concat_columns,
                    null_column_of)


def _payload_nbytes(p) -> int:
    if isinstance(p, (bytes, bytearray)):
        return len(p)
    return memoryview(p).nbytes

MAGIC = b"TPQ1"
TRAILER_V2 = b"TPQ2"  # trailing magic of checksummed (v2) files
VERSION = 2
CREATED_BY = "repro-tpq 0.2"

DEFAULT_PAGE_ROWS = 8192
DEFAULT_ROW_GROUP_ROWS = 131072


# ---------------------------------------------------------------------------
# Writer
# ---------------------------------------------------------------------------
class TPQWriter:
    def __init__(self, path: str, *, codec: str = enc.CODEC_ZLIB, level: int = 1,
                 encoding: str = enc.AUTO, page_rows: int = DEFAULT_PAGE_ROWS,
                 row_group_rows: int = DEFAULT_ROW_GROUP_ROWS,
                 with_bloom: bool = True,
                 field_encodings: Optional[Dict[str, str]] = None,
                 field_codecs: Optional[Dict[str, str]] = None,
                 file_kind: str = "base",
                 checksums: bool = True):
        # file_kind: "base" | "upsert" | "tombstone" — a footer flag marking
        # merge-on-read delta files, so an orphaned .tpq is self-describing
        # even without the manifest (crash forensics, external tools).
        # checksums=False writes the exact legacy v1 layout (no crcs, TPQ1
        # trailer) — kept for back-compat tests and external v1 consumers.
        self.file_kind = file_kind
        self.path = path
        self.checksums = checksums
        self._fault(len(MAGIC))
        self._fh = open(path, "wb")
        self._fh.write(MAGIC)
        self._off = len(MAGIC)
        self.codec, self.level, self.encoding = codec, level, encoding
        self.page_rows, self.row_group_rows = page_rows, row_group_rows
        self.with_bloom = with_bloom
        self.field_encodings = field_encodings or {}
        self.field_codecs = field_codecs or {}
        self._row_groups: List[dict] = []
        self._schema: Optional[Schema] = None
        self._num_rows = 0
        self._closed = False

    def _fault(self, nbytes: int) -> None:
        # IO fault injection point (ENOSPC/EIO harness): called before every
        # disk write so tests can make the "disk" fill after K bytes
        if integrity.WRITE_FAULT_HOOK is not None:
            integrity.WRITE_FAULT_HOOK(self.path, nbytes)

    # -- buffers ---------------------------------------------------------------
    def _put(self, payload, encoding: str, meta: dict, codec: str,
             count: int) -> dict:
        # payload is any C-contiguous bytes-like (bytes, memoryview, uint8
        # ndarray): both zlib and the file write consume the buffer protocol,
        # so encoded pages reach disk without an intermediate .tobytes() copy
        nbytes = _payload_nbytes(payload)
        comp = enc.compress(payload, codec, self.level)
        if len(comp) >= nbytes:  # store raw when compression loses
            comp, codec, clen = payload, enc.CODEC_NONE, nbytes
        else:
            clen = len(comp)
        d = {"off": self._off, "len": clen, "enc": encoding,
             "codec": codec, "count": count}
        if self.checksums:
            # hash the *stored* bytes: verification is then one crc pass
            # over the raw page slice, before any decompression or decode
            d["crc"] = zlib.crc32(comp) & 0xFFFFFFFF
        if meta:
            d["meta"] = meta
        self._fault(clen)
        self._fh.write(comp)
        self._off += clen
        return d

    # encodings that already strip redundancy — compressing them again costs
    # CPU for ~no size win, so skip unless the user pinned a field codec
    _ENTROPY_CODED = frozenset({enc.BITPACK, enc.DICT, enc.DELTA, enc.RLE})

    def _write_values(self, arr: np.ndarray, name: str) -> dict:
        encoding = self.field_encodings.get(name, self.encoding)
        chosen, meta, payload = enc.encode(arr, encoding)
        if name in self.field_codecs:
            codec = self.field_codecs[name]
        elif chosen in self._ENTROPY_CODED:
            codec = enc.CODEC_NONE
        else:
            codec = self.codec
        return self._put(payload, chosen, meta, codec, len(arr))

    def _write_validity(self, validity: Optional[np.ndarray]) -> Optional[dict]:
        if validity is None or validity.all():
            return None
        payload = np.packbits(validity, bitorder="little")
        return self._put(payload, "bitmap", {}, self.codec, len(validity))

    def _write_column_page(self, col: Column, name: str) -> dict:
        page: Dict[str, Any] = {"rows": len(col)}
        vb = self._write_validity(col.validity)
        if vb is not None:
            page["validity"] = vb
        k = col.dtype.kind
        if k == KIND_NUMERIC:
            page["values"] = self._write_values(col.values, name)
        elif k == KIND_TENSOR:
            page["values"] = self._write_values(col.values.reshape(-1), name)
        elif k in (KIND_STRING, KIND_BINARY):
            lens = np.diff(col.offsets)
            page["lengths"] = self._write_values(lens, name)
            blob = np.ascontiguousarray(
                col.blob[col.offsets[0]:col.offsets[-1]])
            page["blob"] = self._put(blob, enc.PLAIN, {},
                                     self.field_codecs.get(name, self.codec),
                                     int(len(blob)))
        elif k == KIND_LIST:
            lens = np.diff(col.offsets)
            page["lengths"] = self._write_values(lens, name)
            child = col.child.slice(int(col.offsets[0]), int(col.offsets[-1]))
            page["child"] = self._write_column_page(child, name)
        # KIND_NULL: rows only
        return page

    # -- row groups --------------------------------------------------------------
    def write_table(self, table: Table) -> None:
        for start in range(0, max(table.num_rows, 1), self.row_group_rows):
            piece = table.slice(start, start + self.row_group_rows)
            if piece.num_rows == 0 and table.num_rows > 0:
                break
            self.write_row_group(piece)
            if table.num_rows == 0:
                break

    def write_row_group(self, table: Table) -> None:
        if self._schema is None:
            self._schema = table.schema
        elif not self._schema.equals_names_types(table.schema):
            raise ValueError("row group schema mismatch within one file")
        n = table.num_rows
        rg: Dict[str, Any] = {"num_rows": n, "columns": {}}
        for f in table.schema:
            col = table.column(f.name)
            pages, pstats = [], []
            for s in range(0, max(n, 1), self.page_rows):
                if s >= n and n > 0:
                    break
                piece = col.slice(s, min(s + self.page_rows, n))
                page = self._write_column_page(piece, f.name)
                # pages carry min/max/null stats only; the bloom fingerprint
                # lives at chunk level (like Parquet) — per-page blooms made
                # the footer JSON dominate file size and write time
                st = compute_stats(piece, with_bloom=False)
                page["stats"] = st.to_dict()
                pages.append(page)
                pstats.append(st)
                if n == 0:
                    break
            chunk_stats = merge_stats(pstats) if pstats else ColumnStats()
            if self.with_bloom and pstats:
                chunk_stats.bloom = compute_bloom(col)
            rg["columns"][f.name] = {
                "pages": pages,
                "stats": chunk_stats.to_dict(),
            }
        self._row_groups.append(rg)
        self._num_rows += n

    def close(self) -> None:
        if self._closed:
            return
        footer = {
            "version": VERSION if self.checksums else 1,
            "created_by": CREATED_BY,
            "num_rows": self._num_rows,
            "schema": (self._schema or Schema([])).to_dict(),
            "row_groups": self._row_groups,
        }
        if self.file_kind != "base":
            footer["kind"] = self.file_kind
        blob = zlib.compress(json.dumps(footer).encode("utf-8"), 6)
        if self.checksums:
            trailer = struct.pack("<I", zlib.crc32(blob) & 0xFFFFFFFF) \
                + struct.pack("<Q", len(blob)) + TRAILER_V2
        else:
            trailer = struct.pack("<Q", len(blob)) + MAGIC
        self._fault(len(blob) + len(trailer))
        self._fh.write(blob)
        self._fh.write(trailer)
        self._fh.flush()
        self._fh.close()
        self._closed = True

    def abort(self) -> None:
        """Close the handle *without* writing a footer.

        Used on write faults (ENOSPC/EIO mid-file): the partial file is left
        footer-less — structurally truncated, so any later open fails typed
        — and the caller unlinks it.  Idempotent with :meth:`close`.
        """
        if not self._closed:
            self._fh.close()
            self._closed = True

    def __enter__(self):
        return self

    def __exit__(self, exc_type, *exc):
        # a failed write must NOT be sealed with a valid footer: the file
        # is incomplete, and a footer would make it open cleanly
        if exc_type is not None:
            self.abort()
        else:
            self.close()


def write_table(path: str, table: Table, **kw) -> None:
    with TPQWriter(path, **kw) as w:
        w.write_table(table)


# ---------------------------------------------------------------------------
# Reader
# ---------------------------------------------------------------------------
class TPQReader:
    def __init__(self, path: str):
        self.path = path
        with open(path, "rb") as fh:
            # map the whole file read-only: footer-described buffer ranges
            # become memoryview slices (no seek/read syscall per page, no
            # bytes copy for uncompressed buffers); falls back to one bulk
            # read where mmap is unavailable.  The fd can close immediately
            # — the mapping (and any ndarray viewing it) keeps the pages.
            # Windows cannot delete a mapped file, which would break the
            # orphan GC after compaction (cached readers hold maps for
            # their lifetime) — bulk-read there instead.
            self._mm = None
            if os.name != "nt":
                try:
                    self._mm = mmap.mmap(fh.fileno(), 0,
                                         access=mmap.ACCESS_READ)
                except (ValueError, OSError):
                    self._mm = None
            if self._mm is not None:
                self._buf = memoryview(self._mm)
            else:
                fh.seek(0)
                self._buf = memoryview(fh.read())
        buf = self._buf
        if len(buf) < 16:
            raise TruncatedFileError(
                path, f"file too short ({len(buf)} bytes) — torn write?")
        if bytes(buf[:4]) != MAGIC:
            raise CorruptFooterError(
                path, f"bad magic {bytes(buf[:4])!r} (not a TPQ file)")
        trailer = bytes(buf[-4:])
        if trailer == TRAILER_V2:
            # v2: ... <crc32 of blob> <footer len> TPQ2
            self.checksummed = True
            (flen,) = struct.unpack("<Q", buf[-12:-4])
            if len(buf) < 20 or flen > len(buf) - 20:
                raise TruncatedFileError(
                    path, f"footer length {flen} exceeds file size "
                    f"{len(buf)} — truncated")
            blob = buf[-(16 + flen):-16]
            (want,) = struct.unpack("<I", buf[-16:-12])
            got = zlib.crc32(blob) & 0xFFFFFFFF
            if got != want:
                raise CorruptFooterError(
                    path, f"footer checksum mismatch "
                    f"(crc32 {got:#010x} != recorded {want:#010x})")
        elif trailer == MAGIC:
            # legacy v1: no checksums anywhere in the file
            self.checksummed = False
            (flen,) = struct.unpack("<Q", buf[-12:-4])
            if flen > len(buf) - 16:
                raise TruncatedFileError(
                    path, f"footer length {flen} exceeds file size "
                    f"{len(buf)} — truncated")
            blob = buf[-(12 + flen):-12]
        else:
            raise TruncatedFileError(
                path, f"bad trailing magic {trailer!r} — truncated or "
                "torn footer")
        try:
            footer = json.loads(zlib.decompress(blob))
            self.footer = footer
            self.schema = Schema.from_dict(footer["schema"])
            self.file_kind: str = footer.get("kind", "base")
            self.num_rows: int = footer["num_rows"]
            self.row_groups: List[dict] = footer["row_groups"]
        except (zlib.error, ValueError, KeyError, TypeError) as e:
            # garbage blob, broken JSON, or parsed-but-wrong-shape footer
            raise CorruptFooterError(
                path, f"footer unreadable: {type(e).__name__}: {e}") from e
        self._file_stats: Optional[Dict[str, ColumnStats]] = None
        self._rg_stats: List[Optional[Dict[str, ColumnStats]]] = \
            [None] * len(self.row_groups)

    def dup(self) -> "TPQReader":
        """Per-thread handle over the same file mapping.

        Shares the mmap/buffer and the parsed footer (all read-only after
        construction) but gets private stats-memo slots, so scan workers on
        different threads never write the same memo cell.  Costs no I/O and
        no footer re-parse — this is what the per-thread reader cache in
        ``store.py`` hands to morsel workers.
        """
        other = object.__new__(TPQReader)
        other.path = self.path
        other._mm = self._mm          # mapping outlives both handles
        other._buf = self._buf
        other.footer = self.footer
        other.schema = self.schema
        other.checksummed = self.checksummed
        other.file_kind = self.file_kind
        other.num_rows = self.num_rows
        other.row_groups = self.row_groups
        other._file_stats = None
        other._rg_stats = [None] * len(self.row_groups)
        return other

    # -- stats access ------------------------------------------------------------
    # Everything here is served from the (already-parsed) footer: the scan
    # planner prunes fragments and row groups without touching a data page.
    @property
    def num_row_groups(self) -> int:
        return len(self.row_groups)

    def row_group_num_rows(self, i: int) -> int:
        return self.row_groups[i]["num_rows"]

    def row_group_stats(self, i: int) -> Dict[str, ColumnStats]:
        # memoized: the planner, the reader, and write-path pruning all
        # consult the same stats — rebuild the ColumnStats objects once
        st = self._rg_stats[i]
        if st is None:
            st = {name: ColumnStats.from_dict(c["stats"])
                  for name, c in self.row_groups[i]["columns"].items()}
            self._rg_stats[i] = st
        return st

    def file_stats(self) -> Dict[str, ColumnStats]:
        """Whole-file per-column stats (row-group stats merged), cached."""
        if self._file_stats is None:
            self._file_stats = merge_stat_maps(
                [self.row_group_stats(i) for i in range(len(self.row_groups))])
        return self._file_stats

    def page_stats(self, rg: int, name: str) -> List[ColumnStats]:
        return [ColumnStats.from_dict(p["stats"])
                for p in self.row_groups[rg]["columns"][name]["pages"]]

    # -- page reads ----------------------------------------------------------------
    def _get(self, buf: dict, verify: bool = False, ctx: tuple = ()):
        """Raw (decompressed) buffer bytes — a zero-copy slice of the file
        mapping when the buffer is stored uncompressed.

        ``verify=True`` checks the buffer's recorded crc32 (hashed over the
        stored bytes, so this is one pass before decompression) and raises
        :class:`CorruptPageError` on mismatch; ``ctx`` is the
        ``(row_group, column, page)`` coordinates carried by the error.
        Legacy buffers without a ``"crc"`` key skip the check.
        """
        raw = self._buf[buf["off"]:buf["off"] + buf["len"]]
        if verify and "crc" in buf \
                and zlib.crc32(raw) & 0xFFFFFFFF != buf["crc"]:
            raise CorruptPageError(self.path, "page checksum mismatch",
                                   **_ctx_kw(ctx))
        if buf["codec"] == enc.CODEC_NONE:
            return raw
        try:
            return enc.decompress(raw, buf["codec"])
        except Exception as e:
            # without checksums a flipped bit usually lands here; with
            # them, only when verification was explicitly switched off
            raise CorruptPageError(
                self.path, f"page decompress failed: {e}",
                **_ctx_kw(ctx)) from e

    def _read_values(self, buf: dict, np_dtype, verify: bool = False,
                     ctx: tuple = ()) -> np.ndarray:
        payload = self._get(buf, verify=verify, ctx=ctx)
        return active_backend().decode(buf["enc"], buf.get("meta", {}),
                                       payload, buf["count"], np_dtype)

    # -- scrubbing ---------------------------------------------------------------
    def iter_page_buffers(self) -> Iterator[tuple]:
        """Yield ``(row_group, column, page, key, buf)`` for every stored
        buffer — validity/values/lengths/blob plus list children.  Used by
        the scrubber (:meth:`verify_pages`) and the fault-injection harness
        (which needs every page's byte extent to corrupt)."""
        for i, rg in enumerate(self.row_groups):
            for name, chunk in rg["columns"].items():
                for j, page in enumerate(chunk["pages"]):
                    stack = [page]
                    while stack:
                        p = stack.pop()
                        for k in ("validity", "values", "lengths", "blob"):
                            if k in p:
                                yield (i, name, j, k, p[k])
                        if "child" in p:
                            stack.append(p["child"])

    def verify_pages(self) -> int:
        """Crc-check every stored buffer (no decompression, no decode).

        Returns the number of buffers verified; raises
        :class:`CorruptPageError` with coordinates at the first mismatch.
        Legacy (v1) buffers carry no crc and count as unverified.
        """
        n = 0
        for i, name, j, _k, buf in self.iter_page_buffers():
            if "crc" not in buf:
                continue
            raw = self._buf[buf["off"]:buf["off"] + buf["len"]]
            if zlib.crc32(raw) & 0xFFFFFFFF != buf["crc"]:
                raise CorruptPageError(self.path, "page checksum mismatch",
                                       row_group=i, column=name, page=j)
            n += 1
        return n

    def _read_column_page(self, page: dict, dtype: DType,
                          sel: Optional[np.ndarray] = None,
                          counters=None, verify: bool = False,
                          ctx: tuple = ()) -> Column:
        """Decode one column page, optionally late-materialized.

        ``sel`` is a selection vector (sorted row indices within the page,
        from the filter-column mask): only the selected rows are
        materialized — for var-len columns the page-slice and ``take`` are
        fused, so unselected blob bytes are never copied out of the page
        buffer.  ``None`` decodes the full page.  ``counters`` (a
        ``ScanCounters``) accumulates ``bytes_saved_late``.
        """
        rows = page["rows"]
        validity = None
        if "validity" in page:
            raw = self._get(page["validity"], verify=verify, ctx=ctx)
            validity = np.unpackbits(np.frombuffer(raw, np.uint8), count=rows,
                                     bitorder="little").astype(bool)
            if sel is not None:
                validity = validity[sel]
        k = dtype.kind
        if k == KIND_NUMERIC:
            vals = self._read_values(page["values"], dtype.np,
                                     verify=verify, ctx=ctx)
            if sel is not None:
                vals = vals[sel]
                _late_saved(counters, (rows - len(sel)) * vals.dtype.itemsize)
            return Column(dtype, values=vals, validity=validity)
        if k == KIND_TENSOR:
            flat = self._read_values(page["values"], dtype.np,
                                     verify=verify, ctx=ctx)
            vals = flat.reshape(rows, *dtype.shape)
            if sel is not None:
                vals = vals[sel]
                _late_saved(counters, (rows - len(sel)) * flat.dtype.itemsize
                            * int(np.prod(dtype.shape)))
            return Column(dtype, values=vals, validity=validity)
        if k in (KIND_STRING, KIND_BINARY):
            lens = self._read_values(page["lengths"], np.int64,
                                     verify=verify, ctx=ctx)
            offsets = np.zeros(rows + 1, np.int64)
            np.cumsum(lens, out=offsets[1:])
            blob = np.frombuffer(
                self._get(page["blob"], verify=verify, ctx=ctx), np.uint8)
            if sel is not None:
                new_off, gather = _ragged_gather_index(offsets, sel)
                _late_saved(counters, int(offsets[-1]) - len(gather))
                return Column(dtype, offsets=new_off, blob=blob[gather],
                              validity=validity)
            return Column(dtype, offsets=offsets, blob=blob, validity=validity)
        if k == KIND_LIST:
            lens = self._read_values(page["lengths"], np.int64,
                                     verify=verify, ctx=ctx)
            offsets = np.zeros(rows + 1, np.int64)
            np.cumsum(lens, out=offsets[1:])
            if sel is not None:
                new_off, child_sel = _ragged_gather_index(offsets, sel)
                child = self._read_column_page(page["child"], dtype.child,
                                               sel=child_sel,
                                               counters=counters,
                                               verify=verify, ctx=ctx)
                return Column(dtype, offsets=new_off, child=child,
                              validity=validity)
            child = self._read_column_page(page["child"], dtype.child,
                                           counters=counters,
                                           verify=verify, ctx=ctx)
            return Column(dtype, offsets=offsets, child=child,
                          validity=validity)
        return Column.nulls(rows if sel is None else len(sel))

    # -- table reads ------------------------------------------------------------
    def _project(self, columns: Optional[Sequence[str]],
                 filter_expr: Optional[Expr]) -> List[str]:
        names = list(columns) if columns is not None else self.schema.names
        for n in names:
            if n not in self.schema:
                raise KeyError(f"unknown column {n!r}; file has {self.schema.names}")
        if filter_expr is not None:
            for n in filter_expr.columns():
                if n in self.schema and n not in names:
                    names.append(n)
        return names

    def read(self, columns: Optional[Sequence[str]] = None,
             filter_expr: Optional[Expr] = None,
             row_groups: Optional[Sequence[int]] = None,
             prune_pages: bool = True, counters=None,
             verify: Optional[str] = None) -> Table:
        parts = list(self.iter_row_group_tables(
            columns, filter_expr, row_groups, prune_pages=prune_pages,
            counters=counters, verify=verify))
        names = self._project(columns, filter_expr)
        keep = list(columns) if columns is not None else names
        if not parts:
            sub = self.schema.select(keep)
            return Table(sub, {f.name: null_column_of(f.dtype, 0) for f in sub})
        out = _concat_same_schema(parts)
        return out.select(keep)

    def iter_row_group_tables(self, columns=None, filter_expr=None,
                              row_groups=None, prune_pages: bool = True,
                              counters=None,
                              verify: Optional[str] = None) -> Iterator[Table]:
        """Yield one (filtered, projected) Table per surviving row group.

        ``counters``, when given, is a duck-typed observer (in practice a
        :class:`repro.core.scan.ScanCounters`) whose ``row_groups_scanned``,
        ``row_groups_skipped``, ``pages_scanned``, ``pages_skipped``,
        ``rows_scanned`` and ``bytes_decoded`` attributes are incremented as
        the reader prunes and decodes.

        ``verify`` is ``"page"`` (default — crc-check every stored buffer
        before decoding it, raising :class:`CorruptPageError` with
        coordinates), or ``"footer"``/``"off"`` to skip the per-page check
        (the footer checksum was already validated at open).

        An explicit ``row_groups`` selection is treated as authoritative at
        row-group granularity (the caller — normally the scan planner — has
        already consulted the stats); page-level pruning still applies.
        """
        vp = verify is None or verify == "page"
        names = self._project(columns, filter_expr)
        sub_schema = self.schema.select(names)
        filter_cols = ([c for c in dict.fromkeys(filter_expr.columns())
                        if c in self.schema]
                       if filter_expr is not None else [])
        two_phase = bool(filter_cols) and len(filter_cols) < len(names)
        rg_sel = set(row_groups) if row_groups is not None else None
        for i, rg in enumerate(self.row_groups):
            if rg_sel is not None and i not in rg_sel:
                continue
            if (rg_sel is None and filter_expr is not None
                    and not filter_expr.prune(self.row_group_stats(i))):
                if counters is not None:
                    counters.row_groups_skipped += 1
                continue  # row-group pushdown: skip entirely
            first_chunk = (next(iter(rg["columns"].values()))
                           if rg["columns"] else None)
            npages = len(first_chunk["pages"]) if first_chunk else 0
            page_sel = list(range(npages))
            if prune_pages and filter_expr is not None and npages > 1:
                page_sel = self._select_pages(i, filter_expr, npages)
                if not page_sel:
                    if counters is not None:
                        counters.row_groups_skipped += 1
                        counters.pages_skipped += npages
                    continue
            if counters is not None:
                counters.row_groups_scanned += 1
                counters.pages_scanned += len(page_sel)
                counters.pages_skipped += npages - len(page_sel)
                counters.rows_scanned += sum(
                    first_chunk["pages"][j]["rows"] for j in page_sel) \
                    if first_chunk else 0

            def read_pages(name: str, idxs, sels=None) -> Column:
                pages = rg["columns"][name]["pages"]
                if counters is not None:
                    counters.bytes_decoded += sum(
                        _page_stored_bytes(pages[j]) for j in idxs)
                dtype = self.schema[name].dtype
                if (sels is None and len(idxs) > 1
                        and dtype.kind == KIND_NUMERIC
                        and not any("validity" in pages[j] for j in idxs)):
                    # fused morsel decode: ONE batched backend dispatch per
                    # encoding group instead of one Python-level decode per
                    # page — the GIL-convoy fix for parallel scans (and it
                    # still skips the per-page temporaries + concat copy)
                    total = sum(pages[j]["rows"] for j in idxs)
                    out = np.empty(total, dtype.np)
                    specs = []
                    for j in idxs:
                        b = pages[j]["values"]
                        specs.append((b["enc"], b.get("meta", {}),
                                      self._get(b, verify=vp,
                                                ctx=(i, name, j)),
                                      b["count"]))
                    active_backend().decode_batch(specs, dtype.np, out=out)
                    return Column(dtype, values=out)
                pieces = [self._read_column_page(
                    pages[j], dtype,
                    sel=None if sels is None else sels[jj],
                    counters=counters, verify=vp,
                    ctx=(i, name, j)) for jj, j in enumerate(idxs)]
                return (concat_columns(pieces) if len(pieces) != 1
                        else pieces[0])

            if two_phase:
                # phase 1: decode ONLY the filter columns, page by page;
                # a page with zero matches never touches the other columns.
                # Each surviving page's mask becomes a *selection vector*:
                # phase 2 materializes only the selected rows of the payload
                # columns (late materialization — the page-slice and take
                # are fused inside _read_column_page).
                fschema = self.schema.select(filter_cols)
                # single-column contiguous ranges evaluate through the
                # decode backend's fused range_mask (Pallas filter_range
                # on the jax backend); anything else through Expr.evaluate
                rng = (filter_expr.as_range()
                       if len(filter_cols) == 1 else None)
                if rng is not None and rng[0] != filter_cols[0]:
                    rng = None
                kept: List[int] = []
                sels: List[Optional[np.ndarray]] = []
                fcache: Dict[int, Dict[str, Column]] = {}
                for j in page_sel:
                    fcols = {n: read_pages(n, [j]) for n in filter_cols}
                    mask = None
                    if rng is not None:
                        fc = fcols[filter_cols[0]]
                        if fc.dtype.kind == KIND_NUMERIC \
                                and fc.validity is None:
                            bounds = _inclusive_bounds(rng, fc.values.dtype)
                            if bounds is not None:
                                mask = np.asarray(active_backend().range_mask(
                                    fc.values, bounds[0], bounds[1]), bool)
                    if mask is None:
                        mask = filter_expr.evaluate(Table(fschema, fcols))
                    if mask.any():
                        kept.append(j)
                        sels.append(None if mask.all()
                                    else np.nonzero(mask)[0])
                        fcache[j] = fcols
                if not kept:
                    continue
                if counters is not None:
                    counters.rows_skipped_late += sum(
                        len(fcache[j][filter_cols[0]]) - len(s)
                        for j, s in zip(kept, sels) if s is not None)
                cols: Dict[str, Column] = {}
                for name in names:
                    if name in filter_cols:
                        pieces = [fcache[j][name] if s is None
                                  else fcache[j][name].take(s)
                                  for j, s in zip(kept, sels)]
                        cols[name] = (pieces[0] if len(pieces) == 1
                                      else concat_columns(pieces))
                    else:
                        cols[name] = read_pages(name, kept, sels)
                t = Table(sub_schema, cols)
            else:
                cols = {name: read_pages(name, page_sel) for name in names}
                t = Table(sub_schema, cols)
                if filter_expr is not None:
                    mask = filter_expr.evaluate(t)
                    if not mask.all():
                        t = t.filter_mask(mask)
            if t.num_rows:
                yield t

    def _select_pages(self, rg: int, expr: Expr, npages: int) -> List[int]:
        """Page-index pruning: keep pages whose aligned stats may match."""
        cols = {c for c in expr.columns() if c in self.schema}
        per_page_stats: List[Dict[str, ColumnStats]] = [
            {} for _ in range(npages)]
        for name in cols:
            for j, st in enumerate(self.page_stats(rg, name)):
                per_page_stats[j][name] = st
        return [j for j in range(npages) if expr.prune(per_page_stats[j])]

    def read_row_group_bytes(self, i: int, columns: Optional[Sequence[str]] = None) -> int:
        """Total stored bytes for a row group's (projected) chunks.

        Footer-only (no data pages touched) — used by the scan planner's
        ``bytes_total`` / ``bytes_selected`` accounting and by benchmarks.
        """
        total = 0
        rg = self.row_groups[i]
        for name, chunk in rg["columns"].items():
            if columns is not None and name not in columns:
                continue
            for p in chunk["pages"]:
                total += _page_stored_bytes(p)
        return total


def _inclusive_bounds(rng, np_dtype):
    """Convert an ``Expr.as_range`` 5-tuple to inclusive [lo, hi] in the
    column's dtype, or None when it cannot be done exactly.

    Integer columns snap open/fractional bounds to the next representable
    integer; float columns use ``nextafter`` for strict bounds.  The
    resulting inclusive mask is bit-identical to ``Expr.evaluate`` on a
    fully-valid column.
    """
    _, lo, lo_open, hi, hi_open = rng
    try:
        if np_dtype.kind in "iu":
            # a float bound >= 2^53-2 is within one ulp of int values that
            # numpy's evaluate compares in (rounded) float64; exact integer
            # arithmetic here would then *diverge* from evaluate, making
            # results projection-dependent — keep the residual path instead
            for b in (lo, hi):
                if isinstance(b, (float, np.floating)) \
                        and abs(float(b)) >= 2.0**53 - 2:
                    return None
            info = np.iinfo(np_dtype)
            lo_i = info.min if lo is None else \
                (math.floor(lo) + 1 if lo_open else math.ceil(lo))
            hi_i = info.max if hi is None else \
                (math.ceil(hi) - 1 if hi_open else math.floor(hi))
            if lo_i > info.max or hi_i < info.min:
                return int(info.max), int(info.min)  # provably empty
            return max(int(lo_i), info.min), min(int(hi_i), info.max)
        if np_dtype.kind == "f":
            lo_f = -np.inf if lo is None else \
                (np.nextafter(lo, np.inf) if lo_open else float(lo))
            hi_f = np.inf if hi is None else \
                (np.nextafter(hi, -np.inf) if hi_open else float(hi))
            return lo_f, hi_f
    except (OverflowError, ValueError):
        pass
    return None


def _ctx_kw(ctx: tuple) -> dict:
    """(row_group, column, page) coordinates → CorruptPageError kwargs."""
    if not ctx:
        return {}
    return {"row_group": ctx[0], "column": ctx[1], "page": ctx[2]}


def _late_saved(counters, nbytes: int) -> None:
    """Accumulate payload bytes that late materialization never copied."""
    if counters is not None and nbytes > 0:
        counters.bytes_saved_late += int(nbytes)


def _page_stored_bytes(page: dict) -> int:
    """Stored (compressed) bytes backing one column page, from footer metadata."""
    t = 0
    for k in ("validity", "values", "lengths", "blob"):
        if k in page:
            t += page[k]["len"]
    if "child" in page:
        t += _page_stored_bytes(page["child"])
    return t


def page_codec_split(page: dict) -> tuple:
    """(stored_bytes, codec_compressed_bytes) for one column page.

    Footer-only.  The scan planner's auto-threading heuristic uses the
    ratio: decompression releases the GIL, so pages that are mostly
    codec-compressed parallelize across morsel workers, while raw/
    entropy-coded pages decode under the GIL and do not.
    """
    stored = compressed = 0
    for k in ("validity", "values", "lengths", "blob"):
        if k in page:
            stored += page[k]["len"]
            if page[k].get("codec", enc.CODEC_NONE) != enc.CODEC_NONE:
                compressed += page[k]["len"]
    if "child" in page:
        s, c = page_codec_split(page["child"])
        stored += s
        compressed += c
    return stored, compressed


def _concat_same_schema(parts: List[Table]) -> Table:
    if len(parts) == 1:
        return parts[0]
    schema = parts[0].schema
    cols = {f.name: concat_columns([p.columns[f.name] for p in parts])
            for f in schema}
    return Table(schema, cols)


def read_table(path: str, columns=None, filter_expr=None) -> Table:
    return TPQReader(path).read(columns=columns, filter_expr=filter_expr)
