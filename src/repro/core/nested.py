"""Nested-structure handling: flatten dicts to dotted columns and rebuild.

Implements the paper's §4.4.2 "Flattening Nested Structures" and §4.6.1
"Rebuilding Nested Structures": incoming records may contain arbitrarily nested
dictionaries; they are flattened into columns named ``parent.child1.child2``.
``rebuild`` inverts the mapping.  Empty structs get a dummy field so the column
survives storage (the paper's "Handling Empty Structs").
"""
from __future__ import annotations

from typing import Any, Dict, List

# Name of the placeholder inserted into empty structs (paper §4.4.2).
DUMMY_FIELD = "dummy_variable"
SEP = "."


def flatten_record(rec: Dict[str, Any], prefix: str = "") -> Dict[str, Any]:
    """Flatten one (possibly nested) record dict into dotted keys.

    Lists are left intact (they become list/tensor columns) *unless* they are
    lists of dicts, which stay as opaque python objects for the serializer to
    handle (the paper stores e.g. ``structure.sites`` — a list of dicts — via
    object serialization).
    """
    out: Dict[str, Any] = {}
    for key, val in rec.items():
        name = f"{prefix}{SEP}{key}" if prefix else str(key)
        if isinstance(val, dict):
            if not val:
                out[f"{name}{SEP}{DUMMY_FIELD}"] = True
            else:
                out.update(flatten_record(val, prefix=name))
        else:
            out[name] = val
    return out


def flatten_records(records: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    return [flatten_record(r) for r in records]


def _insert(tree: Dict[str, Any], dotted: str, value: Any) -> None:
    parts = dotted.split(SEP)
    node = tree
    for p in parts[:-1]:
        nxt = node.get(p)
        if not isinstance(nxt, dict):
            nxt = {}
            node[p] = nxt
        node = nxt
    node[parts[-1]] = value


def _strip_dummies(tree: Any) -> Any:
    if isinstance(tree, dict):
        return {
            k: _strip_dummies(v) for k, v in tree.items() if k != DUMMY_FIELD
        }
    return tree


def rebuild_record(flat: Dict[str, Any], strip_dummy: bool = True) -> Dict[str, Any]:
    """Invert :func:`flatten_record` — dotted keys back into nested dicts."""
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        _insert(tree, key, val)
    return _strip_dummies(tree) if strip_dummy else tree


def rebuild_records(flats: List[Dict[str, Any]], strip_dummy: bool = True) -> List[Dict[str, Any]]:
    return [rebuild_record(f, strip_dummy=strip_dummy) for f in flats]


def common_parent(name: str) -> str:
    """Top-level parent of a dotted column name (``a.b.c`` -> ``a``)."""
    return name.split(SEP, 1)[0]


def children_of(names: List[str], parent: str) -> List[str]:
    """All dotted names that live under ``parent`` (including exact match)."""
    pre = parent + SEP
    return [n for n in names if n == parent or n.startswith(pre)]
