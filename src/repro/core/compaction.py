"""Compaction: fold the merge-on-read delta chain back into sorted base files.

``update``/``delete`` are O(delta): they stage small upsert/tombstone files
instead of rewriting base files (see :mod:`repro.core.scan` for the read-time
overlay).  The price is read-side decay — delta-overlapped fragments lose
stats pruning, tombstoned rows are filtered on every scan, and small files
accumulate.  This module is the maintenance half of that bargain:

  - :func:`gather_stats` summarizes the decay from footers alone
    (``db.maintenance_stats()``): base/delta file counts, staged delta rows,
    delta ratio, small-file count, row-group fill.
  - :class:`CompactionPolicy` turns the summary into a **cost-based
    trigger** (``should_compact``): delta file count, delta-to-base row
    ratio, small-file count, and row-group fill each have a threshold.
  - :func:`compact_locked` performs the merge under the caller's writer
    lock: it selects the *affected* base files (those whose id range a
    delta can touch, plus under-filled files), streams them through a
    ``ScanPlan`` with the delta overlay applied, sorts the merged rows by
    id, and rewrites them as full base files.  Untouched base files keep
    their names — compaction cost scales with the affected region, not the
    dataset.

Durability/isolation: compaction is just another manifest commit.  The new
base files are staged first; a crash before the commit leaves the previous
generation (base files + delta chain) fully readable and the staged files
are garbage-collected on the next open.  Old-generation files are *not*
deleted inline after the commit — readers holding the pre-compaction
manifest snapshot keep a consistent view until the next open GCs the
orphans (docs/TRANSACTIONS.md covers the full lifecycle).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, List, Optional

import numpy as np

from .fileformat import DEFAULT_ROW_GROUP_ROWS, TPQReader
from .scan import DeltaOverlay, ScanPlan, resolve_num_threads, scan_pool
from .schema import ID_COLUMN, Schema
from .table import Table, concat_tables
from .transactions import DELTA_TOMBSTONE, DatasetDir, Manifest

__all__ = ["CompactionPolicy", "MaintenanceStats", "CompactionResult",
           "gather_stats", "compact_locked"]


@dataclasses.dataclass
class CompactionPolicy:
    """Thresholds for the cost-based compaction trigger.

    A dataset "needs" compaction when any of these is exceeded; the check
    itself is footer-only (cheap enough to run after every write).
    """
    max_delta_files: int = 4        # delta chain length before folding
    max_delta_ratio: float = 0.10   # staged delta rows / base rows
    max_small_files: int = 4        # under-filled base files to tolerate
    min_file_fill: float = 0.5      # a base file with fewer rows than
    #                                 min_file_fill * target_rows_per_file
    #                                 counts as "small"
    target_rows_per_file: Optional[int] = None
    # rows per rewritten base file, and the reference for small-file
    # detection.  None (default) disables small-file coalescing entirely —
    # only an explicit target declares a layout intent worth rewriting for
    # (otherwise compaction would fight normalize()'s layout) — and chunks
    # rewrites at the TPQ row-group default.
    min_row_group_fill: float = 0.0  # mean rows-per-row-group / target
    #                                  below this triggers; 0 disables
    target_rows_per_group: int = 131_072
    num_threads: Optional[int] = None
    # workers for the affected-file merge scan and the rewrite, on the
    # shared scan pool (None = os.cpu_count(), 1 = serial) — same knob and
    # semantics as LoadConfig.num_threads
    use_threads: bool = True


@dataclasses.dataclass
class MaintenanceStats:
    """Footer-only health summary returned by ``db.maintenance_stats()``."""
    generation: int = 0
    base_files: int = 0
    base_rows: int = 0
    delta_files: int = 0
    upsert_rows: int = 0         # rows staged in upsert deltas
    tombstone_rows: int = 0      # ids staged in tombstone deltas
    delta_ratio: float = 0.0     # (upsert + tombstone rows) / base rows
    small_files: int = 0         # base files below the fill threshold
    row_group_fill: float = 0.0  # mean rows per row group / target
    should_compact: bool = False
    reasons: List[str] = dataclasses.field(default_factory=list)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        lines = [
            f"MaintenanceStats  generation={self.generation}",
            f"  base:   {self.base_files} files, {self.base_rows} rows "
            f"(fill {self.row_group_fill:.2f}, {self.small_files} small)",
            f"  deltas: {self.delta_files} files, {self.upsert_rows} upsert "
            f"rows, {self.tombstone_rows} tombstoned ids "
            f"(ratio {self.delta_ratio:.3f})",
            f"  compact recommended: {self.should_compact}"
            + (f" ({'; '.join(self.reasons)})" if self.reasons else ""),
        ]
        return "\n".join(lines)


@dataclasses.dataclass
class CompactionResult:
    """Outcome of one ``db.compact()`` call."""
    compacted: bool
    reasons: List[str] = dataclasses.field(default_factory=list)
    files_merged: int = 0        # base files rewritten
    deltas_merged: int = 0       # delta files folded in
    files_written: int = 0       # new base files produced
    rows_written: int = 0
    dropped_files: List[str] = dataclasses.field(default_factory=list)
    generation: int = 0          # manifest generation after the commit

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def gather_stats(man: Manifest, reader_of: Callable[[str], TPQReader],
                 policy: CompactionPolicy) -> MaintenanceStats:
    """Summarize dataset health from footers; never touches a data page."""
    s = MaintenanceStats(generation=man.generation,
                         base_files=len(man.files),
                         delta_files=len(man.deltas))
    n_groups = 0
    for fn in man.files:
        rd = reader_of(fn)
        s.base_rows += rd.num_rows
        n_groups += max(rd.num_row_groups, 1)
        if (policy.target_rows_per_file
                and rd.num_rows < policy.min_file_fill
                * policy.target_rows_per_file):
            s.small_files += 1
    for d in man.deltas:
        rd = reader_of(d.name)
        if d.kind == DELTA_TOMBSTONE:
            s.tombstone_rows += rd.num_rows
        else:
            s.upsert_rows += rd.num_rows
    s.delta_ratio = (s.upsert_rows + s.tombstone_rows) / max(s.base_rows, 1)
    s.row_group_fill = (s.base_rows / n_groups
                        / policy.target_rows_per_group) if n_groups else 0.0
    if s.delta_files > policy.max_delta_files:
        s.reasons.append(f"delta chain length {s.delta_files} "
                         f"> {policy.max_delta_files}")
    if s.delta_files and s.delta_ratio > policy.max_delta_ratio:
        s.reasons.append(f"delta ratio {s.delta_ratio:.3f} "
                         f"> {policy.max_delta_ratio}")
    if s.small_files > policy.max_small_files:
        s.reasons.append(f"{s.small_files} small files "
                         f"> {policy.max_small_files}")
    if (policy.min_row_group_fill and s.base_files
            and s.row_group_fill < policy.min_row_group_fill):
        s.reasons.append(f"row-group fill {s.row_group_fill:.3f} "
                         f"< {policy.min_row_group_fill}")
    s.should_compact = bool(s.reasons)
    return s


def _affected_files(files: List[str], reader_of, policy: CompactionPolicy,
                    shadow_ids: np.ndarray, force: bool) -> List[str]:
    """Base files (of one partition group) that must be rewritten, in order.

    A file is affected when a delta can touch it (any shadowed id inside
    its id range — conservative range check via the footer stats, then
    exact against the sorted shadow set) or when it is under-filled and a
    small-file coalesce is due.  ``force`` selects everything.  On a
    partitioned dataset this runs once per partition, so the small-file
    trigger below counts files *within* one partition directory.
    """
    if force:
        return list(files)
    small: List[str] = []
    touched: List[str] = []
    lo_hi = (int(shadow_ids[0]), int(shadow_ids[-1])) if len(shadow_ids) \
        else None
    for fn in files:
        rd = reader_of(fn)
        hit = False
        if lo_hi is not None:
            st = rd.file_stats().get(ID_COLUMN)
            if st is None or st.min is None:
                hit = True
            elif st.overlaps_range(*lo_hi):
                a = np.searchsorted(shadow_ids, st.min, "left")
                b = np.searchsorted(shadow_ids, st.max, "right")
                hit = b > a
        if hit:
            touched.append(fn)
        elif (policy.target_rows_per_file
                and rd.num_rows < policy.min_file_fill
                * policy.target_rows_per_file):
            small.append(fn)
    # coalescing a single small file is churn, not progress — only merge
    # small files when there are at least two (or they ride along a delta
    # merge anyway)
    if touched or len(small) >= 2:
        order = {fn: i for i, fn in enumerate(files)}
        return sorted(set(touched) | set(small), key=order.__getitem__)
    return touched


def compact_locked(dirobj: DatasetDir, man: Manifest, schema: Schema,
                   reader_of: Callable[[str], TPQReader],
                   write_file: Callable[[str, Table], None],
                   policy: Optional[CompactionPolicy] = None,
                   force: bool = False,
                   partitioning=None) -> CompactionResult:
    """Merge deltas + small files into sorted base files; mutate ``man``.

    Caller must hold the writer lock and commit ``man`` afterwards iff
    ``result.compacted``.  Staged files become garbage (collected on next
    open) if the caller's commit never happens — crash-safe by construction.

    ``partitioning`` (a :class:`~repro.core.partition.Partitioning`) scopes
    the whole pass to one partition at a time: affected-file selection,
    the merge scan, the id sort and the rewrite each see only one
    partition's files, so cost scales with the *touched partitions*, not
    the dataset — and new files land back in their ``col=value/``
    directory with the partition map updated.  Sound because partition
    columns are immutable (a delta row's partition always matches the base
    row it shadows).
    """
    policy = policy or CompactionPolicy()
    result = CompactionResult(compacted=False, generation=man.generation)
    if not man.files and not man.deltas:
        return result
    # Resolve the chain once: the same overlay drives affected-file
    # selection here and the merge scans below.  The manifest schema always
    # leads with the id column, so it is a valid overlay read schema.
    overlay = DeltaOverlay(man.deltas, reader_of, schema) if man.deltas \
        else None
    shadow = overlay.shadow_ids if overlay is not None \
        else np.empty(0, np.int64)
    if partitioning is None:
        groups = [(None, list(man.files))]
    else:
        by_key: dict = {}
        for fn in man.files:
            by_key.setdefault(partitioning.key_of(fn), []).append(fn)
        groups = sorted(by_key.items(),
                        key=lambda kv: (kv[0] is None, kv[0] or ""))
    merge_of = {key: _affected_files(files, reader_of, policy, shadow, force)
                for key, files in groups}
    n_merge = sum(len(m) for m in merge_of.values())
    if overlay is not None and len(overlay.upsert_ids) and not n_merge:
        # never drop an upsert: merge everything
        merge_of = {key: list(files) for key, files in groups}
        n_merge = len(man.files)
    if not n_merge and not man.deltas:
        return result
    if man.deltas:
        result.reasons.append(f"fold {len(man.deltas)} delta files")
    if n_merge:
        result.reasons.append(f"rewrite {n_merge} base files")
    merged_set = {fn for m in merge_of.values() for fn in m}
    keep_all = [fn for fn in man.files if fn not in merged_set]
    new_files: List[str] = []
    rows_written = 0
    pieces: List[tuple] = []
    for key, files in groups:
        merge = merge_of[key]
        if not merge:
            continue
        vals = partitioning.files.get(merge[0]) \
            if partitioning is not None else None
        subdir = partitioning.dir_of(vals) if vals is not None else None
        # Merged view of this group's affected region only: the overlay
        # substitutes upserts / drops tombstones while streaming; every
        # shadowed base row lives in an affected file of its own partition
        # (range check is conservative-inclusive and partitions are
        # immutable), so the per-group scans jointly observe the complete
        # delta effect before the chain is cleared below.
        plan = ScanPlan(merge, reader_of, schema, deltas=man.deltas,
                        overlay=overlay, cfg=policy)
        parts = list(plan.execute())
        if partitioning is not None:
            for fn in merge:
                partitioning.forget(fn)
        if not parts:
            continue  # every row of the group tombstoned
        merged = concat_tables(parts)
        ids = merged.column(ID_COLUMN).values
        order = np.argsort(ids, kind="stable")
        merged = merged.take(order)
        step = max(int(policy.target_rows_per_file
                       or DEFAULT_ROW_GROUP_ROWS), 1)
        # A kept file may sit *between* merged files in id space; an output
        # file spanning its range would break per-partition id order (and
        # future id-range overlap checks).  Cut the sorted merge at every
        # same-partition kept file's min id so output ranges interleave
        # cleanly with kept ones.
        keep_g = [fn for fn in files if fn not in merged_set]
        cut_ids = sorted(_min_id(reader_of(fn)) for fn in keep_g)
        cuts = np.unique(np.searchsorted(ids[order], cut_ids))
        bounds = [0] + [int(c) for c in cuts if 0 < c < merged.num_rows] \
            + [merged.num_rows]
        # name files serially (new_file_name mutates the manifest), write
        # them in parallel at the end — outputs are disjoint paths, and a
        # crash mid-write only leaves uncommitted files for the next
        # open's GC
        for seg_lo, seg_hi in zip(bounds, bounds[1:]):
            for s in range(seg_lo, seg_hi, step):
                piece = merged.slice(s, min(s + step, seg_hi))
                nf = dirobj.new_file_name(man, subdir=subdir)
                if vals is not None:
                    partitioning.record(nf, vals)
                pieces.append((nf, piece))
                new_files.append(nf)
                rows_written += piece.num_rows
    if pieces:
        # write fan-out only on an explicit thread count: encoding under
        # auto mode is usually GIL-bound (same reasoning as the scan's
        # profitability gate, which the merge ScanPlans above apply)
        nthreads = resolve_num_threads(policy) \
            if policy.num_threads is not None else 1
        first_err: Optional[OSError] = None
        if nthreads > 1 and len(pieces) > 1:
            futs = [scan_pool(nthreads).submit(
                write_file, dirobj.file_path(nf), piece)
                for nf, piece in pieces]
            for f in futs:
                try:
                    f.result()  # re-raises with the worker traceback
                except OSError as e:
                    first_err = first_err or e
        else:
            for nf, piece in pieces:
                try:
                    write_file(dirobj.file_path(nf), piece)
                except OSError as e:
                    first_err = e
                    break
        if first_err is not None:
            # a write fault (ENOSPC/EIO) aborts the whole pass: the
            # manifest is never committed, so eagerly remove every piece
            # already written instead of leaving them for the next
            # open's GC — a failed compaction must not consume the very
            # disk space it was asked to reclaim
            for nf, _piece in pieces:
                try:
                    os.unlink(dirobj.file_path(nf))
                except OSError:
                    pass
            raise first_err
    man_order = {fn: i for i, fn in enumerate(man.files)}
    dropped = sorted(merged_set, key=man_order.__getitem__)
    result.dropped_files = dropped + [d.name for d in man.deltas]
    man.files = _sorted_by_min_id(keep_all + new_files, reader_of)
    man.deltas = []
    if partitioning is not None:
        partitioning.store(man)
    result.compacted = True
    result.files_merged = len(dropped)
    result.deltas_merged = len(result.dropped_files) - len(dropped)
    result.files_written = len(new_files)
    result.rows_written = rows_written
    return result


def _min_id(rd: TPQReader):
    st = rd.file_stats().get(ID_COLUMN)
    return st.min if st is not None and st.min is not None else 0


def _sorted_by_min_id(files: List[str], reader_of) -> List[str]:
    """Order base files by their minimum id so scans stay id-ordered."""
    return sorted(files, key=lambda fn: _min_id(reader_of(fn)))
