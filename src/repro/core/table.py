"""In-memory columnar Table — the unified internal representation.

Mirrors the paper's data flow (§4.4): every accepted input format (list of
dicts, dict of column arrays, list/np arrays) is converted into this columnar
form before any storage operation.  Columns are numpy-backed with optional
validity masks; nested dicts are flattened to dotted columns by
:mod:`repro.core.nested` before they reach the Table.

Column physical layouts
  numeric  values:(n,) ndarray
  tensor   values:(n, *shape) ndarray          (fixed-shape per-row tensors)
  string   offsets:(n+1,) int64 + utf-8 blob uint8
  binary   offsets:(n+1,) int64 + raw blob uint8
  list     offsets:(n+1,) int64 + child Column (flat values)
  null     just a length
"""
from __future__ import annotations

import contextlib
import gc
import json
import pickle
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from . import nested
from .dtypes import (DType, KIND_BINARY, KIND_LIST, KIND_NULL, KIND_NUMERIC,
                     KIND_STRING, KIND_TENSOR, promote)
from .schema import Field, Schema

# Field-metadata key marking transparently-serialized python objects
SERIALIZED_KEY = "serialized"  # value: "json" | "pickle"


@contextlib.contextmanager
def _gc_paused():
    """Pause cyclic GC around bulk materialization.

    ``tolist`` on a multi-million-row table allocates millions of objects
    and creates no reference cycles; letting the generational collector
    scan mid-build roughly doubles materialization time.
    """
    was = gc.isenabled()
    gc.disable()
    try:
        yield
    finally:
        if was:
            gc.enable()


# ---------------------------------------------------------------------------
# Column
# ---------------------------------------------------------------------------
class Column:
    __slots__ = ("dtype", "values", "offsets", "blob", "child", "validity", "_n")

    def __init__(self, dtype: DType, *, values=None, offsets=None, blob=None,
                 child: "Column" = None, validity: Optional[np.ndarray] = None,
                 length: Optional[int] = None):
        self.dtype = dtype
        self.values = values
        self.offsets = offsets
        self.blob = blob
        self.child = child
        self.validity = validity
        if dtype.kind in (KIND_NUMERIC, KIND_TENSOR):
            self._n = len(values)
        elif dtype.kind in (KIND_STRING, KIND_BINARY, KIND_LIST):
            self._n = len(offsets) - 1
        else:  # null
            self._n = int(length)

    def __len__(self) -> int:
        return self._n

    @property
    def null_count(self) -> int:
        if self.dtype.kind == KIND_NULL:
            return self._n
        return 0 if self.validity is None else int((~self.validity).sum())

    # -- constructors --------------------------------------------------------
    @staticmethod
    def nulls(n: int) -> "Column":
        return Column(DType.null(), length=n)

    @staticmethod
    def numeric(arr: np.ndarray, validity=None) -> "Column":
        arr = np.ascontiguousarray(arr)
        return Column(DType.from_numpy(arr.dtype), values=arr, validity=validity)

    @staticmethod
    def tensor(arr: np.ndarray, validity=None) -> "Column":
        arr = np.ascontiguousarray(arr)
        dt = DType.tensor(DType.from_numpy(arr.dtype).code, arr.shape[1:])
        return Column(dt, values=arr, validity=validity)

    @staticmethod
    def strings(strs: Sequence[Optional[str]]) -> "Column":
        return _varlen_from_bytes(
            [None if s is None else s.encode("utf-8") for s in strs],
            DType.string())

    @staticmethod
    def binary(bs: Sequence[Optional[bytes]], dtype: Optional[DType] = None) -> "Column":
        return _varlen_from_bytes(list(bs), dtype or DType.binary())

    @staticmethod
    def list_(offsets: np.ndarray, child: "Column", validity=None) -> "Column":
        return Column(DType.list_(child.dtype), offsets=np.asarray(offsets, np.int64),
                      child=child, validity=validity)

    # -- element access (slow path, used by to_pylist) ------------------------
    def _blob_view(self) -> memoryview:
        """Zero-copy view of the blob buffer (no ``.tobytes()`` round-trip)."""
        return memoryview(np.ascontiguousarray(self.blob))

    def _get(self, i: int):
        if self.validity is not None and not self.validity[i]:
            return None
        k = self.dtype.kind
        if k == KIND_NUMERIC:
            return self.values[i].item()
        if k == KIND_TENSOR:
            return self.values[i]
        if k in (KIND_STRING, KIND_BINARY):
            mv = self._blob_view()[self.offsets[i]:self.offsets[i + 1]]
            return str(mv, "utf-8") if k == KIND_STRING else bytes(mv)
        if k == KIND_LIST:
            lo, hi = int(self.offsets[i]), int(self.offsets[i + 1])
            child = self.child
            # bulk-slice flat numeric children instead of per-element _get
            if child.dtype.kind == KIND_NUMERIC and child.validity is None:
                return child.values[lo:hi].tolist()
            return [child._get(j) for j in range(lo, hi)]
        return None  # null column

    def to_pylist(self) -> list:
        k = self.dtype.kind
        if k == KIND_NUMERIC:                      # C-speed fast path
            out = self.values.tolist()
            if self.validity is not None:
                for i in np.nonzero(~self.validity)[0]:
                    out[i] = None
            return out
        if k == KIND_TENSOR and self.validity is None:
            return list(self.values)
        if k in (KIND_STRING, KIND_BINARY):
            # memoryview slicing: no full-blob copy, one small copy per value
            off = self.offsets.tolist()
            mv = self._blob_view()
            if k == KIND_STRING:
                out = [str(mv[off[i]:off[i + 1]], "utf-8")
                       for i in range(self._n)]
            else:
                out = [bytes(mv[off[i]:off[i + 1]]) for i in range(self._n)]
            if self.validity is not None:
                for i in np.nonzero(~self.validity)[0]:
                    out[i] = None
            return out
        return [self._get(i) for i in range(self._n)]

    def to_numpy(self) -> np.ndarray:
        k = self.dtype.kind
        if k in (KIND_NUMERIC, KIND_TENSOR):
            if self.validity is not None and not self.validity.all():
                if self.dtype.is_float:
                    out = self.values.astype(self.dtype.np, copy=True)
                    out[~self.validity] = np.nan
                    return out
            return self.values
        raise TypeError(f"to_numpy unsupported for {self.dtype}")

    # -- bulk ops -------------------------------------------------------------
    def take(self, idx: np.ndarray) -> "Column":
        idx = np.asarray(idx, np.int64)
        val = None if self.validity is None else self.validity[idx]
        k = self.dtype.kind
        if k in (KIND_NUMERIC, KIND_TENSOR):
            return Column(self.dtype, values=self.values[idx], validity=val)
        if k in (KIND_STRING, KIND_BINARY):
            new_off, gather = _ragged_gather_index(self.offsets, idx)
            return Column(self.dtype, offsets=new_off,
                          blob=np.ascontiguousarray(self.blob)[gather],
                          validity=val)
        if k == KIND_LIST:
            new_off, child_idx = _ragged_gather_index(self.offsets, idx)
            return Column(self.dtype, offsets=new_off,
                          child=self.child.take(child_idx), validity=val)
        return Column.nulls(len(idx))

    def slice(self, start: int, stop: int) -> "Column":
        val = None if self.validity is None else self.validity[start:stop]
        k = self.dtype.kind
        if k in (KIND_NUMERIC, KIND_TENSOR):
            return Column(self.dtype, values=self.values[start:stop], validity=val)
        if k in (KIND_STRING, KIND_BINARY):
            off = self.offsets[start:stop + 1]
            blob = self.blob[off[0]:off[-1]]
            return Column(self.dtype, offsets=off - off[0], blob=blob, validity=val)
        if k == KIND_LIST:
            off = self.offsets[start:stop + 1]
            child = self.child.slice(int(off[0]), int(off[-1]))
            return Column(self.dtype, offsets=(off - off[0]).astype(np.int64),
                          child=child, validity=val)
        return Column.nulls(stop - start)

    def cast(self, dtype: DType) -> "Column":
        if dtype == self.dtype:
            return self
        if self.dtype.kind == KIND_NULL:
            return null_column_of(dtype, self._n)
        k = self.dtype.kind
        if k == KIND_NUMERIC and dtype.kind == KIND_NUMERIC:
            return Column(dtype, values=self.values.astype(dtype.np),
                          validity=self.validity)
        if k == KIND_TENSOR and dtype.kind == KIND_TENSOR and dtype.shape == self.dtype.shape:
            return Column(dtype, values=self.values.astype(dtype.np),
                          validity=self.validity)
        if k == KIND_LIST and dtype.kind == KIND_LIST:
            return Column(dtype, offsets=self.offsets,
                          child=self.child.cast(dtype.child), validity=self.validity)
        raise TypeError(f"cannot cast {self.dtype} -> {dtype}")

    def combined_validity(self) -> Optional[np.ndarray]:
        return self.validity


def _ragged_gather_index(offsets: np.ndarray, idx: np.ndarray):
    """Vectorized ragged take: flat gather indices for rows ``idx``.

    Returns ``(new_offsets, gather)`` where ``gather`` maps every output
    element position to its source position — one fancy-index instead of a
    per-row python loop (the take hot path for string/list columns).
    """
    lens = (offsets[1:] - offsets[:-1])[idx]
    new_off = np.zeros(len(idx) + 1, np.int64)
    np.cumsum(lens, out=new_off[1:])
    total = int(new_off[-1])
    if total == 0:
        return new_off, np.empty(0, np.int64)
    starts = offsets[idx]
    gather = np.repeat(starts - new_off[:-1], lens) + np.arange(total)
    return new_off, gather


def _varlen_from_bytes(items: List[Optional[bytes]], dtype: DType) -> Column:
    n = len(items)
    validity = None
    if any(it is None for it in items):
        validity = np.array([it is not None for it in items], bool)
        items = [b"" if it is None else it for it in items]
    offsets = np.zeros(n + 1, np.int64)
    np.cumsum([len(it) for it in items], out=offsets[1:])
    blob = np.frombuffer(b"".join(items), np.uint8).copy() if n else np.empty(0, np.uint8)
    return Column(dtype, offsets=offsets, blob=blob, validity=validity)


def null_column_of(dtype: DType, n: int) -> Column:
    """All-null column with a concrete dtype (for schema-evolution backfill)."""
    validity = np.zeros(n, bool)
    k = dtype.kind
    if k == KIND_NUMERIC:
        return Column(dtype, values=np.zeros(n, dtype.np), validity=validity)
    if k == KIND_TENSOR:
        return Column(dtype, values=np.zeros((n, *dtype.shape), dtype.np), validity=validity)
    if k in (KIND_STRING, KIND_BINARY):
        return Column(dtype, offsets=np.zeros(n + 1, np.int64),
                      blob=np.empty(0, np.uint8), validity=validity)
    if k == KIND_LIST:
        return Column(dtype, offsets=np.zeros(n + 1, np.int64),
                      child=null_column_of(dtype.child, 0), validity=validity)
    return Column.nulls(n)


def concat_columns(cols: List[Column]) -> Column:
    """Concatenate columns of identical dtype (callers promote/cast first)."""
    assert cols, "empty concat"
    if len(cols) == 1:
        return cols[0]  # columns are immutable: no defensive copy
    dtype = cols[0].dtype
    assert all(c.dtype == dtype for c in cols), [str(c.dtype) for c in cols]
    n = sum(len(c) for c in cols)
    if any(c.validity is not None for c in cols):
        validity = np.concatenate([
            c.validity if c.validity is not None else np.ones(len(c), bool)
            for c in cols])
    else:
        validity = None
    k = dtype.kind
    if k in (KIND_NUMERIC, KIND_TENSOR):
        return Column(dtype, values=np.concatenate([c.values for c in cols]),
                      validity=validity)
    if k in (KIND_STRING, KIND_BINARY, KIND_LIST):
        sizes = [c.offsets[-1] for c in cols]
        bases = np.zeros(len(cols), np.int64)
        np.cumsum(sizes[:-1], out=bases[1:])
        offsets = np.concatenate(
            [np.zeros(1, np.int64)] +
            [c.offsets[1:] + b for c, b in zip(cols, bases)])
        if k == KIND_LIST:
            child = concat_columns([c.child for c in cols])
            return Column(dtype, offsets=offsets, child=child, validity=validity)
        blob = np.concatenate([c.blob for c in cols]) if n else np.empty(0, np.uint8)
        return Column(dtype, offsets=offsets, blob=blob, validity=validity)
    return Column.nulls(n)


# ---------------------------------------------------------------------------
# Python-value -> Column inference
# ---------------------------------------------------------------------------
def _try_json(v) -> Optional[bytes]:
    try:
        return json.dumps(v).encode("utf-8")
    except (TypeError, ValueError):
        return None


def infer_column(values: List[Any], *, ragged: bool = False,
                 convert_to_fixed_shape: bool = True,
                 dtype_hint: Optional[DType] = None) -> Tuple[Column, Optional[dict]]:
    """Build a Column from a list of python values.

    Returns (column, field_metadata).  field_metadata is non-None when values
    were transparently serialized (dict / heterogeneous objects), mirroring the
    paper's ``serialize_python_objects``.

    ``dtype_hint`` (from an existing dataset schema) lets steady-state appends
    skip the type-sniffing cascade: the hinted bulk builder is attempted first
    and silently falls through to full inference when the values don't fit.
    """
    n = len(values)
    if dtype_hint is not None and not ragged:
        if dtype_hint.kind == KIND_LIST:
            # the dataset already types this column as a ragged list; an
            # all-empty or accidentally-uniform batch must not re-infer as
            # a fixed-shape tensor (which would fail schema unification)
            ragged = True
        else:
            col = _column_from_hint(values, dtype_hint)
            if col is not None:
                return col, None
    # fast path: uniform numeric values, no Nones — one C-level conversion
    # instead of 2n isinstance checks (the pylist ingest hot path)
    try:
        arr = np.asarray(values)
        if arr.ndim == 1 and arr.dtype != object and arr.dtype.kind in "biuf":
            return Column.numeric(arr if arr.dtype.kind != "i"
                                  else arr.astype(np.int64, copy=False)), None
    except (ValueError, TypeError, OverflowError):
        pass
    first = next((v for v in values if v is not None), None)
    if first is None:
        return Column.nulls(n), None

    if isinstance(first, str):
        col = _bulk_strings(values)
        if col is not None:
            return col, None
    present = [v for v in values if v is not None]

    if isinstance(first, (bool, np.bool_)) and all(isinstance(v, (bool, np.bool_)) for v in present):
        return _masked_numeric(values, np.bool_), None
    if isinstance(first, bytes) and all(isinstance(v, bytes) for v in present):
        return Column.binary(values), None
    if _all_scalar_number(present):
        if any(isinstance(v, (float, np.floating)) for v in present):
            return _masked_numeric(values, np.float64), None
        return _masked_numeric(values, np.int64), None
    if isinstance(first, np.ndarray) or isinstance(first, (list, tuple)):
        col = _infer_sequence_column(values, present, ragged, convert_to_fixed_shape)
        if col is not None:
            return col, None
    # fallback: serialize objects (dicts, lists-of-dicts, ...)
    enc, meta = [], {SERIALIZED_KEY: "json"}
    for v in values:
        if v is None:
            enc.append(None)
            continue
        b = _try_json(v)
        if b is None:
            meta = {SERIALIZED_KEY: "pickle"}
            break
        enc.append(b)
    if meta[SERIALIZED_KEY] == "pickle":
        enc = [None if v is None else pickle.dumps(v) for v in values]
    return Column.binary(enc), meta


def _all_scalar_number(vals) -> bool:
    return all(
        isinstance(v, (int, float, np.integer, np.floating))
        and not isinstance(v, (bool, np.bool_)) for v in vals)


def _bulk_strings(values: List[Any]) -> Optional[Column]:
    """One-pass UTF-8 blob + offsets build; None when values aren't all str.

    Validation is folded into the encode pass itself (``str.encode`` raises
    on non-strings) instead of a separate full ``isinstance`` sweep.
    """
    try:
        enc = [b"" if v is None else str.encode(v, "utf-8") for v in values]
    except TypeError:  # str.encode rejects any non-str element
        return None
    n = len(values)
    offsets = np.zeros(n + 1, np.int64)
    np.cumsum([len(b) for b in enc], out=offsets[1:])
    blob = (np.frombuffer(b"".join(enc), np.uint8)
            if offsets[-1] else np.empty(0, np.uint8))
    validity = None
    if any(v is None for v in values):
        validity = np.array([v is not None for v in values], bool)
    return Column(DType.string(), offsets=offsets, blob=blob, validity=validity)


def _column_from_hint(values: List[Any], dtype: DType) -> Optional[Column]:
    """Schema-reuse bulk build: decode ``values`` straight into ``dtype``.

    Used by steady-state appends (the dataset schema is known) to skip the
    inference cascade.  Returns None — caller falls back to full inference —
    whenever the values don't losslessly fit the hinted type.
    """
    k = dtype.kind
    if k == KIND_STRING:
        return _bulk_strings(values)
    if k == KIND_NUMERIC:
        try:
            arr = np.asarray(values)
        except (ValueError, TypeError, OverflowError):
            return None
        if arr.ndim != 1 or arr.dtype.kind not in "biuf":
            return None  # Nones / mixed types: full inference handles masks
        if arr.dtype == dtype.np:
            return Column(dtype, values=arr)
        if np.can_cast(arr.dtype, dtype.np, "safe"):
            return Column(dtype, values=arr.astype(dtype.np))
        return None  # would truncate (e.g. floats into an int column)
    return None  # tensor/list/binary hints: inference is already bulk


def _masked_numeric(values: List[Any], np_dtype) -> Column:
    validity = None
    if any(v is None for v in values):
        validity = np.array([v is not None for v in values], bool)
        fill = False if np_dtype is np.bool_ else 0
        values = [fill if v is None else v for v in values]
    return Column(DType.from_numpy(np.dtype(np_dtype)),
                  values=np.asarray(values, np_dtype), validity=validity)


def _infer_sequence_column(values, present, ragged, convert_to_fixed_shape):
    """list/ndarray values -> tensor column (fixed shape) or ragged list."""
    arrs = []
    for v in present:
        a = np.asarray(v)
        if a.dtype == object or a.dtype.kind in "US":
            # list of strings -> ragged list of strings; anything else -> None
            if all(isinstance(x, str) for x in _flat_py(v)):
                return _ragged_strings(values)
            return None
        arrs.append(a)
    shapes = {a.shape for a in arrs}
    if len(shapes) == 1 and not ragged and convert_to_fixed_shape:
        shape = next(iter(shapes))
        dt = np.result_type(*[a.dtype for a in arrs])
        stack = np.zeros((len(values), *shape), dt)
        validity = np.ones(len(values), bool)
        j = 0
        for i, v in enumerate(values):
            if v is None:
                validity[i] = False
            else:
                stack[i] = arrs[j]
                j += 1
        val = None if validity.all() else validity
        return Column(DType.tensor(DType.from_numpy(dt).code, shape),
                      values=stack, validity=val)
    # ragged 1-d lists
    if all(a.ndim == 1 for a in arrs):
        dt = np.result_type(*[a.dtype for a in arrs]) if arrs else np.int64
        validity = np.array([v is not None for v in values], bool)
        lens = [0 if v is None else len(np.asarray(v)) for v in values]
        offsets = np.zeros(len(values) + 1, np.int64)
        np.cumsum(lens, out=offsets[1:])
        flat = (np.concatenate([a.astype(dt) for a in arrs])
                if arrs else np.empty(0, dt))
        child = Column(DType.from_numpy(dt), values=flat)
        val = None if validity.all() else validity
        return Column(DType.list_(child.dtype), offsets=offsets, child=child,
                      validity=val)
    return None  # ragged nd — fall back to serialization


def _flat_py(v):
    for x in v:
        if isinstance(x, (list, tuple)):
            yield from _flat_py(x)
        else:
            yield x


def _ragged_strings(values):
    validity = np.array([v is not None for v in values], bool)
    lens = [0 if v is None else len(v) for v in values]
    offsets = np.zeros(len(values) + 1, np.int64)
    np.cumsum(lens, out=offsets[1:])
    flat: List[str] = []
    for v in values:
        if v is not None:
            flat.extend(v)
    child = Column.strings(flat)
    val = None if validity.all() else validity
    return Column(DType.list_(child.dtype), offsets=offsets, child=child, validity=val)


# ---------------------------------------------------------------------------
# Vectorized pylist ingest
# ---------------------------------------------------------------------------
def _needs_flatten(records: List[dict]) -> bool:
    """True when any record needs the flatten pass: a nested dict value
    (dotted-column flatten) or a non-string key (flatten coerces keys via
    ``str``; without it mixed key types crash the column sort).

    Flat string-keyed records (the overwhelmingly common ingest shape) skip
    the per-record dict rebuild in :func:`nested.flatten_records` entirely.
    """
    return any(isinstance(v, dict) or type(k) is not str
               for r in records for k, v in r.items())


def _from_pylist_uniform(records: List[dict],
                         metadata: Optional[dict]) -> Optional["Table"]:
    """All-scalar uniform-record fast path: one 2-D conversion, no sniffing.

    Applies when every record has exactly the first record's key set and the
    first record's values are homogeneously ``int`` or ``float`` (the paper's
    Fig. 5 workload: n rows x 100 integer columns).  One ``itemgetter`` pass
    transposes the rows, one ``np.asarray`` builds the matrix, and columns
    are contiguous slices — replacing the per-column python scan that made
    ingest interpreter-bound.  Returns None (caller runs full inference) on
    any mismatch; the dtype check after conversion rejects rows that smuggle
    in strings, Nones, dicts or ragged values, so the fallback stays sound.
    """
    if not records:
        return None
    import operator
    r0 = records[0]
    names0 = list(r0)
    ncols = len(names0)
    if ncols == 0 or any(type(k) is not str for k in names0):
        return None  # non-string keys go through the flatten/str() path
    kinds = {type(v) for v in r0.values()}
    if kinds == {int}:
        want = "iu"
    elif kinds == {float}:
        want = "f"
    else:
        return None
    getter = operator.itemgetter(*names0)
    try:
        rows = [getter(r) for r in records if len(r) == ncols]
    except (KeyError, TypeError):
        return None
    if len(rows) != len(records):
        return None  # some record had extra keys alongside missing ones
    try:
        arr = np.asarray(rows)
    except (ValueError, TypeError, OverflowError):
        return None
    if ncols == 1:  # itemgetter with one key returns scalars
        arr = arr.reshape(-1, 1)
    if arr.ndim != 2 or arr.dtype.kind not in want:
        return None  # mixed/ragged/object rows: full inference handles them
    if arr.dtype.kind == "u":
        # a value >= 2**63 pushed the whole matrix to uint64; astype(int64)
        # would wrap it negative, and keeping "u" would mistype every other
        # column — only per-column inference preserves exact dtypes here
        return None
    if arr.dtype.kind == "i":
        arr = arr.astype(np.int64, copy=False)
    order = sorted(range(ncols), key=lambda j: names0[j])
    cols = {names0[j]: Column.numeric(np.ascontiguousarray(arr[:, j]))
            for j in order}
    fields = [Field(names0[j], cols[names0[j]].dtype) for j in order]
    return Table(Schema(fields, metadata=metadata), cols)


# ---------------------------------------------------------------------------
# Table
# ---------------------------------------------------------------------------
class Table:
    """Immutable-ish columnar table: Schema + aligned Columns."""

    def __init__(self, schema: Schema, columns: Dict[str, Column]):
        self.schema = schema
        self.columns = {name: columns[name] for name in schema.names}
        lens = {len(c) for c in self.columns.values()}
        if len(lens) > 1:
            raise ValueError(f"ragged table: column lengths {lens}")
        self._n = lens.pop() if lens else 0

    # -- properties -----------------------------------------------------------
    @property
    def num_rows(self) -> int:
        return self._n

    @property
    def num_columns(self) -> int:
        return len(self.columns)

    @property
    def column_names(self) -> List[str]:
        return self.schema.names

    def column(self, name: str) -> Column:
        return self.columns[name]

    def __getitem__(self, name: str) -> Column:
        return self.columns[name]

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    # -- constructors ----------------------------------------------------------
    @staticmethod
    def empty(schema: Optional[Schema] = None) -> "Table":
        schema = schema or Schema([])
        return Table(schema, {f.name: null_column_of(f.dtype, 0) for f in schema})

    @staticmethod
    def from_pylist(records: List[dict], *, treat_fields_as_ragged=(),
                    convert_to_fixed_shape: bool = True,
                    metadata: Optional[dict] = None,
                    schema_hint: Optional[Schema] = None) -> "Table":
        if not treat_fields_as_ragged:
            t = _from_pylist_uniform(records, metadata)
            if t is not None:
                return t
        flats = records if not _needs_flatten(records) \
            else nested.flatten_records(records)
        names: List[str] = sorted({k for r in flats for k in r})
        hints: Dict[str, DType] = {}
        if schema_hint is not None:
            # reuse dataset dtypes for plain fields (serialized fields carry
            # metadata and must re-run inference to re-serialize)
            name_set = set(names)
            hints = {f.name: f.dtype for f in schema_hint
                     if not f.metadata and f.name in name_set}
        cols, fields = {}, []
        for name in names:
            vals = [r.get(name) for r in flats]
            col, fmeta = infer_column(
                vals, ragged=name in set(treat_fields_as_ragged),
                convert_to_fixed_shape=convert_to_fixed_shape,
                dtype_hint=hints.get(name))
            cols[name] = col
            fields.append(Field(name, col.dtype, metadata=fmeta))
        t = Table(Schema(fields, metadata=metadata), cols)
        t._n = len(records) if not names else t._n
        return t

    @staticmethod
    def from_pydict(data: Dict[str, Any], *, treat_fields_as_ragged=(),
                    convert_to_fixed_shape: bool = True,
                    metadata: Optional[dict] = None,
                    schema_hint: Optional[Schema] = None) -> "Table":
        cols, fields = {}, []
        for name in sorted(data.keys()):
            v = data[name]
            hint = (schema_hint[name].dtype
                    if schema_hint is not None and name in schema_hint
                    and not schema_hint[name].metadata else None)
            if isinstance(v, Column):
                col, fmeta = v, None
            elif isinstance(v, np.ndarray) and v.ndim == 1 and v.dtype != object:
                col, fmeta = Column.numeric(v), None
            elif isinstance(v, np.ndarray) and v.ndim > 1:
                col, fmeta = Column.tensor(v), None
            else:
                col, fmeta = infer_column(
                    list(v), ragged=name in set(treat_fields_as_ragged),
                    convert_to_fixed_shape=convert_to_fixed_shape,
                    dtype_hint=hint)
            cols[name] = col
            fields.append(Field(name, col.dtype, metadata=fmeta))
        return Table(Schema(fields, metadata=metadata), cols)

    @staticmethod
    def from_columns(schema: Schema, columns: Dict[str, Column]) -> "Table":
        return Table(schema, columns)

    # -- transforms --------------------------------------------------------------
    def select(self, names: List[str]) -> "Table":
        return Table(self.schema.select(names), {n: self.columns[n] for n in names})

    def drop(self, names: List[str]) -> "Table":
        keep = [n for n in self.column_names if n not in set(names)]
        return self.select(keep)

    def take(self, idx: np.ndarray) -> "Table":
        return Table(self.schema, {n: c.take(idx) for n, c in self.columns.items()})

    def filter_mask(self, mask: np.ndarray) -> "Table":
        return self.take(np.nonzero(np.asarray(mask, bool))[0])

    def slice(self, start: int, stop: int) -> "Table":
        stop = min(stop, self._n)
        t = Table(self.schema,
                  {n: c.slice(start, stop) for n, c in self.columns.items()})
        t._n = max(stop - start, 0)
        return t

    def set_column(self, name: str, col: Column, metadata: Optional[dict] = None) -> "Table":
        fields = [f for f in self.schema if f.name != name]
        fields.append(Field(name, col.dtype, metadata=metadata))
        cols = dict(self.columns)
        cols[name] = col
        return Table(Schema(fields, metadata=self.schema.metadata), cols)

    def align_to_schema(self, schema: Schema) -> "Table":
        """Cast/backfill so this table matches ``schema`` exactly.

        Missing fields become all-null columns of the target dtype; numeric
        columns widen (paper: 'casts the data to fit the existing schema').
        """
        cols: Dict[str, Column] = {}
        for f in schema:
            if f.name in self.columns:
                cols[f.name] = self.columns[f.name].cast(f.dtype)
            else:
                cols[f.name] = null_column_of(f.dtype, self._n)
        t = Table(schema, cols)
        t._n = self._n
        return t

    # -- export -------------------------------------------------------------------
    def to_pylist(self, *, rebuild_nested: bool = False) -> List[dict]:
        with _gc_paused():
            pl = {n: _decode_objects(self.schema[n], c)
                  for n, c in self.columns.items()}
            rows = [{n: pl[n][i] for n in self.column_names}
                    for i in range(self._n)]
        if rebuild_nested:
            rows = nested.rebuild_records(rows)
        return rows

    def to_pydict(self) -> Dict[str, list]:
        with _gc_paused():
            return {n: _decode_objects(self.schema[n], c)
                    for n, c in self.columns.items()}

    def __repr__(self) -> str:
        return f"Table[{self._n} rows x {self.num_columns} cols]({self.schema})"


def _decode_objects(field: Field, col: Column) -> list:
    vals = col.to_pylist()
    mode = (field.metadata or {}).get(SERIALIZED_KEY)
    if mode == "json":
        return [None if v is None else json.loads(v) for v in vals]
    if mode == "pickle":
        return [None if v is None else pickle.loads(v) for v in vals]
    return vals


def concat_tables(tables: List[Table]) -> Table:
    """Concatenate with schema unification (evolution-aware)."""
    tables = [t for t in tables if t.num_rows or t.num_columns]
    if not tables:
        return Table.empty()
    schema = tables[0].schema
    for t in tables[1:]:
        schema = schema.unify(t.schema)
    aligned = [t.align_to_schema(schema) for t in tables]
    cols = {f.name: concat_columns([t.columns[f.name] for t in aligned])
            for f in schema}
    return Table(schema, cols)
