"""In-memory columnar Table — the unified internal representation.

Mirrors the paper's data flow (§4.4): every accepted input format (list of
dicts, dict of column arrays, list/np arrays) is converted into this columnar
form before any storage operation.  Columns are numpy-backed with optional
validity masks; nested dicts are flattened to dotted columns by
:mod:`repro.core.nested` before they reach the Table.

Column physical layouts
  numeric  values:(n,) ndarray
  tensor   values:(n, *shape) ndarray          (fixed-shape per-row tensors)
  string   offsets:(n+1,) int64 + utf-8 blob uint8
  binary   offsets:(n+1,) int64 + raw blob uint8
  list     offsets:(n+1,) int64 + child Column (flat values)
  null     just a length
"""
from __future__ import annotations

import json
import pickle
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from . import nested
from .dtypes import (DType, KIND_BINARY, KIND_LIST, KIND_NULL, KIND_NUMERIC,
                     KIND_STRING, KIND_TENSOR, promote)
from .schema import Field, Schema

# Field-metadata key marking transparently-serialized python objects
SERIALIZED_KEY = "serialized"  # value: "json" | "pickle"


# ---------------------------------------------------------------------------
# Column
# ---------------------------------------------------------------------------
class Column:
    __slots__ = ("dtype", "values", "offsets", "blob", "child", "validity", "_n")

    def __init__(self, dtype: DType, *, values=None, offsets=None, blob=None,
                 child: "Column" = None, validity: Optional[np.ndarray] = None,
                 length: Optional[int] = None):
        self.dtype = dtype
        self.values = values
        self.offsets = offsets
        self.blob = blob
        self.child = child
        self.validity = validity
        if dtype.kind in (KIND_NUMERIC, KIND_TENSOR):
            self._n = len(values)
        elif dtype.kind in (KIND_STRING, KIND_BINARY, KIND_LIST):
            self._n = len(offsets) - 1
        else:  # null
            self._n = int(length)

    def __len__(self) -> int:
        return self._n

    @property
    def null_count(self) -> int:
        if self.dtype.kind == KIND_NULL:
            return self._n
        return 0 if self.validity is None else int((~self.validity).sum())

    # -- constructors --------------------------------------------------------
    @staticmethod
    def nulls(n: int) -> "Column":
        return Column(DType.null(), length=n)

    @staticmethod
    def numeric(arr: np.ndarray, validity=None) -> "Column":
        arr = np.ascontiguousarray(arr)
        return Column(DType.from_numpy(arr.dtype), values=arr, validity=validity)

    @staticmethod
    def tensor(arr: np.ndarray, validity=None) -> "Column":
        arr = np.ascontiguousarray(arr)
        dt = DType.tensor(DType.from_numpy(arr.dtype).code, arr.shape[1:])
        return Column(dt, values=arr, validity=validity)

    @staticmethod
    def strings(strs: Sequence[Optional[str]]) -> "Column":
        return _varlen_from_bytes(
            [None if s is None else s.encode("utf-8") for s in strs],
            DType.string())

    @staticmethod
    def binary(bs: Sequence[Optional[bytes]], dtype: Optional[DType] = None) -> "Column":
        return _varlen_from_bytes(list(bs), dtype or DType.binary())

    @staticmethod
    def list_(offsets: np.ndarray, child: "Column", validity=None) -> "Column":
        return Column(DType.list_(child.dtype), offsets=np.asarray(offsets, np.int64),
                      child=child, validity=validity)

    # -- element access (slow path, used by to_pylist) ------------------------
    def _get(self, i: int):
        if self.validity is not None and not self.validity[i]:
            return None
        k = self.dtype.kind
        if k == KIND_NUMERIC:
            return self.values[i].item()
        if k == KIND_TENSOR:
            return self.values[i]
        if k in (KIND_STRING, KIND_BINARY):
            b = bytes(self.blob[self.offsets[i]:self.offsets[i + 1]])
            return b.decode("utf-8") if k == KIND_STRING else b
        if k == KIND_LIST:
            lo, hi = int(self.offsets[i]), int(self.offsets[i + 1])
            return [self.child._get(j) for j in range(lo, hi)]
        return None  # null column

    def to_pylist(self) -> list:
        k = self.dtype.kind
        if k == KIND_NUMERIC:                      # C-speed fast path
            out = self.values.tolist()
            if self.validity is not None:
                for i in np.nonzero(~self.validity)[0]:
                    out[i] = None
            return out
        if k == KIND_TENSOR and self.validity is None:
            return list(self.values)
        if k == KIND_STRING and self.validity is None:
            off = self.offsets
            blob = self.blob.tobytes()
            return [blob[off[i]:off[i + 1]].decode("utf-8")
                    for i in range(self._n)]
        return [self._get(i) for i in range(self._n)]

    def to_numpy(self) -> np.ndarray:
        k = self.dtype.kind
        if k in (KIND_NUMERIC, KIND_TENSOR):
            if self.validity is not None and not self.validity.all():
                if self.dtype.is_float:
                    out = self.values.astype(self.dtype.np, copy=True)
                    out[~self.validity] = np.nan
                    return out
            return self.values
        raise TypeError(f"to_numpy unsupported for {self.dtype}")

    # -- bulk ops -------------------------------------------------------------
    def take(self, idx: np.ndarray) -> "Column":
        idx = np.asarray(idx, np.int64)
        val = None if self.validity is None else self.validity[idx]
        k = self.dtype.kind
        if k in (KIND_NUMERIC, KIND_TENSOR):
            return Column(self.dtype, values=self.values[idx], validity=val)
        if k in (KIND_STRING, KIND_BINARY):
            lens = (self.offsets[1:] - self.offsets[:-1])[idx]
            new_off = np.zeros(len(idx) + 1, np.int64)
            np.cumsum(lens, out=new_off[1:])
            new_blob = np.empty(int(new_off[-1]), np.uint8)
            src_off = self.offsets
            for out_i, src_i in enumerate(idx):
                lo, hi = src_off[src_i], src_off[src_i + 1]
                new_blob[new_off[out_i]:new_off[out_i + 1]] = self.blob[lo:hi]
            return Column(self.dtype, offsets=new_off, blob=new_blob, validity=val)
        if k == KIND_LIST:
            lens = (self.offsets[1:] - self.offsets[:-1])[idx]
            new_off = np.zeros(len(idx) + 1, np.int64)
            np.cumsum(lens, out=new_off[1:])
            # gather child indices
            child_idx = np.empty(int(new_off[-1]), np.int64)
            for out_i, src_i in enumerate(idx):
                lo, hi = int(self.offsets[src_i]), int(self.offsets[src_i + 1])
                child_idx[new_off[out_i]:new_off[out_i + 1]] = np.arange(lo, hi)
            return Column(self.dtype, offsets=new_off,
                          child=self.child.take(child_idx), validity=val)
        return Column.nulls(len(idx))

    def slice(self, start: int, stop: int) -> "Column":
        val = None if self.validity is None else self.validity[start:stop]
        k = self.dtype.kind
        if k in (KIND_NUMERIC, KIND_TENSOR):
            return Column(self.dtype, values=self.values[start:stop], validity=val)
        if k in (KIND_STRING, KIND_BINARY):
            off = self.offsets[start:stop + 1]
            blob = self.blob[off[0]:off[-1]]
            return Column(self.dtype, offsets=off - off[0], blob=blob, validity=val)
        if k == KIND_LIST:
            off = self.offsets[start:stop + 1]
            child = self.child.slice(int(off[0]), int(off[-1]))
            return Column(self.dtype, offsets=(off - off[0]).astype(np.int64),
                          child=child, validity=val)
        return Column.nulls(stop - start)

    def cast(self, dtype: DType) -> "Column":
        if dtype == self.dtype:
            return self
        if self.dtype.kind == KIND_NULL:
            return null_column_of(dtype, self._n)
        k = self.dtype.kind
        if k == KIND_NUMERIC and dtype.kind == KIND_NUMERIC:
            return Column(dtype, values=self.values.astype(dtype.np),
                          validity=self.validity)
        if k == KIND_TENSOR and dtype.kind == KIND_TENSOR and dtype.shape == self.dtype.shape:
            return Column(dtype, values=self.values.astype(dtype.np),
                          validity=self.validity)
        if k == KIND_LIST and dtype.kind == KIND_LIST:
            return Column(dtype, offsets=self.offsets,
                          child=self.child.cast(dtype.child), validity=self.validity)
        raise TypeError(f"cannot cast {self.dtype} -> {dtype}")

    def combined_validity(self) -> Optional[np.ndarray]:
        return self.validity


def _varlen_from_bytes(items: List[Optional[bytes]], dtype: DType) -> Column:
    n = len(items)
    validity = None
    if any(it is None for it in items):
        validity = np.array([it is not None for it in items], bool)
        items = [b"" if it is None else it for it in items]
    offsets = np.zeros(n + 1, np.int64)
    np.cumsum([len(it) for it in items], out=offsets[1:])
    blob = np.frombuffer(b"".join(items), np.uint8).copy() if n else np.empty(0, np.uint8)
    return Column(dtype, offsets=offsets, blob=blob, validity=validity)


def null_column_of(dtype: DType, n: int) -> Column:
    """All-null column with a concrete dtype (for schema-evolution backfill)."""
    validity = np.zeros(n, bool)
    k = dtype.kind
    if k == KIND_NUMERIC:
        return Column(dtype, values=np.zeros(n, dtype.np), validity=validity)
    if k == KIND_TENSOR:
        return Column(dtype, values=np.zeros((n, *dtype.shape), dtype.np), validity=validity)
    if k in (KIND_STRING, KIND_BINARY):
        return Column(dtype, offsets=np.zeros(n + 1, np.int64),
                      blob=np.empty(0, np.uint8), validity=validity)
    if k == KIND_LIST:
        return Column(dtype, offsets=np.zeros(n + 1, np.int64),
                      child=null_column_of(dtype.child, 0), validity=validity)
    return Column.nulls(n)


def concat_columns(cols: List[Column]) -> Column:
    """Concatenate columns of identical dtype (callers promote/cast first)."""
    assert cols, "empty concat"
    dtype = cols[0].dtype
    assert all(c.dtype == dtype for c in cols), [str(c.dtype) for c in cols]
    n = sum(len(c) for c in cols)
    if any(c.validity is not None for c in cols):
        validity = np.concatenate([
            c.validity if c.validity is not None else np.ones(len(c), bool)
            for c in cols])
    else:
        validity = None
    k = dtype.kind
    if k in (KIND_NUMERIC, KIND_TENSOR):
        return Column(dtype, values=np.concatenate([c.values for c in cols]),
                      validity=validity)
    if k in (KIND_STRING, KIND_BINARY, KIND_LIST):
        sizes = [c.offsets[-1] for c in cols]
        bases = np.zeros(len(cols), np.int64)
        np.cumsum(sizes[:-1], out=bases[1:])
        offsets = np.concatenate(
            [np.zeros(1, np.int64)] +
            [c.offsets[1:] + b for c, b in zip(cols, bases)])
        if k == KIND_LIST:
            child = concat_columns([c.child for c in cols])
            return Column(dtype, offsets=offsets, child=child, validity=validity)
        blob = np.concatenate([c.blob for c in cols]) if n else np.empty(0, np.uint8)
        return Column(dtype, offsets=offsets, blob=blob, validity=validity)
    return Column.nulls(n)


# ---------------------------------------------------------------------------
# Python-value -> Column inference
# ---------------------------------------------------------------------------
def _try_json(v) -> Optional[bytes]:
    try:
        return json.dumps(v).encode("utf-8")
    except (TypeError, ValueError):
        return None


def infer_column(values: List[Any], *, ragged: bool = False,
                 convert_to_fixed_shape: bool = True) -> Tuple[Column, Optional[dict]]:
    """Build a Column from a list of python values.

    Returns (column, field_metadata).  field_metadata is non-None when values
    were transparently serialized (dict / heterogeneous objects), mirroring the
    paper's ``serialize_python_objects``.
    """
    n = len(values)
    # fast path: uniform numeric values, no Nones — one C-level conversion
    # instead of 2n isinstance checks (the pylist ingest hot path)
    try:
        arr = np.asarray(values)
        if arr.ndim == 1 and arr.dtype != object and arr.dtype.kind in "biuf":
            return Column.numeric(arr if arr.dtype.kind != "i"
                                  else arr.astype(np.int64, copy=False)), None
    except (ValueError, TypeError, OverflowError):
        pass
    present = [v for v in values if v is not None]
    if not present:
        return Column.nulls(n), None
    first = present[0]

    if isinstance(first, (bool, np.bool_)) and all(isinstance(v, (bool, np.bool_)) for v in present):
        return _masked_numeric(values, np.bool_), None
    if isinstance(first, str) and all(isinstance(v, str) for v in present):
        return Column.strings(values), None
    if isinstance(first, bytes) and all(isinstance(v, bytes) for v in present):
        return Column.binary(values), None
    if _all_scalar_number(present):
        if any(isinstance(v, (float, np.floating)) for v in present):
            return _masked_numeric(values, np.float64), None
        return _masked_numeric(values, np.int64), None
    if isinstance(first, np.ndarray) or isinstance(first, (list, tuple)):
        col = _infer_sequence_column(values, present, ragged, convert_to_fixed_shape)
        if col is not None:
            return col, None
    # fallback: serialize objects (dicts, lists-of-dicts, ...)
    enc, meta = [], {SERIALIZED_KEY: "json"}
    for v in values:
        if v is None:
            enc.append(None)
            continue
        b = _try_json(v)
        if b is None:
            meta = {SERIALIZED_KEY: "pickle"}
            break
        enc.append(b)
    if meta[SERIALIZED_KEY] == "pickle":
        enc = [None if v is None else pickle.dumps(v) for v in values]
    return Column.binary(enc), meta


def _all_scalar_number(vals) -> bool:
    return all(
        isinstance(v, (int, float, np.integer, np.floating))
        and not isinstance(v, (bool, np.bool_)) for v in vals)


def _masked_numeric(values: List[Any], np_dtype) -> Column:
    validity = None
    if any(v is None for v in values):
        validity = np.array([v is not None for v in values], bool)
        fill = False if np_dtype is np.bool_ else 0
        values = [fill if v is None else v for v in values]
    return Column(DType.from_numpy(np.dtype(np_dtype)),
                  values=np.asarray(values, np_dtype), validity=validity)


def _infer_sequence_column(values, present, ragged, convert_to_fixed_shape):
    """list/ndarray values -> tensor column (fixed shape) or ragged list."""
    arrs = []
    for v in present:
        a = np.asarray(v)
        if a.dtype == object or a.dtype.kind in "US":
            # list of strings -> ragged list of strings; anything else -> None
            if all(isinstance(x, str) for x in _flat_py(v)):
                return _ragged_strings(values)
            return None
        arrs.append(a)
    shapes = {a.shape for a in arrs}
    if len(shapes) == 1 and not ragged and convert_to_fixed_shape:
        shape = next(iter(shapes))
        dt = np.result_type(*[a.dtype for a in arrs])
        stack = np.zeros((len(values), *shape), dt)
        validity = np.ones(len(values), bool)
        j = 0
        for i, v in enumerate(values):
            if v is None:
                validity[i] = False
            else:
                stack[i] = arrs[j]
                j += 1
        val = None if validity.all() else validity
        return Column(DType.tensor(DType.from_numpy(dt).code, shape),
                      values=stack, validity=val)
    # ragged 1-d lists
    if all(a.ndim == 1 for a in arrs):
        dt = np.result_type(*[a.dtype for a in arrs]) if arrs else np.int64
        validity = np.array([v is not None for v in values], bool)
        lens = [0 if v is None else len(np.asarray(v)) for v in values]
        offsets = np.zeros(len(values) + 1, np.int64)
        np.cumsum(lens, out=offsets[1:])
        flat = (np.concatenate([a.astype(dt) for a in arrs])
                if arrs else np.empty(0, dt))
        child = Column(DType.from_numpy(dt), values=flat)
        val = None if validity.all() else validity
        return Column(DType.list_(child.dtype), offsets=offsets, child=child,
                      validity=val)
    return None  # ragged nd — fall back to serialization


def _flat_py(v):
    for x in v:
        if isinstance(x, (list, tuple)):
            yield from _flat_py(x)
        else:
            yield x


def _ragged_strings(values):
    validity = np.array([v is not None for v in values], bool)
    lens = [0 if v is None else len(v) for v in values]
    offsets = np.zeros(len(values) + 1, np.int64)
    np.cumsum(lens, out=offsets[1:])
    flat: List[str] = []
    for v in values:
        if v is not None:
            flat.extend(v)
    child = Column.strings(flat)
    val = None if validity.all() else validity
    return Column(DType.list_(child.dtype), offsets=offsets, child=child, validity=val)


# ---------------------------------------------------------------------------
# Table
# ---------------------------------------------------------------------------
class Table:
    """Immutable-ish columnar table: Schema + aligned Columns."""

    def __init__(self, schema: Schema, columns: Dict[str, Column]):
        self.schema = schema
        self.columns = {name: columns[name] for name in schema.names}
        lens = {len(c) for c in self.columns.values()}
        if len(lens) > 1:
            raise ValueError(f"ragged table: column lengths {lens}")
        self._n = lens.pop() if lens else 0

    # -- properties -----------------------------------------------------------
    @property
    def num_rows(self) -> int:
        return self._n

    @property
    def num_columns(self) -> int:
        return len(self.columns)

    @property
    def column_names(self) -> List[str]:
        return self.schema.names

    def column(self, name: str) -> Column:
        return self.columns[name]

    def __getitem__(self, name: str) -> Column:
        return self.columns[name]

    def __contains__(self, name: str) -> bool:
        return name in self.columns

    # -- constructors ----------------------------------------------------------
    @staticmethod
    def empty(schema: Optional[Schema] = None) -> "Table":
        schema = schema or Schema([])
        return Table(schema, {f.name: null_column_of(f.dtype, 0) for f in schema})

    @staticmethod
    def from_pylist(records: List[dict], *, treat_fields_as_ragged=(),
                    convert_to_fixed_shape: bool = True,
                    metadata: Optional[dict] = None) -> "Table":
        flats = nested.flatten_records(records)
        names: List[str] = sorted({k for r in flats for k in r})
        cols, fields = {}, []
        for name in names:
            vals = [r.get(name) for r in flats]
            col, fmeta = infer_column(
                vals, ragged=name in set(treat_fields_as_ragged),
                convert_to_fixed_shape=convert_to_fixed_shape)
            cols[name] = col
            fields.append(Field(name, col.dtype, metadata=fmeta))
        t = Table(Schema(fields, metadata=metadata), cols)
        t._n = len(records) if not names else t._n
        return t

    @staticmethod
    def from_pydict(data: Dict[str, Any], *, treat_fields_as_ragged=(),
                    convert_to_fixed_shape: bool = True,
                    metadata: Optional[dict] = None) -> "Table":
        cols, fields = {}, []
        for name in sorted(data.keys()):
            v = data[name]
            if isinstance(v, Column):
                col, fmeta = v, None
            elif isinstance(v, np.ndarray) and v.ndim == 1 and v.dtype != object:
                col, fmeta = Column.numeric(v), None
            elif isinstance(v, np.ndarray) and v.ndim > 1:
                col, fmeta = Column.tensor(v), None
            else:
                col, fmeta = infer_column(
                    list(v), ragged=name in set(treat_fields_as_ragged),
                    convert_to_fixed_shape=convert_to_fixed_shape)
            cols[name] = col
            fields.append(Field(name, col.dtype, metadata=fmeta))
        return Table(Schema(fields, metadata=metadata), cols)

    @staticmethod
    def from_columns(schema: Schema, columns: Dict[str, Column]) -> "Table":
        return Table(schema, columns)

    # -- transforms --------------------------------------------------------------
    def select(self, names: List[str]) -> "Table":
        return Table(self.schema.select(names), {n: self.columns[n] for n in names})

    def drop(self, names: List[str]) -> "Table":
        keep = [n for n in self.column_names if n not in set(names)]
        return self.select(keep)

    def take(self, idx: np.ndarray) -> "Table":
        return Table(self.schema, {n: c.take(idx) for n, c in self.columns.items()})

    def filter_mask(self, mask: np.ndarray) -> "Table":
        return self.take(np.nonzero(np.asarray(mask, bool))[0])

    def slice(self, start: int, stop: int) -> "Table":
        stop = min(stop, self._n)
        t = Table(self.schema,
                  {n: c.slice(start, stop) for n, c in self.columns.items()})
        t._n = max(stop - start, 0)
        return t

    def set_column(self, name: str, col: Column, metadata: Optional[dict] = None) -> "Table":
        fields = [f for f in self.schema if f.name != name]
        fields.append(Field(name, col.dtype, metadata=metadata))
        cols = dict(self.columns)
        cols[name] = col
        return Table(Schema(fields, metadata=self.schema.metadata), cols)

    def align_to_schema(self, schema: Schema) -> "Table":
        """Cast/backfill so this table matches ``schema`` exactly.

        Missing fields become all-null columns of the target dtype; numeric
        columns widen (paper: 'casts the data to fit the existing schema').
        """
        cols: Dict[str, Column] = {}
        for f in schema:
            if f.name in self.columns:
                cols[f.name] = self.columns[f.name].cast(f.dtype)
            else:
                cols[f.name] = null_column_of(f.dtype, self._n)
        t = Table(schema, cols)
        t._n = self._n
        return t

    # -- export -------------------------------------------------------------------
    def to_pylist(self, *, rebuild_nested: bool = False) -> List[dict]:
        pl = {n: _decode_objects(self.schema[n], c) for n, c in self.columns.items()}
        rows = [{n: pl[n][i] for n in self.column_names} for i in range(self._n)]
        if rebuild_nested:
            rows = nested.rebuild_records(rows)
        return rows

    def to_pydict(self) -> Dict[str, list]:
        return {n: _decode_objects(self.schema[n], c)
                for n, c in self.columns.items()}

    def __repr__(self) -> str:
        return f"Table[{self._n} rows x {self.num_columns} cols]({self.schema})"


def _decode_objects(field: Field, col: Column) -> list:
    vals = col.to_pylist()
    mode = (field.metadata or {}).get(SERIALIZED_KEY)
    if mode == "json":
        return [None if v is None else json.loads(v) for v in vals]
    if mode == "pickle":
        return [None if v is None else pickle.loads(v) for v in vals]
    return vals


def concat_tables(tables: List[Table]) -> Table:
    """Concatenate with schema unification (evolution-aware)."""
    tables = [t for t in tables if t.num_rows or t.num_columns]
    if not tables:
        return Table.empty()
    schema = tables[0].schema
    for t in tables[1:]:
        schema = schema.unify(t.schema)
    aligned = [t.align_to_schema(schema) for t in tables]
    cols = {f.name: concat_columns([t.columns[f.name] for t in aligned])
            for f in schema}
    return Table(schema, cols)
