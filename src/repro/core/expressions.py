"""Predicate expression language with statistics-based pruning.

The paper exposes PyArrow compute expressions (``pc.field('energy') > -1.0``).
This module provides the same surface: ``field(name)`` returns a reference with
overloaded comparison operators; expressions combine with ``&``, ``|``, ``~``
and evaluate to boolean masks against an in-memory Table.

The crucial part for the paper's "statistics replace indexes" claim is
``Expr.prune(stats)``: given per-chunk ColumnStats it returns False only when
the chunk *provably* cannot contain a matching row — that is predicate
pushdown.  Pruning is conservative: True means "must read".

Every expression renders as a SQL-ish, fully parenthesized string via
``repr`` — ``((age >= 30) AND (city == 'SF'))`` — which is what
``ScanReport`` and ``Query.explain()`` print, so plans stay readable.

Beyond predicates, :class:`Arith` is the *value* expression used by
``Query.select(**computed)``: ``field('x') + field('y')``, ``field('x') * 2``
etc. build an arithmetic tree that evaluates to a numeric Column per batch
(null if any operand is null).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .statistics import ColumnStats
from .table import Column, Table
from .dtypes import KIND_NUMERIC, KIND_STRING

StatsMap = Dict[str, ColumnStats]


class Expr:
    def __and__(self, other: "Expr") -> "Expr":
        return And(self, other)

    def __or__(self, other: "Expr") -> "Expr":
        return Or(self, other)

    def __invert__(self) -> "Expr":
        return Not(self)

    # subclasses implement:
    def evaluate(self, table: Table) -> np.ndarray:  # bool mask (n,)
        raise NotImplementedError

    def prune(self, stats: StatsMap) -> bool:  # may-match?
        raise NotImplementedError

    def all_match(self, stats: StatsMap) -> bool:
        """True only when statistics prove EVERY row in the chunk matches.

        The dual of :meth:`prune` (which proves *no* row matches):
        together they classify a chunk as fully-covered / fully-pruned /
        partial, which is what lets ``ParquetDB.aggregate`` answer a
        predicate-filtered aggregate from footer statistics without
        decoding a page.  Conservative: False means "must decode", so a
        subclass that cannot decide simply inherits this default.  Null
        semantics follow :meth:`evaluate` (null rows match no comparison),
        hence comparisons require ``null_count == 0``; NaN rows are
        invisible to min/max, hence ordering ops require ``nan_count == 0``.
        """
        return False

    def columns(self) -> List[str]:
        raise NotImplementedError

    def negate(self) -> Optional["Expr"]:
        """Logical negation under this engine's null semantics, or None.

        ``evaluate`` treats null as non-matching for comparisons, so the
        negation of ``x == v`` is ``(x != v) | x.is_null()`` — rows where x
        is null DO match ``~(x == v)``.  Used by ``Not.prune`` to push
        negations down to stats-prunable leaves; None means "cannot be
        expressed prunably", in which case pruning stays conservative.
        """
        return None

    def as_range(self) -> Optional[tuple]:
        """``(column, lo, lo_open, hi, hi_open)`` when this expression is
        exactly a contiguous range test on one column, else None.

        ``lo``/``hi`` may be None (unbounded end); the ``*_open`` flags mark
        strict inequalities.  The two-phase reader converts the bounds to an
        inclusive interval in the column's dtype and routes page-mask
        evaluation through the decode backend's fused ``range_mask`` (the
        Pallas ``filter_range`` kernel on the jax backend).  Must be
        *exact*: the converted mask on a fully-valid numeric column equals
        ``evaluate``'s mask.
        """
        return None


def _column_values(table: Table, name: str):
    """Numeric -> ndarray; string -> object ndarray; else error."""
    if name not in table:
        raise KeyError(
            f"filter references unknown column {name!r}; have {table.column_names}")
    col = table.column(name)
    k = col.dtype.kind
    if k == KIND_NUMERIC:
        return col.values, col.validity
    if k == KIND_STRING:
        return np.array(col.to_pylist(), dtype=object), col.validity
    if col.dtype.kind == "null":  # all-null: nothing ever matches
        return np.zeros(len(col)), np.zeros(len(col), bool)
    raise TypeError(f"cannot filter on column {name!r} of type {col.dtype}")


_OPS = {
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
}


class Comparison(Expr):
    def __init__(self, name: str, op: str, value: Any):
        self.name, self.op, self.value = name, op, value

    def evaluate(self, table: Table) -> np.ndarray:
        vals, validity = _column_values(table, self.name)
        if isinstance(self.value, FieldRef):
            other, ov = _column_values(table, self.value.name)
            mask = _OPS[self.op](vals, other)
            if ov is not None:
                mask &= ov
        else:
            mask = _OPS[self.op](vals, self.value)
        mask = np.asarray(mask, bool)
        if validity is not None:
            mask &= validity  # null never matches (SQL-like)
        return mask

    def prune(self, stats: StatsMap) -> bool:
        if isinstance(self.value, FieldRef):
            return True  # column-vs-column: no pushdown
        st = stats.get(self.name)
        if st is None or st.min is None:
            return not (st is not None and st.all_null())
        v, lo, hi = self.value, st.min, st.max
        try:
            if self.op == "==":
                return st.may_contain(v)
            if self.op == "!=":
                # NaN rows match "!=" but are invisible to min/max
                if st.nan_count:
                    return True
                return not (lo == hi == v)
            if self.op == "<":
                return lo < v
            if self.op == "<=":
                return lo <= v
            if self.op == ">":
                return hi > v
            if self.op == ">=":
                return hi >= v
        except TypeError:
            return True
        return True

    def all_match(self, stats: StatsMap) -> bool:
        if isinstance(self.value, FieldRef):
            return False  # column-vs-column: stats cannot decide
        st = stats.get(self.name)
        if st is None:
            return False
        if st.num_values == 0:
            return True  # vacuous: an empty chunk has no non-matching row
        if st.null_count or st.min is None:
            return False  # null rows never match a comparison
        v, lo, hi = self.value, st.min, st.max
        try:
            if self.op == "!=":
                # NaN rows DO match "!=" — only equality to v must be
                # excluded, which may_contain can refute via min/max or
                # the bloom fingerprint
                return not st.may_contain(v)
            if st.nan_count:
                return False  # NaN matches no ordering op / equality
            if self.op == "==":
                return bool(lo == hi == v)
            if self.op == "<":
                return bool(hi < v)
            if self.op == "<=":
                return bool(hi <= v)
            if self.op == ">":
                return bool(lo > v)
            if self.op == ">=":
                return bool(lo >= v)
        except TypeError:
            return False
        return False

    def columns(self) -> List[str]:
        cols = [self.name]
        if isinstance(self.value, FieldRef):
            cols.append(self.value.name)
        return cols

    def as_range(self) -> Optional[tuple]:
        v = self.value
        if isinstance(v, FieldRef) or isinstance(v, (bool, np.bool_)) \
                or not isinstance(v, (int, float, np.integer, np.floating)):
            return None
        if self.op == "==":
            return (self.name, v, False, v, False)
        if self.op == ">=":
            return (self.name, v, False, None, False)
        if self.op == ">":
            return (self.name, v, True, None, False)
        if self.op == "<=":
            return (self.name, None, False, v, False)
        if self.op == "<":
            return (self.name, None, False, v, True)
        return None  # "!=" is not a contiguous range

    _NEG_OP = {"==": "!=", "!=": "==", "<": ">=", "<=": ">",
               ">": "<=", ">=": "<"}

    def negate(self) -> Optional[Expr]:
        if isinstance(self.value, FieldRef):
            return None  # col-vs-col has no pushdown either way
        # null rows match the negation (evaluate masks them out of `self`)
        neg = Or(Comparison(self.name, self._NEG_OP[self.op], self.value),
                 IsNull(self.name))
        if self.op in ("<", "<=", ">", ">="):
            # NaN rows also match ~(x < v) etc. but the negated comparison's
            # min/max prune cannot see them — add an explicit NaN term
            neg = Or(neg, IsNaN(self.name))
        return neg

    def __repr__(self):
        return f"({self.name} {self.op} {self.value!r})"


class IsIn(Expr):
    def __init__(self, name: str, values: Sequence[Any]):
        self.name, self.values = name, list(values)

    def evaluate(self, table: Table) -> np.ndarray:
        vals, validity = _column_values(table, self.name)
        mask = np.isin(vals, np.array(self.values, dtype=vals.dtype if vals.dtype != object else object))
        if validity is not None:
            mask &= validity
        return mask

    def prune(self, stats: StatsMap) -> bool:
        st = stats.get(self.name)
        if st is None:
            return True
        return any(st.may_contain(v) for v in self.values)

    def all_match(self, stats: StatsMap) -> bool:
        st = stats.get(self.name)
        if st is None:
            return False
        if st.num_values == 0:
            return True
        if st.null_count or st.nan_count or st.min is None:
            return False
        # decidable only for a constant chunk whose single value is listed
        try:
            return bool(st.min == st.max and
                        any(st.min == v for v in self.values))
        except TypeError:
            return False

    def columns(self):
        return [self.name]

    def __repr__(self):
        vals = ", ".join(repr(v) for v in self.values)
        return f"({self.name} IN ({vals}))"


class IsNull(Expr):
    def __init__(self, name: str, *, negate: bool = False):
        # stored as _negated so the attribute doesn't shadow Expr.negate()
        self.name, self._negated = name, negate

    def evaluate(self, table: Table) -> np.ndarray:
        col = table.column(self.name)
        valid = (np.ones(len(col), bool) if col.validity is None
                 else col.validity.copy())
        return valid if self._negated else ~valid

    def prune(self, stats: StatsMap) -> bool:
        st = stats.get(self.name)
        if st is None:
            return True
        if self._negated:  # is_valid
            return st.null_count < st.num_values
        return st.null_count > 0

    def all_match(self, stats: StatsMap) -> bool:
        st = stats.get(self.name)
        if st is None:
            return False
        if self._negated:  # is_valid: every row non-null
            return st.null_count == 0
        return st.null_count == st.num_values

    def columns(self):
        return [self.name]

    def negate(self) -> Optional[Expr]:
        return IsNull(self.name, negate=not self._negated)

    def __repr__(self):
        return (f"({self.name} IS NOT NULL)" if self._negated
                else f"({self.name} IS NULL)")


class IsNaN(Expr):
    """Matches float NaN rows.

    Produced by ``Comparison.negate`` for ordering ops: NaN rows match the
    negation of any ordering comparison yet are excluded from min/max stats,
    so the negated expression carries this term to keep pruning sound.
    Prunes against ``ColumnStats.nan_count``.
    """

    def __init__(self, name: str):
        self.name = name

    def evaluate(self, table: Table) -> np.ndarray:
        vals, validity = _column_values(table, self.name)
        if getattr(vals.dtype, "kind", None) != "f":
            return np.zeros(len(vals), bool)
        mask = np.isnan(vals)
        if validity is not None:
            mask &= validity
        return mask

    def prune(self, stats: StatsMap) -> bool:
        st = stats.get(self.name)
        return True if st is None else st.nan_count > 0

    def all_match(self, stats: StatsMap) -> bool:
        st = stats.get(self.name)
        if st is None:
            return False
        return st.null_count == 0 and st.nan_count == st.num_values

    def columns(self):
        return [self.name]

    def __repr__(self):
        return f"isnan({self.name})"


def _tighter_bound(va, oa, vb, ob, *, hi: bool):
    """Intersect two one-sided bounds ((value, open); value None = unbounded)."""
    if va is None:
        return vb, ob
    if vb is None:
        return va, oa
    if va == vb:
        return va, oa or ob
    take_a = va < vb if hi else va > vb
    return (va, oa) if take_a else (vb, ob)


class And(Expr):
    def __init__(self, a: Expr, b: Expr):
        self.a, self.b = a, b

    def evaluate(self, table):
        return self.a.evaluate(table) & self.b.evaluate(table)

    def prune(self, stats):
        return self.a.prune(stats) and self.b.prune(stats)

    def all_match(self, stats: StatsMap) -> bool:
        return self.a.all_match(stats) and self.b.all_match(stats)

    def columns(self):
        return self.a.columns() + self.b.columns()

    def negate(self) -> Optional[Expr]:
        na, nb = self.a.negate(), self.b.negate()
        return Or(na, nb) if na is not None and nb is not None else None

    def as_range(self) -> Optional[tuple]:
        # (lo <= x) & (x < hi) on the same column is still one range
        ra, rb = self.a.as_range(), self.b.as_range()
        if ra is None or rb is None or ra[0] != rb[0]:
            return None
        lo, lo_open = _tighter_bound(ra[1], ra[2], rb[1], rb[2], hi=False)
        hi, hi_open = _tighter_bound(ra[3], ra[4], rb[3], rb[4], hi=True)
        return (ra[0], lo, lo_open, hi, hi_open)

    def __repr__(self):
        return f"({self.a!r} AND {self.b!r})"


class Or(Expr):
    def __init__(self, a: Expr, b: Expr):
        self.a, self.b = a, b

    def evaluate(self, table):
        return self.a.evaluate(table) | self.b.evaluate(table)

    def prune(self, stats):
        return self.a.prune(stats) or self.b.prune(stats)

    def all_match(self, stats: StatsMap) -> bool:
        # sufficient, not necessary (a/b may cover disjoint halves) — but
        # False only ever costs a decode, never correctness
        return self.a.all_match(stats) or self.b.all_match(stats)

    def columns(self):
        return self.a.columns() + self.b.columns()

    def negate(self) -> Optional[Expr]:
        na, nb = self.a.negate(), self.b.negate()
        return And(na, nb) if na is not None and nb is not None else None

    def __repr__(self):
        return f"({self.a!r} OR {self.b!r})"


class Not(Expr):
    def __init__(self, a: Expr):
        self.a = a

    def evaluate(self, table):
        return ~self.a.evaluate(table)

    def prune(self, stats):
        # push the negation down to prunable leaves (null-safe, see
        # Expr.negate); unsupported shapes stay conservative
        neg = self.a.negate()
        return True if neg is None else neg.prune(stats)

    def all_match(self, stats: StatsMap) -> bool:
        # ~a matches everything iff a matches nothing, which is exactly
        # what a.prune refuting the chunk proves (evaluate's null/NaN
        # semantics make ~ a plain mask complement, so no extra terms)
        if not self.a.prune(stats):
            return True
        neg = self.a.negate()
        return neg.all_match(stats) if neg is not None else False

    def columns(self):
        return self.a.columns()

    def negate(self) -> Optional[Expr]:
        return self.a

    def __repr__(self):
        return f"(NOT {self.a!r})"


class _ArithOps:
    """Mixin giving FieldRef/Arith the ``+ - * /`` operators (value exprs)."""

    def __add__(self, other):
        return Arith("+", self, other)

    def __radd__(self, other):
        return Arith("+", other, self)

    def __sub__(self, other):
        return Arith("-", self, other)

    def __rsub__(self, other):
        return Arith("-", other, self)

    def __mul__(self, other):
        return Arith("*", self, other)

    def __rmul__(self, other):
        return Arith("*", other, self)

    def __truediv__(self, other):
        return Arith("/", self, other)

    def __rtruediv__(self, other):
        return Arith("/", other, self)

    def __neg__(self):
        return Arith("-", 0, self)


_ARITH_FNS = {"+": np.add, "-": np.subtract, "*": np.multiply,
              "/": np.true_divide}


def _operand_values(x, table: Table):
    """(values ndarray-or-scalar, validity-or-None) of one Arith operand."""
    if isinstance(x, FieldRef):
        col = table.column(x.name)
        if col.dtype.kind != KIND_NUMERIC:
            raise TypeError(f"computed expression needs a numeric column, "
                            f"but {x.name!r} is {col.dtype}")
        vals = col.values
        if vals.dtype.kind == "b":
            # bool is numeric (b1), but numpy's +|*|- on bool arrays are
            # logical ops / errors — arithmetic means ints here
            vals = vals.astype(np.int64)
        return vals, col.validity
    if isinstance(x, Arith):
        col = x.evaluate_column(table)
        return col.values, col.validity
    if isinstance(x, (int, float, np.integer, np.floating)) \
            and not isinstance(x, (bool, np.bool_)):
        return x, None
    raise TypeError(f"unsupported operand in computed expression: {x!r}")


def _operand_repr(x) -> str:
    if isinstance(x, FieldRef):
        return x.name
    return repr(x)


class Arith(_ArithOps):
    """Arithmetic *value* expression over numeric columns and scalars.

    Built by operator overloading — ``field('x') * 2 + field('y')`` — and
    consumed by ``Query.select(**computed)``: :meth:`evaluate_column`
    produces one numeric Column per batch.  Null semantics: a row is null
    in the result when any column operand is null in that row (validity
    masks AND together).  Division always yields float64 (``0/0`` and
    ``x/0`` follow IEEE NaN/inf, warnings suppressed).
    """

    def __init__(self, op: str, a, b):
        assert op in _ARITH_FNS, op
        self.op, self.a, self.b = op, a, b

    def evaluate_column(self, table: Table) -> Column:
        av, avd = _operand_values(self.a, table)
        bv, bvd = _operand_values(self.b, table)
        with np.errstate(all="ignore"):
            out = _ARITH_FNS[self.op](av, bv)
        out = np.asarray(out)
        if out.ndim == 0:  # scalar-only tree: broadcast to the batch
            out = np.full(table.num_rows, out[()])
        if avd is None:
            validity = None if bvd is None else bvd.copy()
        else:
            validity = avd.copy() if bvd is None else (avd & bvd)
        return Column.numeric(np.ascontiguousarray(out), validity=validity)

    def columns(self) -> List[str]:
        cols: List[str] = []
        for x in (self.a, self.b):
            if isinstance(x, FieldRef):
                cols.append(x.name)
            elif isinstance(x, Arith):
                cols.extend(x.columns())
        return cols

    def __repr__(self):
        return f"({_operand_repr(self.a)} {self.op} {_operand_repr(self.b)})"


class FieldRef(_ArithOps):
    """``field('energy') > -1.0`` builds a Comparison."""

    def __init__(self, name: str):
        self.name = name

    def evaluate_column(self, table: Table) -> Column:
        """A bare FieldRef used as a computed column is a copy/rename."""
        return table.column(self.name)

    def columns(self) -> List[str]:
        return [self.name]

    def __eq__(self, v):  # type: ignore[override]
        return Comparison(self.name, "==", v)

    def __ne__(self, v):  # type: ignore[override]
        return Comparison(self.name, "!=", v)

    def __lt__(self, v):
        return Comparison(self.name, "<", v)

    def __le__(self, v):
        return Comparison(self.name, "<=", v)

    def __gt__(self, v):
        return Comparison(self.name, ">", v)

    def __ge__(self, v):
        return Comparison(self.name, ">=", v)

    def isin(self, values: Sequence[Any]) -> Expr:
        return IsIn(self.name, values)

    def is_null(self) -> Expr:
        return IsNull(self.name)

    def is_valid(self) -> Expr:
        return IsNull(self.name, negate=True)

    def __hash__(self):
        return hash(("FieldRef", self.name))

    def __repr__(self):
        return f"field({self.name!r})"


def field(name: str) -> FieldRef:
    return FieldRef(name)


def combine_filters(filters: Optional[Sequence[Expr]]) -> Optional[Expr]:
    """Paper semantics: a list of filters is AND-combined."""
    if not filters:
        return None
    expr = filters[0]
    for f in filters[1:]:
        expr = expr & f
    return expr
