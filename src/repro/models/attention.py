"""GQA attention: blockwise (flash-style) training path + KV-cache decode.

The training/prefill path never materializes the (S, S) score matrix: KV is
scanned block-by-block with an online-softmax carry (m, l, acc), so peak
activation memory is O(S·block_kv) per head — this is what lets the 32k
prefill shapes fit HBM in the dry run.  Causal and sliding-window masks are
applied per block.  GQA is computed in grouped form (B, KH, G, ...) so the
KV tensors are never broadcast to n_heads.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import AttnCfg, ModelConfig
from .layers import P, apply_rope, rope

NEG_INF = -1e30


def attn_spec(cfg: ModelConfig) -> Dict[str, P]:
    a, d = cfg.attn, cfg.d_model
    spec = {
        "wq": P((d, a.n_heads, a.head_dim), ("embed", "heads", "hdim")),
        "wk": P((d, a.n_kv_heads, a.head_dim), ("embed", "kv", "hdim")),
        "wv": P((d, a.n_kv_heads, a.head_dim), ("embed", "kv", "hdim")),
        "wo": P((a.n_heads, a.head_dim, d), ("heads", "hdim", "embed"),
                scale=0.02 / 2),
    }
    if a.qkv_bias:
        spec["bq"] = P((a.n_heads, a.head_dim), ("heads", "hdim"), init="zeros")
        spec["bk"] = P((a.n_kv_heads, a.head_dim), ("kv", "hdim"), init="zeros")
        spec["bv"] = P((a.n_kv_heads, a.head_dim), ("kv", "hdim"), init="zeros")
    if a.qk_norm:
        spec["q_norm"] = P((a.head_dim,), ("hdim",), init="ones")
        spec["k_norm"] = P((a.head_dim,), ("hdim",), init="ones")
    return spec


def _rms(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def project_qkv(p: Dict, x: jnp.ndarray, a: AttnCfg,
                positions: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, ...]:
    """x (B,S,d) -> q (B,S,H,dh), k/v (B,S,KH,dh) with bias/qk-norm/rope."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if a.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if a.qk_norm:
        q = _rms(q, p["q_norm"])
        k = _rms(k, p["k_norm"])
    if positions is not None:
        cos, sin = rope(positions, a.head_dim, a.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        pos_q: jnp.ndarray, pos_kv: jnp.ndarray,
                        *, causal: bool = True,
                        window: Optional[int] = None,
                        block_kv: int = 1024,
                        scores_bf16: bool = False) -> jnp.ndarray:
    """Online-softmax attention over KV blocks.

    q (B,Sq,H,dh); k,v (B,Skv,KH,dh); pos_* absolute positions (Sq,)/(Skv,).
    ``scores_bf16`` (§Perf) keeps the S²-sized score/prob tensors in bf16
    while the softmax statistics (m, l) and the accumulator stay f32.
    Returns (B,Sq,H,dh).
    """
    B, Sq, H, dh = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    G = H // KH
    qf = (q * (dh ** -0.5)).reshape(B, Sq, KH, G, dh)
    s_dtype = jnp.bfloat16 if scores_bf16 else jnp.float32
    s_neg = jnp.asarray(NEG_INF, s_dtype)   # -1e30 is representable in bf16

    nb = -(-Skv // block_kv)
    pad = nb * block_kv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos_kv = jnp.pad(pos_kv, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    kb = k.reshape(B, nb, block_kv, KH, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block_kv, KH, dh).transpose(1, 0, 2, 3, 4)
    pb = pos_kv.reshape(nb, block_kv)

    m0 = jnp.full((B, KH, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KH, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, KH, G, Sq, dh), jnp.float32)

    def step(carry, blk):
        m, l, acc = carry
        kj, vj, pj = blk
        # the dot emits bf16 (inputs are bf16); only the baseline pays for an
        # f32 copy of the S²-sized tensor
        s = jnp.einsum("bqkgd,bpkd->bkgqp", qf, kj).astype(s_dtype)
        mask = jnp.ones((Sq, block_kv), bool)
        if causal:
            mask &= pj[None, :] <= pos_q[:, None]
        else:
            mask &= (pj[None, :] < jnp.iinfo(jnp.int32).max)
        if window is not None:
            mask &= pj[None, :] > pos_q[:, None] - window
        s = jnp.where(mask[None, None, None], s, s_neg)
        m_new = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
        # exp stays in s_dtype; reductions accumulate in f32 WITHOUT
        # materializing an f32 copy (dtype= / preferred_element_type=)
        p = jnp.exp(s - m_new[..., None].astype(s_dtype))
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
        pv = jnp.einsum("bkgqp,bpkd->bkgqd", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, dh).astype(q.dtype)


def _maybe_shard_q(q: jnp.ndarray, cfg: ModelConfig, mesh):
    """§Perf: when heads don't divide the model axis (phi4: 24 vs 16) the
    S²-score compute replicates over "model"; shard the *query-sequence* dim
    there instead (each q row's softmax is independent, KV stays as-is)."""
    if not cfg.attn_batch_shard or mesh is None:
        return q
    if q.shape[1] % mesh.shape["model"]:
        return q
    from jax.sharding import NamedSharding, PartitionSpec as PS
    batch = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    spec = PS(batch if len(batch) > 1 else batch[0], "model", None, None)
    return jax.lax.with_sharding_constraint(q, NamedSharding(mesh, spec))


def attn_train(p: Dict, x: jnp.ndarray, cfg: ModelConfig,
               positions: jnp.ndarray, *, causal: bool = True,
               mesh=None) -> jnp.ndarray:
    a = cfg.attn
    q, k, v = project_qkv(p, x, a, positions)
    if cfg.shard_activations:
        from .act_sharding import constrain
        q = constrain(q, mesh, ("batch", None, "model", None))
        k = constrain(k, mesh, ("batch", None, "model", None))
        v = constrain(v, mesh, ("batch", None, "model", None))
    q = _maybe_shard_q(q, cfg, mesh)
    out = blockwise_attention(q, k, v, positions, positions, causal=causal,
                              window=a.window, block_kv=cfg.attn_block_kv,
                              scores_bf16=cfg.attn_scores_bf16)
    if cfg.shard_activations:
        out = constrain(out, mesh, ("batch", None, "model", None))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def cross_attn_train(p: Dict, x: jnp.ndarray, enc: jnp.ndarray,
                     cfg: ModelConfig, mesh=None) -> jnp.ndarray:
    """Decoder cross-attention: kv from encoder output, no mask, no rope."""
    a = cfg.attn
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", enc.astype(dt), p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc.astype(dt), p["wv"].astype(dt))
    if cfg.shard_activations:
        from .act_sharding import constrain
        q = constrain(q, mesh, ("batch", None, "model", None))
        k = constrain(k, mesh, ("batch", None, "model", None))
        v = constrain(v, mesh, ("batch", None, "model", None))
    pos_kv = jnp.arange(enc.shape[1], dtype=jnp.int32)
    pos_q = jnp.arange(x.shape[1], dtype=jnp.int32)
    out = blockwise_attention(q, k, v, pos_q, pos_kv, causal=False,
                              block_kv=cfg.attn_block_kv,
                              scores_bf16=cfg.attn_scores_bf16)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# decode with KV cache
# ---------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int,
                  n_layers: int, dtype=jnp.bfloat16):
    a = cfg.attn
    size = min(max_seq, a.window) if a.window else max_seq
    shape = (n_layers, batch, size, a.n_kv_heads, a.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_axes(_: ModelConfig):
    ax = ("layers", "batch", "seq", "kv", "hdim")
    return {"k": ax, "v": ax}


def attn_decode(p: Dict, x: jnp.ndarray, k_cache: jnp.ndarray,
                v_cache: jnp.ndarray, pos, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token step.  x (B,1,d); k/v_cache (B,C,KH,dh).

    ``pos`` is scalar int32 (synchronized decode — the dry-run/benchmark path,
    lowers to dynamic_update_slice) or (B,) int32 (per-slot positions for the
    continuous-batching engine, lowers to a batched scatter).

    For sliding-window attention the cache is a ring buffer of size window
    (write slot = pos % window); otherwise the cache is the full context.
    Returns (y (B,1,d), new_k, new_v).
    """
    a = cfg.attn
    pos = jnp.asarray(pos, jnp.int32)
    B = x.shape[0]
    rope_pos = (jnp.full((1,), pos, jnp.int32) if pos.ndim == 0
                else pos[:, None])
    q, k_new, v_new = project_qkv(p, x, a, rope_pos)
    C = k_cache.shape[1]
    idx = jnp.arange(C, dtype=jnp.int32)
    if pos.ndim == 0:
        slot = (pos % C) if a.window else pos
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_new.astype(k_cache.dtype), (0, slot, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_new.astype(v_cache.dtype), (0, slot, 0, 0))
        if a.window:
            age = (slot - idx) % C                 # 0 = current token
            valid = (age < jnp.minimum(pos + 1, C))[None]
        else:
            valid = (idx <= pos)[None]             # (1, C) broadcasts over B
    else:
        slot = (pos % C) if a.window else pos      # (B,)
        barange = jnp.arange(B)
        k_cache = k_cache.at[barange, slot].set(
            k_new[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[barange, slot].set(
            v_new[:, 0].astype(v_cache.dtype))
        if a.window:
            age = (slot[:, None] - idx[None, :]) % C
            valid = age < jnp.minimum(pos + 1, C)[:, None]
        else:
            valid = idx[None, :] <= pos[:, None]   # (B, C)

    _, _, H, dh = q.shape
    KH = a.n_kv_heads
    G = H // KH
    qf = (q * (dh ** -0.5)).reshape(B, KH, G, dh)
    s = jnp.einsum("bkgd,bckd->bkgc", qf,
                   k_cache.astype(q.dtype)).astype(jnp.float32)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", w.astype(v_cache.dtype),
                     v_cache).reshape(B, 1, H, dh).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, k_cache, v_cache
