"""Unified Model facade: init / loss / prefill / decode across all families.

The training batch dict is produced by the data pipeline (or ``input_specs``
for the dry run):
  tokens   (B, S) int32      — always present
  embeds   (B, Sf, D) bf16   — only for frontend-stub archs (audio/vlm/encdec)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import encdec, transformer
from .config import ModelConfig
from .frontends import frontend_embed_struct
from .layers import cross_entropy_loss, set_rmsnorm_bf16


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._mod = encdec if cfg.family == "encdec" else transformer

    # -- params ----------------------------------------------------------------
    def init(self, rng) -> Dict[str, Any]:
        return self._mod.init_params(self.cfg, rng)

    def init_abstract(self, rng=None) -> Dict[str, Any]:
        """Shape-only params (no allocation) for the dry run."""
        return jax.eval_shape(
            lambda: self._mod.init_params(self.cfg, jax.random.key(0)))

    def params_axes(self) -> Dict[str, Any]:
        return self._mod.params_axes(self.cfg)

    # -- train -------------------------------------------------------------------
    def loss(self, params, batch: Dict[str, jnp.ndarray], mesh=None
             ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        set_rmsnorm_bf16(cfg.rmsnorm_bf16)
        tokens = batch["tokens"]
        if cfg.family == "encdec":
            logits, aux = encdec.forward(params, cfg, tokens, batch["embeds"],
                                         mesh=mesh)
            ce = cross_entropy_loss(logits[:, :-1], tokens[:, 1:])
        elif cfg.frontend is not None:   # vlm/audio decoder-only
            logits, aux = transformer.forward(params, cfg, tokens,
                                              extra_embeds=batch["embeds"],
                                              mesh=mesh)
            sf = batch["embeds"].shape[1]
            ce = cross_entropy_loss(logits[:, sf - 1:-1], tokens)
        else:
            logits, aux = transformer.forward(params, cfg, tokens, mesh=mesh)
            ce = cross_entropy_loss(logits[:, :-1], tokens[:, 1:])
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux}

    # -- serve --------------------------------------------------------------------
    def prefill(self, params, batch: Dict[str, jnp.ndarray], mesh=None,
                cache_len: Optional[int] = None):
        cfg = self.cfg
        set_rmsnorm_bf16(cfg.rmsnorm_bf16)
        if cfg.family == "encdec":
            return encdec.prefill(params, cfg, batch["tokens"], batch["embeds"],
                                  mesh=mesh, cache_len=cache_len)
        return transformer.prefill(params, cfg, batch["tokens"],
                                   extra_embeds=batch.get("embeds"),
                                   mesh=mesh, cache_len=cache_len)

    def decode_step(self, params, cache, tokens, pos, mesh=None):
        set_rmsnorm_bf16(self.cfg.rmsnorm_bf16)
        if self.cfg.family == "encdec":
            return encdec.decode(params, self.cfg, cache, tokens, pos, mesh=mesh)
        return transformer.decode(params, self.cfg, cache, tokens, pos,
                                  mesh=mesh)

    def init_cache(self, batch: int, max_seq: int):
        if self.cfg.family == "encdec":
            return encdec.init_cache(self.cfg, batch, max_seq)
        return transformer.init_cache(self.cfg, batch, max_seq)

    def cache_axes(self):
        if self.cfg.family == "encdec":
            return encdec.cache_axes(self.cfg)
        return transformer.cache_axes(self.cfg)

    def cache_abstract(self, batch: int, max_seq: int):
        return jax.eval_shape(lambda: self.init_cache(batch, max_seq))

    # -- dry-run inputs --------------------------------------------------------------
    def input_specs(self, batch: int, seq: int) -> Dict[str, Any]:
        specs = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
        emb = frontend_embed_struct(self.cfg, batch)
        if emb is not None:
            specs["embeds"] = emb
        return specs
