"""Modality-frontend STUBS (per the assignment: ``[audio]``/``[vlm]`` entries
specify the transformer BACKBONE only; ``input_specs()`` provides precomputed
frame/patch embeddings).

These helpers define the shapes/dtypes of the precomputed embeddings and a
deterministic synthetic generator for smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig


def frontend_embed_shape(cfg: ModelConfig, batch: int):
    """(B, S_frontend, D) precomputed embeddings fed around the tokenizer."""
    if cfg.family == "encdec":
        return (batch, cfg.src_seq, cfg.d_model)
    if cfg.frontend is not None:
        return (batch, cfg.frontend_seq, cfg.d_model)
    return None


def frontend_embed_struct(cfg: ModelConfig, batch: int):
    shape = frontend_embed_shape(cfg, batch)
    if shape is None:
        return None
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16)


def synthetic_embeds(cfg: ModelConfig, batch: int, seed: int = 0):
    shape = frontend_embed_shape(cfg, batch)
    if shape is None:
        return None
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32) * 0.02,
                       dtype=jnp.bfloat16)
