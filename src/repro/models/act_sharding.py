"""Activation sharding constraints (§Perf iteration 2).

Without anchors, GSPMD propagates ambiguous shardings through the attention
einsums (a GQA kv tensor with 2 heads offers no shardable dim) and falls back
to replicating S²-sized score tensors with the GLOBAL batch on every device
(the 'involuntary full rematerialization' warnings; confirmed by the
per-instruction byte breakdown: f32[256,4096,1024] per device ×144).

``constrain`` pins the batch dim of every block-boundary activation to
("pod","data") and the heads dim to "model" when divisible, exactly like
MaxText's logical-axis annotations.  Gated by ``ModelConfig.shard_activations``
so the unconstrained baseline stays reproducible.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def constrain(x: jnp.ndarray, mesh, axes) -> jnp.ndarray:
    """axes: tuple of logical names per dim from {"batch", "model", None}."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as PS
    names = set(mesh.axis_names)
    parts = []
    for dim, ax in zip(x.shape, axes):
        if ax == "batch":
            ba = tuple(a for a in ("pod", "data") if a in names)
            while ba and dim % int(np.prod([mesh.shape[a] for a in ba])):
                ba = ba[:-1]   # drop axes until the dim divides
            parts.append(ba if len(ba) > 1 else (ba[0] if ba else None))
        elif ax == "model":
            parts.append("model" if ("model" in names and dim %
                                     mesh.shape["model"] == 0) else None)
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PS(*parts)))
